"""End-to-end driver #1 (the paper's own experiment): train LeNet on
(synthetic) MNIST, measure the DNN-accuracy-loss (DAL) of each approximate
multiplier, then apply the hardware-driven co-optimization — QAT retraining
with the weight-band regularizer — and measure the recovery. Checkpoints and
restarts are exercised along the way.

    PYTHONPATH=src python examples/lenet_mnist_qat.py [--steps 150] [--net lenet_plus]
"""
import argparse
import os
import tempfile

import jax
import jax.numpy as jnp

from repro.core.approx import ApproxConfig
from repro.core.metrics import dal
from repro.data.synthetic import image_dataset
from repro.models.cnn import cnn_forward, init_cnn
from repro.quant.affine import calibrate
from repro.quant.qat import band_regularizer
from repro.train.checkpoint import restore_checkpoint, save_checkpoint


def make_step(model_defs, cfg, lr, band_reg=0.0):
    def loss_fn(layers, x, y):
        logits = cnn_forward({"defs": model_defs, "layers": layers}, x, cfg)
        ce = -jnp.mean(jnp.sum(jax.nn.log_softmax(logits) * jax.nn.one_hot(y, 10), -1))
        reg = 0.0
        if band_reg > 0:
            for p in jax.tree.leaves(layers):
                if p.ndim >= 2:
                    qp = calibrate(p, axis=(p.ndim - 2,), qmax=255)
                    reg = reg + band_regularizer(p, qp, band=(0, 31))
        return ce + band_reg * reg

    @jax.jit
    def step(layers, x, y):
        l, g = jax.value_and_grad(loss_fn)(layers, x, y)
        return jax.tree.map(lambda p, gr: p - lr * gr, layers, g), l

    return step


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--net", default="lenet", choices=["lenet", "lenet_plus"])
    ap.add_argument("--steps", type=int, default=150)
    ap.add_argument("--retrain-steps", type=int, default=40)
    ap.add_argument("--bs", type=int, default=64)
    ap.add_argument("--ckpt", default="")
    args = ap.parse_args()

    data = image_dataset("mnist", n_train=2048, n_test=512, seed=0)
    model = init_cnn(args.net, jax.random.PRNGKey(0), in_shape=(28, 28, 1))
    fl = ApproxConfig(mode="float")
    step = make_step(model["defs"], fl, lr=0.05)

    ckpt_dir = args.ckpt or os.path.join(tempfile.gettempdir(), "lenet_qat_ckpt")
    layers, n = model["layers"], data.x_train.shape[0]
    for i in range(args.steps):
        j = (i * args.bs) % (n - args.bs)
        layers, loss = step(layers, jnp.asarray(data.x_train[j:j+args.bs]),
                            jnp.asarray(data.y_train[j:j+args.bs]))
        if i % 50 == 49:
            save_checkpoint(ckpt_dir, i + 1, {"layers": layers}, keep=2)
            print(f"step {i+1}: loss {float(loss):.4f} (checkpointed)")
    model["layers"] = layers

    def acc(cfg, layers=None):
        m = dict(model, layers=layers if layers is not None else model["layers"])
        logits = cnn_forward(m, jnp.asarray(data.x_test), cfg)
        return float(jnp.mean(jnp.argmax(logits, -1) == jnp.asarray(data.y_test)))

    acc0 = acc(fl)
    print(f"\nfloat accuracy: {acc0:.4f}")
    print(f"{'multiplier':12s} {'acc':>7s} {'DAL':>8s} {'retrained':>10s} {'DAL':>8s}")
    for mult in ("exact", "mul8x8_1", "mul8x8_2", "mul8x8_3", "pkm"):
        mode = "exact_quant" if mult == "exact" else ("lowrank" if mult.startswith("mul8x8") else "lut")
        acfg = ApproxConfig(multiplier=mult, mode=mode)
        a = acc(acfg)
        # co-optimization: QAT fine-tune under approximate forward, with the
        # band regularizer pushing weight codes into (0,31) (enables MUL8x8_3)
        qstep = make_step(model["defs"], acfg, lr=0.01, band_reg=1e-3)
        lyr = model["layers"]
        for i in range(args.retrain_steps):
            j = (i * args.bs) % (n - args.bs)
            lyr, _ = qstep(lyr, jnp.asarray(data.x_train[j:j+args.bs]),
                           jnp.asarray(data.y_train[j:j+args.bs]))
        a_re = acc(acfg, lyr)
        print(f"{mult:12s} {a:7.4f} {dal(acc0, a):+8.4f} {a_re:10.4f} {dal(acc0, a_re):+8.4f}")

    # restart path: restore the float checkpoint (fault-tolerance exercise)
    restored, s = restore_checkpoint(ckpt_dir, {"layers": jax.eval_shape(lambda: layers)})
    print(f"\nrestored checkpoint at step {s}: accuracy {acc(fl, restored['layers']):.4f}")


if __name__ == "__main__":
    main()
