"""Example #3: batched serving under the approximate multiplier.

Loads (or initializes) a small LM, runs batched decoding through the
scan-based KV-cache engine under each execution mode — float, exact-quant,
the XLA low-rank approximate path, and (with ``--pallas``) the fused Pallas
approx-matmul kernel itself (interpret mode on CPU) — and reports agreement
and throughput, plus the scan-vs-legacy-loop speedup.

    PYTHONPATH=src python examples/llm_approx_serve.py --batch 4 --new 16
    PYTHONPATH=src python examples/llm_approx_serve.py --pallas --new 4
    PYTHONPATH=src python examples/llm_approx_serve.py --continuous

With ``--continuous`` a mixed-length request trace additionally runs through
the continuous-batching scheduler (``repro.serve.ServeSession``) under BOTH
cache layouts — the slot-striped cache and the paged block-table cache (at
half the slot layout's KV memory) — and each request's output is checked
against running its prompt alone through ``generate``: the
order-independence oracle, which for the paged arm also pins the block
gather/scatter path bit-identical to the contiguous one.  The paged arm
runs under both host loops (the PR-3 synchronous tick loop and the async
double-buffered pipeline) and once more with ``attn_impl="pallas"`` — the
in-place Pallas paged-attention kernel (interpret mode on CPU) — so the
oracle pins the async loop's and the kernel's token-exactness too; see
docs/serving.md for the full serve-stack architecture.
"""
import argparse
import dataclasses
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config, reduced_config
from repro.models.transformer import init_params
from repro.serve.engine import (
    generate,
    greedy_generate_legacy,
    resolve_execution_mode,
)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=8)
    ap.add_argument("--new", type=int, default=16)
    ap.add_argument("--multiplier", default="mul8x8_2")
    ap.add_argument("--pallas", action="store_true",
                    help="add an 'approx' arm that routes every projection "
                         "matmul through the Pallas kernel (interpret mode "
                         "on CPU — slow but bit-exact to the LUT)")
    ap.add_argument("--continuous", action="store_true",
                    help="also serve a mixed-length trace through the "
                         "continuous-batching scheduler and verify each "
                         "request against a standalone generate() run")
    args = ap.parse_args()

    base = dataclasses.replace(
        reduced_config(get_config("granite-3-2b")),
        num_layers=4, d_model=256, num_heads=4, num_kv_heads=2, head_dim=64,
        d_ff=512, vocab_size=1024, remat=False, q_chunk=64, dtype="float32",
    )
    params = init_params(base, jax.random.PRNGKey(0))
    prompt = jax.random.randint(jax.random.PRNGKey(1), (args.batch, args.prompt_len), 0, base.vocab_size)

    arms = [
        ("float", resolve_execution_mode("exact")),
        ("exact_quant", resolve_execution_mode("exact_quant")),
        (args.multiplier, resolve_execution_mode("approx_lowrank", args.multiplier)),
    ]
    if args.pallas:
        arms.append(("approx_pallas", resolve_execution_mode("approx", args.multiplier)))

    results = {}
    for label, acfg in arms:
        cfg = dataclasses.replace(base, approx=acfg)
        new = min(args.new, 4) if label == "approx_pallas" else args.new
        out = generate(cfg, params, prompt, max_new=new)       # compile
        jax.block_until_ready(out)
        t0 = time.perf_counter()
        out = generate(cfg, params, prompt, max_new=new)
        jax.block_until_ready(out)
        dt = time.perf_counter() - t0
        tps = args.batch * new / dt
        results[label] = out
        print(f"{label:12s}: {tps:8.1f} tok/s  sample: {out[0, args.prompt_len:].tolist()}")

    # scan engine vs the legacy per-token Python loop (float arm)
    jax.block_until_ready(greedy_generate_legacy(base, params, prompt, max_new=args.new))
    t0 = time.perf_counter()
    jax.block_until_ready(greedy_generate_legacy(base, params, prompt, max_new=args.new))
    legacy_tps = args.batch * args.new / (time.perf_counter() - t0)
    print(f"{'legacy loop':12s}: {legacy_tps:8.1f} tok/s  (float, per-token dispatch)")

    agree = float(jnp.mean(results["float"][:, args.prompt_len:] ==
                           results[args.multiplier][:, args.prompt_len:]))
    agree_q = float(jnp.mean(results["exact_quant"][:, args.prompt_len:] ==
                             results[args.multiplier][:, args.prompt_len:]))
    print(f"\ntoken agreement vs float: {agree*100:.1f}%; vs exact-quant: {agree_q*100:.1f}%")
    if args.pallas:
        n = results["approx_pallas"].shape[1] - args.prompt_len
        agree_p = float(jnp.mean(
            results["approx_pallas"][:, args.prompt_len:] ==
            results[args.multiplier][:, args.prompt_len:args.prompt_len + n]
        ))
        print(f"pallas-kernel vs lowrank agreement (same semantics): {agree_p*100:.1f}%")
    print("(random-init model: near-uniform logits make argmax quant-sensitive;"
          " see examples/lenet_mnist_qat.py for the trained-model DAL story)")

    if args.continuous:
        from repro.serve.scheduler import ServeSession

        max_len = 8 * -(-max(64, 16 + args.new) // 8)
        rng = np.random.default_rng(0)
        trace = []
        for i in range(10):
            plen = int(rng.integers(2, 13))
            prompt = rng.integers(0, base.vocab_size, plen)
            max_new = int(rng.integers(min(2, args.new), args.new + 1))
            trace.append((i, prompt, max_new))
        oracle = {
            rid: np.asarray(generate(base, params, prompt[None, :].astype(np.int32),
                                     max_new=max_new)[0, len(prompt):])
            for rid, prompt, max_new in trace
        }

        for layout, loop, impl in (("slots", "async", "gather"),
                                   ("paged", "sync", "gather"),
                                   ("paged", "async", "gather"),
                                   ("paged", "async", "pallas")):
            print(f"\n-- continuous batching, {layout} KV cache, {loop} loop, "
                  f"{impl} attention (float, greedy) --")
            kw = dict(num_slots=4, max_len=max_len, prompt_buckets=(4, 8, 16),
                      loop=loop)
            if layout == "paged":
                # half the slot layout's KV memory: blocks are handed out by
                # actual context length, so the same trace still fits; the
                # pallas arm attends over the block pool in place (interpret
                # mode on CPU — slow, but running the real kernel body)
                kw.update(cache_layout="paged", block_size=8,
                          num_blocks=4 * max_len // 8 // 2, attn_impl=impl)
            sess = ServeSession(base, params, **kw)
            sess.warmup()
            for rid, prompt, max_new in trace:
                sess.submit(prompt, max_new=max_new, req_id=rid)
            t0 = time.perf_counter()
            out = sess.run()
            dt = time.perf_counter() - t0
            n_gen = sum(len(r.tokens) for r in out.values())
            st = sess.stats
            extra = (f", peak blocks {st.peak_blocks_in_use}/{sess.num_blocks}"
                     if layout == "paged" else "")
            label = f"{layout}/{loop}" + ("/pallas" if impl == "pallas" else "")
            print(f"{label:12s}: {n_gen/dt:8.1f} tok/s  "
                  f"({len(out)} mixed-length requests, slot utilization "
                  f"{st.slot_utilization*100:.1f}%, overlap "
                  f"{st.overlap_fraction*100:.0f}%{extra})")
            exact = sum(
                np.array_equal(oracle[rid], out[rid].tokens)
                for rid, _, _ in trace
            )
            print(f"order-independence oracle: {exact}/{len(trace)} requests "
                  "bit-identical to a standalone generate() run")


if __name__ == "__main__":
    main()
