"""Example #3: batched serving under the approximate multiplier.

Loads (or initializes) a small LM, runs batched greedy decoding through the
KV-cache serve path with the exact vs approximate multiplier, and reports
agreement + throughput — the serving-side counterpart of the QAT driver.

    PYTHONPATH=src python examples/llm_approx_serve.py --batch 4 --new 16
"""
import argparse
import dataclasses
import time

import jax
import jax.numpy as jnp

from repro.configs import get_config, reduced_config
from repro.core.approx import ApproxConfig
from repro.models.transformer import init_params
from repro.serve.engine import greedy_generate


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=8)
    ap.add_argument("--new", type=int, default=16)
    ap.add_argument("--multiplier", default="mul8x8_2")
    args = ap.parse_args()

    base = dataclasses.replace(
        reduced_config(get_config("granite-3-2b")),
        num_layers=4, d_model=256, num_heads=4, num_kv_heads=2, head_dim=64,
        d_ff=512, vocab_size=1024, remat=False, q_chunk=64, dtype="float32",
    )
    params = init_params(base, jax.random.PRNGKey(0))
    prompt = jax.random.randint(jax.random.PRNGKey(1), (args.batch, args.prompt_len), 0, base.vocab_size)

    results = {}
    for label, acfg in [
        ("float", ApproxConfig(mode="float")),
        ("exact_quant", ApproxConfig(multiplier="exact", mode="exact_quant")),
        (args.multiplier, ApproxConfig(multiplier=args.multiplier, mode="lowrank")),
    ]:
        cfg = dataclasses.replace(base, approx=acfg)
        t0 = time.perf_counter()
        out = greedy_generate(cfg, params, prompt, max_new=args.new)
        jax.block_until_ready(out)
        dt = time.perf_counter() - t0
        tps = args.batch * args.new / dt
        results[label] = out
        print(f"{label:12s}: {tps:8.1f} tok/s  sample: {out[0, args.prompt_len:].tolist()}")

    agree = float(jnp.mean(results["float"][:, args.prompt_len:] ==
                           results[args.multiplier][:, args.prompt_len:]))
    agree_q = float(jnp.mean(results["exact_quant"][:, args.prompt_len:] ==
                             results[args.multiplier][:, args.prompt_len:]))
    print(f"\ntoken agreement vs float: {agree*100:.1f}%; vs exact-quant: {agree_q*100:.1f}%")
    print("(random-init model: near-uniform logits make argmax quant-sensitive;"
          " see examples/lenet_mnist_qat.py for the trained-model DAL story)")


if __name__ == "__main__":
    main()
