"""End-to-end driver #2: approximate-multiplier QAT on a language model.

Trains a granite-family LM (default ~8M params for CPU; --preset 100m gives
the ~100M-parameter configuration) on synthetic token streams with the
MUL8x8_2 forward, band regularization, checkpoint/restart, preemption guard
and straggler monitoring — the single-host version of launch/train.py.

    PYTHONPATH=src python examples/approx_qat_lm.py --steps 200
    PYTHONPATH=src python examples/approx_qat_lm.py --preset 100m --steps 300
"""
import argparse
import dataclasses
import os
import tempfile

import jax
import jax.numpy as jnp

from repro.configs import get_config
from repro.core.approx import ApproxConfig
from repro.data.synthetic import token_batches
from repro.models.transformer import init_params
from repro.train import optim as O
from repro.train.checkpoint import latest_step, restore_checkpoint, save_checkpoint
from repro.train.fault import PreemptionGuard, StragglerMonitor
from repro.train.loop import init_state, make_train_step

PRESETS = {
    # ~8M: fast on 1 CPU core; ~100M: the assignment's end-to-end scale
    "8m": dict(num_layers=4, d_model=256, num_heads=4, num_kv_heads=2, head_dim=64,
               d_ff=1024, vocab_size=2048),
    "100m": dict(num_layers=12, d_model=768, num_heads=12, num_kv_heads=4, head_dim=64,
                 d_ff=3072, vocab_size=8192),
}


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--preset", default="8m", choices=sorted(PRESETS))
    ap.add_argument("--steps", type=int, default=200)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--multiplier", default="mul8x8_2")
    ap.add_argument("--mode", default="lowrank",
                    choices=["float", "exact_quant", "lut", "lowrank"])
    ap.add_argument("--ckpt", default="")
    ap.add_argument("--ckpt-every", type=int, default=50)
    args = ap.parse_args()

    cfg = dataclasses.replace(
        get_config("granite-3-2b"),
        **PRESETS[args.preset],
        dtype="float32",
        q_chunk=64,
        remat=False,
        approx=ApproxConfig(multiplier=args.multiplier, mode=args.mode, band_reg=1e-4),
    )
    n_params = cfg.param_count()
    print(f"model: {args.preset} ({n_params/1e6:.1f}M params), approx={args.mode}/{args.multiplier}")

    opt = O.OptConfig(lr=3e-4, warmup_steps=20, total_steps=args.steps, clip_norm=1.0)
    ckpt_dir = args.ckpt or os.path.join(tempfile.gettempdir(), f"approx_qat_lm_{args.preset}")

    state = init_state(cfg, opt, jax.random.PRNGKey(0))
    start = 0
    if latest_step(ckpt_dir) is not None:
        state, start = restore_checkpoint(ckpt_dir, jax.eval_shape(lambda: state))
        print(f"resumed from checkpoint step {start}")

    step_fn = jax.jit(make_train_step(cfg, opt))
    mon = StragglerMonitor(threshold=3.0,
                           on_straggler=lambda s, dt, e: print(f"  [straggler] step {s}: {dt:.2f}s vs ewma {e:.2f}s"))
    batches = token_batches(cfg.vocab_size, args.batch, args.seq, seed=1)

    import time

    with PreemptionGuard() as guard:
        for i in range(start, args.steps):
            toks, labels = next(batches)
            t0 = time.perf_counter()
            state, m = step_fn(state, {"tokens": jnp.asarray(toks), "labels": jnp.asarray(labels)})
            jax.block_until_ready(m["loss"])
            dt = time.perf_counter() - t0
            mon.record(i, dt)
            if i % 20 == 0 or i == args.steps - 1:
                print(f"step {i:4d} loss {float(m['loss']):.4f} ce {float(m['ce']):.4f} "
                      f"band_reg {float(m['band_reg']):.2e} ({dt:.2f}s)")
            if (i + 1) % args.ckpt_every == 0 or guard.should_stop:
                save_checkpoint(ckpt_dir, i + 1, state, keep=3)
                if guard.should_stop:
                    print("preemption requested: checkpoint flushed, exiting cleanly")
                    return
    print(f"done. stragglers observed: {len(mon.events)}")


if __name__ == "__main__":
    main()
