"""Quickstart: the paper's multipliers as a composable JAX feature.

    PYTHONPATH=src python examples/quickstart.py
"""
import jax
import jax.numpy as jnp
import numpy as np

from repro.core import lowrank, multipliers as M
from repro.core.approx import ApproxConfig, approx_dense, quantized_matmul
from repro.core.metrics import multiplier_metrics
from repro.kernels.approx_matmul.ref import approx_matmul_ref


def main():
    print("== 1. The paper's multipliers as LUTs ==")
    for name in ("mul8x8_1", "mul8x8_2", "mul8x8_3", "pkm", "etm"):
        m = multiplier_metrics(M.mul8x8_table(name), name)
        print(f"  {name:10s} ER={m.er:6.2f}%  MED={m.med:8.2f}  NMED={m.nmed:5.2f}%  MRED={m.mred:6.2f}%")

    print("\n== 2. Exact low-rank decomposition (the TPU-native form) ==")
    for name in ("mul8x8_1", "mul8x8_2", "mul8x8_3"):
        c = lowrank.build_correction(name, side="rhs")
        cp = lowrank.build_correction(name, side="rhs", rhs_max=31)
        print(f"  {name}: approx(A,B) = A@B - sum of {c.num_features} feature dots"
              f" (co-optimized weights<32: {cp.num_features})")

    print("\n== 3. Bit-exact approximate matmul, three ways ==")
    rng = np.random.default_rng(0)
    a = jnp.asarray(rng.integers(0, 256, (64, 128)), jnp.uint8)
    b = jnp.asarray(rng.integers(0, 256, (128, 32)), jnp.uint8)
    lut = approx_matmul_ref(a, b, jnp.asarray(M.mul8x8_table("mul8x8_2")))
    lowr = quantized_matmul(a, b, ApproxConfig(multiplier="mul8x8_2", mode="lowrank"))
    from repro.kernels.approx_matmul.ops import approx_matmul_pallas

    pal = approx_matmul_pallas(a, b, multiplier="mul8x8_2")
    print("  LUT-oracle == lowrank-MXU :", bool(jnp.all(lut == lowr.astype(lut.dtype))))
    print("  LUT-oracle == pallas      :", bool(jnp.all(lut == pal)))

    print("\n== 4. A real-valued dense layer under the approximate multiplier ==")
    x = jnp.asarray(rng.normal(size=(8, 64)), jnp.float32)
    w = jnp.asarray(rng.normal(size=(64, 16)), jnp.float32)
    y_exact = x @ w
    for mult in ("exact", "mul8x8_2", "mul8x8_3"):
        mode = "exact_quant" if mult == "exact" else "lowrank"
        y = approx_dense(x, w, ApproxConfig(multiplier=mult, mode=mode))
        rel = float(jnp.linalg.norm(y - y_exact) / jnp.linalg.norm(y_exact))
        print(f"  {mult:10s} rel-error vs float matmul: {rel:.4f}")

    print("\n== 5. Gradients flow (QAT straight-through) ==")
    cfg = ApproxConfig(multiplier="mul8x8_2", mode="lowrank")
    g = jax.grad(lambda w: jnp.sum(approx_dense(x, w, cfg) ** 2))(w)
    print("  d/dw finite:", bool(jnp.all(jnp.isfinite(g))), " norm:", float(jnp.linalg.norm(g)))


if __name__ == "__main__":
    main()
