"""Serving: scan-based batched engine (PR 1) + continuous-batching
scheduler over a slot-based KV cache (PR 2)."""
from repro.serve.engine import (
    EXECUTION_MODES,
    GenerationState,
    SamplingConfig,
    freeze_params,
    generate,
    greedy_generate,
    greedy_generate_legacy,
    resolve_execution_mode,
    select_token,
)
from repro.serve.scheduler import (
    CompletedRequest,
    Request,
    SchedulerStats,
    ServeSession,
    scheduler_compile_stats,
)

__all__ = [
    "EXECUTION_MODES",
    "GenerationState",
    "SamplingConfig",
    "freeze_params",
    "generate",
    "greedy_generate",
    "greedy_generate_legacy",
    "resolve_execution_mode",
    "select_token",
    "CompletedRequest",
    "Request",
    "SchedulerStats",
    "ServeSession",
    "scheduler_compile_stats",
]
