"""Serving: scan-based batched engine (PR 1) + continuous-batching
scheduler over a slot-based (PR 2) or paged block-table (PR 3) KV cache
with copy-on-write prefix sharing and preemption (PR 6)."""
from repro.serve.cache import BlockPool, PrefixCache, PromptBuckets, SlotPool
from repro.serve.engine import (
    EXECUTION_MODES,
    GenerationState,
    SamplingConfig,
    freeze_params,
    generate,
    greedy_generate,
    greedy_generate_legacy,
    resolve_execution_mode,
    select_token,
)
from repro.serve.scheduler import (
    ADMISSION_POLICIES,
    ATTN_IMPLS,
    CACHE_LAYOUTS,
    SERVE_LOOPS,
    CompletedRequest,
    Request,
    SchedulerStats,
    ServeSession,
    scheduler_compile_stats,
)

__all__ = [
    "ADMISSION_POLICIES",
    "ATTN_IMPLS",
    "CACHE_LAYOUTS",
    "SERVE_LOOPS",
    "BlockPool",
    "PrefixCache",
    "PromptBuckets",
    "SlotPool",
    "EXECUTION_MODES",
    "GenerationState",
    "SamplingConfig",
    "freeze_params",
    "generate",
    "greedy_generate",
    "greedy_generate_legacy",
    "resolve_execution_mode",
    "select_token",
    "CompletedRequest",
    "Request",
    "SchedulerStats",
    "ServeSession",
    "scheduler_compile_stats",
]
