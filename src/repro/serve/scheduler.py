"""Continuous-batching serve scheduler over a fixed pool of decode slots.

The PR-1 engine (``repro.serve.engine.generate``) serves one fixed batch of
same-length requests end-to-end: every request in the batch pays for the
longest prompt and the largest ``max_new``.  ``ServeSession`` instead keeps
a pool of ``num_slots`` decode slots hot and refills each slot from a
request queue the moment its occupant finishes (EOS or max-token), so the
approximate-multiplier matmuls stay saturated instead of idling behind the
longest request.

Two **cache layouts** share the session (``cache_layout=``):

* ``"slots"`` — every request reserves a worst-case ``max_len`` KV stripe
  for its lifetime (the PR-2 engine, kept as the parity oracle);
* ``"paged"`` — K/V live in a global ``BlockPool`` of fixed-size blocks
  and each request holds only the blocks its actual context occupies,
  recorded in a fixed-width per-slot block table.  Admission allocates
  ``ceil(prompt_len / block_size)`` blocks, decode appends one block only
  when a request's context crosses a block boundary, and completion frees
  every held block immediately — so mixed-context traffic shares HBM
  instead of stranding it, and ``num_slots`` (decode width) decouples from
  memory.  Admission reserves each request's worst case
  (``ceil((prompt_len + max_new - 1) / block_size)`` blocks) against the
  pool, which makes mid-decode block appends infallible: no preemption
  path is ever needed.  Greedy float outputs are bit-identical to the slot
  layout (and to standalone ``generate``) — masked block-gather garbage
  receives softmax probability exactly 0.0.

Everything runs under **fixed compiled shapes**:

* ONE decode program per (config, sampling, num_slots, max_len [, layout])
  — a single ``decode_step`` / ``paged_decode_step`` over the pooled cache
  each tick, all slots at once; block-table *contents* are traced data, so
  no context layout recompiles;
* ONE prefill program per prompt-length *bucket* (``PromptBuckets``):
  every admission in a tick shares a single batched (width ``num_slots``)
  fused ``forward(return_kv=True)`` pass that seeds the freed slots' KV rows
  and samples each first token (SSM/hybrid families fall back to a masked
  teacher-forced scan inside the same jit); unadmitted rows degenerate to
  exact no-ops (``cache.scatter_rows`` where-gather for slots, dropped
  sentinel-block scatters for paged), and the other slots' rows are
  untouched.

No request pattern (arrival order, prompt length, max_new mix) triggers a
recompile after ``warmup()`` — asserted by ``compile_stats`` deltas in
tests/test_scheduler.py.

Two **host loops** drive those programs (``loop=``):

* ``"sync"`` — the PR-3 tick loop, kept as the parity baseline: each
  ``step()`` admits, dispatches one decode chunk, and immediately blocks on
  the chunk's tokens before doing any bookkeeping, so host scheduling and
  device compute strictly alternate;
* ``"async"`` (default) — a **double-buffered pipeline**: ``step()``
  dispatches decode chunk *N+1* (and any admits) *before* blocking on chunk
  *N*'s token transfer.  The decode carry (``last_token`` and the per-slot
  PRNG keys) stays device-resident between chunks and admissions merge
  their first sampled tokens into it with a fixed-shape scatter
  (``cache.merge_admit_carry``), so no host sync sits between dispatches —
  queue management, admission decisions, and ``_finish`` bookkeeping all
  overlap device compute.  The price is one chunk of lag on *observing*
  completions: a request that finishes inside the in-flight chunk decodes
  one extra garbage chunk before the host sees it (discarded, counted as
  idle — the same overshoot discipline as ``steps_per_tick``).  Length
  completions never pay that lag: **predictive early turnover** releases a
  row whose in-flight chunk provably finishes it by length (an eos can
  only finish it sooner), so a successor admits into the slot before the
  harvest and the async schedule matches the sync loop tick-for-tick.
  Greedy float outputs remain bit-identical to the sync loop and to
  standalone ``generate``: each row's math depends only on its own
  carry/cache state, which both loops feed identically.  On accelerators
  every cache-consuming program additionally donates its cache operand
  (each cache future is consumed exactly once by the next dispatch), so
  the pipeline rebuilds the pooled cache in place instead of doubling HBM
  traffic; on CPU donation is deliberately off — see
  ``_resolve_cache_donation``.

**Prefill/decode interleaving** rate-limits admission so a burst of long
prompts cannot starve resident decodes: with ``prefill_decode_ratio=R``,
each ``step()`` admits at most ``R * n_active * steps_per_tick`` bucketed
prompt tokens (``prefill_token_budget=B`` is the flat-budget variant); the
queue head is deferred — never skipped — when it exceeds the remaining
budget, and admission is unthrottled while no decode is resident (nothing
to starve, and the queue must drain).  ``SchedulerStats`` surfaces the
policy: ``prefill_stall_ticks`` counts steps that deferred an admissible
request, ``max_decode_gap_ticks`` is the starvation gauge (worst
device-work gap between a resident request's consecutive accepted tokens,
bounded by ``steps_per_tick + ceil(R * steps_per_tick)`` under the ratio
policy — the carry-based work accounting makes that bound exact), and
``overlap_fraction`` reports how much of the wall clock the async loop hid
host work behind device compute.

Sampling is per-request deterministic: each request gets
``fold_in(session_key, req_id)`` and each sampled token position folds in
its cache position, so a request's output is independent of which slot it
lands in and of what else is in flight (bit-exact under float execution;
quantized modes couple batch rows through the dynamic per-tensor activation
scale, so there parity is statistical, not bitwise).

Execution modes: the session serves whatever ``cfg.approx`` selects —
``exact`` / ``exact_quant`` / ``approx`` (Pallas kernel) /
``approx_lowrank`` / ``approx_msr`` — and accepts ``freeze_params``
QWeight trees.

**Quality tiers** (``tiers=("exact", "approx", "approx_msr")``) instead
route each REQUEST through its own execution mode: one compiled decode
program per ladder rung, dispatched per step for the rungs holding active
rows, with the other tiers' rows made write-inert exactly the way released
rows already are (sentinel block tables / out-of-bounds ``cur_len``).  A
request's rung is frozen at admission — ``submit(..., tier=...)`` names the
requested rung, and the **load shedder** (``shed_queue_depth`` /
``shed_gap_ticks``) may demote new admissions further down the ladder while
the session is overloaded, restoring with hysteresis
(``shed_hold_steps``).  Per-rung configs use per-row activation scales
(``act_per_row``), so every request's greedy output is bit-identical to a
single-mode oracle session of its effective rung regardless of what else
shares the batch.  Tier sessions take raw float params (the rungs disagree
about quantization, so ``freeze_params`` trees cannot be shared).
"""
from __future__ import annotations

import contextlib
import dataclasses
import heapq
import os
import time
from collections import deque
from typing import Any, ClassVar, Dict, List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig
from repro.models.attention import ATTN_IMPLS
from repro.models.transformer import (
    decode_step,
    forward,
    init_cache,
    init_paged_cache,
    paged_chunk_prefill_step,
    paged_decode_step,
    paged_verify_step,
)
from repro.parallel.sharding import constrain as _sh_constrain
from repro.serve import cache as C
from repro.serve.engine import (
    EXECUTION_MODES,
    SamplingConfig,
    draft_config,
    resolve_execution_mode,
    select_token,
)

__all__ = [
    "Request",
    "CompletedRequest",
    "SchedulerStats",
    "ServeSession",
    "scheduler_compile_stats",
    "ATTN_IMPLS",
    "CACHE_LAYOUTS",
    "ADMISSION_POLICIES",
    "SERVE_LOOPS",
]

CACHE_LAYOUTS = ("slots", "paged")
ADMISSION_POLICIES = ("priority", "fifo", "sjf")
SERVE_LOOPS = ("async", "sync")

def _resolve_cache_donation() -> Tuple[str, ...]:
    """Donate the cache operand of every cache-consuming program so the
    pooled KV is rebuilt IN PLACE instead of copied per dispatch — sound
    because the loop hands each cache future to exactly one next dispatch,
    and warmup() chains its outputs the same way.  Default ON for
    accelerators (the ROADMAP cache-donation item: cuts HBM traffic and
    halves peak pool memory) but OFF on CPU: XLA CPU honors aliasing
    (measured ~40x on a pool-sized ``.at[].set``), yet donating there makes
    the chunk execute effectively inline with its dispatch, which
    serializes the very host/device overlap the async loop exists to
    create (measured: both loops' overlap_fraction -> ~0.99 and the async
    win -> ~1.0x with CPU donation on; the pool copy it avoids is
    negligible at bench scale).  ``REPRO_SERVE_DONATE=0|1`` overrides the
    per-backend default.  Resolved lazily (first program call, via
    ``_LazyJit``) so importing this module never initializes the jax
    backend and the decision reads the platform the application actually
    configured."""
    env = os.environ.get("REPRO_SERVE_DONATE", "")
    if env == "1":
        return ("cache",)
    if env == "0":
        return ()
    return ("cache",) if jax.default_backend() != "cpu" else ()


def _pin_pool(cache):
    """Pin the paged pool's placement at program outputs: block contents
    shard along the KV-head dim over ``"model"`` (matching ``cache_pspecs
    (layout="paged")``).  Without the pin, jit is free to pick a different
    output sharding than the input's, and the NEXT dispatch of the same
    program would see changed operand placements — one recompile per flip.
    ``constrain`` degrades to a no-op off-mesh and drops the axis when the
    head count does not divide it, so single-device serving is untouched."""
    spec = (None, None, None, "model", None)
    return dict(
        cache,
        k=_sh_constrain(cache["k"], spec),
        v=_sh_constrain(cache["v"], spec),
    )


class _LazyJit:
    """Defer ``jax.jit`` wrapping to the first call.  Keeps module import
    free of backend initialization and lets the donation decision see the
    configured platform; exposes ``_cache_size`` like a real jit so the
    compile-count plumbing is unchanged (0 before the first call — no
    programs exist yet)."""

    def __init__(self, build):
        self._build = build
        self._fn = None

    def __call__(self, *args, **kwargs):
        if self._fn is None:
            self._fn = self._build()
        return self._fn(*args, **kwargs)

    def _cache_size(self) -> int:
        if self._fn is None:
            return 0
        get = getattr(self._fn, "_cache_size", None)
        return int(get()) if callable(get) else -1


# ---------------------------------------------------------------------------
# Compiled programs (module-level lazy jits: cfg/sampling static, shared
# cache, cache operand donated per _resolve_cache_donation)
# ---------------------------------------------------------------------------


def _decode_tick(
    cfg: ModelConfig,
    params,
    cache,
    last_token: jax.Array,     # (N,) int32
    cur_len: jax.Array,        # (N,) int32
    active: jax.Array,         # (N,) bool
    slot_keys: jax.Array,      # (N, 2) uint32 per-request PRNG keys
    tables: Optional[jax.Array] = None,   # (N, W) int32 — paged layout only
    *,
    sampling: SamplingConfig,
    steps: int = 1,
    block_size: int = 0,
    attn_impl: str = "gather",
):
    """``steps`` decode steps across all slots in one dispatch (decode
    chunk).  Inactive slots compute garbage into their own rows only (masked
    out here and overwritten at next admit; under the paged layout their
    all-sentinel table rows drop the writes entirely).  Rows that finish
    mid-chunk (eos here, max-token on the host) overshoot at most
    ``steps - 1`` positions; the host discards the extra tokens.  Overshoot
    cache writes go through per-row ``.at[...].set`` scatters, whose
    out-of-bounds updates are dropped (unlike ``dynamic_update_slice``,
    which CLAMPS — do not swap the write path without rechecking this); the
    hard guarantee, though, is ``submit``'s ``prompt_len + max_new <=
    max_len`` bound: no attending row ever reads a position an overshooting
    row could have written.  ``tables is None`` selects the slot layout at
    trace time — both layouts share this entry point, so the compile-count
    recompile checks cover them uniformly.

    Returns ``(cache, toks, last_token)``: the final ``last_token`` carry is
    a device array the async loop feeds straight into the next chunk's
    dispatch, which is what lets chunk N+1 launch before chunk N's tokens
    ever reach the host (the sync loop ignores it and rebuilds the value
    from the fetched tokens — same numbers, same program)."""

    def one(carry, _):
        cache, last_token, cur_len, done = carry
        if tables is None:
            logits, cache = decode_step(
                cfg, params, cache, {"tokens": last_token[:, None]}, cur_len
            )
        else:
            logits, cache = paged_decode_step(
                cfg, params, cache, {"tokens": last_token[:, None]}, cur_len,
                tables, block_size=block_size, attn_impl=attn_impl,
            )
        # the sampled token lands at position cur_len + 1 -> unique, slot-
        # and schedule-independent key per token
        keys = jax.vmap(jax.random.fold_in)(slot_keys, cur_len + 1)
        toks = jax.vmap(lambda l, k: select_token(l[None], sampling, k)[0])(
            logits[:, 0, :], keys
        )
        if sampling.eos_id >= 0:
            toks = jnp.where(done, jnp.int32(sampling.eos_id), toks)
            done = done | (toks == sampling.eos_id)
        toks = jnp.where(active, toks, 0)
        last_token = jnp.where(active, toks, last_token)
        return (cache, last_token, cur_len + active, done), toks

    carry = (cache, last_token, cur_len, jnp.zeros_like(active))
    (cache, last_token, _, _), toks = jax.lax.scan(one, carry, None, length=steps)
    if tables is not None:
        cache = _pin_pool(cache)
    # only the sampled tokens (and the tiny carry) replicate back to the
    # host loop — logits/activations stay sharded inside the program
    toks = _sh_constrain(toks, (None, None))
    last_token = _sh_constrain(last_token, (None,))
    return cache, toks, last_token          # toks: (steps, N)


_decode_tick_jit = _LazyJit(lambda: jax.jit(
    _decode_tick,
    static_argnames=("cfg", "sampling", "steps", "block_size", "attn_impl"),
    donate_argnames=_resolve_cache_donation(),
))


def _spec_tick(
    cfg: ModelConfig,
    draft_cfg: ModelConfig,
    params,
    cache,
    last_token: jax.Array,     # (N,) int32
    cur_len: jax.Array,        # (N,) int32 — position of last_token
    active: jax.Array,         # (N,) bool
    slot_keys: jax.Array,      # (N, 2) uint32 per-request PRNG keys
    tables: jax.Array,         # (N, W) int32 — spec decode is paged-only
    *,
    sampling: SamplingConfig,
    draft_k: int,
    block_size: int,
    attn_impl: str,
):
    """One self-speculative work tick: ``draft_k`` decode steps through the
    approximate draft path (``draft_cfg`` differs from ``cfg`` only in
    ``cfg.approx`` — same params, zero extra weights), then ONE exact
    verify pass over the K+1 positions [last accepted token; K drafts],
    accepting per row the longest draft prefix that matches the exact
    sampler plus the verifier's correction token.

    Exactness by construction, for ANY sampling config: the verify step
    replays the sequential decode's per-position instruction sequence
    (``paged_verify_attention``), and the positional ``fold_in(slot_key,
    position)`` key schedule makes the exact token at position ``p`` a
    function of the prefix alone — a token is only accepted when its whole
    prefix matched, so accepted tokens are bit-identical to the
    non-speculative oracle.  The draft's only power is over *which*
    positions get verified, i.e. throughput, never content.

    Cache discipline: the draft scan writes approximate K/V at positions
    ``c .. c+K-1`` and the verify pass overwrites ``c .. c+K`` with exact
    K/V; positions past the accept point hold wrong-token K/V but sit
    beyond the new ``cur_len`` and are rewritten by the next tick's draft
    or verify before any attention horizon reaches them (the same
    masked-overshoot discipline as ``_decode_tick``; sentinel table
    entries drop writes past a row's allocation).

    Returns ``(cache, toks, n_acc, last_token, cur_len)``: ``toks`` is
    (K+1, N) with each row's accepted tokens in ``toks[:n_acc[row], row]``
    (zeros past them), ``n_acc`` is (N,) in 1..K+1 for live rows / 0 for
    inactive ones, and the carries advance per row by its own ``n_acc`` —
    the async loop feeds them straight into the next dispatch."""
    S = draft_k + 1

    def one(carry, _):
        cache, tok, pos = carry
        logits, cache = paged_decode_step(
            draft_cfg, params, cache, {"tokens": tok[:, None]}, pos,
            tables, block_size=block_size, attn_impl=attn_impl,
        )
        # the draft samples with the SAME positional keys as the verifier,
        # so a perfect draft (draft_mode="exact") accepts every token
        keys = jax.vmap(jax.random.fold_in)(slot_keys, pos + 1)
        nxt = jax.vmap(lambda l, k: select_token(l[None], sampling, k)[0])(
            logits[:, 0, :], keys
        )
        nxt = jnp.where(active, nxt, 0)
        return (cache, nxt, pos + active), nxt

    (cache, _, _), drafts = jax.lax.scan(
        one, (cache, last_token, cur_len), None, length=draft_k
    )
    drafts = drafts.T                                # (N, K)

    tokens_in = jnp.concatenate([last_token[:, None], drafts], axis=1)
    logits, cache = paged_verify_step(
        cfg, params, cache, {"tokens": tokens_in}, cur_len, tables,
        block_size=block_size,
    )
    # exact token at position cur_len + j + 1, for j = 0..K
    pos = cur_len[:, None] + 1 + jnp.arange(S, dtype=cur_len.dtype)[None, :]
    keys = jax.vmap(
        lambda k, p: jax.vmap(jax.random.fold_in, in_axes=(None, 0))(k, p)
    )(slot_keys, pos)
    exact = jax.vmap(jax.vmap(
        lambda l, k: select_token(l[None], sampling, k)[0]
    ))(logits, keys)                                 # (N, K+1)

    # longest matching draft prefix m -> emit those m tokens plus the
    # verifier's correction token exact[m]
    match = (exact[:, :draft_k] == drafts).astype(jnp.int32)
    n_acc = jnp.sum(jnp.cumprod(match, axis=1), axis=1) + 1
    if sampling.eos_id >= 0:
        # never emit past the first exact eos (the oracle stops there)
        is_eos = exact == sampling.eos_id
        first = jnp.where(
            jnp.any(is_eos, axis=1), jnp.argmax(is_eos, axis=1), S
        )
        n_acc = jnp.minimum(n_acc, first + 1)
    n_acc = jnp.where(active, n_acc, 0)
    idx = jnp.arange(S, dtype=jnp.int32)[None, :]
    toks = jnp.where((idx < n_acc[:, None]) & active[:, None], exact, 0)
    new_last = jnp.take_along_axis(
        exact, jnp.maximum(n_acc - 1, 0)[:, None], axis=1
    )[:, 0]
    last_token = jnp.where(active, new_last, last_token)
    max_pos = tables.shape[1] * block_size - 1       # == max_len - 1
    cur_len = jnp.where(
        active, jnp.minimum(cur_len + n_acc, max_pos), cur_len
    )
    cache = _pin_pool(cache)
    toks = _sh_constrain(toks.T, (None, None))
    n_acc = _sh_constrain(n_acc, (None,))
    last_token = _sh_constrain(last_token, (None,))
    cur_len = _sh_constrain(cur_len, (None,))
    return cache, toks, n_acc, last_token, cur_len       # toks: (K+1, N)


_spec_tick_jit = _LazyJit(lambda: jax.jit(
    _spec_tick,
    static_argnames=(
        "cfg", "draft_cfg", "sampling", "draft_k", "block_size", "attn_impl"
    ),
    donate_argnames=_resolve_cache_donation(),
))


def _request_keys(base_key, req_ids):
    """(A,) request ids -> (A, 2) per-request PRNG keys (computed in-jit so
    admission costs no extra host dispatches)."""
    return jax.vmap(jax.random.fold_in, in_axes=(None, 0))(base_key, req_ids)


def _first_tokens(last_logits, req_keys, prompt_lens, sampling: SamplingConfig):
    """(A, V) last-position logits -> (A,) first sampled tokens under the
    per-request fold_in key schedule (position == prompt_len)."""
    keys = jax.vmap(jax.random.fold_in)(req_keys, prompt_lens)
    return jax.vmap(lambda l, k: select_token(l[None], sampling, k)[0])(
        last_logits, keys
    )


_scatter_rows = C.scatter_rows


def _admit_fused(
    cfg: ModelConfig,
    params,
    cache,
    prompts: jax.Array,        # (A, S_bucket) int32, right-padded
    prompt_lens: jax.Array,    # (A,) int32
    slots: jax.Array,          # (A,) int32 — a permutation of range(num_slots)
    valid: jax.Array,          # (A,) bool — rows actually being admitted
    req_ids: jax.Array,        # (A,) int32
    base_key: jax.Array,       # (2,) uint32 session key
    *,
    sampling: SamplingConfig,
):
    """Batched fused prefill-on-admit (attention families): ONE
    full-sequence pass prefills every admission of this tick, seeds their
    slots' KV rows [0, S_bucket), and samples each first token.  Compiled
    once per bucket size; invalid rows are no-ops (see ``_scatter_rows``),
    so 1..A admissions share the program."""
    logits, _, kvs = forward(cfg, params, {"tokens": prompts}, return_kv=True)
    last = jnp.take_along_axis(
        logits, (prompt_lens - 1)[:, None, None], axis=1
    )[:, 0, :]
    k, v = kvs                                  # (L, A, S_bucket, Hkv, hd)
    Sb = prompts.shape[1]
    cache = dict(
        cache,
        k=_scatter_rows(cache["k"], k, slots, valid, s_cap=Sb),
        v=_scatter_rows(cache["v"], v, slots, valid, s_cap=Sb),
    )
    req_keys = _request_keys(base_key, req_ids)
    return cache, _first_tokens(last, req_keys, prompt_lens, sampling), req_keys


_admit_fused_jit = _LazyJit(lambda: jax.jit(
    _admit_fused, static_argnames=("cfg", "sampling"),
    donate_argnames=_resolve_cache_donation(),
))


def _admit_decode(
    cfg: ModelConfig,
    params,
    cache,
    prompts: jax.Array,        # (A, S_bucket) int32, right-padded
    prompt_lens: jax.Array,    # (A,) int32
    slots: jax.Array,          # (A,) int32 — a permutation of range(num_slots)
    valid: jax.Array,          # (A,) bool
    req_ids: jax.Array,        # (A,) int32
    base_key: jax.Array,       # (2,) uint32 session key
    *,
    sampling: SamplingConfig,
    max_len: int,
    cache_dtype: str,
):
    """Batched teacher-forced prefill-on-admit for SSM/hybrid caches
    (conv/ssm state has no fused seeding path): scan the bucket positions on
    a fresh batch-A cache, freezing each row's state updates past its own
    prompt_len, then scatter the rows into their slots."""
    A, Sb = prompts.shape
    slot_cache = init_cache(cfg, A, max_len, jnp.dtype(cache_dtype))

    def body(carry, xs):
        cache_c, last = carry
        t, toks = xs
        logits, new_cache = decode_step(
            cfg, params, cache_c, {"tokens": toks[:, None]},
            jnp.full((A,), t, jnp.int32),
        )
        take = t < prompt_lens                   # (A,) per-row freeze
        cache_c = jax.tree.map(
            lambda n, o: jnp.where(
                take.reshape((1, A) + (1,) * (n.ndim - 2)), n, o
            ),
            new_cache,
            cache_c,
        )
        last = jnp.where((t == prompt_lens - 1)[:, None], logits[:, 0, :], last)
        return (cache_c, last), None

    init = (slot_cache, jnp.zeros((A, cfg.padded_vocab), jnp.float32))
    (slot_cache, last), _ = jax.lax.scan(
        body, init, (jnp.arange(Sb, dtype=jnp.int32), prompts.T)
    )
    cache = jax.tree.map(
        lambda full, part: _scatter_rows(full, part, slots, valid), cache, slot_cache
    )
    req_keys = _request_keys(base_key, req_ids)
    return cache, _first_tokens(last, req_keys, prompt_lens, sampling), req_keys


_admit_decode_jit = _LazyJit(lambda: jax.jit(
    _admit_decode,
    static_argnames=("cfg", "sampling", "max_len", "cache_dtype"),
    donate_argnames=_resolve_cache_donation(),
))


def _admit_fused_paged(
    cfg: ModelConfig,
    params,
    cache,
    prompts: jax.Array,        # (A, S_bucket) int32, right-padded
    prompt_lens: jax.Array,    # (A,) int32
    block_ids: jax.Array,      # (A, ceil(S_bucket/block_size)) int32
    req_ids: jax.Array,        # (A,) int32
    base_key: jax.Array,       # (2,) uint32 session key
    *,
    sampling: SamplingConfig,
    block_size: int,
):
    """Batched fused prefill-on-admit against the paged cache: ONE
    full-sequence pass prefills every admission of this tick, scatters each
    row's K/V into its allocated blocks, and samples each first token.
    Unallocated / padding-row entries of ``block_ids`` hold the sentinel
    ``num_blocks`` and are dropped by the scatter — no ``valid`` mask is
    needed, and 1..A admissions share the program (compiled once per
    (admit width, bucket))."""
    logits, _, kvs = forward(cfg, params, {"tokens": prompts}, return_kv=True)
    last = jnp.take_along_axis(
        logits, (prompt_lens - 1)[:, None, None], axis=1
    )[:, 0, :]
    cache = _pin_pool(C.scatter_prompt_blocks(cache, kvs, block_ids, block_size))
    req_keys = _request_keys(base_key, req_ids)
    tok0s = _sh_constrain(
        _first_tokens(last, req_keys, prompt_lens, sampling), (None,)
    )
    return cache, tok0s, _sh_constrain(req_keys, (None, None))


_admit_fused_paged_jit = _LazyJit(lambda: jax.jit(
    _admit_fused_paged, static_argnames=("cfg", "sampling", "block_size"),
    donate_argnames=_resolve_cache_donation(),
))


def _prefill_chunk(
    cfg: ModelConfig,
    params,
    cache,
    tokens: jax.Array,         # (A, C_bucket) int32, right-padded chunk tokens
    starts: jax.Array,         # (A,) int32 prefill cursor (position of tokens[:, 0])
    chunk_lens: jax.Array,     # (A,) int32 real tokens this chunk
    tables: jax.Array,         # (A, W) int32 per-row block tables, sentinel-tailed
    req_ids: jax.Array,        # (A,) int32
    base_key: jax.Array,       # (2,) uint32 session key
    *,
    sampling: SamplingConfig,
    block_size: int,
):
    """Chunked prefill: teacher-force one chunk of each row's prompt into
    the paged pool at positions ``[starts, starts + chunk_lens)``, reading
    the already-written prefix through the block table (see
    ``paged_chunk_prefill_step`` — bit-identical to the fused one-shot
    prefill by construction).  Padding rows carry all-sentinel tables, so
    their writes are dropped like ``_admit_fused_paged``'s; no ``valid``
    mask is needed and 1..A chunks share the program (compiled once per
    (admit width, chunk bucket) — the same ``{1,2,4,...} x buckets``
    program set as the one-shot path, so chunking adds no shapes).

    ``tok0s`` is each row's first sampled token *assuming this is its final
    chunk*: the key folds in ``starts + chunk_lens``, which equals the
    effective prompt length exactly when the chunk completes the prompt —
    the same positional key the one-shot path folds — and is garbage the
    host ignores for non-final chunks."""
    logits, cache = paged_chunk_prefill_step(
        cfg, params, cache, {"tokens": tokens}, starts, tables,
        block_size=block_size,
    )
    last = jnp.take_along_axis(
        logits, (chunk_lens - 1)[:, None, None], axis=1
    )[:, 0, :]
    cache = _pin_pool(cache)
    req_keys = _request_keys(base_key, req_ids)
    tok0s = _sh_constrain(
        _first_tokens(last, req_keys, starts + chunk_lens, sampling), (None,)
    )
    return cache, tok0s, _sh_constrain(req_keys, (None, None))


_prefill_chunk_jit = _LazyJit(lambda: jax.jit(
    _prefill_chunk, static_argnames=("cfg", "sampling", "block_size"),
    donate_argnames=_resolve_cache_donation(),
))


def _evict(cache, slot: jax.Array):
    return C.evict_slot(cache, slot)


_evict_jit = _LazyJit(lambda: jax.jit(
    _evict, donate_argnames=_resolve_cache_donation(),
))


def _copy_block(cache, src: jax.Array, dst: jax.Array):
    """Copy-on-write fork (see ``cache.copy_block``): src/dst are traced, so
    one compiled program forks any block pair; warmed by ``warmup()`` when
    prefix sharing is on so the first real fork never compiles.  The copy is
    head-local under TP (each shard copies its own Hkv/tp slice), so the
    pool pin adds no traffic."""
    return _pin_pool(C.copy_block(cache, src, dst))


_copy_block_jit = _LazyJit(lambda: jax.jit(
    _copy_block, donate_argnames=_resolve_cache_donation(),
))


def _admit_merge(
    last_token: jax.Array,     # (N,) int32 device-resident decode carry
    slot_keys: jax.Array,      # (N, 2) uint32 per-request PRNG keys
    slots: jax.Array,          # (A,) int32 — distinct slot ids
    tok0s: jax.Array,          # (A,) int32 first sampled tokens (admit output)
    keys: jax.Array,           # (A, 2) uint32 per-request keys (admit output)
    valid: jax.Array,          # (A,) bool — rows actually admitted
):
    """Async loop: merge an admission batch's first tokens and PRNG keys into
    the device-resident decode carry (see ``cache.merge_admit_carry``).
    ``tok0s``/``keys`` are usually still in-flight futures of an admit
    program — composing here instead of on the host is what keeps the
    pipeline free of syncs between dispatches."""
    lt, sk = C.merge_admit_carry(last_token, slot_keys, slots, tok0s, keys, valid)
    return _sh_constrain(lt, (None,)), _sh_constrain(sk, (None, None))


_admit_merge_jit = _LazyJit(lambda: jax.jit(_admit_merge))


def _spec_merge_len(
    cur_len: jax.Array,        # (N,) int32 device-resident length carry
    slots: jax.Array,          # (A,) int32 — distinct slot ids
    lens: jax.Array,           # (A,) int32 admitted prompt lengths
    valid: jax.Array,          # (A,) bool — rows actually admitted
):
    """Async speculative loop: merge an admission batch's prompt lengths
    into the device-resident ``cur_len`` carry (see ``cache.merge_spec_len``
    — spec rows advance by data-dependent accepted counts, so the async
    loop keeps ``cur_len`` on device next to the token carry)."""
    return _sh_constrain(C.merge_spec_len(cur_len, slots, lens, valid), (None,))


_spec_merge_len_jit = _LazyJit(lambda: jax.jit(_spec_merge_len))

# TP placement normalizers (warmup only): pass session state through tiny
# jitted pins so every program's warmup operands carry exactly the sharding
# representation their serving-time operands will have — outputs of GSPMD
# programs under the mesh — instead of the ctor's device_put shardings.
# Without this, the FIRST program compiled against each state piece would
# key on the device_put sharding and recompile once at its first real
# dispatch.
_pin_carry_jit = _LazyJit(
    lambda: jax.jit(lambda x: _sh_constrain(x, (None,) * x.ndim))
)
_pin_pool_jit = _LazyJit(lambda: jax.jit(_pin_pool))


def _jit_cache_size(fn) -> int:
    """Compiled-program count of a jitted callable. ``_cache_size`` is a
    private jax attribute (stable across 0.4.x); fall back to a sentinel
    rather than crash serving if a jax upgrade drops it — the
    zero-recompile tests compare these values, so a sentinel keeps the
    deltas zero and surfaces the API break via the recorded -1."""
    get = getattr(fn, "_cache_size", None)
    return int(get()) if callable(get) else -1


def scheduler_compile_stats() -> Dict[str, int]:
    """Compiled-program counts of the scheduler's jit entry points.  A trace
    that triggers zero recompiles leaves every count unchanged."""
    return {
        "decode_tick": _jit_cache_size(_decode_tick_jit),
        "spec_tick": _jit_cache_size(_spec_tick_jit),
        "spec_merge_len": _jit_cache_size(_spec_merge_len_jit),
        "admit_fused": _jit_cache_size(_admit_fused_jit),
        "admit_decode": _jit_cache_size(_admit_decode_jit),
        "admit_paged": _jit_cache_size(_admit_fused_paged_jit),
        "prefill_chunk": _jit_cache_size(_prefill_chunk_jit),
        "admit_merge": _jit_cache_size(_admit_merge_jit),
        "evict": _jit_cache_size(_evict_jit),
        "copy_block": _jit_cache_size(_copy_block_jit),
    }


# ---------------------------------------------------------------------------
# Requests / results / stats
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class Request:
    """One generation request. ``arrival`` is in scheduler ticks (one decode
    step == one tick); ``priority`` orders admission (lower first, FIFO
    within a class); ``tier`` names the requested quality-ladder rung
    (``None`` = the session's best rung; tier sessions only)."""

    req_id: int
    prompt: np.ndarray          # (S0,) int32
    max_new: int
    priority: int = 0
    arrival: int = 0
    tier: Optional[str] = None


@dataclasses.dataclass(frozen=True)
class CompletedRequest:
    req_id: int
    prompt: np.ndarray
    tokens: np.ndarray          # generated tokens (first token included)
    finish_reason: str          # "eos" | "length"
    admitted_tick: int
    finished_tick: int
    # quality tiers: the EFFECTIVE rung the request was served at (requested
    # rung, possibly demoted by the load shedder); "" when tiers are off
    tier: str = ""
    # time-to-first-token in scheduler ticks since arrival (the same sample
    # appended to SchedulerStats.ttft_ticks — kept per-request here so
    # benches can split TTFT by request class); -1 if never recorded
    ttft: int = -1

    @property
    def full_sequence(self) -> np.ndarray:
        return np.concatenate([self.prompt, self.tokens])


@dataclasses.dataclass
class SchedulerStats:
    """Serve-session counters and gauges.

    Every field and derived property is documented in :data:`DOCS` (one line
    per metric, asserted complete by ``tests/test_docs.py``) so the metric
    names the serve benchmarks emit into their ``BENCH_*.json`` artifacts
    are self-describing — benches embed ``SchedulerStats.DOCS`` under a
    ``"field_docs"`` key.

    Two clocks appear below.  *Scheduler ticks* (``ticks``, the latency
    lists) count executed decode steps only — one decode step across all
    slots == one tick, admission is free — and are the unit of ``Request
    .arrival``.  *Work ticks* (``work_ticks``, ``max_decode_gap_ticks``)
    additionally charge each admission its prefill cost, normalized to
    decode widths (``ceil(bucketed prompt tokens / num_slots)``), so they
    approximate device occupancy and make prefill-induced decode starvation
    measurable deterministically (no wall-clock flakiness)."""

    DOCS: ClassVar[Dict[str, str]] = {
        "ticks": "decode ticks executed (1 tick = one decode step across "
                 "all slots; steps_per_tick of them per decode chunk)",
        "busy_slot_steps": "slot-steps that produced an accepted token "
                           "(sum over chunks of accepted tokens)",
        "idle_slot_steps": "slot-steps wasted: empty slots, mid-chunk "
                           "overshoot, and async garbage chunks "
                           "(ticks * num_slots - busy_slot_steps)",
        "admitted": "requests admitted (prefilled into a slot)",
        "completed": "requests finished (eos or length)",
        "generated_tokens": "tokens accepted across all requests, "
                            "including each request's admit-time first token",
        "admit_calls": "batched prefill dispatches (one per admission "
                       "batch, covering 1..num_slots requests)",
        "prefills": "prompt-bucket size -> prefill dispatches charged at "
                    "that bucket (each request's OWN effective-prompt "
                    "bucket — replayed preemption victims count at their "
                    "longer replay bucket — not the admit batch's padding "
                    "bucket; under chunked prefill every CHUNK counts at "
                    "its own chunk bucket, so one long request contributes "
                    "several entries)",
        "peak_active": "max concurrently-resident requests",
        "peak_blocks_in_use": "paged layout: max KV pool blocks held at "
                              "once",
        "ttft_ticks": "per-request time-to-first-token in scheduler ticks "
                      "since the request's arrival (queue wait + prefill), "
                      "appended at admit — under chunked prefill, at the "
                      "FINAL chunk's dispatch, when the first token is "
                      "actually sampled",
        "latency_ticks": "per-request total latency in scheduler ticks "
                         "since arrival, appended at finish",
        "prefill_tokens": "bucketed prompt tokens admitted (the device "
                          "prefill work the interleaving budget meters; "
                          "excludes admit-width padding rows)",
        "work_ticks": "device-work clock: decode steps + prefill charged "
                      "at bucketed tokens / num_slots, integerized "
                      "through a carry so rounding never compounds",
        "prefill_stall_ticks": "scheduler steps where the interleaving "
                               "budget deferred an otherwise-admissible "
                               "request (slots and memory both fit)",
        "max_decode_gap_ticks": "starvation gauge: worst work-tick gap "
                                "between a resident request's consecutive "
                                "accepted tokens (<= steps_per_tick + "
                                "ceil(prefill_decode_ratio * "
                                "steps_per_tick) under the ratio policy; "
                                "chunked prefill tightens the per-item "
                                "budget overshoot from one prompt bucket "
                                "to one chunk — docs/serving.md)",
        "host_block_s": "wall seconds the host spent blocked on device "
                        "token transfers (np.asarray of chunk outputs)",
        "wall_s": "wall seconds spent inside step() in total",
        "slot_utilization": "busy_slot_steps / (busy + idle): fraction of "
                            "decode capacity that produced accepted tokens",
        "ttft_p50": "median time-to-first-token, scheduler ticks",
        "ttft_p95": "95th-percentile time-to-first-token, scheduler ticks",
        "latency_p50": "median request latency, scheduler ticks",
        "latency_p95": "95th-percentile request latency, scheduler ticks",
        "overlap_fraction": "1 - host_block_s / wall_s: fraction of step() "
                            "wall time NOT spent blocked on the device — "
                            "the async loop's pipelining win (sync loop "
                            "reports its serial block share for contrast); "
                            "clamped to [0, 1] because the two timers nest "
                            "imperfectly (a block timed inside a step can "
                            "skew the raw ratio past either end)",
        "prefix_hit_blocks": "prefix sharing: prompt blocks admitted by "
                             "pointing the block table at an already-"
                             "resident shared block instead of acquiring "
                             "and prefill-writing a new one",
        "cow_forks": "prefix sharing: copy-on-write forks — a request "
                     "about to write into a block it shares acquired a "
                     "private copy via copy_block first",
        "preemptions": "preemption: resident requests evicted to free "
                       "blocks for another row's append/fork; the victim "
                       "re-enters the ready queue and replays from its "
                       "accepted tokens (bit-identical under the "
                       "positional key schedule)",
        "attn_impl": "paged decode-attention implementation the session's "
                     "decode program compiled: 'gather' (XLA block gather, "
                     "the oracle) or 'pallas' (in-place block-pool kernel)",
        "draft_tokens": "speculative decoding: tokens proposed by the "
                        "approximate draft path (draft_k per live row per "
                        "verify)",
        "accepted_tokens": "speculative decoding: drafted tokens the exact "
                           "verifier accepted — excludes the correction "
                           "token every verify emits, so accepted == "
                           "drafted means a perfect draft",
        "verify_calls": "speculative decoding: per-row exact verify "
                        "passes (one per live row per spec tick)",
        "accept_rate": "speculative decoding: accepted_tokens / "
                       "draft_tokens — the live end-to-end readout of the "
                       "draft multiplier's error rate (0.0 when spec "
                       "decode is off)",
        "tp": "tensor-parallel degree: size of the session mesh's "
              "'model' axis (1 for single-device serving)",
        "devices": "devices the session mesh spans (1 off-mesh)",
        "peak_block_bytes_per_device": "paged layout: KV pool bytes "
                                       "resident on EACH device for the "
                                       "peak_blocks_in_use blocks — the "
                                       "pool shards along the KV-head dim "
                                       "under TP, so this scales as 1/tp "
                                       "at equal block counts",
        "draft_k_current": "speculative decoding: the draft window the "
                           "NEXT spec tick will dispatch — equals the "
                           "configured draft_k unless dynamic_draft_k "
                           "shrank/regrew it on the rolling accept rate",
        "draft_k_shrinks": "speculative decoding: times dynamic_draft_k "
                           "halved the draft window (rolling accept rate "
                           "below break-even 1/draft_cost_ratio)",
        "draft_k_grows": "speculative decoding: times dynamic_draft_k "
                         "re-grew the draft window (rolling accept rate "
                         "back at/above break-even)",
        "tier_demotions": "quality tiers: times the load shedder raised "
                          "the shed level (new admissions demoted one rung "
                          "further down the tier ladder)",
        "tier_restorations": "quality tiers: times the shedder lowered the "
                             "shed level after shed_hold_steps consecutive "
                             "healthy steps (the hysteresis window clears "
                             "on every level change)",
        "shed_level": "quality tiers: current shed level — new admissions "
                      "serve at ladder rung max(requested, shed_level); "
                      "0 = no shedding in effect",
        "active_per_tier": "quality tiers: currently-resident requests per "
                           "EFFECTIVE ladder rung (the rung each request "
                           "was admitted at, post-shedding); empty when "
                           "tiers are off",
        "prefill_chunks": "chunked prefill: partial-prompt chunk rows "
                          "dispatched (each long request contributes "
                          "ceil(effective_prompt / prefill_chunk) rows; 0 "
                          "when chunking is off or every prompt fits one "
                          "chunk)",
    }

    ticks: int = 0
    busy_slot_steps: int = 0
    idle_slot_steps: int = 0
    admitted: int = 0
    completed: int = 0
    generated_tokens: int = 0
    admit_calls: int = 0
    prefills: Dict[int, int] = dataclasses.field(default_factory=dict)
    peak_active: int = 0
    peak_blocks_in_use: int = 0
    ttft_ticks: List[int] = dataclasses.field(default_factory=list)
    latency_ticks: List[int] = dataclasses.field(default_factory=list)
    prefill_tokens: int = 0
    work_ticks: int = 0
    prefill_stall_ticks: int = 0
    max_decode_gap_ticks: int = 0
    host_block_s: float = 0.0
    wall_s: float = 0.0
    prefix_hit_blocks: int = 0
    cow_forks: int = 0
    preemptions: int = 0
    attn_impl: str = "gather"
    draft_tokens: int = 0
    accepted_tokens: int = 0
    verify_calls: int = 0
    tp: int = 1
    devices: int = 1
    peak_block_bytes_per_device: int = 0
    draft_k_current: int = 0
    draft_k_shrinks: int = 0
    draft_k_grows: int = 0
    tier_demotions: int = 0
    tier_restorations: int = 0
    shed_level: int = 0
    active_per_tier: Dict[str, int] = dataclasses.field(default_factory=dict)
    prefill_chunks: int = 0

    @property
    def accept_rate(self) -> float:
        if not self.draft_tokens:
            return 0.0
        return self.accepted_tokens / self.draft_tokens

    @property
    def slot_utilization(self) -> float:
        cap = self.busy_slot_steps + self.idle_slot_steps
        return self.busy_slot_steps / cap if cap else 0.0

    @property
    def overlap_fraction(self) -> float:
        if not self.wall_s:
            return 0.0
        return min(1.0, max(0.0, 1.0 - self.host_block_s / self.wall_s))

    @staticmethod
    def _pct(xs: List[int], q: float) -> float:
        return float(np.percentile(np.asarray(xs), q)) if xs else 0.0

    # time-to-first-token (queue wait + prefill) and total latency, both in
    # ticks relative to the request's arrival tick
    @property
    def ttft_p50(self) -> float:
        return self._pct(self.ttft_ticks, 50)

    @property
    def ttft_p95(self) -> float:
        return self._pct(self.ttft_ticks, 95)

    @property
    def latency_p50(self) -> float:
        return self._pct(self.latency_ticks, 50)

    @property
    def latency_p95(self) -> float:
        return self._pct(self.latency_ticks, 95)


@dataclasses.dataclass
class _ActiveSlot:
    req: Request
    slot: int
    tokens: List[int]
    admitted_tick: int
    # set by _finish; the async loop uses it to skip chunk tokens of rows
    # whose completion was discovered after their last chunk was dispatched
    done: bool = False
    # slot/blocks already freed (predictive early turnover — the async loop
    # releases a row whose in-flight chunk provably completes it by length,
    # so a successor can refill the slot before the harvest)
    released: bool = False
    # evicted mid-decode to free blocks for another row; the request is back
    # in the ready queue and will replay from its accepted tokens — every
    # token this state still has in flight is discarded (replay regenerates
    # it bit-identically under the positional key schedule)
    preempted: bool = False
    # async loop: admit-time first token dispatched but not yet harvested
    # (re-admitted rows have non-empty `tokens` while it is still pending,
    # so emptiness can no longer stand in for this)
    pending_first: bool = False
    # quality tiers: the effective ladder rung this request decodes under,
    # frozen at admission (preemption replays re-admit at the same rung so
    # the replay stays bit-identical)
    tier_idx: int = 0
    # chunked prefill: the resident-but-still-prefilling cursor.  A chunked
    # row holds its slot and grows its block table chunk by chunk;
    # `prefill_pos` counts effective-prompt tokens already dispatched and
    # `eff_prompt` caches the effective prompt (prompt + replayed accepted
    # tokens).  One-shot admits leave both at 0/None, so `prefilling` is
    # False for every non-chunked row.
    prefill_pos: int = 0
    prefill_len: int = 0
    eff_prompt: Optional[np.ndarray] = None
    # per-request TTFT sample (ticks since arrival), -1 until the first
    # token is dispatched — survives preemption via the resume snapshot so
    # each request is sampled exactly once
    ttft: int = -1

    @property
    def prefilling(self) -> bool:
        return self.prefill_pos < self.prefill_len


@dataclasses.dataclass
class _Inflight:
    """One dispatched-but-unharvested decode chunk (async loop).

    ``states`` snapshots ``self._active`` at dispatch time: only those rows
    may accept this chunk's tokens (rows admitted later first appear in the
    *next* chunk).  ``work_end`` is the work-tick clock just after this
    chunk's steps were charged — the emission time used by the starvation
    gauge."""

    toks: Any                  # (steps, N) device future; quality tiers: a
                               # tuple of per-rung futures (disjoint row
                               # masks — merged by elementwise sum at harvest)
    steps: int
    states: List[Optional[_ActiveSlot]]
    work_end: int
    # speculative chunks only: (N,) device future of per-row accepted
    # counts (the chunk's rows advanced unevenly — see _spec_tick)
    n_acc: Any = None
    # speculative chunks only: the draft window THIS chunk was dispatched
    # with (dynamic_draft_k may change _draft_k_eff before the harvest)
    draft_k: int = 0


# ---------------------------------------------------------------------------
# ServeSession
# ---------------------------------------------------------------------------


class ServeSession:
    """Continuous-batching serving over a slot pool (see module docstring).

    >>> sess = ServeSession(cfg, params, num_slots=8, max_len=256)
    >>> sess.submit(prompt_ids, max_new=64)
    >>> results = sess.run()          # {req_id: CompletedRequest}

    ``cache_layout="paged"`` swaps the per-slot ``max_len`` KV stripes for a
    global ``BlockPool`` of ``num_blocks`` blocks of ``block_size`` KV rows:
    ``num_slots`` then bounds decode *width* only, and memory admission is
    governed by each request's worst-case block reservation.  The default
    ``num_blocks`` matches the slot layout's HBM exactly
    (``num_slots * max_len / block_size``); raise ``num_slots`` (or lower
    ``num_blocks``) to oversubscribe.  ``policy`` orders the ready queue:
    ``"priority"`` (the ``Request.priority`` classes, FIFO within a class —
    the default, and plain FIFO when priorities are untouched), ``"fifo"``
    (ignore priorities), or ``"sjf"`` — shortest job first on
    ``max_new + bucketed prompt len``, which minimizes mean latency on a
    drain tail.

    ``loop="async"`` (default) runs the double-buffered pipeline —
    ``step()`` dispatches the next decode chunk before blocking on the
    previous one's tokens, keeping the decode carry device-resident; pass
    ``loop="sync"`` for the PR-3 strictly-alternating loop (the parity
    baseline ``benchmarks/serve_async.py`` measures against).
    ``attn_impl`` selects the paged decode-attention path: ``"gather"``
    (XLA clamp-gather-mask, the exact oracle) or ``"pallas"`` (the
    ``kernels.paged_attention`` in-place block-pool kernel; interpret mode
    off-TPU).  ``prefill_decode_ratio`` / ``prefill_token_budget`` bound the bucketed
    prompt tokens each ``step()`` may admit while decodes are resident
    (``ratio * n_active * steps_per_tick`` resp. a flat budget), so a burst
    of long prompts spreads over several steps instead of stalling every
    resident decode behind one giant prefill train.

    ``spec_decode=True`` turns each work tick into SELF-speculative
    decoding (paged layout, ``steps_per_tick=1`` only): ``draft_k`` decode
    steps through the approximate draft path (``draft_mode`` x
    ``draft_multiplier`` — the same weights with only ``cfg.approx``
    swapped, see ``engine.draft_config``), then one exact verify pass that
    accepts each row's longest matching draft prefix plus a correction
    token.  Accepted outputs are bit-identical to the non-speculative
    session under float execution BY CONSTRUCTION (see ``_spec_tick``), so
    ``stats.accept_rate`` is a pure throughput readout of the draft
    multiplier's error rate — the paper's claim, measured end-to-end.
    Rows advance unevenly (1..draft_k+1 tokens per tick), which is why the
    async loop keeps a device-resident length carry next to the token
    carry.  ``close()`` flushes the in-flight chunk and seals the session:
    later ``submit``/``step`` raise ``RuntimeError``.

    ``tiers=("exact", "approx", "approx_msr")`` turns on per-request
    quality-tier routing (attention families; mutually exclusive with
    ``spec_decode``): each rung gets its own compiled decode/prefill
    programs (the session cfg with only ``cfg.approx`` swapped —
    ``tier_multiplier`` names the approximate design, MSR rungs default to
    ``mul8x8_msr4``), ``submit(..., tier=...)`` picks a request's rung, and
    every step dispatches one decode chunk per rung holding active rows.
    ``warmup()`` compiles the full rung x width x bucket program set, so no
    tier mix recompiles.  ``shed_queue_depth`` / ``shed_gap_ticks`` arm the
    load shedder: breaches demote NEW admissions one rung down the ladder
    (resident requests never switch rungs — a request's output is
    bit-identical to a single-mode oracle of its effective rung), and
    recovery restores one rung after ``shed_hold_steps`` consecutive steps
    below ``shed_restore_fraction`` of the thresholds."""

    def __init__(
        self,
        cfg: ModelConfig,
        params,
        *,
        num_slots: int = 4,
        max_len: int = 256,
        prompt_buckets: Sequence[int] = (8, 16, 32, 64),
        sampling: Optional[SamplingConfig] = None,
        cache_dtype=jnp.float32,
        seed: int = 0,
        zero_on_evict: bool = False,
        steps_per_tick: int = 1,
        cache_layout: str = "slots",
        block_size: int = 16,
        num_blocks: Optional[int] = None,
        policy: str = "priority",
        loop: str = "async",
        prefill_decode_ratio: Optional[float] = None,
        prefill_token_budget: Optional[int] = None,
        chunked_prefill: bool = False,
        prefill_chunk: Optional[int] = None,
        attn_impl: str = "gather",
        pad_id: int = 0,
        prefix_sharing: bool = False,
        preemption: bool = False,
        spec_decode: bool = False,
        draft_k: int = 4,
        draft_mode: str = "approx",
        draft_multiplier: str = "mul8x8_2",
        dynamic_draft_k: bool = False,
        draft_cost_ratio: float = 4.0,
        draft_window: int = 32,
        tiers: Optional[Sequence[str]] = None,
        tier_multiplier: str = "mul8x8_2",
        shed_queue_depth: Optional[int] = None,
        shed_gap_ticks: Optional[int] = None,
        shed_hold_steps: int = 8,
        shed_restore_fraction: float = 0.5,
        mesh=None,
        tp_axis: str = "model",
    ):
        if not cfg.embed_input:
            raise ValueError(f"{cfg.name}: token serving requires an embed-input arch")
        if cache_layout not in CACHE_LAYOUTS:
            raise ValueError(f"cache_layout {cache_layout!r} not in {CACHE_LAYOUTS}")
        if policy not in ADMISSION_POLICIES:
            raise ValueError(f"policy {policy!r} not in {ADMISSION_POLICIES}")
        if loop not in SERVE_LOOPS:
            raise ValueError(f"loop {loop!r} not in {SERVE_LOOPS}")
        if attn_impl not in ATTN_IMPLS:
            raise ValueError(f"attn_impl {attn_impl!r} not in {ATTN_IMPLS}")
        if attn_impl != "gather" and cache_layout != "paged":
            raise ValueError(
                f"attn_impl {attn_impl!r} requires cache_layout='paged' — "
                "the slot layout has no block table to walk"
            )
        if prefill_decode_ratio is not None and prefill_token_budget is not None:
            raise ValueError(
                "prefill_decode_ratio and prefill_token_budget are alternative "
                "interleaving policies — set at most one"
            )
        if prefill_decode_ratio is not None and prefill_decode_ratio <= 0:
            raise ValueError(
                f"prefill_decode_ratio must be > 0, got {prefill_decode_ratio}"
            )
        if prefill_token_budget is not None and prefill_token_budget < 1:
            raise ValueError(
                f"prefill_token_budget must be >= 1, got {prefill_token_budget}"
            )
        if (prefix_sharing or preemption) and cache_layout != "paged":
            raise ValueError(
                "prefix_sharing/preemption operate on the shared BlockPool — "
                'they require cache_layout="paged"'
            )
        if spec_decode:
            if cache_layout != "paged":
                raise ValueError(
                    "spec_decode verifies drafted positions against the "
                    'block pool — it requires cache_layout="paged"'
                )
            if steps_per_tick != 1:
                raise ValueError(
                    "spec_decode replaces the decode chunk with draft_k "
                    "drafts + one verify per tick — steps_per_tick must "
                    f"stay 1, got {steps_per_tick}"
                )
            if draft_k < 1:
                raise ValueError(f"draft_k must be >= 1, got {draft_k}")
            if cfg.family == "moe":
                raise ValueError(
                    "spec_decode requires a dense attention family: moe "
                    "routing is capacity-coupled across the token batch, "
                    "so a batched verify would route differently than "
                    "sequential decode and lose the exactness contract"
                )
        if dynamic_draft_k:
            if not spec_decode:
                raise ValueError("dynamic_draft_k requires spec_decode=True")
            if draft_cost_ratio <= 1.0:
                raise ValueError(
                    "draft_cost_ratio is verify-work / draft-step-work and "
                    f"must be > 1 (break-even accept rate is its inverse), "
                    f"got {draft_cost_ratio}"
                )
            if draft_window < 1:
                raise ValueError(f"draft_window must be >= 1, got {draft_window}")
        if tiers is not None:
            tiers = tuple(tiers)
            if not tiers:
                raise ValueError("tiers must name at least one execution mode")
            if len(set(tiers)) != len(tiers):
                raise ValueError(f"tiers contains duplicate rungs: {tiers}")
            for t in tiers:
                if t not in EXECUTION_MODES:
                    raise ValueError(
                        f"tier {t!r} not in execution modes {EXECUTION_MODES}"
                    )
            if spec_decode:
                raise ValueError(
                    "tiers and spec_decode both repurpose the per-dispatch "
                    "cfg.approx execution routing — set at most one"
                )
            if cfg.family in ("ssm", "hybrid"):
                raise ValueError(
                    "quality tiers dispatch one decode program per rung and "
                    "rely on positional KV writes to keep the other rungs' "
                    f"rows untouched — {cfg.family} carries non-positional "
                    "conv/ssm state, so tier serving requires an attention "
                    "family"
                )
        shed_on = shed_queue_depth is not None or shed_gap_ticks is not None
        if shed_on:
            if tiers is None or len(tiers) < 2:
                raise ValueError(
                    "load shedding demotes admissions down the quality "
                    "ladder — it requires tiers with >= 2 rungs"
                )
            if shed_queue_depth is not None and shed_queue_depth < 1:
                raise ValueError(
                    f"shed_queue_depth must be >= 1, got {shed_queue_depth}"
                )
            if shed_gap_ticks is not None and shed_gap_ticks < 1:
                raise ValueError(
                    f"shed_gap_ticks must be >= 1, got {shed_gap_ticks}"
                )
            if shed_hold_steps < 1:
                raise ValueError(
                    f"shed_hold_steps must be >= 1, got {shed_hold_steps}"
                )
            if not 0.0 < shed_restore_fraction <= 1.0:
                raise ValueError(
                    "shed_restore_fraction must be in (0, 1], got "
                    f"{shed_restore_fraction}"
                )
        if mesh is not None:
            if tp_axis != "model":
                raise ValueError(
                    f"tp_axis must be 'model' (param_pspec/cache_pspecs key "
                    f"their TP rules on it), got {tp_axis!r}"
                )
            if tp_axis not in mesh.axis_names:
                raise ValueError(
                    f"mesh has no {tp_axis!r} axis (axes: {mesh.axis_names})"
                )
            if cache_layout != "paged":
                raise ValueError(
                    "mesh serving shards the paged BlockPool along the "
                    'KV-head dim — it requires cache_layout="paged"'
                )
        self.cfg = cfg
        self.params = params
        self.sampling = sampling if sampling is not None else SamplingConfig()
        self.max_len = int(max_len)
        self.layout = cache_layout
        self.policy = policy
        self.loop = loop
        self.attn_impl = attn_impl
        self.pad_id = int(pad_id)
        self.prefix_sharing = bool(prefix_sharing)
        self.preempt = bool(preemption)
        self.prefill_decode_ratio = prefill_decode_ratio
        self.prefill_token_budget = prefill_token_budget
        self.spec = bool(spec_decode)
        self.draft_k = int(draft_k)
        self.dynamic_draft = bool(dynamic_draft_k)
        self.draft_cost_ratio = float(draft_cost_ratio)
        self.draft_window = int(draft_window)
        # halving ladder draft_k -> 1: the rungs dynamic_draft_k may visit.
        # draft_k is a STATIC jit arg, so warmup() compiles every rung and
        # adaptation never compiles mid-trace.
        ks: List[int] = []
        k = max(1, self.draft_k)
        while True:
            ks.append(k)
            if k == 1:
                break
            k //= 2
        self._draft_ks: Tuple[int, ...] = tuple(ks)
        self._draft_k_eff = self.draft_k
        # rolling (drafted, accepted) pairs over the last draft_window live
        # rows; cleared on every rung change so each rung re-measures a full
        # window before the next decision
        self._accept_hist: deque = deque(maxlen=self.draft_window)
        self.draft_mode = draft_mode if self.spec else None
        # the draft model IS the session model with only cfg.approx swapped
        # (shared weights; one extra compiled decode program) — see
        # engine.draft_config
        self.draft_cfg = (
            draft_config(cfg, draft_mode, draft_multiplier) if self.spec
            else None
        )
        # -- quality tiers ----------------------------------------------------
        # One ModelConfig per ladder rung: the session cfg with only `approx`
        # swapped (the draft_config pattern — shared weights, one compiled
        # decode program per rung).  act_per_row=True makes each batch row's
        # quantized math independent of its neighbours, which is what makes
        # a mixed-tier batch bit-identical per request to a single-mode
        # oracle session of its rung.
        self.tiers: Optional[Tuple[str, ...]] = tiers
        self.tier_multiplier = tier_multiplier
        self._tier_cfgs: Tuple[ModelConfig, ...] = (
            tuple(
                dataclasses.replace(
                    cfg,
                    approx=resolve_execution_mode(
                        t, tier_multiplier, act_per_row=True
                    ),
                )
                for t in tiers
            )
            if tiers is not None else ()
        )
        self._shed_on = shed_on
        self.shed_queue_depth = shed_queue_depth
        self.shed_gap_ticks = shed_gap_ticks
        self.shed_hold_steps = int(shed_hold_steps)
        self.shed_restore_fraction = float(shed_restore_fraction)
        self._shed_level = 0
        # consecutive healthy steps toward a restore; cleared on every shed-
        # level change and on every unhealthy step (the hysteresis window)
        self._shed_ok_steps = 0
        self._tier_active_counts: List[int] = [0] * (len(tiers) if tiers else 0)
        self.buckets = C.PromptBuckets(prompt_buckets)
        if self.buckets.max_size > self.max_len:
            raise ValueError(
                f"largest prompt bucket {self.buckets.max_size} > max_len {self.max_len}"
            )
        # -- chunked prefill --------------------------------------------------
        # Split one prompt's prefill into prefill_chunk-wide chunks dispatched
        # across successive scheduler steps (resumed through _prefilling), so
        # a long prompt never monopolizes a tick and the interleaving budget
        # meters chunks, not whole buckets.  v1 composes with preemption (a
        # replayed victim's long recompute is itself chunked) but not with
        # the features below — each gated with its reason.
        if prefill_chunk is not None and not chunked_prefill:
            raise ValueError("prefill_chunk requires chunked_prefill=True")
        if chunked_prefill:
            if cache_layout != "paged":
                raise ValueError(
                    "chunked prefill resumes a partially-written block table "
                    'across ticks — it requires cache_layout="paged" (the '
                    "slot layout has no sentinel-tailed table to grow)"
                )
            if cfg.family == "moe":
                raise ValueError(
                    "chunked prefill teacher-forces chunk tokens through a "
                    "batched pass; moe routing is capacity-coupled across "
                    "the token batch, so chunks would route differently "
                    "than the fused prefill oracle and lose the exactness "
                    "contract"
                )
            if spec_decode:
                raise ValueError(
                    "chunked prefill and spec_decode both repurpose the "
                    "multi-position verify pass with different per-tick "
                    "schedules — composing them is a ROADMAP follow-on; "
                    "set at most one"
                )
            if tiers is not None:
                raise ValueError(
                    "chunked prefill dispatches chunk batches outside the "
                    "per-rung admit grouping — composing it with quality "
                    "tiers is a ROADMAP follow-on"
                )
            if prefix_sharing:
                raise ValueError(
                    "prefix sharing publishes prompt blocks at admission, "
                    "but a chunk-prefilled block is written ticks after its "
                    "table entry exists — a sharer could map it before its "
                    "K/V lands; publish-at-chunk-boundary is a ROADMAP "
                    "follow-on"
                )
            if prefill_chunk is None:
                prefill_chunk = self.buckets.max_size
            if prefill_chunk not in self.buckets.sizes:
                raise ValueError(
                    f"prefill_chunk {prefill_chunk} must be one of the "
                    f"prompt buckets {self.buckets.sizes} — chunk widths are "
                    "drawn from the bucket set so the compiled program set "
                    "stays (admit widths x buckets)"
                )
        self.chunked = bool(chunked_prefill)
        self.prefill_chunk = int(prefill_chunk) if chunked_prefill else 0
        self.pool = C.SlotPool(num_slots)
        self.num_slots = num_slots
        self.cache_dtype = jnp.dtype(cache_dtype).name
        self.zero_on_evict = zero_on_evict
        if steps_per_tick < 1:
            raise ValueError(f"steps_per_tick must be >= 1, got {steps_per_tick}")
        # decode-chunk size: dispatches amortize steps_per_tick-fold, rows
        # finishing mid-chunk waste <= steps_per_tick - 1 slot-steps each
        self.steps_per_tick = int(steps_per_tick)
        # SSM/hybrid caches carry conv/ssm state -> masked teacher-forced admit
        self.prefill_mode = "decode" if cfg.family in ("ssm", "hybrid") else "fused"

        if cache_layout == "paged":
            if cfg.family in ("ssm", "hybrid"):
                raise ValueError(
                    f"{cfg.family} decode state is O(1) per request (no KV "
                    "sequence axis) — there is nothing to page; use "
                    'cache_layout="slots"'
                )
            if zero_on_evict:
                raise ValueError(
                    "zero_on_evict applies to the slot layout only (freed "
                    "blocks are invisible until re-seeded by their next owner)"
                )
            if block_size < 1:
                raise ValueError(f"block_size must be >= 1, got {block_size}")
            if self.max_len % block_size:
                raise ValueError(
                    f"max_len {self.max_len} must be a multiple of "
                    f"block_size {block_size} (fixed-width block tables)"
                )
            self.block_size = int(block_size)
            self.table_width = self.max_len // self.block_size
            if num_blocks is None:
                num_blocks = num_slots * self.table_width    # == slot-layout HBM
            self.blocks = C.BlockPool(num_blocks)
            self.num_blocks = int(num_blocks)
            self.cache = init_paged_cache(
                cfg, self.num_blocks, self.block_size, jnp.dtype(cache_dtype)
            )
            # per-slot block table (sentinel == num_blocks -> writes dropped),
            # held physical blocks, and not-yet-held worst-case reservation
            self._tables = np.full(
                (num_slots, self.table_width), self.num_blocks, np.int32
            )
            self._held: List[List[int]] = [[] for _ in range(num_slots)]
            self._future = np.zeros((num_slots,), np.int64)
            self._reserved_total = 0           # future blocks across all rows
            # prefix sharing: content -> physical block; the scheduler takes
            # one pool ref per published block on the cache's behalf
            self._prefix = C.PrefixCache() if self.prefix_sharing else None
            # preemption: req_id -> (accepted tokens, original admit tick,
            # effective tier rung), consumed when the victim re-admits and
            # replays (at the SAME rung — the replay must be bit-identical)
            self._preempt_resume: Dict[int, Tuple[List[int], int, int]] = {}
        else:
            self._prefix = None
            self._preempt_resume = {}
            self.cache = init_cache(cfg, num_slots, self.max_len, jnp.dtype(cache_dtype))

        # -- tensor parallelism ----------------------------------------------
        # Shard params by the param_pspec rules (Megatron column/row split)
        # and the paged pool along the KV-head dim (cache_pspecs paged
        # layout); all program dispatches then run under `with mesh:` (see
        # _mesh_ctx) so constrain() sees the mesh at trace time.
        self.mesh = mesh
        self.tp_axis = tp_axis
        self.tp = int(mesh.shape[tp_axis]) if mesh is not None else 1
        if mesh is not None:
            from repro.parallel.sharding import cache_pspecs, param_shardings

            if attn_impl == "pallas":
                from repro.kernels.paged_attention import validate_tp_heads

                validate_tp_heads(cfg.num_heads, cfg.num_kv_heads, self.tp)
            self.params = jax.device_put(
                self.params, param_shardings(cfg, self.params, mesh)
            )
            self.cache = jax.device_put(
                self.cache, cache_pspecs(cfg, mesh, self.cache, layout="paged")
            )
        # per-device bytes of ONE pool block (0 for the slot layout): the
        # peak_block_bytes_per_device gauge and the bench's 1/tp KV-bytes
        # claim both read it
        self._block_bytes_dev = (
            C.pool_bytes_per_device(self.cache) // self.num_blocks
            if self.layout == "paged" else 0
        )

        self._last_token = np.zeros((num_slots,), np.int32)
        self._cur_len = np.zeros((num_slots,), np.int32)
        self._slot_keys = np.zeros((num_slots, 2), np.uint32)
        # quality tiers: each slot occupant's effective ladder rung (valid
        # only where a slot is occupied — per-rung dispatch masks on it)
        self._slot_tier = np.zeros((num_slots,), np.int32)
        self._base_key = jax.random.PRNGKey(seed)

        self._active: List[Optional[_ActiveSlot]] = [None] * num_slots
        # future arrivals: heap of (arrival, submit seq, req) — submit pushes
        # in O(log n) and _pull_arrivals pops in O(log n), replacing the
        # per-submit sort + O(n) list.pop(0) that made long traces O(n^2);
        # the seq tiebreak reproduces the old stable-sort admission order
        self._pending: List[Tuple[int, int, Request]] = []
        self._ready: List[Tuple[int, int, Request]] = []  # heap (policy key, seq)
        self._seq = 0
        self._next_id = 0
        self.clock = 0
        self.stats = SchedulerStats(
            attn_impl=attn_impl,
            tp=self.tp,
            devices=int(mesh.size) if mesh is not None else 1,
            draft_k_current=self.draft_k if self.spec else 0,
        )
        self._completed: Dict[int, CompletedRequest] = {}
        self._just_finished: List[int] = []     # drained by each step()
        # -- async pipeline state --------------------------------------------
        self._closed = False
        self._inflight: Optional[_Inflight] = None
        # device-resident decode carry: the async loop never fetches these,
        # it chains chunk outputs and admit merges into the next dispatch
        self._lt_dev: jax.Array = jnp.zeros((num_slots,), jnp.int32)
        self._sk_dev: jax.Array = jnp.zeros((num_slots, 2), jnp.uint32)
        # speculative async loop: rows advance by data-dependent accepted
        # counts, so cur_len joins the device carry (_cl_dev); the host
        # keeps _cur_len as a conservative UPPER bound (every live row
        # charged the full draft_k + 1 at dispatch, reconciled at harvest)
        # for block allocation, and _cl_true as the truth through the last
        # harvested chunk (the CoW guard's lower bound)
        self._cl_dev: jax.Array = jnp.zeros((num_slots,), jnp.int32)
        self._cl_true = np.zeros((num_slots,), np.int32)
        # admissions dispatched since the last harvest: their first sampled
        # tokens are fetched together with the next chunk's tokens
        self._pending_tok0: List[Tuple[List[_ActiveSlot], Any]] = []
        # work-tick of each slot occupant's latest accepted token (gauge)
        self._last_emit_work = np.zeros((num_slots,), np.int64)
        # prefill-token residue below one work tick (carried, not ceil'd)
        self._prefill_carry = 0
        # chunked prefill: resident rows whose prompt is still being written,
        # FIFO between the arrival heap and the decoding set — each step
        # resumes their next chunk (budget permitting) BEFORE admitting new
        # work, so in-flight prefills finish first and bound their own TTFT
        self._prefilling: List[_ActiveSlot] = []

    # -- queue ---------------------------------------------------------------

    def submit(
        self,
        prompt,
        max_new: int,
        *,
        req_id: Optional[int] = None,
        priority: int = 0,
        arrival: int = 0,
        tier: Optional[str] = None,
    ) -> int:
        """Queue one request; returns its id. ``arrival`` in ticks.
        ``tier`` names the requested quality-ladder rung (tier sessions
        only; ``None`` = the session's best rung) — the load shedder may
        still demote the EFFECTIVE rung at admission time.

        Every shape constraint is validated HERE, naming the request — a
        request that can never be admitted must fail at submit, not deep
        inside an admission tick.  A sealed session (after ``close()``)
        refuses loudly rather than queueing work that will never run."""
        prompt = np.asarray(prompt, np.int32).reshape(-1)
        rid = self._next_id if req_id is None else req_id
        if self._closed:
            raise RuntimeError(
                f"request {rid}: submitted after close() — the session is "
                "sealed and its pipeline flushed; create a new ServeSession"
            )
        if tier is not None:
            if self.tiers is None:
                raise ValueError(
                    f"request {rid}: tier={tier!r} on a session without a "
                    "quality ladder — construct ServeSession(tiers=(...))"
                )
            if tier not in self.tiers:
                raise ValueError(
                    f"request {rid}: tier {tier!r} not in session tiers "
                    f"{self.tiers}"
                )
        if prompt.size < 1:
            raise ValueError(f"request {rid}: empty prompt")
        if max_new < 1:
            raise ValueError(f"request {rid}: max_new must be >= 1, got {max_new}")
        if prompt.size > self.buckets.max_size and not self.chunked:
            raise ValueError(
                f"request {rid}: prompt_len {prompt.size} exceeds the largest "
                f"prompt bucket {self.buckets.max_size} (buckets "
                f"{self.buckets.sizes}) — split the prompt, widen the "
                "buckets, or enable chunked_prefill"
            )
        # strict `>`: the exact-fill boundary prompt_len + max_new == max_len
        # IS admissible — the last cache write lands at position
        # prompt_len + max_new - 2 <= max_len - 2 (the final token is
        # sampled, never written; see _worst_blocks) and decode's cur_len
        # clamp at max_len - 1 is never binding before the row finishes.
        # Pinned for both layouts by tests/test_scheduler.py
        # (test_exact_fill_boundary_admits_and_completes).
        if prompt.size > self.buckets.max_size:
            # chunked-only admission: no single bucket pads this prompt —
            # every chunk pads to its own chunk bucket and writes stay
            # within the prompt's blocks, so only the raw context binds
            if prompt.size + max_new > self.max_len:
                raise ValueError(
                    f"request {rid}: prompt_len {prompt.size} + max_new "
                    f"{max_new} exceeds cache max_len {self.max_len}"
                )
        else:
            bucket = self.buckets.bucket(prompt.size)
            if max(bucket, prompt.size + max_new) > self.max_len:
                raise ValueError(
                    f"request {rid}: prompt_len {prompt.size} + max_new "
                    f"{max_new} (bucket {bucket}) exceeds cache max_len "
                    f"{self.max_len}"
                )
        if self.layout == "paged":
            worst = self._worst_blocks(prompt.size, max_new)
            if worst > self.num_blocks:
                raise ValueError(
                    f"request {rid}: worst-case context needs {worst} blocks "
                    f"but the pool only has {self.num_blocks} — it could "
                    "never be admitted"
                )
            if (self.prefix_sharing and not self.preempt
                    and prompt.size % self.block_size
                    and worst + 1 > self.num_blocks):
                raise ValueError(
                    f"request {rid}: prefix sharing reserves {worst} + 1 "
                    "blocks (worst case + the partial tail's potential "
                    f"copy-on-write fork) but the pool only has "
                    f"{self.num_blocks} — it could never be admitted"
                )
            # with chunking the replay prompt needs no single bucket — its
            # chunks each pad to a chunk bucket, like any long prompt
            if (self.preempt and not self.chunked
                    and prompt.size + max_new - 1 > self.buckets.max_size):
                raise ValueError(
                    f"request {rid}: preemption replays prompt + accepted "
                    f"tokens through the bucketed prefill — its replay "
                    f"prompt can reach {prompt.size + max_new - 1} tokens, "
                    f"exceeding the largest prompt bucket "
                    f"{self.buckets.max_size}; widen the buckets or lower "
                    "max_new"
                )
        if req_id is None:
            req_id = rid
        elif (
            req_id in self._completed
            or any(r.req_id == req_id for _, _, r in self._pending)
            or any(r.req_id == req_id for _, _, r in self._ready)
            or any(s is not None and s.req.req_id == req_id for s in self._active)
        ):
            raise ValueError(f"req_id {req_id} already in use")
        self._next_id = max(self._next_id, req_id) + 1
        req = Request(req_id, prompt, int(max_new), int(priority), int(arrival),
                      tier)
        if req.arrival > self.clock:
            heapq.heappush(self._pending, (req.arrival, self._seq, req))
            self._seq += 1
        else:
            self._push_ready(req)
        return req_id

    def submit_all(self, requests: Sequence[Request]) -> None:
        for r in requests:
            self.submit(r.prompt, r.max_new, req_id=r.req_id,
                        priority=r.priority, arrival=r.arrival, tier=r.tier)

    def _ready_key(self, req: Request, eff_len: Optional[int] = None) -> int:
        """Admission-order key under the session policy (ties broken FIFO by
        submission sequence).  SJF ranks the EFFECTIVE prompt: a preempted
        request re-admits by replaying prompt + accepted tokens through the
        prefill, so its cost is the longer replay prompt, not the original
        ``req.prompt`` (``_pick_victim`` passes the would-be replay length
        of a still-resident row the same way)."""
        if self.policy == "sjf":
            # shortest job first: expected residency = generation budget +
            # bucketed prefill cost (summed over chunks when chunking)
            if eff_len is None:
                eff_len = int(self._eff_prompt(req).size)
            return req.max_new + self._prefill_cost(eff_len)
        if self.policy == "fifo":
            return 0
        return req.priority

    def _push_ready(self, req: Request) -> None:
        heapq.heappush(self._ready, (self._ready_key(req), self._seq, req))
        self._seq += 1

    # -- admission -----------------------------------------------------------

    def _worst_blocks(self, prompt_len: int, max_new: int) -> int:
        """Blocks the request could ever hold: its last cache write lands at
        position ``prompt_len + max_new - 2`` (token ``t`` of ``max_new`` is
        written at ``prompt_len + t - 2``; the final sampled token is output,
        never written), and prefill occupies ``[0, prompt_len)`` — bucket
        right-padding past the last prompt block is dropped, never stored."""
        return -(-(prompt_len + max_new - 1) // self.block_size)

    # -- chunked-prefill planning --------------------------------------------

    def _chunks_prefill(self, eff_len: int) -> bool:
        """Whether a prompt of this effective length takes the chunked path.
        Prompts that fit one chunk keep the one-shot ``_admit_many`` path —
        identical dispatch to an unchunked session, which is what lets the
        parity oracle share every short-prompt program."""
        return self.chunked and eff_len > self.prefill_chunk

    def _chunk_spans(self, eff_len: int) -> List[int]:
        """Deterministic host-side chunk plan: ``prefill_chunk``-wide spans
        plus a remainder.  Each span dispatches at its own bucket
        (``bucket(span) <= prefill_chunk``), so every chunk shape is already
        in the warmed (admit width x bucket) program set."""
        spans, pos = [], 0
        while pos < eff_len:
            s = min(self.prefill_chunk, eff_len - pos)
            spans.append(s)
            pos += s
        return spans

    def _prefill_cost(self, eff_len: int) -> int:
        """Bucketed prefill tokens this prompt will charge in total — the
        one-shot bucket, or the sum of chunk buckets when chunking (used by
        SJF ranking and victim selection; safe past ``buckets.max_size``,
        where ``bucket()`` itself would raise)."""
        if not self._chunks_prefill(eff_len):
            return self.buckets.bucket(eff_len)
        return sum(self.buckets.bucket(s) for s in self._chunk_spans(eff_len))

    def _head_charge(self, eff_len: int) -> int:
        """Tokens the interleaving budget charges when this request admits
        THIS step: the first chunk's bucket on the chunked path (later
        chunks are charged step by step from the resume queue), else the
        whole one-shot bucket."""
        if self._chunks_prefill(eff_len):
            return self.prefill_chunk
        return self.buckets.bucket(eff_len)

    # -- prefix sharing / preemption helpers ---------------------------------

    def _eff_prompt(self, req: Request) -> np.ndarray:
        """The prompt a (re-)admission actually prefills: the original
        prompt, extended by the accepted tokens snapshotted at preemption —
        recompute-based re-admission replays the victim as a longer prompt,
        and the positional fold_in key schedule makes the replayed samples
        bit-identical to the uninterrupted run."""
        resume = self._preempt_resume.get(req.req_id)
        if resume is None:
            return req.prompt
        return np.concatenate(
            [req.prompt, np.asarray(resume[0], np.int32)]
        ).astype(np.int32)

    def _reclaimable_blocks(self) -> int:
        """Published blocks held ONLY by the prefix cache (refcount 1):
        evictable on demand, so admission may count them as free."""
        if self._prefix is None:
            return 0
        return sum(1 for b in self._prefix.lru_blocks()
                   if self.blocks.refcount(b) == 1)

    def _reclaim_cache_block(self) -> bool:
        """Evict the least-recently-used cache-only published block back to
        the free heap.  Returns False when every published block is still
        shared with a resident request (nothing to reclaim)."""
        if self._prefix is None:
            return False
        for b in self._prefix.lru_blocks():
            if self.blocks.refcount(b) == 1:
                self._prefix.drop_block(b)
                self.blocks.release(b)          # the cache's own reference
                return True
        return False

    def _pick_victim(self, excl_slot: int) -> Optional[_ActiveSlot]:
        """Preemption victim: the least-important resident row — highest
        policy key (lowest priority class), then youngest admit, then
        highest req_id — excluding the row that needs the block."""
        best = None
        best_key = None
        for state in self._active:
            if (state is None or state.done or state.released
                    or state.preempted or state.slot == excl_slot):
                continue
            # a victim re-admits by replaying prompt + accepted tokens, so
            # rank it on that replay length (what SJF would charge it)
            key = (self._ready_key(
                       state.req,
                       eff_len=state.req.prompt.size + len(state.tokens),
                   ),
                   state.admitted_tick,
                   state.req.req_id)
            if best_key is None or key > best_key:
                best, best_key = state, key
        return best

    def _preempt(self, state: _ActiveSlot) -> None:
        """Evict ``state`` mid-decode: snapshot its accepted tokens for
        replay, free its private blocks (shared ones just decref — the
        zeroed table row makes any in-flight writes sentinel-dropped), and
        push the original request back on the ready queue."""
        state.preempted = True
        # the 4th element carries the first-token latency across the
        # eviction: ttft is counted exactly once per request, and a
        # mid-prefill victim (chunked path, ttft still unsampled) gets its
        # ttft at the REPLAY's final chunk instead
        self._preempt_resume[state.req.req_id] = (
            list(state.tokens), state.admitted_tick, state.tier_idx,
            state.ttft,
        )
        self._release_resources(state)
        self._push_ready(state.req)
        self.stats.preemptions += 1

    def _acquire_block(self, requesting_slot: int) -> int:
        """One block for ``requesting_slot``, escalating: free heap ->
        reclaim a cache-only published block -> (preemption on) evict the
        least-important other resident row, repeating until a block frees.
        Deadlock-free: submit bounds every request's worst case at
        ``num_blocks``, so once every other row is evicted and every
        cache-only block reclaimed, the pool can always fund the requester's
        next block."""
        b = self.blocks.acquire()
        if b is not None:
            return b
        while self._reclaim_cache_block():
            b = self.blocks.acquire()
            if b is not None:
                return b
        if not self.preempt:
            raise AssertionError("block append failed despite reservation")
        while True:
            victim = self._pick_victim(requesting_slot)
            if victim is None:
                raise AssertionError(
                    "block pool exhausted with no victim left — submit's "
                    "worst-case bound should make this unreachable"
                )
            self._preempt(victim)
            while self._reclaim_cache_block():
                pass
            b = self.blocks.acquire()
            if b is not None:
                return b

    def _admit_block(self) -> int:
        """One block for an admission row.  Never preempts: admission was
        gated on ``free + reclaimable`` (preemption) or the reservation
        (without), so free-heap + cache reclaim must always fund it."""
        b = self.blocks.acquire()
        while b is None and self._reclaim_cache_block():
            b = self.blocks.acquire()
        assert b is not None, "admission admitted an unfundable request"
        return b

    def _cow_guard(self, slot: int, state: _ActiveSlot, idx: int) -> None:
        """Copy-on-write: before a chunk writes into held block ``idx``,
        make that block privately owned and unpublished.  Publication is
        dropped first (the content is about to diverge from its key); if
        the block is still shared with another request after that, fork it
        through ``copy_block`` into a private copy.  ``_chunk_inputs``
        passes the block holding ``cur_len`` (the only pre-existing block a
        non-speculative chunk can touch — later positions land in freshly
        acquired private blocks) or, speculatively, every block index the
        chunk's write span could reach; guarding a privately held index is
        a no-op."""
        held = self._held[slot]
        if idx >= len(held):
            return                          # next write opens a fresh block
        b = held[idx]
        if self._prefix is not None and self._prefix.holds_block(b):
            self._prefix.drop_block(b)
            self.blocks.release(b)          # the cache's reference
        if self.blocks.refcount(b) <= 1:
            return                          # sole owner: write in place
        nb = self._acquire_block(slot)
        self.cache = _copy_block_jit(self.cache, np.int32(b), np.int32(nb))
        self.blocks.release(b)              # this row's shared reference
        held[idx] = nb
        self._tables[slot, idx] = nb
        self.stats.cow_forks += 1
        if not self.preempt:
            # the fork consumes the +1 reserve _admit_many added for a
            # shared tail, keeping appends infallible without preemption
            self._future[slot] -= 1
            self._reserved_total -= 1

    def _admit_width(self, n: int) -> int:
        """Admission rows are width-bucketed to powers of two (capped at
        ``num_slots``) so small admissions don't pay a full-width prefill:
        the compiled-program set stays {1, 2, 4, ...} x prompt buckets."""
        w = 1
        while w < n:
            w <<= 1
        return min(w, self.num_slots)

    def _admit_many(self, reqs: List[Request], tier_idx: int = 0) -> None:
        """Admit up to ``num_slots`` requests with ONE prefill dispatch: all
        prompts pad to the largest needed bucket, the row count pads to the
        admit-width bucket, and padding rows are no-ops — so the compiled
        program depends only on (admit width, prompt bucket).  Under the
        paged layout each request additionally acquires its prompt's blocks
        (``ceil(prompt_len / block_size)`` — proportional to the *actual*
        context, not the bucket or ``max_len``), converting that much of the
        reservation ``step`` took out when it popped the request.  On a tier
        session every request of the batch shares the effective rung
        ``tier_idx`` (``_admit_phase`` groups by rung) and prefills under
        that rung's config — the prompt KV must be seeded by the same
        execution mode its decode runs."""
        assert 0 < len(reqs) <= self.pool.free_count
        acfg = self._tier_cfgs[tier_idx] if self.tiers is not None else self.cfg
        A = self._admit_width(len(reqs))
        effs = [self._eff_prompt(r) for r in reqs]   # replay prompt if resumed
        bucket = max(self.buckets.bucket(e.size) for e in effs)
        # right-pad with the model's real pad id: token 0 can be a meaningful
        # vocab entry, and the masked teacher-forced ssm/hybrid prefill rows
        # see the pad positions before their per-row freeze
        prompts = np.full((A, bucket), self.pad_id, np.int32)
        prompt_lens = np.ones((A,), np.int32)
        valid = np.zeros((A,), bool)
        req_ids = np.zeros((A,), np.int32)
        row_slot = [self.pool.acquire() for _ in reqs]
        for i, req in enumerate(reqs):
            plen = effs[i].size
            prompts[i, :plen] = effs[i]
            prompt_lens[i] = plen
            valid[i] = True
            req_ids[i] = req.req_id
        # valid rows -> their acquired slots; padding rows -> distinct other
        # slot ids, keeping `slots` collision-free (deterministic scatter,
        # and the no-op rows rewrite rows they gathered — see _scatter_rows
        # and merge_admit_carry)
        rest = [s for s in range(self.num_slots) if s not in row_slot]
        slots = np.asarray((row_slot + rest)[:A], np.int32)
        if self.layout == "paged":
            nb = -(-bucket // self.block_size)
            block_ids = np.full((A, nb), self.num_blocks, np.int32)
            bs = self.block_size
            for i, req in enumerate(reqs):
                slot = row_slot[i]
                eff = effs[i]
                plen = int(eff.size)
                ninit = -(-plen // bs)
                held: List[int] = []
                self._tables[slot, :] = self.num_blocks
                if self._prefix is not None:
                    # rolling-key walk over the prompt's blocks: a hit maps
                    # the table entry at the already-resident block and
                    # leaves block_ids at the sentinel, so the (still full-
                    # shape) prefill dispatch's writes for that span are
                    # dropped; a miss acquires, writes, and publishes.
                    # Publishing happens host-side before the next request
                    # of this batch is processed, so batch-mates share too
                    # (the single dispatch writes each block exactly once —
                    # the one non-sentinel row).  Quality tiers: a block's
                    # K/V is rung-specific (it was prefilled under one
                    # rung's execution mode), so each rung chains from its
                    # OWN root — distinct negative roots never collide with
                    # interned kids (>= 0), keeping the rung chains disjoint
                    parent = C.PrefixCache.ROOT - tier_idx
                    for j in range(ninit):
                        toks = eff[j * bs:min((j + 1) * bs, plen)]
                        kid = self._prefix.key(parent, toks)
                        parent = kid
                        hit = self._prefix.lookup(kid)
                        if hit is not None:
                            self.blocks.share(hit)
                            held.append(hit)
                            self.stats.prefix_hit_blocks += 1
                        else:
                            b = self._admit_block()
                            block_ids[i, j] = b
                            held.append(b)
                            self.blocks.share(b)    # the cache's reference
                            self._prefix.insert(kid, b)
                else:
                    for j in range(ninit):
                        b = self._admit_block()
                        block_ids[i, j] = b
                        held.append(b)
                self._held[slot] = held
                self._tables[slot, :ninit] = held
                if not self.preempt:
                    # a partial tail under sharing is (or may become)
                    # published/shared: its eventual copy-on-write fork
                    # consumes one reserved block, pre-funded by
                    # _pop_admissible's +1 (see _cow_guard)
                    fork_reserve = int(
                        self._prefix is not None and plen % bs != 0
                    )
                    self._future[slot] = (
                        self._worst_blocks(req.prompt.size, req.max_new)
                        - ninit + fork_reserve
                    )
                    self._reserved_total -= ninit      # reservation -> held
            self.cache, tok0s, req_keys = _admit_fused_paged_jit(
                cfg=acfg, params=self.params, cache=self.cache,
                prompts=prompts, prompt_lens=prompt_lens, block_ids=block_ids,
                req_ids=req_ids, base_key=self._base_key,
                sampling=self.sampling, block_size=self.block_size,
            )
            self.stats.peak_blocks_in_use = max(
                self.stats.peak_blocks_in_use, self.blocks.busy_count
            )
            self.stats.peak_block_bytes_per_device = (
                self.stats.peak_blocks_in_use * self._block_bytes_dev
            )
        else:
            if self.prefill_mode == "fused":
                self.cache, tok0s, req_keys = _admit_fused_jit(
                    cfg=acfg, params=self.params, cache=self.cache,
                    prompts=prompts, prompt_lens=prompt_lens, slots=slots,
                    valid=valid, req_ids=req_ids, base_key=self._base_key,
                    sampling=self.sampling,
                )
            else:
                self.cache, tok0s, req_keys = _admit_decode_jit(
                    cfg=acfg, params=self.params, cache=self.cache,
                    prompts=prompts, prompt_lens=prompt_lens, slots=slots,
                    valid=valid, req_ids=req_ids, base_key=self._base_key,
                    sampling=self.sampling,
                    max_len=self.max_len, cache_dtype=self.cache_dtype,
                )
        self.stats.admit_calls += 1
        # charge the EFFECTIVE prompts: a replayed preemption victim
        # prefills prompt + accepted tokens, not its original prompt —
        # charging req.prompt here undercounted prefill_tokens/work_ticks
        # (and so the starvation gauge) after every preemption, and it is
        # the per-request effective bucket, not the batch-max padding
        # bucket, that _pop_admissible meters against the budget
        tok_sum = 0
        for e in effs:
            b = self.buckets.bucket(e.size)
            self.stats.prefills[b] = self.stats.prefills.get(b, 0) + 1
            tok_sum += b
        self.stats.prefill_tokens += tok_sum
        # prefill device work in decode-width-normalized ticks (the unit of
        # the starvation gauge); padding rows are a constant-factor artifact
        # the budget already ignores, so charge the metered tokens.  The
        # integer carry keeps rounding from compounding across admission
        # batches — that is what makes the documented gap bound
        # steps_per_tick + ceil(R * steps_per_tick) provable (a per-batch
        # ceil could overcharge a step by one tick per batch)
        self._prefill_carry += tok_sum
        self.stats.work_ticks += self._prefill_carry // self.num_slots
        self._prefill_carry %= self.num_slots

        if self.loop == "async":
            # no host sync: merge the admit program's (still in-flight)
            # first tokens + keys into the device-resident decode carry so
            # these rows join the next dispatched chunk; their tok0s are
            # fetched at the next harvest (eos/max_new==1 finishes are then
            # discovered one chunk late — the garbage chunk is discarded)
            self._lt_dev, self._sk_dev = _admit_merge_jit(
                self._lt_dev, self._sk_dev, slots, tok0s, req_keys, valid
            )
            if self.spec:
                # the length carry lives on device too (rows advance by
                # data-dependent accepted counts) — same fixed-shape merge
                self._cl_dev = _spec_merge_len_jit(
                    self._cl_dev, slots, prompt_lens, valid
                )
            states: List[_ActiveSlot] = []
            for i, req in enumerate(reqs):
                slot = row_slot[i]
                self._cur_len[slot] = int(prompt_lens[i])
                self._cl_true[slot] = int(prompt_lens[i])
                self._last_emit_work[slot] = self.stats.work_ticks
                resume = self._preempt_resume.pop(req.req_id, None)
                if resume is None:
                    self.stats.admitted += 1
                    state = _ActiveSlot(req, slot, [], self.clock,
                                        tier_idx=tier_idx)
                    state.ttft = self.clock - req.arrival
                    self.stats.ttft_ticks.append(state.ttft)
                else:
                    # re-admission after preemption: the request keeps its
                    # accepted tokens and original admit tick — admitted/
                    # ttft were already counted at first admit (a chunked
                    # victim evicted mid-prefill carries ttft < 0 and
                    # samples it now, on the replay that reaches a token)
                    state = _ActiveSlot(req, slot, list(resume[0]), resume[1],
                                        tier_idx=tier_idx)
                    state.ttft = resume[3]
                    if state.ttft < 0:
                        state.ttft = self.clock - req.arrival
                        self.stats.ttft_ticks.append(state.ttft)
                state.pending_first = True
                self._slot_tier[slot] = tier_idx
                self._bump_tier_gauge(tier_idx, +1)
                self._active[slot] = state
                states.append(state)
            # row indices into tok0s travel with the states: a chunked
            # dispatch merges only its FINAL rows, so the harvest needs to
            # know which tok0 row belongs to which state
            self._pending_tok0.append((states, tok0s, list(range(len(states)))))
            return

        # the sync loop blocks here until the prefill program completes —
        # time it as host_block_s so overlap_fraction stays comparable with
        # the async loop (whose tok0 fetches are timed in _harvest)
        tb = time.perf_counter()
        tok0s = np.asarray(tok0s)
        req_keys = np.asarray(req_keys, np.uint32)
        self.stats.host_block_s += time.perf_counter() - tb
        eos = self.sampling.eos_id
        for i, req in enumerate(reqs):
            slot, tok0 = row_slot[i], int(tok0s[i])
            self._last_token[slot] = tok0
            self._cur_len[slot] = int(prompt_lens[i])
            self._slot_keys[slot] = req_keys[i]
            self._last_emit_work[slot] = self.stats.work_ticks
            resume = self._preempt_resume.pop(req.req_id, None)
            if resume is None:
                self.stats.admitted += 1
                state = _ActiveSlot(req, slot, [tok0], self.clock,
                                    tier_idx=tier_idx)
                state.ttft = self.clock - req.arrival
                self.stats.ttft_ticks.append(state.ttft)
            else:
                state = _ActiveSlot(req, slot, list(resume[0]) + [tok0],
                                    resume[1], tier_idx=tier_idx)
                state.ttft = resume[3]
                if state.ttft < 0:
                    state.ttft = self.clock - req.arrival
                    self.stats.ttft_ticks.append(state.ttft)
            self._slot_tier[slot] = tier_idx
            self._bump_tier_gauge(tier_idx, +1)
            self.stats.generated_tokens += 1
            if len(state.tokens) >= req.max_new or (eos >= 0 and tok0 == eos):
                self._finish(state, "eos" if (eos >= 0 and tok0 == eos) else "length")
            else:
                self._active[slot] = state

    def _bump_tier_gauge(self, tier_idx: int, delta: int) -> None:
        """Maintain the ``active_per_tier`` residency gauge (tier sessions
        only): +1 at each admission, -1 at each release — exactly-once by
        the same ``state.released`` discipline as the resources."""
        if self.tiers is None:
            return
        self._tier_active_counts[tier_idx] += delta
        self.stats.active_per_tier = {
            t: int(c) for t, c in zip(self.tiers, self._tier_active_counts)
        }

    def _release_resources(self, state: _ActiveSlot) -> None:
        """Free ``state``'s slot — and under the paged layout every held
        block plus the unused remainder of its worst-case reservation —
        exactly once (``state.released`` guards the double-call when a
        predictively released row is later finished at harvest).  Stale
        cache contents are invisible: a slot stripe / block re-enters
        attention only after its next owner's prefill/decode writes
        overwrite the exposed positions."""
        state.released = True
        self._bump_tier_gauge(state.tier_idx, -1)
        if state.prefilling:
            # a mid-prefill victim leaves the resume queue with its slot —
            # the replay restarts the chunk plan from position 0
            self._prefilling = [s for s in self._prefilling if s is not state]
        if self._active[state.slot] is state:   # a successor may already own it
            self._active[state.slot] = None
        self.pool.release(state.slot)
        if self.layout == "paged":
            slot = state.slot
            self.blocks.release_many(self._held[slot])
            self._held[slot] = []
            self._tables[slot, :] = self.num_blocks
            self._reserved_total -= int(self._future[slot])
            self._future[slot] = 0
        elif self.zero_on_evict:
            self.cache = _evict_jit(self.cache, np.int32(state.slot))

    def _finish(self, state: _ActiveSlot, reason: str) -> None:
        state.done = True
        if not state.released:
            self._release_resources(state)
        self.stats.completed += 1
        self.stats.latency_ticks.append(self.clock - state.req.arrival)
        self._just_finished.append(state.req.req_id)
        self._completed[state.req.req_id] = CompletedRequest(
            req_id=state.req.req_id,
            prompt=state.req.prompt,
            tokens=np.asarray(state.tokens, np.int32),
            finish_reason=reason,
            admitted_tick=state.admitted_tick,
            finished_tick=self.clock,
            tier=self.tiers[state.tier_idx] if self.tiers is not None else "",
            ttft=state.ttft,
        )

    def _ensure_blocks(self, slot: int, hi: int) -> None:
        """Paged layout: append blocks to ``slot``'s table until it covers
        cache position ``hi`` (a no-op when already covered — a request only
        pays a pool op when its context actually crosses a block boundary).
        Without preemption the admission reservation makes the acquire
        infallible; with it, ``_acquire_block`` reclaims published blocks
        and evicts other rows until the pool funds the append."""
        held = self._held[slot]
        while len(held) * self.block_size <= hi:
            b = self._acquire_block(slot)
            self._tables[slot, len(held)] = b
            held.append(b)
            if not self.preempt:
                self._future[slot] -= 1
                self._reserved_total -= 1

    # -- stepping ------------------------------------------------------------

    def _pull_arrivals(self) -> None:
        while self._pending and self._pending[0][0] <= self.clock:
            self._push_ready(heapq.heappop(self._pending)[2])

    @property
    def n_active(self) -> int:
        return sum(s is not None for s in self._active)

    @property
    def n_decoding(self) -> int:
        """Resident rows actually decoding — mid-prefill rows (chunked
        path) hold a slot but join no decode chunk, so they neither starve
        nor scale the interleaving budget."""
        return sum(
            s is not None and not s.prefilling for s in self._active
        )

    @property
    def drained(self) -> bool:
        return not (
            self._pending or self._ready or self.n_active or self._inflight
        )

    def _drain_finished(self) -> List[CompletedRequest]:
        done = [self._completed[i] for i in self._just_finished]
        self._just_finished.clear()
        return done

    def _prefill_budget(self) -> float:
        """Bucketed prompt tokens this step may admit under the interleaving
        policy.  Unlimited when no policy is set, and unlimited while no
        decode is resident — there is nothing to starve, and the queue must
        be able to drain (a head whose bucket exceeds the per-step budget
        therefore waits at most until the resident decodes finish)."""
        if self.prefill_decode_ratio is None and self.prefill_token_budget is None:
            return float("inf")
        if self.n_decoding == 0:
            return float("inf")
        if self.prefill_token_budget is not None:
            return float(self.prefill_token_budget)
        return self.prefill_decode_ratio * self.n_decoding * self.steps_per_tick

    def _pop_admissible(
        self, budget: float = float("inf")
    ) -> Tuple[List[Request], float, bool]:
        """Pop ready requests that fit the free slots, (paged) the block
        pool, and the prefill-token ``budget``.  Memory admission is
        reservation-based: a request is popped only if its worst-case block
        count fits what the pool can still promise (``free - reserved``),
        and that worst case is reserved on the spot — which is exactly what
        makes mid-decode appends and the no-preemption guarantee sound.  The
        queue head blocks admission when it doesn't fit (no skip-ahead):
        policy order is preserved and a big request cannot be starved by a
        stream of small ones.  Returns ``(batch, remaining budget, stalled)``
        where ``stalled`` means the head was deferred by the budget alone
        (slots and memory both had room)."""
        batch: List[Request] = []
        stalled = False
        pending_need = 0
        reclaimable = (
            self._reclaimable_blocks()
            if self.layout == "paged" and self.preempt else 0
        )
        while self._ready and len(batch) < self.pool.free_count:
            req = self._ready[0][2]
            eff_len = req.prompt.size
            worst = 0
            if self.layout == "paged":
                eff_len = int(self._eff_prompt(req).size)
                if self.preempt:
                    # oversubscription: admit on the *immediate* prompt need
                    # (prefix hits only shrink it; cache-only published
                    # blocks count as free because reclaim evicts them on
                    # demand) — mid-decode appends are funded by reclaim and
                    # preemption instead of a worst-case reservation.  A
                    # chunked admission's immediate need is its FIRST
                    # chunk's blocks; later chunks append like decode does
                    head = (
                        min(eff_len, self.prefill_chunk)
                        if self._chunks_prefill(eff_len) else eff_len
                    )
                    need = -(-head // self.block_size)
                    if pending_need + need > (
                        self.blocks.free_count + reclaimable
                    ):
                        break
                else:
                    worst = self._worst_blocks(req.prompt.size, req.max_new)
                    if self._prefix is not None and eff_len % self.block_size:
                        # +1 pre-funds the partial tail's potential copy-on-
                        # write fork so mid-decode forks stay infallible
                        # under the reservation discipline (see _admit_many)
                        worst += 1
                    # published blocks pin otherwise-free pool capacity;
                    # evict LRU cache-only blocks before refusing the head
                    while (
                        worst > self.blocks.free_count - self._reserved_total
                        and self._reclaim_cache_block()
                    ):
                        pass
                    if worst > self.blocks.free_count - self._reserved_total:
                        break
            b = self._head_charge(eff_len)
            if b > budget:
                stalled = True
                break
            if self.layout == "paged":
                if self.preempt:
                    pending_need += -(-head // self.block_size)
                else:
                    self._reserved_total += worst
            budget -= b
            heapq.heappop(self._ready)
            batch.append(req)
        return batch, budget, stalled

    def _eff_tier(self, req: Request) -> int:
        """The ladder rung ``req`` admits at RIGHT NOW: the requested rung,
        demoted to the current shed level when that is lower-quality (higher
        index).  A preemption victim replays at the rung it originally
        admitted under — re-deciding would break the bit-identical replay
        (the snapshotted tokens were generated by the original rung)."""
        resume = self._preempt_resume.get(req.req_id)
        if resume is not None:
            return resume[2]
        want = self.tiers.index(req.tier) if req.tier is not None else 0
        return max(want, self._shed_level)

    def _group_by_tier(
        self, batch: List[Request]
    ) -> List[Tuple[int, List[Request]]]:
        """Split an admission batch by effective rung (admission order kept
        inside each group, groups in ladder order) — each group prefills
        under its own rung config in one dispatch."""
        groups: Dict[int, List[Request]] = {}
        for r in batch:
            groups.setdefault(self._eff_tier(r), []).append(r)
        return sorted(groups.items())

    def _admit_phase(self) -> None:
        """Admit ready requests in policy order, subject to free slots,
        (paged) the block-pool reservation, and the interleaving budget —
        shared across every admission batch of this step.  Chunked prefill:
        resident mid-prefill rows spend the budget FIRST (oldest prefill
        first, no skip-ahead — a stalled resident chunk also closes
        admission for the step), so every started prefill finishes before
        new prompts open and the budget bounds each step's prefill work by
        one chunk bucket per row instead of one prompt bucket."""
        budget = self._prefill_budget()
        stalled = False
        if self._prefilling:
            budget, stalled = self._resume_chunks(budget)
        while not stalled and self._ready and self.pool.free_count:
            batch, budget, st = self._pop_admissible(budget)
            stalled = stalled or st
            if not batch:
                break                 # head doesn't fit the pool/budget yet
            if self.tiers is None:
                chunked = [
                    r for r in batch
                    if self._chunks_prefill(int(self._eff_prompt(r).size))
                ]
                oneshot = [r for r in batch if r not in chunked]
                if oneshot:
                    self._admit_many(oneshot)  # sync: may free slots again
                if chunked:
                    started = [self._start_chunked(r) for r in chunked]
                    # first chunk dispatches the same step the budget was
                    # charged for it (_head_charge); later chunks resume
                    # above on subsequent steps
                    self._dispatch_chunks(started)
            else:
                for t, group in self._group_by_tier(batch):
                    self._admit_many(group, tier_idx=t)
        if stalled:
            self.stats.prefill_stall_ticks += 1
        self.stats.peak_active = max(self.stats.peak_active, self.n_active)

    def _resume_chunks(self, budget: float) -> Tuple[float, bool]:
        """Dispatch the next chunk for every resident mid-prefill row the
        budget covers, in start order (FIFO, no skip-ahead: a stalled head
        blocks younger rows' chunks, which is what keeps each prefill's
        finish time bounded).  One chunk per row per step — the decode
        interleave between chunks is the whole point."""
        rows: List[_ActiveSlot] = []
        stalled = False
        for state in list(self._prefilling):
            clen = min(
                self.prefill_chunk, state.prefill_len - state.prefill_pos
            )
            b = self.buckets.bucket(clen)
            if b > budget:
                stalled = True
                break
            budget -= b
            rows.append(state)
        if rows:
            self._dispatch_chunks(rows)
        return budget, stalled

    def _start_chunked(self, req: Request) -> _ActiveSlot:
        """Make a chunked admission resident WITHOUT prefilling anything
        yet: acquire the slot, zero the table row (all-sentinel — blocks
        are acquired chunk by chunk in ``_dispatch_chunks``), and park the
        row on the resume queue with its cursor at 0.  Without preemption
        the worst-case reservation ``_pop_admissible`` took stays
        unconverted (``_future`` carries all of it) and ``_ensure_blocks``
        converts per acquired block."""
        eff = self._eff_prompt(req)
        slot = self.pool.acquire()
        self._tables[slot, :] = self.num_blocks
        self._held[slot] = []
        self._cur_len[slot] = 0
        self._cl_true[slot] = 0
        self._last_emit_work[slot] = self.stats.work_ticks
        if not self.preempt:
            self._future[slot] = self._worst_blocks(
                req.prompt.size, req.max_new
            )
        resume = self._preempt_resume.pop(req.req_id, None)
        if resume is None:
            self.stats.admitted += 1
            state = _ActiveSlot(req, slot, [], self.clock)
        else:
            # chunked replay of a preemption victim: accepted tokens are
            # part of the effective prompt (``eff``) AND the resume token
            # list — the final chunk's sampled token appends after them
            state = _ActiveSlot(req, slot, list(resume[0]), resume[1])
            state.ttft = resume[3]
        state.prefill_pos = 0
        state.prefill_len = int(eff.size)
        state.eff_prompt = eff
        self._slot_tier[slot] = 0
        self._bump_tier_gauge(0, +1)
        self._active[slot] = state
        self._prefilling.append(state)
        return state

    def _dispatch_chunks(self, rows: List[_ActiveSlot]) -> None:
        """ONE ``_prefill_chunk`` dispatch advancing every row in ``rows``
        by its next chunk.  Rows pad to the admit-width x max-chunk-bucket
        shape (program key: that pair — the warmed one-shot program
        family), each row reading its already-written prefix through its
        block table and scattering this chunk's K/V into freshly ensured
        blocks.  Rows that reach the end of their prompt sample their
        first token in-program (same key/position fold as the one-shot
        admit) and join the decode set; for the others the sampled token
        is garbage the host never reads."""
        A = self._admit_width(len(rows))
        clens = [
            min(self.prefill_chunk, s.prefill_len - s.prefill_pos)
            for s in rows
        ]
        cb = max(self.buckets.bucket(c) for c in clens)
        toks = np.full((A, cb), self.pad_id, np.int32)
        starts = np.zeros((A,), np.int32)
        chunk_lens = np.ones((A,), np.int32)
        req_ids = np.zeros((A,), np.int32)
        tables = np.full(
            (A, self._tables.shape[1]), self.num_blocks, np.int32
        )
        for i, (state, clen) in enumerate(zip(rows, clens)):
            if state.released or state.preempted:
                # evicted by an earlier row's _ensure_blocks this very
                # loop: its table row stays all-sentinel (chunk writes
                # drop) and its cursor is left for the replay
                continue
            slot, pos = state.slot, state.prefill_pos
            self._ensure_blocks(slot, pos + clen - 1)
            toks[i, :clen] = state.eff_prompt[pos:pos + clen]
            starts[i] = pos
            chunk_lens[i] = clen
            req_ids[i] = state.req.req_id
            tables[i] = self._tables[slot]
        self.stats.peak_blocks_in_use = max(
            self.stats.peak_blocks_in_use, self.blocks.busy_count
        )
        self.stats.peak_block_bytes_per_device = (
            self.stats.peak_blocks_in_use * self._block_bytes_dev
        )
        self.cache, tok0s, req_keys = _prefill_chunk_jit(
            cfg=self.cfg, params=self.params, cache=self.cache,
            tokens=toks, starts=starts, chunk_lens=chunk_lens,
            tables=tables, req_ids=req_ids, base_key=self._base_key,
            sampling=self.sampling, block_size=self.block_size,
        )
        # per-chunk work charge: each chunk bills its OWN bucket, so
        # prefill_tokens / work_ticks (and with them the starvation gauge)
        # meter what the device actually ran this step — not the whole
        # prompt at admission
        tok_sum = 0
        live = [
            (i, s, c) for i, (s, c) in enumerate(zip(rows, clens))
            if not (s.released or s.preempted)
        ]
        for _, _, clen in live:
            b = self.buckets.bucket(clen)
            self.stats.prefills[b] = self.stats.prefills.get(b, 0) + 1
            tok_sum += b
        self.stats.prefill_chunks += len(live)
        self.stats.prefill_tokens += tok_sum
        self._prefill_carry += tok_sum
        self.stats.work_ticks += self._prefill_carry // self.num_slots
        self._prefill_carry %= self.num_slots
        finals: List[Tuple[int, _ActiveSlot]] = []
        for i, state, clen in live:
            state.prefill_pos += clen
            self._cur_len[state.slot] = state.prefill_pos
            self._cl_true[state.slot] = state.prefill_pos
            if not state.prefilling:
                finals.append((i, state))
        for _, state in finals:
            self._prefilling.remove(state)
            self._last_emit_work[state.slot] = self.stats.work_ticks
            if state.ttft < 0:
                state.ttft = self.clock - state.req.arrival
                self.stats.ttft_ticks.append(state.ttft)
        if self.loop == "async":
            if finals:
                # merge ONLY the final rows' first tokens + keys into the
                # device carry; mid-prefill rows stay out of the decode
                # set, so their carry entries stay whatever they were.
                # slots/valid align with the dispatch's tok0 rows, and the
                # non-final rows borrow distinct unclaimed slot ids so the
                # scatter stays collision-free (invalid rows rewrite what
                # they gathered — see merge_admit_carry)
                row_slot = {i: s.slot for i, s in finals}
                rest = [
                    s for s in range(self.num_slots)
                    if s not in row_slot.values()
                ]
                slots = np.empty((A,), np.int32)
                valid = np.zeros((A,), bool)
                for i in range(A):
                    if i in row_slot:
                        slots[i] = row_slot[i]
                        valid[i] = True
                    else:
                        slots[i] = rest.pop()
                self._lt_dev, self._sk_dev = _admit_merge_jit(
                    self._lt_dev, self._sk_dev, slots, tok0s, req_keys,
                    valid,
                )
                for _, s in finals:
                    s.pending_first = True
                self._pending_tok0.append(
                    ([s for _, s in finals], tok0s, [i for i, _ in finals])
                )
            return
        if not finals:
            return
        tb = time.perf_counter()
        tok0s = np.asarray(tok0s)
        req_keys = np.asarray(req_keys, np.uint32)
        self.stats.host_block_s += time.perf_counter() - tb
        eos = self.sampling.eos_id
        for i, state in finals:
            slot, tok0 = state.slot, int(tok0s[i])
            self._last_token[slot] = tok0
            self._slot_keys[slot] = req_keys[i]
            state.tokens.append(tok0)
            self.stats.generated_tokens += 1
            if (len(state.tokens) >= state.req.max_new
                    or (eos >= 0 and tok0 == eos)):
                self._finish(
                    state, "eos" if (eos >= 0 and tok0 == eos) else "length"
                )

    def _decode_states(self) -> List[Optional[_ActiveSlot]]:
        """The rows a decode chunk serves: ``_active`` with mid-prefill
        rows masked to ``None`` — the chunk's tokens/advances for those
        rows are garbage (their table rows were scrubbed at dispatch), and
        the None mask makes every acceptance/advance loop skip them the
        same way it skips empty slots."""
        return [
            None if (s is not None and s.prefilling) else s
            for s in self._active
        ]

    def _chunk_inputs(self):
        """Dispatch inputs shared by both loops: the active-row mask and
        (paged) this chunk's block tables, grown to cover every position the
        chunk could write an ACCEPTED token to (overshoot past max_new
        targets sentinel entries and is dropped); the admission reservation
        guarantees these acquires can never fail."""
        steps = self.steps_per_tick
        tables = None
        block_size = 0
        # write span past cur_len: a decode chunk's last accepted write
        # lands at cur_len + steps - 1; a speculative tick's verify writes
        # through cur_len + draft_k (see _spec_tick)
        span = self._draft_k_eff if self.spec else steps - 1
        if self.layout == "paged":
            bs = self.block_size
            for slot, state in enumerate(self._active):
                if state is None or state.prefilling:
                    # mid-prefill rows join no decode chunk: their blocks
                    # grow in _dispatch_chunks, not here
                    continue
                hi = min(
                    int(self._cur_len[slot]) + span,
                    state.req.prompt.size + state.req.max_new - 2,
                )
                # CoW first: every block this chunk may write into must be
                # private and unpublished before its writes reach it.  A
                # non-speculative chunk writes from cur_len; a speculative
                # async chunk writes anywhere in [_cl_true, hi] (the host
                # only bounds cur_len between harvests), so guard the whole
                # candidate range — privately held indices are no-ops.
                # Both the guard's fork and _ensure_blocks may preempt
                # other rows (preemption on): a victim later in this loop
                # reads as None, an earlier one already has its table row
                # zeroed — either way the active mask below and the
                # sentinel discipline keep the dispatch exact.
                if self._prefix is not None:
                    lo = (
                        int(self._cl_true[slot])
                        if self.spec and self.loop == "async"
                        else int(self._cur_len[slot])
                    )
                    for idx in range(lo // bs, hi // bs + 1):
                        self._cow_guard(slot, state, idx)
                self._ensure_blocks(slot, hi)
            self.stats.peak_blocks_in_use = max(
                self.stats.peak_blocks_in_use, self.blocks.busy_count
            )
            self.stats.peak_block_bytes_per_device = (
                self.stats.peak_blocks_in_use * self._block_bytes_dev
            )
            tables = self._tables.copy()
            for slot, state in enumerate(self._active):
                if state is not None and state.prefilling:
                    # the decode tick writes K/V for EVERY row at its
                    # cur_len; a mid-prefill row's real table holds
                    # already-written prompt K/V a garbage decode write
                    # would corrupt, so its row in the dispatched copy is
                    # scrubbed to the sentinel (writes drop, like released
                    # rows)
                    tables[slot, :] = self.num_blocks
            block_size = self.block_size
        active = np.asarray(
            [s is not None and not s.prefilling for s in self._active], bool
        )
        return active, tables, block_size, steps

    def _accept_chunk(
        self,
        states: List[Optional[_ActiveSlot]],
        toks: np.ndarray,
        steps: int,
        work_end: int,
    ) -> None:
        """Accept a fetched chunk's tokens for the rows that were live at
        its dispatch: each row takes tokens until it finishes (eos /
        max_new) and discards the bounded overshoot; rows whose completion
        was discovered after the dispatch (``state.done``) contribute only
        idle steps.  Updates the busy/idle accounting and the starvation
        gauge (``work_end`` is the chunk's position on the work clock)."""
        eos = self.sampling.eos_id
        accepted = 0
        for slot, state in enumerate(states):
            if state is None or state.done or state.preempted:
                # preempted rows discard their in-flight tokens (counted
                # idle): the replay regenerates them bit-identically
                continue
            # predictively released rows may already have a successor in the
            # slot; leave the successor's emission mark alone
            early = state.released
            for s in range(steps):
                tok = int(toks[s, slot])
                state.tokens.append(tok)
                accepted += 1
                if eos >= 0 and tok == eos:
                    self._finish(state, "eos")
                    break
                if len(state.tokens) >= state.req.max_new:
                    self._finish(state, "length")
                    break
            if not early:
                gap = int(work_end - self._last_emit_work[slot])
                if gap > self.stats.max_decode_gap_ticks:
                    self.stats.max_decode_gap_ticks = gap
                self._last_emit_work[slot] = work_end
        self.stats.busy_slot_steps += accepted
        self.stats.idle_slot_steps += self.num_slots * steps - accepted
        self.stats.generated_tokens += accepted

    def _accept_spec_chunk(
        self,
        states: List[Optional[_ActiveSlot]],
        toks: np.ndarray,          # (draft_k + 1, N)
        n_acc: np.ndarray,         # (N,)
        work_end: int,
        draft_k: int,
    ) -> None:
        """Speculative counterpart of ``_accept_chunk``: each live row takes
        its own ``n_acc`` tokens (1..draft_k+1 — uneven per row), finishing
        on eos / max_new exactly as sequential acceptance would.  A tick's
        device capacity is ``num_slots * (draft_k + 1)`` token-slots; the
        accept-rate counters meter the draft multiplier's hit rate
        (``n_acc - 1`` drafted tokens survived the exact verifier, clipped
        to what the row could still emit so end-of-request truncation never
        inflates the readout).  ``draft_k`` is the window the CHUNK was
        dispatched with (dynamic_draft_k may have moved ``_draft_k_eff``
        since), and each live row also feeds the rolling accept window the
        adaptation rule reads."""
        eos = self.sampling.eos_id
        accepted = 0
        cap = draft_k + 1
        for slot, state in enumerate(states):
            if state is None or state.done or state.preempted:
                # preempted rows discard their in-flight tokens (counted
                # idle): the replay regenerates them bit-identically
                continue
            early = state.released
            na = int(n_acc[slot])
            self.stats.verify_calls += 1
            self.stats.draft_tokens += draft_k
            emitted = 0
            for s in range(na):
                tok = int(toks[s, slot])
                state.tokens.append(tok)
                accepted += 1
                emitted += 1
                if eos >= 0 and tok == eos:
                    self._finish(state, "eos")
                    break
                if len(state.tokens) >= state.req.max_new:
                    self._finish(state, "length")
                    break
            self.stats.accepted_tokens += max(0, min(na - 1, emitted))
            if self.dynamic_draft:
                self._accept_hist.append((draft_k, max(0, min(na - 1, emitted))))
            if not early:
                gap = int(work_end - self._last_emit_work[slot])
                if gap > self.stats.max_decode_gap_ticks:
                    self.stats.max_decode_gap_ticks = gap
                self._last_emit_work[slot] = work_end
        self.stats.busy_slot_steps += accepted
        self.stats.idle_slot_steps += self.num_slots * cap - accepted
        self.stats.generated_tokens += accepted
        if self.dynamic_draft:
            self._update_draft_k()

    def _update_draft_k(self) -> None:
        """dynamic_draft_k adaptation rule (applies to the NEXT dispatch).

        A drafted token costs ``1/draft_cost_ratio`` of a verify position,
        so drafting pays iff the accept rate is at least the break-even
        ``1/draft_cost_ratio``.  Over a full rolling window of per-row
        (drafted, accepted) pairs: strictly below break-even -> halve the
        window (next rung down the warmed ladder); at/above break-even ->
        re-grow one rung.  The window clears on every change, so each rung
        is measured on a full window of its own chunks before the next
        move — that hysteresis is the regression-pinned contract
        (tests/test_specdec.py)."""
        if len(self._accept_hist) < self.draft_window:
            return
        drafted = sum(d for d, _ in self._accept_hist)
        acc = sum(a for _, a in self._accept_hist)
        if not drafted:
            return
        rate = acc / drafted
        i = self._draft_ks.index(self._draft_k_eff)
        if rate < 1.0 / self.draft_cost_ratio and i + 1 < len(self._draft_ks):
            self._draft_k_eff = self._draft_ks[i + 1]
            self.stats.draft_k_shrinks += 1
            self._accept_hist.clear()
        elif rate >= 1.0 / self.draft_cost_ratio and i > 0:
            self._draft_k_eff = self._draft_ks[i - 1]
            self.stats.draft_k_grows += 1
            self._accept_hist.clear()
        self.stats.draft_k_current = self._draft_k_eff

    def step(self) -> List[CompletedRequest]:
        """Admit what fits (under the interleaving budget), run one decode
        chunk, release finished slots.  Returns the requests completed
        during this call — under ``loop="async"`` completions surface one
        step after their chunk was dispatched (the pipeline lag)."""
        if self._closed:
            raise RuntimeError(
                "ServeSession is closed — its pipeline was flushed by "
                "close(); create a new session"
            )
        t0 = time.perf_counter()
        try:
            with self._mesh_ctx():
                if self.loop == "async":
                    return self._step_async()
                return self._step_sync()
        finally:
            self.stats.wall_s += time.perf_counter() - t0

    def _mesh_ctx(self):
        """Every device dispatch runs under ``with mesh:`` when the session
        is tensor-parallel — ``constrain()`` and GSPMD read the mesh from the
        thread-resource env at trace time, and the mesh context is part of
        the jit cache key, so warmup and serving must install the SAME
        context for the zero-recompile contract to hold."""
        return self.mesh if self.mesh is not None else contextlib.nullcontext()

    # -- quality tiers: load shedding and per-rung dispatch -------------------

    def _current_decode_gap(self) -> int:
        """LIVE starvation signal: worst work-tick gap since a resident
        row's latest accepted token (``max_decode_gap_ticks`` is its
        monotone high-water mark — useless for a shedder that must observe
        recovery)."""
        g = 0
        for slot, state in enumerate(self._active):
            if (state is None or state.done or state.released
                    or state.prefilling):
                # mid-prefill rows haven't emitted yet — they are metered
                # by ttft, not the decode gap
                continue
            g = max(g, int(self.stats.work_ticks - self._last_emit_work[slot]))
        return g

    def _update_shed(self) -> None:
        """Load-adaptive shedding, once per step before admission.  A BREACH
        — ready-queue depth above ``shed_queue_depth`` or the live decode
        gap above ``shed_gap_ticks`` — raises the shed level one rung (new
        admissions then serve at ``max(requested, level)``); recovery only
        lowers it after ``shed_hold_steps`` CONSECUTIVE steps below
        ``shed_restore_fraction`` of the breach thresholds, and the
        consecutive-step window clears on every level change or unhealthy
        step — the same measure-a-full-window-per-rung hysteresis contract
        as ``_update_draft_k``, so the level cannot flap."""
        if not self._shed_on:
            return
        depth = len(self._ready)
        gap = self._current_decode_gap()
        breach = (
            (self.shed_queue_depth is not None
             and depth > self.shed_queue_depth)
            or (self.shed_gap_ticks is not None and gap > self.shed_gap_ticks)
        )
        if breach:
            self._shed_ok_steps = 0
            if self._shed_level + 1 < len(self.tiers):
                self._shed_level += 1
                self.stats.tier_demotions += 1
                self.stats.shed_level = self._shed_level
            return
        healthy = (
            (self.shed_queue_depth is None
             or depth <= self.shed_restore_fraction * self.shed_queue_depth)
            and (self.shed_gap_ticks is None
                 or gap <= self.shed_restore_fraction * self.shed_gap_ticks)
        )
        if not healthy:
            self._shed_ok_steps = 0
            return
        if self._shed_level == 0:
            return
        self._shed_ok_steps += 1
        if self._shed_ok_steps >= self.shed_hold_steps:
            self._shed_level -= 1
            self.stats.tier_restorations += 1
            self.stats.shed_level = self._shed_level
            self._shed_ok_steps = 0

    def _dispatch_tier_chunks(self, active, tables, block_size, steps):
        """One ``_decode_tick`` dispatch per ladder rung holding >= 1 active
        row, chaining the cache (and, async, the device token carry) through
        the rung dispatches in ladder order.  Each dispatch masks ``active``
        down to its rung's rows and makes the OTHER rungs' resident rows
        write-inert the same way released rows already are — paged: their
        table rows scrubbed to the sentinel in this rung's copy, so every KV
        scatter drops; slots: their ``cur_len`` pinned to ``max_len``, so
        every positional ``.at[].set`` lands out of bounds and drops (do not
        swap either path for a clamping primitive — see ``_decode_tick``).
        In-program ``where(active, toks, 0)`` zeroes non-rung rows' tokens,
        so the per-rung outputs merge by elementwise sum.  Returns the
        (still in-flight) per-rung token futures."""
        async_ = self.loop == "async"
        parts = []
        for t in range(len(self.tiers)):
            mask = active & (self._slot_tier == t)
            if not mask.any():
                continue
            if self.layout == "paged":
                tb, cl = tables.copy(), self._cur_len.copy()
                tb[~mask, :] = self.num_blocks
            else:
                tb = None
                cl = np.where(mask, self._cur_len, self.max_len)
                cl = cl.astype(np.int32)
            self.cache, toks_f, lt = _decode_tick_jit(
                cfg=self._tier_cfgs[t], params=self.params, cache=self.cache,
                last_token=self._lt_dev if async_ else self._last_token,
                cur_len=cl, active=mask,
                slot_keys=self._sk_dev if async_ else self._slot_keys,
                tables=tb, sampling=self.sampling, steps=steps,
                block_size=block_size, attn_impl=self.attn_impl,
            )
            if async_:
                self._lt_dev = lt
            parts.append(toks_f)
        return parts

    def _step_sync(self) -> List[CompletedRequest]:
        """PR-3 strictly-alternating loop: dispatch one chunk, block on its
        tokens, then do every piece of bookkeeping — the parity baseline the
        async loop is benchmarked against."""
        self._pull_arrivals()
        self._update_shed()
        self._admit_phase()

        if self.n_active == 0:
            # idle: jump to the next arrival instead of burning empty ticks
            if self._pending:
                self.clock = max(self.clock + 1, self._pending[0][0])
            else:
                self.clock += 1
            return self._drain_finished()
        if self.n_decoding == 0:
            # only mid-prefill rows resident: nothing to decode this step
            # (their chunks were dispatched in _admit_phase); the clock
            # still advances so ttft/latency stay meaningful and the next
            # step keeps the chunks flowing
            self.clock += 1
            return self._drain_finished()

        active, tables, block_size, steps = self._chunk_inputs()
        if self.spec:
            k = self._draft_k_eff
            self.cache, toks, n_acc, _, _ = _spec_tick_jit(
                cfg=self.cfg, draft_cfg=self.draft_cfg, params=self.params,
                cache=self.cache, last_token=self._last_token,
                cur_len=self._cur_len, active=active,
                slot_keys=self._slot_keys, tables=tables,
                sampling=self.sampling, draft_k=k,
                block_size=block_size, attn_impl=self.attn_impl,
            )
            tb = time.perf_counter()
            toks = np.asarray(toks)              # (draft_k + 1, N)
            n_acc = np.asarray(n_acc)
            self.stats.host_block_s += time.perf_counter() - tb
            # one spec tick on the scheduler clock; the device ran
            # draft_k + 1 token-steps' worth of work
            self.clock += 1
            self.stats.ticks += 1
            self.stats.work_ticks += k + 1

            states = self._decode_states()
            self._accept_spec_chunk(states, toks, n_acc, self.stats.work_ticks, k)
            for slot, state in enumerate(states):
                if state is None:
                    continue
                # per-row uneven advance: mirror the device carry exactly
                # (continuing rows accepted all n_acc tokens; finished rows'
                # values are reset at the slot's next admission)
                na = int(n_acc[slot])
                self._cur_len[slot] = min(
                    self._cur_len[slot] + na, self.max_len - 1
                )
                if na:
                    self._last_token[slot] = int(toks[na - 1, slot])
            return self._drain_finished()

        if self.tiers is not None:
            parts = self._dispatch_tier_chunks(active, tables, block_size, steps)
        else:
            self.cache, toks_f, _ = _decode_tick_jit(
                cfg=self.cfg, params=self.params, cache=self.cache,
                last_token=self._last_token, cur_len=self._cur_len,
                active=active, slot_keys=self._slot_keys, tables=tables,
                sampling=self.sampling, steps=steps, block_size=block_size,
                attn_impl=self.attn_impl,
            )
            parts = [toks_f]
        tb = time.perf_counter()
        toks = np.asarray(parts[0])              # (steps, N)
        for p in parts[1:]:
            # per-rung chunks carry disjoint row masks (zeros elsewhere),
            # so the merged chunk is the elementwise sum
            toks = toks + np.asarray(p)
        self.stats.host_block_s += time.perf_counter() - tb
        self.clock += steps
        self.stats.ticks += steps
        self.stats.work_ticks += steps

        states = self._decode_states()
        self._accept_chunk(states, toks, steps, self.stats.work_ticks)
        for slot, state in enumerate(states):
            if state is None:
                continue
            # device advanced this row all `steps` steps whether or not it
            # finished mid-chunk; keep the host view in lockstep
            self._cur_len[slot] = min(self._cur_len[slot] + steps, self.max_len - 1)
            self._last_token[slot] = int(toks[steps - 1, slot])
        return self._drain_finished()

    def _release_predicted_done(self) -> None:
        """Predictive early slot turnover (async loop): a row whose
        in-flight chunk provably completes it by length — pending first
        token + accepted tokens + the chunk's steps reach ``max_new``; an
        eos can only finish it *sooner* — releases its slot and blocks NOW,
        so this step's admissions refill the slot without waiting for the
        harvest.  The successor's admit and first chunk queue behind the
        in-flight chunk on the device stream, so the retiring row's stale
        writes land before the successor's prefill overwrites them and are
        never attended.  Its tokens still arrive at the next harvest
        (``_Inflight.states`` holds the reference); ``state.released``
        keeps the resource frees exactly-once."""
        fl = self._inflight
        if fl is None:
            return
        # a speculative chunk's guaranteed emission is 1 (accept-0 still
        # emits the verifier's correction token); lockstep chunks emit
        # exactly fl.steps
        min_emit = 1 if self.spec else fl.steps
        for state in fl.states:
            if state is None or state.done or state.released:
                continue
            tok0_pending = 1 if state.pending_first else 0
            if len(state.tokens) + tok0_pending + min_emit >= state.req.max_new:
                self._release_resources(state)

    def _step_async(self) -> List[CompletedRequest]:
        """Double-buffered pipeline step: admit (no sync — first tokens
        merge into the device carry), dispatch chunk N+1, and only then
        block on chunk N's tokens — so queue management, admission, and
        finish bookkeeping for chunk N overlap the device computing N+1."""
        self._release_predicted_done()
        self._pull_arrivals()
        self._update_shed()
        self._admit_phase()

        prev, new = self._inflight, None
        if self.n_decoding:
            active, tables, block_size, steps = self._chunk_inputs()
            if self.spec:
                # the length carry is device-resident (_cl_dev): rows
                # advance by their own accepted counts, which the host
                # only learns at harvest.  _cur_len meanwhile tracks the
                # conservative upper bound (full draft_k + 1 per live
                # row), which is all block allocation needs.
                k = self._draft_k_eff
                (self.cache, toks_f, n_acc_f, self._lt_dev,
                 self._cl_dev) = _spec_tick_jit(
                    cfg=self.cfg, draft_cfg=self.draft_cfg,
                    params=self.params, cache=self.cache,
                    last_token=self._lt_dev, cur_len=self._cl_dev,
                    active=active, slot_keys=self._sk_dev, tables=tables,
                    sampling=self.sampling, draft_k=k,
                    block_size=block_size, attn_impl=self.attn_impl,
                )
                self.clock += 1
                self.stats.ticks += 1
                self.stats.work_ticks += k + 1
                new = _Inflight(toks_f, 1, self._decode_states(),
                                self.stats.work_ticks, n_acc=n_acc_f,
                                draft_k=k)
                self._cur_len = np.minimum(
                    self._cur_len + (k + 1) * active,
                    self.max_len - 1,
                ).astype(np.int32)
            else:
                if self.tiers is not None:
                    # per-rung dispatches (each masks cur_len/tables itself
                    # with fresh arrays and chains _lt_dev through)
                    toks_f = tuple(self._dispatch_tier_chunks(
                        active, tables, block_size, steps
                    ))
                else:
                    # cur_len is copied because the host mutates it while the
                    # chunk is in flight (numpy operands may be aliased
                    # zero-copy by the device buffer); `active` and `tables`
                    # are fresh arrays already
                    self.cache, toks_f, self._lt_dev = _decode_tick_jit(
                        cfg=self.cfg, params=self.params, cache=self.cache,
                        last_token=self._lt_dev, cur_len=self._cur_len.copy(),
                        active=active, slot_keys=self._sk_dev, tables=tables,
                        sampling=self.sampling, steps=steps,
                        block_size=block_size, attn_impl=self.attn_impl,
                    )
                self.clock += steps
                self.stats.ticks += steps
                self.stats.work_ticks += steps
                new = _Inflight(toks_f, steps, self._decode_states(),
                                self.stats.work_ticks)
                # advance the host view past the chunk just dispatched (the
                # device carry advances identically; the clamp matches the
                # sync loop's post-harvest update)
                self._cur_len = np.minimum(
                    self._cur_len + steps * active, self.max_len - 1
                ).astype(np.int32)
        elif self.n_active:
            # only mid-prefill rows resident: no decode chunk to dispatch
            # (their chunks went out in _admit_phase); the clock still
            # advances so the next step keeps the chunks flowing
            self.clock += 1
        elif prev is None:
            # idle: jump to the next arrival instead of burning empty ticks
            if self._pending:
                self.clock = max(self.clock + 1, self._pending[0][0])
            else:
                self.clock += 1
        self._inflight = new
        if prev is not None:
            self._harvest(prev)
        return self._drain_finished()

    def _harvest(self, fl: _Inflight) -> None:
        """Block on an in-flight chunk's token transfer (the device is
        already executing the next chunk) and run the deferred bookkeeping:
        admit-time first tokens queued since the previous harvest, then the
        chunk's tokens for the rows that were live at its dispatch."""
        tb = time.perf_counter()
        if isinstance(fl.toks, tuple):
            # quality tiers: per-rung chunk parts with disjoint row masks
            # (zeros elsewhere) — the merged chunk is the elementwise sum
            toks = np.asarray(fl.toks[0])
            for p in fl.toks[1:]:
                toks = toks + np.asarray(p)
        else:
            toks = np.asarray(fl.toks)           # (steps, N)
        n_acc = np.asarray(fl.n_acc) if fl.n_acc is not None else None
        pend, self._pending_tok0 = self._pending_tok0, []
        drained = [
            (states, np.asarray(t0s), idxs) for states, t0s, idxs in pend
        ]
        self.stats.host_block_s += time.perf_counter() - tb

        eos = self.sampling.eos_id
        for states, tok0s, idxs in drained:
            for state, i in zip(states, idxs):
                state.pending_first = False
                if state.preempted:
                    # preempted before its first token was harvested: the
                    # resume snapshot holds only accepted tokens, so this
                    # tok0 is discarded and replayed identically
                    continue
                tok0 = int(tok0s[i])
                state.tokens.append(tok0)
                self.stats.generated_tokens += 1
                if (len(state.tokens) >= state.req.max_new
                        or (eos >= 0 and tok0 == eos)):
                    # discovered one chunk late: the row decoded one garbage
                    # chunk meanwhile (skipped below via state.done);
                    # len(tokens) covers re-admitted rows that resume with
                    # their accepted tokens already in the list
                    self._finish(
                        state, "eos" if (eos >= 0 and tok0 == eos) else "length"
                    )
        if n_acc is None:
            self._accept_chunk(fl.states, toks, fl.steps, fl.work_end)
            return
        # speculative chunk: reconcile the host length views with the
        # now-known per-row accepted counts before accepting.  Only rows
        # still owned by their dispatched occupant matter — a finished or
        # preempted row's slot values are rewritten at its next admission
        # (and the identity guard is what makes a successor admitted
        # between dispatch and harvest safe)
        for slot, state in enumerate(fl.states):
            if (state is None or state.done or state.preempted
                    or self._active[slot] is not state):
                continue
            na = int(n_acc[slot])
            self._cl_true[slot] = min(
                int(self._cl_true[slot]) + na, self.max_len - 1
            )
            ub = int(self._cl_true[slot])
            if self._inflight is not None and self._inflight.states[slot] is state:
                ub += self._inflight.draft_k + 1  # the still-in-flight chunk
            self._cur_len[slot] = min(ub, self.max_len - 1)
        self._accept_spec_chunk(fl.states, toks, n_acc, fl.work_end, fl.draft_k)

    def close(self) -> Dict[int, CompletedRequest]:
        """Flush the pipeline (harvest the in-flight chunk and any pending
        admit tokens) and seal the session: subsequent ``submit``/``step``/
        ``run`` raise ``RuntimeError``.  Ready/pending requests that were
        never admitted stay unserved.  Idempotent; returns the completed
        results."""
        if not self._closed:
            fl, self._inflight = self._inflight, None
            if fl is not None:
                with self._mesh_ctx():
                    self._harvest(fl)
            self._closed = True
        return dict(self._completed)

    def run(self, max_steps: Optional[int] = None) -> Dict[int, CompletedRequest]:
        """Drive until every queued request completes, or ``max_steps``
        calls to ``step()`` (each executes up to ``steps_per_tick`` decode
        ticks — a watchdog on scheduler iterations, not device ticks)."""
        if self._closed:
            raise RuntimeError(
                "ServeSession is closed — its pipeline was flushed by "
                "close(); create a new session"
            )
        n = 0
        while not self.drained:
            self.step()
            n += 1
            if max_steps is not None and n >= max_steps:
                break
        return dict(self._completed)

    @property
    def results(self) -> Dict[int, CompletedRequest]:
        return dict(self._completed)

    # -- warmup / compile accounting ------------------------------------------

    def warmup(self) -> Dict[str, int]:
        """Compile the decode tick, the admit-carry merge, and every
        prompt-bucket prefill program up-front.  All warmup rows are no-ops,
        so session state is semantically untouched; the output caches are
        *chained* back into ``self.cache`` (content-identical up to
        positions that are invisible until overwritten) because the
        cache-donating programs consume their input buffers on non-CPU
        backends.  After this, no request pattern recompiles; returns
        ``compile_stats``."""
        with self._mesh_ctx():
            return self._warmup_impl()

    def _warmup_impl(self) -> Dict[str, int]:
        if self.mesh is not None:
            # normalize placements (see _pin_carry_jit): every later warmup
            # and serving dispatch then sees identical operand shardings
            self.cache = _pin_pool_jit(self.cache)
            self._lt_dev = _pin_carry_jit(self._lt_dev)
            self._sk_dev = _pin_carry_jit(self._sk_dev)
            self._cl_dev = _pin_carry_jit(self._cl_dev)
            self._base_key = _pin_carry_jit(self._base_key)
        widths = sorted({self._admit_width(n) for n in range(1, self.num_slots + 1)})
        # quality tiers: every program that keys on the model config compiles
        # once PER LADDER RUNG (serving never dispatches the base cfg then)
        warm_cfgs = self._tier_cfgs if self.tiers is not None else (self.cfg,)
        for A in widths:
            for b in self.buckets.sizes:
                prompts = np.zeros((A, b), np.int32)
                prompt_lens = np.ones((A,), np.int32)
                slots = np.arange(A, dtype=np.int32)
                valid = np.zeros((A,), bool)    # all rows no-op: state safe
                req_ids = np.zeros((A,), np.int32)
                for acfg in warm_cfgs:
                    if self.layout == "paged":
                        nb = -(-b // self.block_size)
                        out = _admit_fused_paged_jit(
                            cfg=acfg, params=self.params, cache=self.cache,
                            prompts=prompts, prompt_lens=prompt_lens,
                            # all-sentinel ids: every scatter dropped,
                            # state safe
                            block_ids=np.full((A, nb), self.num_blocks,
                                              np.int32),
                            req_ids=req_ids, base_key=self._base_key,
                            sampling=self.sampling, block_size=self.block_size,
                        )
                    elif self.prefill_mode == "fused":
                        out = _admit_fused_jit(
                            cfg=acfg, params=self.params, cache=self.cache,
                            prompts=prompts, prompt_lens=prompt_lens,
                            slots=slots, valid=valid, req_ids=req_ids,
                            base_key=self._base_key, sampling=self.sampling,
                        )
                    else:
                        out = _admit_decode_jit(
                            cfg=acfg, params=self.params, cache=self.cache,
                            prompts=prompts, prompt_lens=prompt_lens,
                            slots=slots, valid=valid, req_ids=req_ids,
                            base_key=self._base_key, sampling=self.sampling,
                            max_len=self.max_len, cache_dtype=self.cache_dtype,
                        )
                    jax.block_until_ready(out)
                    self.cache = out[0]
                    if self.chunked:
                        # chunk prefill dispatches at (admit width x chunk
                        # bucket) with the session's fixed table width —
                        # all-sentinel tables make every warmup write drop,
                        # so state stays semantically untouched
                        out = _prefill_chunk_jit(
                            cfg=acfg, params=self.params, cache=self.cache,
                            tokens=np.zeros((A, b), np.int32),
                            starts=np.zeros((A,), np.int32),
                            chunk_lens=np.ones((A,), np.int32),
                            tables=np.full(
                                (A, self._tables.shape[1]),
                                self.num_blocks, np.int32,
                            ),
                            req_ids=req_ids, base_key=self._base_key,
                            sampling=self.sampling,
                            block_size=self.block_size,
                        )
                        jax.block_until_ready(out)
                        self.cache = out[0]
            # the async admit-carry merge compiles once per admit width;
            # all-False valid keeps the device carry content intact.  tok0s
            # and keys are jnp arrays on purpose: the real calls pass admit-
            # program futures, and the jit cache keys numpy and jax.Array
            # operands separately even at identical avals
            t0w, kw = jnp.zeros((A,), jnp.int32), jnp.zeros((A, 2), jnp.uint32)
            if self.mesh is not None:
                # under the mesh, match the real futures' shardings exactly:
                # use the admit program's own (no-op) outputs
                t0w, kw = out[1], out[2]
            self._lt_dev, self._sk_dev = _admit_merge_jit(
                self._lt_dev, self._sk_dev, np.arange(A, dtype=np.int32),
                t0w, kw, np.zeros((A,), bool),
            )
            if self.spec and self.loop == "async":
                # the spec length-carry merge compiles once per admit
                # width; all-False valid keeps the carry content intact.
                # The real call passes host numpy prompt_lens — match it
                self._cl_dev = _spec_merge_len_jit(
                    self._cl_dev, np.arange(A, dtype=np.int32),
                    np.ones((A,), np.int32), np.zeros((A,), bool),
                )
        # warm the work-tick program with the SAME operand types the
        # session's loop dispatches (async: device-resident carry; sync:
        # host numpy) — mixing them would leave the first real chunk a
        # cache miss.  Speculative sessions dispatch _spec_tick instead of
        # the decode tick, never both
        dev_carry = self.loop == "async"
        if self.spec:
            # dynamic_draft_k: draft_k is a STATIC jit arg, so warm every
            # rung of the halving ladder — adaptation then switches between
            # already-compiled programs and never compiles mid-trace
            for dk in (self._draft_ks if self.dynamic_draft else (self.draft_k,)):
                out = _spec_tick_jit(
                    cfg=self.cfg, draft_cfg=self.draft_cfg, params=self.params,
                    cache=self.cache,
                    last_token=self._lt_dev if dev_carry else self._last_token,
                    cur_len=self._cl_dev if dev_carry else self._cur_len.copy(),
                    active=np.zeros((self.num_slots,), bool),
                    slot_keys=self._sk_dev if dev_carry else self._slot_keys,
                    tables=self._tables.copy(),
                    sampling=self.sampling, draft_k=dk,
                    block_size=self.block_size, attn_impl=self.attn_impl,
                )
                jax.block_until_ready(out)
                self.cache = out[0]
                if dev_carry:
                    self._lt_dev, self._cl_dev = out[3], out[4]
        else:
            for tcfg in warm_cfgs:
                out = _decode_tick_jit(
                    cfg=tcfg, params=self.params, cache=self.cache,
                    last_token=self._lt_dev if dev_carry else self._last_token,
                    cur_len=self._cur_len.copy(),
                    active=np.zeros((self.num_slots,), bool),
                    slot_keys=self._sk_dev if dev_carry else self._slot_keys,
                    tables=self._tables.copy() if self.layout == "paged" else None,
                    sampling=self.sampling, steps=self.steps_per_tick,
                    block_size=self.block_size if self.layout == "paged" else 0,
                    attn_impl=self.attn_impl,
                )
                jax.block_until_ready(out)
                self.cache = out[0]
        if self.layout == "paged" and self.prefix_sharing:
            # copy-on-write fork program: src == dst makes the warmup copy a
            # content no-op; src/dst are traced, so this one compile serves
            # every real fork
            self.cache = _copy_block_jit(self.cache, np.int32(0), np.int32(0))
            jax.block_until_ready(self.cache)
        if self.zero_on_evict:
            self.cache = _evict_jit(self.cache, np.int32(0))
            jax.block_until_ready(self.cache)
        return self.compile_stats()

    def compile_stats(self) -> Dict[str, int]:
        return scheduler_compile_stats()
