"""Continuous-batching serve scheduler over a fixed pool of decode slots.

The PR-1 engine (``repro.serve.engine.generate``) serves one fixed batch of
same-length requests end-to-end: every request in the batch pays for the
longest prompt and the largest ``max_new``.  ``ServeSession`` instead keeps
a pool of ``num_slots`` decode slots hot and refills each slot from a
request queue the moment its occupant finishes (EOS or max-token), so the
approximate-multiplier matmuls stay saturated instead of idling behind the
longest request.

Two **cache layouts** share the session (``cache_layout=``):

* ``"slots"`` — every request reserves a worst-case ``max_len`` KV stripe
  for its lifetime (the PR-2 engine, kept as the parity oracle);
* ``"paged"`` — K/V live in a global ``BlockPool`` of fixed-size blocks
  and each request holds only the blocks its actual context occupies,
  recorded in a fixed-width per-slot block table.  Admission allocates
  ``ceil(prompt_len / block_size)`` blocks, decode appends one block only
  when a request's context crosses a block boundary, and completion frees
  every held block immediately — so mixed-context traffic shares HBM
  instead of stranding it, and ``num_slots`` (decode width) decouples from
  memory.  Admission reserves each request's worst case
  (``ceil((prompt_len + max_new - 1) / block_size)`` blocks) against the
  pool, which makes mid-decode block appends infallible: no preemption
  path is ever needed.  Greedy float outputs are bit-identical to the slot
  layout (and to standalone ``generate``) — masked block-gather garbage
  receives softmax probability exactly 0.0.

Everything runs under **fixed compiled shapes**:

* ONE decode program per (config, sampling, num_slots, max_len [, layout])
  — a single ``decode_step`` / ``paged_decode_step`` over the pooled cache
  each tick, all slots at once; block-table *contents* are traced data, so
  no context layout recompiles;
* ONE prefill program per prompt-length *bucket* (``PromptBuckets``):
  every admission in a tick shares a single batched (width ``num_slots``)
  fused ``forward(return_kv=True)`` pass that seeds the freed slots' KV rows
  and samples each first token (SSM/hybrid families fall back to a masked
  teacher-forced scan inside the same jit); unadmitted rows degenerate to
  exact no-ops (``cache.scatter_rows`` where-gather for slots, dropped
  sentinel-block scatters for paged), and the other slots' rows are
  untouched.

No request pattern (arrival order, prompt length, max_new mix) triggers a
recompile after ``warmup()`` — asserted by ``compile_stats`` deltas in
tests/test_scheduler.py.

Sampling is per-request deterministic: each request gets
``fold_in(session_key, req_id)`` and each sampled token position folds in
its cache position, so a request's output is independent of which slot it
lands in and of what else is in flight (bit-exact under float execution;
quantized modes couple batch rows through the dynamic per-tensor activation
scale, so there parity is statistical, not bitwise).

Execution modes: the session serves whatever ``cfg.approx`` selects —
``exact`` / ``exact_quant`` / ``approx`` (Pallas kernel) /
``approx_lowrank`` — and accepts ``freeze_params`` QWeight trees.
"""
from __future__ import annotations

import dataclasses
import functools
import heapq
from typing import Dict, List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig
from repro.models.transformer import (
    decode_step,
    forward,
    init_cache,
    init_paged_cache,
    paged_decode_step,
)
from repro.serve import cache as C
from repro.serve.engine import SamplingConfig, select_token

__all__ = [
    "Request",
    "CompletedRequest",
    "SchedulerStats",
    "ServeSession",
    "scheduler_compile_stats",
    "CACHE_LAYOUTS",
    "ADMISSION_POLICIES",
]

CACHE_LAYOUTS = ("slots", "paged")
ADMISSION_POLICIES = ("priority", "fifo", "sjf")


# ---------------------------------------------------------------------------
# Compiled programs (module-level jits: cfg/sampling static, shared cache)
# ---------------------------------------------------------------------------


@functools.partial(
    jax.jit, static_argnames=("cfg", "sampling", "steps", "block_size")
)
def _decode_tick_jit(
    cfg: ModelConfig,
    params,
    cache,
    last_token: jax.Array,     # (N,) int32
    cur_len: jax.Array,        # (N,) int32
    active: jax.Array,         # (N,) bool
    slot_keys: jax.Array,      # (N, 2) uint32 per-request PRNG keys
    tables: Optional[jax.Array] = None,   # (N, W) int32 — paged layout only
    *,
    sampling: SamplingConfig,
    steps: int = 1,
    block_size: int = 0,
):
    """``steps`` decode steps across all slots in one dispatch (decode
    chunk).  Inactive slots compute garbage into their own rows only (masked
    out here and overwritten at next admit; under the paged layout their
    all-sentinel table rows drop the writes entirely).  Rows that finish
    mid-chunk (eos here, max-token on the host) overshoot at most
    ``steps - 1`` positions; the host discards the extra tokens.  Overshoot
    cache writes go through per-row ``.at[...].set`` scatters, whose
    out-of-bounds updates are dropped (unlike ``dynamic_update_slice``,
    which CLAMPS — do not swap the write path without rechecking this); the
    hard guarantee, though, is ``submit``'s ``prompt_len + max_new <=
    max_len`` bound: no attending row ever reads a position an overshooting
    row could have written.  ``tables is None`` selects the slot layout at
    trace time — both layouts share this entry point, so the compile-count
    recompile checks cover them uniformly."""

    def one(carry, _):
        cache, last_token, cur_len, done = carry
        if tables is None:
            logits, cache = decode_step(
                cfg, params, cache, {"tokens": last_token[:, None]}, cur_len
            )
        else:
            logits, cache = paged_decode_step(
                cfg, params, cache, {"tokens": last_token[:, None]}, cur_len,
                tables, block_size=block_size,
            )
        # the sampled token lands at position cur_len + 1 -> unique, slot-
        # and schedule-independent key per token
        keys = jax.vmap(jax.random.fold_in)(slot_keys, cur_len + 1)
        toks = jax.vmap(lambda l, k: select_token(l[None], sampling, k)[0])(
            logits[:, 0, :], keys
        )
        if sampling.eos_id >= 0:
            toks = jnp.where(done, jnp.int32(sampling.eos_id), toks)
            done = done | (toks == sampling.eos_id)
        toks = jnp.where(active, toks, 0)
        last_token = jnp.where(active, toks, last_token)
        return (cache, last_token, cur_len + active, done), toks

    carry = (cache, last_token, cur_len, jnp.zeros_like(active))
    (cache, _, _, _), toks = jax.lax.scan(one, carry, None, length=steps)
    return cache, toks                      # toks: (steps, N)


def _request_keys(base_key, req_ids):
    """(A,) request ids -> (A, 2) per-request PRNG keys (computed in-jit so
    admission costs no extra host dispatches)."""
    return jax.vmap(jax.random.fold_in, in_axes=(None, 0))(base_key, req_ids)


def _first_tokens(last_logits, req_keys, prompt_lens, sampling: SamplingConfig):
    """(A, V) last-position logits -> (A,) first sampled tokens under the
    per-request fold_in key schedule (position == prompt_len)."""
    keys = jax.vmap(jax.random.fold_in)(req_keys, prompt_lens)
    return jax.vmap(lambda l, k: select_token(l[None], sampling, k)[0])(
        last_logits, keys
    )


_scatter_rows = C.scatter_rows


@functools.partial(jax.jit, static_argnames=("cfg", "sampling"))
def _admit_fused_jit(
    cfg: ModelConfig,
    params,
    cache,
    prompts: jax.Array,        # (A, S_bucket) int32, right-padded
    prompt_lens: jax.Array,    # (A,) int32
    slots: jax.Array,          # (A,) int32 — a permutation of range(num_slots)
    valid: jax.Array,          # (A,) bool — rows actually being admitted
    req_ids: jax.Array,        # (A,) int32
    base_key: jax.Array,       # (2,) uint32 session key
    *,
    sampling: SamplingConfig,
):
    """Batched fused prefill-on-admit (attention families): ONE
    full-sequence pass prefills every admission of this tick, seeds their
    slots' KV rows [0, S_bucket), and samples each first token.  Compiled
    once per bucket size; invalid rows are no-ops (see ``_scatter_rows``),
    so 1..A admissions share the program."""
    logits, _, kvs = forward(cfg, params, {"tokens": prompts}, return_kv=True)
    last = jnp.take_along_axis(
        logits, (prompt_lens - 1)[:, None, None], axis=1
    )[:, 0, :]
    k, v = kvs                                  # (L, A, S_bucket, Hkv, hd)
    Sb = prompts.shape[1]
    cache = dict(
        cache,
        k=_scatter_rows(cache["k"], k, slots, valid, s_cap=Sb),
        v=_scatter_rows(cache["v"], v, slots, valid, s_cap=Sb),
    )
    req_keys = _request_keys(base_key, req_ids)
    return cache, _first_tokens(last, req_keys, prompt_lens, sampling), req_keys


@functools.partial(
    jax.jit, static_argnames=("cfg", "sampling", "max_len", "cache_dtype")
)
def _admit_decode_jit(
    cfg: ModelConfig,
    params,
    cache,
    prompts: jax.Array,        # (A, S_bucket) int32, right-padded
    prompt_lens: jax.Array,    # (A,) int32
    slots: jax.Array,          # (A,) int32 — a permutation of range(num_slots)
    valid: jax.Array,          # (A,) bool
    req_ids: jax.Array,        # (A,) int32
    base_key: jax.Array,       # (2,) uint32 session key
    *,
    sampling: SamplingConfig,
    max_len: int,
    cache_dtype: str,
):
    """Batched teacher-forced prefill-on-admit for SSM/hybrid caches
    (conv/ssm state has no fused seeding path): scan the bucket positions on
    a fresh batch-A cache, freezing each row's state updates past its own
    prompt_len, then scatter the rows into their slots."""
    A, Sb = prompts.shape
    slot_cache = init_cache(cfg, A, max_len, jnp.dtype(cache_dtype))

    def body(carry, xs):
        cache_c, last = carry
        t, toks = xs
        logits, new_cache = decode_step(
            cfg, params, cache_c, {"tokens": toks[:, None]},
            jnp.full((A,), t, jnp.int32),
        )
        take = t < prompt_lens                   # (A,) per-row freeze
        cache_c = jax.tree.map(
            lambda n, o: jnp.where(
                take.reshape((1, A) + (1,) * (n.ndim - 2)), n, o
            ),
            new_cache,
            cache_c,
        )
        last = jnp.where((t == prompt_lens - 1)[:, None], logits[:, 0, :], last)
        return (cache_c, last), None

    init = (slot_cache, jnp.zeros((A, cfg.padded_vocab), jnp.float32))
    (slot_cache, last), _ = jax.lax.scan(
        body, init, (jnp.arange(Sb, dtype=jnp.int32), prompts.T)
    )
    cache = jax.tree.map(
        lambda full, part: _scatter_rows(full, part, slots, valid), cache, slot_cache
    )
    req_keys = _request_keys(base_key, req_ids)
    return cache, _first_tokens(last, req_keys, prompt_lens, sampling), req_keys


@functools.partial(jax.jit, static_argnames=("cfg", "sampling", "block_size"))
def _admit_fused_paged_jit(
    cfg: ModelConfig,
    params,
    cache,
    prompts: jax.Array,        # (A, S_bucket) int32, right-padded
    prompt_lens: jax.Array,    # (A,) int32
    block_ids: jax.Array,      # (A, ceil(S_bucket/block_size)) int32
    req_ids: jax.Array,        # (A,) int32
    base_key: jax.Array,       # (2,) uint32 session key
    *,
    sampling: SamplingConfig,
    block_size: int,
):
    """Batched fused prefill-on-admit against the paged cache: ONE
    full-sequence pass prefills every admission of this tick, scatters each
    row's K/V into its allocated blocks, and samples each first token.
    Unallocated / padding-row entries of ``block_ids`` hold the sentinel
    ``num_blocks`` and are dropped by the scatter — no ``valid`` mask is
    needed, and 1..A admissions share the program (compiled once per
    (admit width, bucket))."""
    logits, _, kvs = forward(cfg, params, {"tokens": prompts}, return_kv=True)
    last = jnp.take_along_axis(
        logits, (prompt_lens - 1)[:, None, None], axis=1
    )[:, 0, :]
    cache = C.scatter_prompt_blocks(cache, kvs, block_ids, block_size)
    req_keys = _request_keys(base_key, req_ids)
    return cache, _first_tokens(last, req_keys, prompt_lens, sampling), req_keys


@functools.partial(jax.jit, static_argnames=())
def _evict_jit(cache, slot: jax.Array):
    return C.evict_slot(cache, slot)


def _jit_cache_size(fn) -> int:
    """Compiled-program count of a jitted callable. ``_cache_size`` is a
    private jax attribute (stable across 0.4.x); fall back to a sentinel
    rather than crash serving if a jax upgrade drops it — the
    zero-recompile tests compare these values, so a sentinel keeps the
    deltas zero and surfaces the API break via the recorded -1."""
    get = getattr(fn, "_cache_size", None)
    return int(get()) if callable(get) else -1


def scheduler_compile_stats() -> Dict[str, int]:
    """Compiled-program counts of the scheduler's jit entry points.  A trace
    that triggers zero recompiles leaves every count unchanged."""
    return {
        "decode_tick": _jit_cache_size(_decode_tick_jit),
        "admit_fused": _jit_cache_size(_admit_fused_jit),
        "admit_decode": _jit_cache_size(_admit_decode_jit),
        "admit_paged": _jit_cache_size(_admit_fused_paged_jit),
        "evict": _jit_cache_size(_evict_jit),
    }


# ---------------------------------------------------------------------------
# Requests / results / stats
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class Request:
    """One generation request. ``arrival`` is in scheduler ticks (one decode
    step == one tick); ``priority`` orders admission (lower first, FIFO
    within a class)."""

    req_id: int
    prompt: np.ndarray          # (S0,) int32
    max_new: int
    priority: int = 0
    arrival: int = 0


@dataclasses.dataclass(frozen=True)
class CompletedRequest:
    req_id: int
    prompt: np.ndarray
    tokens: np.ndarray          # generated tokens (first token included)
    finish_reason: str          # "eos" | "length"
    admitted_tick: int
    finished_tick: int

    @property
    def full_sequence(self) -> np.ndarray:
        return np.concatenate([self.prompt, self.tokens])


@dataclasses.dataclass
class SchedulerStats:
    ticks: int = 0                  # decode ticks executed
    busy_slot_steps: int = 0        # sum over ticks of active slot count
    idle_slot_steps: int = 0        # capacity - busy over executed ticks
    admitted: int = 0
    completed: int = 0
    generated_tokens: int = 0       # across all requests (incl. admit token)
    admit_calls: int = 0            # batched prefill dispatches
    prefills: Dict[int, int] = dataclasses.field(default_factory=dict)  # bucket -> requests
    peak_active: int = 0            # max concurrently-resident requests
    peak_blocks_in_use: int = 0     # paged layout: max pool blocks held at once
    # per-request latencies in scheduler ticks, appended at admit / finish
    ttft_ticks: List[int] = dataclasses.field(default_factory=list)
    latency_ticks: List[int] = dataclasses.field(default_factory=list)

    @property
    def slot_utilization(self) -> float:
        cap = self.busy_slot_steps + self.idle_slot_steps
        return self.busy_slot_steps / cap if cap else 0.0

    @staticmethod
    def _pct(xs: List[int], q: float) -> float:
        return float(np.percentile(np.asarray(xs), q)) if xs else 0.0

    # time-to-first-token (queue wait + prefill) and total latency, both in
    # ticks relative to the request's arrival tick
    @property
    def ttft_p50(self) -> float:
        return self._pct(self.ttft_ticks, 50)

    @property
    def ttft_p95(self) -> float:
        return self._pct(self.ttft_ticks, 95)

    @property
    def latency_p50(self) -> float:
        return self._pct(self.latency_ticks, 50)

    @property
    def latency_p95(self) -> float:
        return self._pct(self.latency_ticks, 95)


@dataclasses.dataclass
class _ActiveSlot:
    req: Request
    slot: int
    tokens: List[int]
    admitted_tick: int


# ---------------------------------------------------------------------------
# ServeSession
# ---------------------------------------------------------------------------


class ServeSession:
    """Continuous-batching serving over a slot pool (see module docstring).

    >>> sess = ServeSession(cfg, params, num_slots=8, max_len=256)
    >>> sess.submit(prompt_ids, max_new=64)
    >>> results = sess.run()          # {req_id: CompletedRequest}

    ``cache_layout="paged"`` swaps the per-slot ``max_len`` KV stripes for a
    global ``BlockPool`` of ``num_blocks`` blocks of ``block_size`` KV rows:
    ``num_slots`` then bounds decode *width* only, and memory admission is
    governed by each request's worst-case block reservation.  The default
    ``num_blocks`` matches the slot layout's HBM exactly
    (``num_slots * max_len / block_size``); raise ``num_slots`` (or lower
    ``num_blocks``) to oversubscribe.  ``policy`` orders the ready queue:
    ``"priority"`` (the ``Request.priority`` classes, FIFO within a class —
    the default, and plain FIFO when priorities are untouched), ``"fifo"``
    (ignore priorities), or ``"sjf"`` — shortest job first on
    ``max_new + bucketed prompt len``, which minimizes mean latency on a
    drain tail."""

    def __init__(
        self,
        cfg: ModelConfig,
        params,
        *,
        num_slots: int = 4,
        max_len: int = 256,
        prompt_buckets: Sequence[int] = (8, 16, 32, 64),
        sampling: Optional[SamplingConfig] = None,
        cache_dtype=jnp.float32,
        seed: int = 0,
        zero_on_evict: bool = False,
        steps_per_tick: int = 1,
        cache_layout: str = "slots",
        block_size: int = 16,
        num_blocks: Optional[int] = None,
        policy: str = "priority",
    ):
        if not cfg.embed_input:
            raise ValueError(f"{cfg.name}: token serving requires an embed-input arch")
        if cache_layout not in CACHE_LAYOUTS:
            raise ValueError(f"cache_layout {cache_layout!r} not in {CACHE_LAYOUTS}")
        if policy not in ADMISSION_POLICIES:
            raise ValueError(f"policy {policy!r} not in {ADMISSION_POLICIES}")
        self.cfg = cfg
        self.params = params
        self.sampling = sampling if sampling is not None else SamplingConfig()
        self.max_len = int(max_len)
        self.layout = cache_layout
        self.policy = policy
        self.buckets = C.PromptBuckets(prompt_buckets)
        if self.buckets.max_size > self.max_len:
            raise ValueError(
                f"largest prompt bucket {self.buckets.max_size} > max_len {self.max_len}"
            )
        self.pool = C.SlotPool(num_slots)
        self.num_slots = num_slots
        self.cache_dtype = jnp.dtype(cache_dtype).name
        self.zero_on_evict = zero_on_evict
        if steps_per_tick < 1:
            raise ValueError(f"steps_per_tick must be >= 1, got {steps_per_tick}")
        # decode-chunk size: dispatches amortize steps_per_tick-fold, rows
        # finishing mid-chunk waste <= steps_per_tick - 1 slot-steps each
        self.steps_per_tick = int(steps_per_tick)
        # SSM/hybrid caches carry conv/ssm state -> masked teacher-forced admit
        self.prefill_mode = "decode" if cfg.family in ("ssm", "hybrid") else "fused"

        if cache_layout == "paged":
            if cfg.family in ("ssm", "hybrid"):
                raise ValueError(
                    f"{cfg.family} decode state is O(1) per request (no KV "
                    "sequence axis) — there is nothing to page; use "
                    'cache_layout="slots"'
                )
            if zero_on_evict:
                raise ValueError(
                    "zero_on_evict applies to the slot layout only (freed "
                    "blocks are invisible until re-seeded by their next owner)"
                )
            if block_size < 1:
                raise ValueError(f"block_size must be >= 1, got {block_size}")
            if self.max_len % block_size:
                raise ValueError(
                    f"max_len {self.max_len} must be a multiple of "
                    f"block_size {block_size} (fixed-width block tables)"
                )
            self.block_size = int(block_size)
            self.table_width = self.max_len // self.block_size
            if num_blocks is None:
                num_blocks = num_slots * self.table_width    # == slot-layout HBM
            self.blocks = C.BlockPool(num_blocks)
            self.num_blocks = int(num_blocks)
            self.cache = init_paged_cache(
                cfg, self.num_blocks, self.block_size, jnp.dtype(cache_dtype)
            )
            # per-slot block table (sentinel == num_blocks -> writes dropped),
            # held physical blocks, and not-yet-held worst-case reservation
            self._tables = np.full(
                (num_slots, self.table_width), self.num_blocks, np.int32
            )
            self._held: List[List[int]] = [[] for _ in range(num_slots)]
            self._future = np.zeros((num_slots,), np.int64)
            self._reserved_total = 0           # future blocks across all rows
        else:
            self.cache = init_cache(cfg, num_slots, self.max_len, jnp.dtype(cache_dtype))
        self._last_token = np.zeros((num_slots,), np.int32)
        self._cur_len = np.zeros((num_slots,), np.int32)
        self._slot_keys = np.zeros((num_slots, 2), np.uint32)
        self._base_key = jax.random.PRNGKey(seed)

        self._active: List[Optional[_ActiveSlot]] = [None] * num_slots
        self._pending: List[Request] = []       # future arrivals, sorted
        self._ready: List[Tuple[int, int, Request]] = []  # heap (policy key, seq)
        self._seq = 0
        self._next_id = 0
        self.clock = 0
        self.stats = SchedulerStats()
        self._completed: Dict[int, CompletedRequest] = {}
        self._just_finished: List[int] = []     # drained by each step()

    # -- queue ---------------------------------------------------------------

    def submit(
        self,
        prompt,
        max_new: int,
        *,
        req_id: Optional[int] = None,
        priority: int = 0,
        arrival: int = 0,
    ) -> int:
        """Queue one request; returns its id. ``arrival`` in ticks.

        Every shape constraint is validated HERE, naming the request — a
        request that can never be admitted must fail at submit, not deep
        inside an admission tick."""
        prompt = np.asarray(prompt, np.int32).reshape(-1)
        rid = self._next_id if req_id is None else req_id
        if prompt.size < 1:
            raise ValueError(f"request {rid}: empty prompt")
        if max_new < 1:
            raise ValueError(f"request {rid}: max_new must be >= 1, got {max_new}")
        if prompt.size > self.buckets.max_size:
            raise ValueError(
                f"request {rid}: prompt_len {prompt.size} exceeds the largest "
                f"prompt bucket {self.buckets.max_size} (buckets "
                f"{self.buckets.sizes}) — split the prompt or widen the buckets"
            )
        bucket = self.buckets.bucket(prompt.size)
        if max(bucket, prompt.size + max_new) > self.max_len:
            raise ValueError(
                f"request {rid}: prompt_len {prompt.size} + max_new {max_new} "
                f"(bucket {bucket}) exceeds cache max_len {self.max_len}"
            )
        if self.layout == "paged":
            worst = self._worst_blocks(prompt.size, max_new)
            if worst > self.num_blocks:
                raise ValueError(
                    f"request {rid}: worst-case context needs {worst} blocks "
                    f"but the pool only has {self.num_blocks} — it could "
                    "never be admitted"
                )
        if req_id is None:
            req_id = rid
        elif (
            req_id in self._completed
            or any(r.req_id == req_id for r in self._pending)
            or any(r.req_id == req_id for _, _, r in self._ready)
            or any(s is not None and s.req.req_id == req_id for s in self._active)
        ):
            raise ValueError(f"req_id {req_id} already in use")
        self._next_id = max(self._next_id, req_id) + 1
        req = Request(req_id, prompt, int(max_new), int(priority), int(arrival))
        if req.arrival > self.clock:
            self._pending.append(req)
            self._pending.sort(key=lambda r: r.arrival)
        else:
            self._push_ready(req)
        return req_id

    def submit_all(self, requests: Sequence[Request]) -> None:
        for r in requests:
            self.submit(r.prompt, r.max_new, req_id=r.req_id,
                        priority=r.priority, arrival=r.arrival)

    def _ready_key(self, req: Request) -> int:
        """Admission-order key under the session policy (ties broken FIFO by
        submission sequence)."""
        if self.policy == "sjf":
            # shortest job first: expected residency = generation budget +
            # bucketed prefill cost
            return req.max_new + self.buckets.bucket(req.prompt.size)
        if self.policy == "fifo":
            return 0
        return req.priority

    def _push_ready(self, req: Request) -> None:
        heapq.heappush(self._ready, (self._ready_key(req), self._seq, req))
        self._seq += 1

    # -- admission -----------------------------------------------------------

    def _worst_blocks(self, prompt_len: int, max_new: int) -> int:
        """Blocks the request could ever hold: its last cache write lands at
        position ``prompt_len + max_new - 2`` (token ``t`` of ``max_new`` is
        written at ``prompt_len + t - 2``; the final sampled token is output,
        never written), and prefill occupies ``[0, prompt_len)`` — bucket
        right-padding past the last prompt block is dropped, never stored."""
        return -(-(prompt_len + max_new - 1) // self.block_size)

    def _admit_width(self, n: int) -> int:
        """Admission rows are width-bucketed to powers of two (capped at
        ``num_slots``) so small admissions don't pay a full-width prefill:
        the compiled-program set stays {1, 2, 4, ...} x prompt buckets."""
        w = 1
        while w < n:
            w <<= 1
        return min(w, self.num_slots)

    def _admit_many(self, reqs: List[Request]) -> None:
        """Admit up to ``num_slots`` requests with ONE prefill dispatch: all
        prompts pad to the largest needed bucket, the row count pads to the
        admit-width bucket, and padding rows are no-ops — so the compiled
        program depends only on (admit width, prompt bucket).  Under the
        paged layout each request additionally acquires its prompt's blocks
        (``ceil(prompt_len / block_size)`` — proportional to the *actual*
        context, not the bucket or ``max_len``), converting that much of the
        reservation ``step`` took out when it popped the request."""
        assert 0 < len(reqs) <= self.pool.free_count
        A = self._admit_width(len(reqs))
        bucket = max(self.buckets.bucket(r.prompt.size) for r in reqs)
        prompts = np.zeros((A, bucket), np.int32)
        prompt_lens = np.ones((A,), np.int32)
        valid = np.zeros((A,), bool)
        req_ids = np.zeros((A,), np.int32)
        row_slot = [self.pool.acquire() for _ in reqs]
        for i, req in enumerate(reqs):
            plen = req.prompt.size
            prompts[i, :plen] = req.prompt
            prompt_lens[i] = plen
            valid[i] = True
            req_ids[i] = req.req_id
        if self.layout == "paged":
            nb = -(-bucket // self.block_size)
            block_ids = np.full((A, nb), self.num_blocks, np.int32)
            for i, req in enumerate(reqs):
                slot = row_slot[i]
                ninit = -(-req.prompt.size // self.block_size)
                got = self.blocks.acquire_many(ninit)
                assert got is not None, "reservation admitted an unfundable request"
                block_ids[i, :ninit] = got
                self._held[slot] = got
                self._tables[slot, :] = self.num_blocks
                self._tables[slot, :ninit] = got
                self._future[slot] = self._worst_blocks(req.prompt.size, req.max_new) - ninit
                self._reserved_total -= ninit          # reservation -> held
            self.cache, tok0s, req_keys = _admit_fused_paged_jit(
                cfg=self.cfg, params=self.params, cache=self.cache,
                prompts=prompts, prompt_lens=prompt_lens, block_ids=block_ids,
                req_ids=req_ids, base_key=self._base_key,
                sampling=self.sampling, block_size=self.block_size,
            )
            self.stats.peak_blocks_in_use = max(
                self.stats.peak_blocks_in_use, self.blocks.busy_count
            )
        else:
            # valid rows -> their acquired slots; padding rows -> distinct
            # other slot ids, keeping `slots` collision-free (deterministic
            # scatter, and the no-op rows rewrite rows they gathered — see
            # _scatter_rows)
            rest = [s for s in range(self.num_slots) if s not in row_slot]
            slots = np.asarray((row_slot + rest)[:A], np.int32)
            if self.prefill_mode == "fused":
                self.cache, tok0s, req_keys = _admit_fused_jit(
                    cfg=self.cfg, params=self.params, cache=self.cache,
                    prompts=prompts, prompt_lens=prompt_lens, slots=slots,
                    valid=valid, req_ids=req_ids, base_key=self._base_key,
                    sampling=self.sampling,
                )
            else:
                self.cache, tok0s, req_keys = _admit_decode_jit(
                    cfg=self.cfg, params=self.params, cache=self.cache,
                    prompts=prompts, prompt_lens=prompt_lens, slots=slots,
                    valid=valid, req_ids=req_ids, base_key=self._base_key,
                    sampling=self.sampling,
                    max_len=self.max_len, cache_dtype=self.cache_dtype,
                )
        tok0s = np.asarray(tok0s)
        req_keys = np.asarray(req_keys, np.uint32)
        self.stats.admit_calls += 1
        self.stats.prefills[bucket] = self.stats.prefills.get(bucket, 0) + len(reqs)
        eos = self.sampling.eos_id
        for i, req in enumerate(reqs):
            slot, tok0 = row_slot[i], int(tok0s[i])
            self._last_token[slot] = tok0
            self._cur_len[slot] = int(prompt_lens[i])
            self._slot_keys[slot] = req_keys[i]
            self.stats.admitted += 1
            self.stats.generated_tokens += 1
            self.stats.ttft_ticks.append(self.clock - req.arrival)
            state = _ActiveSlot(req, slot, [tok0], self.clock)
            if req.max_new == 1 or (eos >= 0 and tok0 == eos):
                self._finish(state, "eos" if (eos >= 0 and tok0 == eos) else "length")
            else:
                self._active[slot] = state

    def _finish(self, state: _ActiveSlot, reason: str) -> None:
        self._active[state.slot] = None
        self.pool.release(state.slot)
        if self.layout == "paged":
            # free every held block immediately and drop the unused remainder
            # of the worst-case reservation; stale block contents are
            # invisible (a block re-enters attention only after its next
            # owner's prefill/decode writes overwrite the exposed positions)
            slot = state.slot
            self.blocks.release_many(self._held[slot])
            self._held[slot] = []
            self._tables[slot, :] = self.num_blocks
            self._reserved_total -= int(self._future[slot])
            self._future[slot] = 0
        elif self.zero_on_evict:
            self.cache = _evict_jit(self.cache, np.int32(state.slot))
        self.stats.completed += 1
        self.stats.latency_ticks.append(self.clock - state.req.arrival)
        self._just_finished.append(state.req.req_id)
        self._completed[state.req.req_id] = CompletedRequest(
            req_id=state.req.req_id,
            prompt=state.req.prompt,
            tokens=np.asarray(state.tokens, np.int32),
            finish_reason=reason,
            admitted_tick=state.admitted_tick,
            finished_tick=self.clock,
        )

    def _ensure_blocks(self, slot: int, hi: int) -> None:
        """Paged layout: append blocks to ``slot``'s table until it covers
        cache position ``hi`` (a no-op when already covered — a request only
        pays a pool op when its context actually crosses a block boundary)."""
        held = self._held[slot]
        while len(held) * self.block_size <= hi:
            b = self.blocks.acquire()
            assert b is not None, "block append failed despite reservation"
            self._tables[slot, len(held)] = b
            held.append(b)
            self._future[slot] -= 1
            self._reserved_total -= 1

    # -- stepping ------------------------------------------------------------

    def _pull_arrivals(self) -> None:
        while self._pending and self._pending[0].arrival <= self.clock:
            self._push_ready(self._pending.pop(0))

    @property
    def n_active(self) -> int:
        return sum(s is not None for s in self._active)

    @property
    def drained(self) -> bool:
        return not (self._pending or self._ready or self.n_active)

    def _drain_finished(self) -> List[CompletedRequest]:
        done = [self._completed[i] for i in self._just_finished]
        self._just_finished.clear()
        return done

    def _pop_admissible(self) -> List[Request]:
        """Pop ready requests that fit the free slots and (paged) the block
        pool.  Memory admission is reservation-based: a request is popped
        only if its worst-case block count fits what the pool can still
        promise (``free - reserved``), and that worst case is reserved on
        the spot — which is exactly what makes mid-decode appends and the
        no-preemption guarantee sound.  The queue head blocks admission when
        it doesn't fit (no skip-ahead): policy order is preserved and a big
        request cannot be starved by a stream of small ones."""
        batch: List[Request] = []
        while self._ready and len(batch) < self.pool.free_count:
            req = self._ready[0][2]
            if self.layout == "paged":
                worst = self._worst_blocks(req.prompt.size, req.max_new)
                if worst > self.blocks.free_count - self._reserved_total:
                    break
                self._reserved_total += worst
            heapq.heappop(self._ready)
            batch.append(req)
        return batch

    def step(self) -> List[CompletedRequest]:
        """Admit what fits, run one decode chunk, release finished slots.
        Returns the requests completed during this call."""
        self._pull_arrivals()
        while self._ready and self.pool.free_count:
            batch = self._pop_admissible()
            if not batch:
                break                 # head doesn't fit the block pool yet
            self._admit_many(batch)   # may free slots again (eos/max_new==1)
        self.stats.peak_active = max(self.stats.peak_active, self.n_active)

        if self.n_active == 0:
            # idle: jump to the next arrival instead of burning empty ticks
            if self._pending:
                self.clock = max(self.clock + 1, self._pending[0].arrival)
            else:
                self.clock += 1
            return self._drain_finished()

        active = np.asarray([s is not None for s in self._active], bool)
        steps = self.steps_per_tick
        tables = None
        block_size = 0
        if self.layout == "paged":
            # grow each row's table to cover every position this chunk could
            # write an ACCEPTED token to (overshoot past max_new targets
            # sentinel entries and is dropped); the admission reservation
            # guarantees these acquires can never fail
            for slot, state in enumerate(self._active):
                if state is None:
                    continue
                hi = min(
                    int(self._cur_len[slot]) + steps - 1,
                    state.req.prompt.size + state.req.max_new - 2,
                )
                self._ensure_blocks(slot, hi)
            self.stats.peak_blocks_in_use = max(
                self.stats.peak_blocks_in_use, self.blocks.busy_count
            )
            tables = self._tables.copy()
            block_size = self.block_size
        self.cache, toks = _decode_tick_jit(
            cfg=self.cfg, params=self.params, cache=self.cache,
            last_token=self._last_token, cur_len=self._cur_len,
            active=active, slot_keys=self._slot_keys, tables=tables,
            sampling=self.sampling, steps=steps, block_size=block_size,
        )
        toks = np.asarray(toks)                  # (steps, N)
        self.clock += steps
        self.stats.ticks += steps

        eos = self.sampling.eos_id
        accepted = 0
        for slot, state in enumerate(self._active):
            if state is None:
                continue
            # device advanced this row all `steps` steps; host accepts tokens
            # until the row finishes and discards the (bounded) overshoot
            for s in range(steps):
                tok = int(toks[s, slot])
                state.tokens.append(tok)
                accepted += 1
                if eos >= 0 and tok == eos:
                    self._finish(state, "eos")
                    break
                if len(state.tokens) >= state.req.max_new:
                    self._finish(state, "length")
                    break
            self._cur_len[slot] = min(self._cur_len[slot] + steps, self.max_len - 1)
            self._last_token[slot] = int(toks[steps - 1, slot])
        self.stats.busy_slot_steps += accepted
        self.stats.idle_slot_steps += self.num_slots * steps - accepted
        self.stats.generated_tokens += accepted
        return self._drain_finished()

    def run(self, max_steps: Optional[int] = None) -> Dict[int, CompletedRequest]:
        """Drive until every queued request completes, or ``max_steps``
        calls to ``step()`` (each executes up to ``steps_per_tick`` decode
        ticks — a watchdog on scheduler iterations, not device ticks)."""
        n = 0
        while not self.drained:
            self.step()
            n += 1
            if max_steps is not None and n >= max_steps:
                break
        return dict(self._completed)

    @property
    def results(self) -> Dict[int, CompletedRequest]:
        return dict(self._completed)

    # -- warmup / compile accounting ------------------------------------------

    def warmup(self) -> Dict[str, int]:
        """Compile the decode tick and every prompt-bucket prefill program
        up-front (results discarded — session state is untouched). After
        this, no request pattern recompiles; returns ``compile_stats``."""
        widths = sorted({self._admit_width(n) for n in range(1, self.num_slots + 1)})
        for A in widths:
            for b in self.buckets.sizes:
                prompts = np.zeros((A, b), np.int32)
                prompt_lens = np.ones((A,), np.int32)
                slots = np.arange(A, dtype=np.int32)
                valid = np.zeros((A,), bool)    # all rows no-op: state safe
                req_ids = np.zeros((A,), np.int32)
                if self.layout == "paged":
                    nb = -(-b // self.block_size)
                    out = _admit_fused_paged_jit(
                        cfg=self.cfg, params=self.params, cache=self.cache,
                        prompts=prompts, prompt_lens=prompt_lens,
                        # all-sentinel ids: every scatter dropped, state safe
                        block_ids=np.full((A, nb), self.num_blocks, np.int32),
                        req_ids=req_ids, base_key=self._base_key,
                        sampling=self.sampling, block_size=self.block_size,
                    )
                elif self.prefill_mode == "fused":
                    out = _admit_fused_jit(
                        cfg=self.cfg, params=self.params, cache=self.cache,
                        prompts=prompts, prompt_lens=prompt_lens, slots=slots,
                        valid=valid, req_ids=req_ids, base_key=self._base_key,
                        sampling=self.sampling,
                    )
                else:
                    out = _admit_decode_jit(
                        cfg=self.cfg, params=self.params, cache=self.cache,
                        prompts=prompts, prompt_lens=prompt_lens, slots=slots,
                        valid=valid, req_ids=req_ids, base_key=self._base_key,
                        sampling=self.sampling,
                        max_len=self.max_len, cache_dtype=self.cache_dtype,
                    )
                jax.block_until_ready(out)
        out = _decode_tick_jit(
            cfg=self.cfg, params=self.params, cache=self.cache,
            last_token=self._last_token, cur_len=self._cur_len,
            active=np.zeros((self.num_slots,), bool),
            slot_keys=self._slot_keys,
            tables=self._tables.copy() if self.layout == "paged" else None,
            sampling=self.sampling, steps=self.steps_per_tick,
            block_size=self.block_size if self.layout == "paged" else 0,
        )
        jax.block_until_ready(out)
        if self.zero_on_evict:
            jax.block_until_ready(_evict_jit(self.cache, np.int32(0)))
        return self.compile_stats()

    def compile_stats(self) -> Dict[str, int]:
        return scheduler_compile_stats()
