"""KV-cache management for continuous batching: slot stripes and paged blocks.

Two cache layouts share this module:

* **slots** (PR 2): the pooled decode cache is the ordinary
  ``transformer.init_cache`` pytree with ``batch == num_slots`` — every
  request reserves a worst-case ``max_len`` stripe for its whole lifetime;
* **paged** (PR 3): K/V live in a global pool of fixed-size blocks
  (``transformer.init_paged_cache`` leaves ``(L, num_blocks, block_size,
  Hkv, hd)``) handed out by ``BlockPool``; each request holds only the
  blocks its *actual* context occupies, recorded in a fixed-width
  per-request block table (``(num_slots, max_len // block_size)`` int32,
  unallocated entries == ``num_blocks``).  Mixed context lengths then share
  HBM instead of each reserving the worst case.

Slot-layout cache ops (pure tree ops, jit-friendly):

* ``scatter_rows``  — batched admission (the scheduler's production path):
  write A request rows into their (distinct) slots in one scatter, with
  invalid rows degenerating to exact no-ops so a fixed-width program admits
  any number <= A of requests;
* ``evict_slot``    — zero slot ``s`` (optional hygiene: stale rows above a
  slot's ``cur_len`` are already invisible, because ``decode_attention``
  masks keys past ``kv_len`` and overwrites position ``cur_len`` before
  attending over it);
* ``insert_slot`` / ``slot_view`` / ``insert_prefill_kv`` — the single-slot
  primitives (scatter_rows restricted to A=1). The scheduler admits through
  scatter_rows only; these exist for per-slot manipulation by tooling and
  the ROADMAP sharded-slots follow-on (where a slot migrates between hosts
  one at a time), and are pinned by tests/test_scheduler.py.

All three take the slot index as a *traced* scalar, so one compiled program
serves every slot — no shape depends on which slot is being filled.  The
paged layout's device ops are ``scatter_prompt_blocks`` here plus
``models.attention.paged_decode_attention``; block ids are likewise traced
data, so one compiled program serves any block-table contents.

``merge_admit_carry`` is the async host loop's primitive: it scatters an
admission batch's first sampled tokens and PRNG keys into the
device-resident decode carry, letting the scheduler compose admit-program
futures into the next chunk's inputs without a host sync (see
``scheduler.ServeSession`` and docs/serving.md).

Host-side bookkeeping lives in ``SlotPool`` (decode-row free list),
``BlockPool`` (KV-block free list — both min-heaps with O(1) membership)
and ``PromptBuckets`` (fixed prompt-length buckets so prefill compiles once
per bucket, never per request length).

**Partial-table invariants (chunked prefill, PR 10).**  A block table is
valid at ANY prefix of its final contents: entries ``[0, ceil(pos / bs))``
map real blocks holding the first ``pos`` written positions, everything
after is the ``num_blocks`` sentinel.  Three properties make a partially
built table safe to serve and to keep extending, all pinned by
tests/test_chunked_prefill.py:

* **sentinel writes drop** — every K/V scatter routes through
  ``where(blk < W, phys, num_blocks)``-style clamping, so a write whose
  position falls past the allocated prefix lands in the pool's dump row
  ``num_blocks`` and is never read;
* **reads never cross ``kv_len``** — attention masks keys at the caller's
  ``cur_len``/``kv_len``, so sentinel-tailed entries (and any garbage
  between a chunk's end and the next write) are invisible: a table with a
  sentinel tail serves reads identically to a truncated context;
* **scatter-before-gather** — a chunk writes its own K/V before attending,
  so position ``pos`` is readable the moment ``kv_len`` reaches it, and
  the next chunk (or decode step) may immediately read through the same
  table row it just extended.

The scheduler grows a mid-prefill row's table one chunk at a time
(``_ensure_blocks`` up to the chunk's last write) and scrubs that row to
all-sentinel in every decode dispatch until the prefill completes — decode
ticks write unconditionally at ``cur_len``, and the scrub is what keeps
those writes off the row's already-written prompt K/V.
"""
from __future__ import annotations

import bisect
import heapq
from collections import OrderedDict
from typing import Any, List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

__all__ = [
    "insert_slot",
    "insert_prefill_kv",
    "scatter_rows",
    "scatter_prompt_blocks",
    "copy_block",
    "pool_bytes_per_device",
    "merge_admit_carry",
    "merge_spec_len",
    "evict_slot",
    "slot_view",
    "PromptBuckets",
    "SlotPool",
    "BlockPool",
    "PrefixCache",
]


# ---------------------------------------------------------------------------
# Pure cache-tree ops (jit-friendly, slot index traced)
# ---------------------------------------------------------------------------


def insert_slot(cache: Any, slot_cache: Any, slot: jax.Array) -> Any:
    """Write a batch-1 cache pytree (leaves (L, 1, ...)) into slot ``slot``
    of the pooled cache (leaves (L, B, ...))."""
    return jax.tree.map(
        lambda full, one: jax.lax.dynamic_update_slice_in_dim(
            full, one.astype(full.dtype), slot, axis=1
        ),
        cache,
        slot_cache,
    )


def slot_view(cache: Any, slot: jax.Array) -> Any:
    """Batch-1 view of one slot (leaves (L, 1, ...))."""
    return jax.tree.map(
        lambda a: jax.lax.dynamic_slice_in_dim(a, slot, 1, axis=1), cache
    )


def evict_slot(cache: Any, slot: jax.Array) -> Any:
    """Zero one slot's rows across every leaf. Correctness never requires
    this (see module docstring); it exists for hygiene/debugging and is
    exercised by the scheduler's ``zero_on_evict`` option."""
    return jax.tree.map(
        lambda a: jax.lax.dynamic_update_slice_in_dim(
            a, jnp.zeros((a.shape[0], 1) + a.shape[2:], a.dtype), slot, axis=1
        ),
        cache,
    )


def scatter_rows(
    full: jax.Array,
    part: jax.Array,
    slots: jax.Array,
    valid: jax.Array,
    s_cap: Optional[int] = None,
) -> jax.Array:
    """Write ``part`` (lead, A, [S,] ...) into batch rows ``slots`` of
    ``full`` (lead, B, [Smax,] ...) — the batched-admission primitive.

    ``slots`` must hold distinct row ids (the scheduler passes a permutation
    of range(B)); rows with ``valid == False`` rewrite the values they
    gathered — an exact no-op — which is how ONE fixed-width compiled
    program admits any number <= A of requests.  ``s_cap`` restricts the
    write to sequence positions [0, s_cap) (fused-prefill K/V, where
    ``part`` covers only the prompt bucket)."""
    vb = valid.reshape((1, -1) + (1,) * (full.ndim - 2))
    if s_cap is None:
        cur = full[:, slots]
        part = jnp.where(vb, part.astype(full.dtype), cur)
        return full.at[:, slots].set(part)
    cur = full[:, slots, :s_cap]
    part = jnp.where(vb, part.astype(full.dtype), cur)
    return full.at[:, slots, :s_cap].set(part)


def merge_admit_carry(
    last_token: jax.Array,
    slot_keys: jax.Array,
    slots: jax.Array,
    tok0s: jax.Array,
    keys: jax.Array,
    valid: jax.Array,
) -> Tuple[jax.Array, jax.Array]:
    """Scatter an admission batch's first sampled tokens ``tok0s`` (A,) and
    per-request PRNG keys ``keys`` (A, 2) into the device-resident decode
    carry ``last_token`` (N,) / ``slot_keys`` (N, 2) at rows ``slots``.

    The async serve loop keeps the decode carry on device between chunks;
    this merge lets freshly admitted rows join the next chunk without the
    host ever fetching the admit program's outputs.  ``slots`` must hold
    distinct ids (the scheduler passes acquired slots padded with distinct
    unused ids); rows with ``valid == False`` rewrite the values they
    gathered — an exact no-op — so one fixed-width compiled program merges
    any number <= A of admissions."""
    lt = last_token.at[slots].set(
        jnp.where(valid, tok0s.astype(last_token.dtype), last_token[slots])
    )
    sk = slot_keys.at[slots].set(
        jnp.where(valid[:, None], keys.astype(slot_keys.dtype), slot_keys[slots])
    )
    return lt, sk


def merge_spec_len(
    cur_len: jax.Array,
    slots: jax.Array,
    lens: jax.Array,
    valid: jax.Array,
) -> jax.Array:
    """Scatter an admission batch's prompt lengths ``lens`` (A,) into the
    device-resident ``cur_len`` carry (N,) at rows ``slots``.

    Speculative decoding advances rows by data-dependent accepted counts,
    so the async serve loop keeps ``cur_len`` on device alongside the
    decode carry.  Same no-op discipline as :func:`merge_admit_carry`:
    rows with ``valid == False`` rewrite the values they gathered."""
    return cur_len.at[slots].set(
        jnp.where(valid, lens.astype(cur_len.dtype), cur_len[slots])
    )


def insert_prefill_kv(cache: Any, kvs: Tuple[jax.Array, jax.Array], slot: jax.Array) -> Any:
    """Write fused-prefill K/V stacks (each (L, 1, S_bucket, Hkv, hd), from
    ``forward(..., return_kv=True)`` on a batch-1 prompt) into positions
    [0, S_bucket) of slot ``slot``.  Attention-family caches only."""
    k, v = kvs
    zeros = (0,) * (cache["k"].ndim - 2)
    start = (0, slot) + zeros

    def write(full, part):
        return jax.lax.dynamic_update_slice(full, part.astype(full.dtype), start)

    return dict(cache, k=write(cache["k"], k), v=write(cache["v"], v))


# ---------------------------------------------------------------------------
# Paged-layout cache ops
# ---------------------------------------------------------------------------


def scatter_prompt_blocks(
    cache: Any,
    kvs: Tuple[jax.Array, jax.Array],
    block_ids: jax.Array,
    block_size: int,
) -> Any:
    """Write fused-prefill K/V stacks (each (L, A, S_bucket, Hkv, hd)) into
    the paged cache (leaves (L, num_blocks, block_size, Hkv, hd)).

    ``block_ids`` is (A, nb) int32 with ``nb == ceil(S_bucket / block_size)``:
    row ``i``'s ``j``-th entry is the physical block receiving positions
    ``[j*block_size, (j+1)*block_size)`` of prompt ``i``.  Entries ``>=
    num_blocks`` (the host's sentinel for unallocated / padding rows) are
    DROPPED by jit scatter semantics — that is how one fixed-width compiled
    program admits any number of requests holding any number of blocks, with
    no ``valid`` mask needed.  Bucket positions past the last allocated block
    hold only right-pad garbage, so dropping them is exact."""
    k, v = kvs
    A, nb = block_ids.shape
    L = k.shape[0]
    pad = nb * block_size - k.shape[2]
    if pad:
        widths = [(0, 0), (0, 0), (0, pad), (0, 0), (0, 0)]
        k = jnp.pad(k, widths)
        v = jnp.pad(v, widths)
    ids = block_ids.reshape(-1)

    def write(full, part):
        part = part.reshape(L, A * nb, block_size, *part.shape[3:])
        return full.at[:, ids].set(part.astype(full.dtype))

    return dict(cache, k=write(cache["k"], k), v=write(cache["v"], v))


def pool_bytes_per_device(cache: Any) -> int:
    """Bytes of KV pool resident on EACH device.

    Under tensor-parallel serving the pool shards along the KV-head dim, so
    every device holds ``1/tp`` of each leaf; ``Sharding.shard_shape`` gives
    the per-device shard shape for sharded and single-device placements
    alike, which makes this the bench/stats primitive for the ``1/tp``
    KV-bytes claim (see benchmarks/serve_tp.py)."""
    total = 0
    for leaf in jax.tree.leaves(cache):
        shard = leaf.sharding.shard_shape(leaf.shape)
        total += int(np.prod(shard)) * leaf.dtype.itemsize
    return total


def copy_block(cache: Any, src: jax.Array, dst: jax.Array) -> Any:
    """Copy one physical block's K/V rows from block ``src`` to block ``dst``
    — the copy-on-write fork primitive.  Both indices are *traced* scalars,
    so ONE compiled program forks any (src, dst) pair; ``dst`` is always a
    freshly acquired (valid) block id, so the clamping semantics of
    ``dynamic_update_slice`` never engage."""

    def cp(full):
        row = jax.lax.dynamic_slice_in_dim(full, src, 1, axis=1)
        return jax.lax.dynamic_update_slice_in_dim(full, row, dst, axis=1)

    return dict(cache, k=cp(cache["k"]), v=cp(cache["v"]))


# ---------------------------------------------------------------------------
# Host-side bookkeeping
# ---------------------------------------------------------------------------


class PromptBuckets:
    """Fixed prompt-length buckets: prefill compiles once per bucket size,
    so no request length ever triggers a new compile."""

    def __init__(self, sizes: Sequence[int]):
        if not sizes:
            raise ValueError("need at least one prompt bucket")
        self.sizes: Tuple[int, ...] = tuple(sorted(set(int(s) for s in sizes)))
        if self.sizes[0] < 1:
            raise ValueError(f"bucket sizes must be >= 1, got {self.sizes}")

    @property
    def max_size(self) -> int:
        return self.sizes[-1]

    def bucket(self, prompt_len: int) -> int:
        """Smallest bucket >= prompt_len (binary search over the sorted
        bucket list)."""
        i = bisect.bisect_left(self.sizes, prompt_len)
        if i == len(self.sizes):
            raise ValueError(
                f"prompt_len={prompt_len} exceeds largest bucket {self.sizes[-1]}"
            )
        return self.sizes[i]

    def pad(self, prompt: np.ndarray, pad_id: int = 0) -> np.ndarray:
        """(S0,) -> (1, bucket) int32, zero-padded on the right.  Pad tokens
        sit at positions >= prompt_len: causality keeps them out of every
        real position's receptive field, and decode masks/overwrites their
        cache rows before ever attending over them."""
        n = int(prompt.shape[0])
        b = self.bucket(n)
        out = np.full((1, b), pad_id, np.int32)
        out[0, :n] = prompt
        return out


class _IdPool:
    """Min-heap free list over ``count`` integer ids with an O(1) membership
    set: ``acquire`` is O(log n) (was O(n) ``list.pop(0)``), ``release`` is
    O(log n) with O(1) double-free detection (was a linear scan + sort).
    Lowest free id first keeps allocation deterministic for tests/replay."""

    _what = "id"

    def __init__(self, count: int):
        if count < 1:
            raise ValueError(f"need at least one {self._what}, got {count}")
        self._count = count
        self._heap: List[int] = list(range(count))   # range is already a heap
        self._free_set = set(self._heap)

    @property
    def free_count(self) -> int:
        return len(self._heap)

    @property
    def busy_count(self) -> int:
        return self._count - len(self._heap)

    def acquire(self) -> Optional[int]:
        if not self._heap:
            return None
        i = heapq.heappop(self._heap)
        self._free_set.discard(i)
        return i

    def acquire_many(self, n: int) -> Optional[List[int]]:
        """All-or-nothing: ``n`` ids, or None (pool untouched) if fewer free."""
        if n > len(self._heap):
            return None
        return [self.acquire() for _ in range(n)]

    def release(self, i: int) -> None:
        if not 0 <= i < self._count:
            raise ValueError(f"{self._what} {i} out of range")
        if i in self._free_set:
            raise ValueError(f"{self._what} {i} double-released")
        heapq.heappush(self._heap, i)
        self._free_set.add(i)

    def _validate_release_many(self, ids: Sequence[int]) -> None:
        seen: set = set()
        for i in ids:
            if not 0 <= i < self._count:
                raise ValueError(f"{self._what} {i} out of range")
            if i in self._free_set or i in seen:
                raise ValueError(f"{self._what} {i} double-released")
            seen.add(i)

    def release_many(self, ids: Sequence[int]) -> None:
        """Atomic batch release: the whole batch is validated before any id
        mutates the pool, so a double-free/out-of-range id raises with
        ``free_count`` (and every invariant a caller might roll back against)
        untouched."""
        self._validate_release_many(ids)
        for i in ids:
            self.release(i)


class SlotPool(_IdPool):
    """Free list over ``num_slots`` decode slots (batch rows of the decode
    program)."""

    _what = "slot"

    def __init__(self, num_slots: int):
        super().__init__(num_slots)
        self.num_slots = num_slots


class BlockPool(_IdPool):
    """Refcounted free list over ``num_blocks`` physical KV blocks — the
    paged layout's global memory allocator.

    ``acquire`` hands out a block with refcount 1; ``share`` takes an extra
    reference on a live block (prefix sharing: several requests' block tables
    — plus the scheduler's prefix cache — point at the same physical block);
    ``release`` drops one reference and only returns the block to the free
    heap when the count hits zero.  ``free_count`` / ``busy_count`` keep
    counting *physical* blocks, so capacity math is unchanged.  The host-side
    block table maps a request's logical block slots to its physical blocks,
    and the sentinel id ``num_blocks`` marks unallocated table entries
    (device writes there are dropped)."""

    _what = "block"

    def __init__(self, num_blocks: int):
        super().__init__(num_blocks)
        self.num_blocks = num_blocks
        self._ref: List[int] = [0] * num_blocks

    @property
    def sentinel(self) -> int:
        return self.num_blocks

    def refcount(self, i: int) -> int:
        if not 0 <= i < self._count:
            raise ValueError(f"block {i} out of range")
        return self._ref[i]

    def acquire(self) -> Optional[int]:
        i = super().acquire()
        if i is not None:
            self._ref[i] = 1
        return i

    def share(self, i: int) -> int:
        """Take one extra reference on a live block; returns the new count."""
        if not 0 <= i < self._count:
            raise ValueError(f"block {i} out of range")
        if self._ref[i] < 1:
            raise ValueError(f"block {i} is free; cannot share")
        self._ref[i] += 1
        return self._ref[i]

    def release(self, i: int) -> None:
        if not 0 <= i < self._count:
            raise ValueError(f"block {i} out of range")
        if self._ref[i] < 1:
            raise ValueError(f"block {i} double-released")
        self._ref[i] -= 1
        if self._ref[i] == 0:
            heapq.heappush(self._heap, i)
            self._free_set.add(i)

    def _validate_release_many(self, ids: Sequence[int]) -> None:
        # Atomicity with refcounts: each id may appear up to refcount(i)
        # times in one batch, so validate per-id multiplicity, not set
        # membership.
        mult: dict = {}
        for i in ids:
            if not 0 <= i < self._count:
                raise ValueError(f"block {i} out of range")
            mult[i] = mult.get(i, 0) + 1
        for i, n in mult.items():
            if n > self._ref[i]:
                raise ValueError(
                    f"block {i}: batch releases {n} refs but only "
                    f"{self._ref[i]} held"
                )


class PrefixCache:
    """Host-side map from prompt-prefix content to the physical block that
    already holds its K/V, enabling copy-on-write prefix sharing.

    Keys are *structural rolling keys*: the key for block ``j`` of a prompt
    is ``intern((key of block j-1, tokens in block j))`` with ``ROOT`` (-1)
    as the zeroth parent — a collision-free stand-in for a rolling hash over
    the token ids (interning compares exact token tuples, so two prefixes
    share a key iff their token contents are identical).  Keys are content-
    bound, not block-bound, so chains self-heal across eviction: evicting a
    mid-chain entry only un-publishes that block; re-inserting the same
    content later re-uses the same key id.

    The cache itself never touches the :class:`BlockPool` — the scheduler
    takes one pool reference per published block (the cache's +1) and drops
    it on eviction, keeping all refcount traffic in one place.  Entries are
    kept in LRU order; ``lru_blocks`` exposes eviction candidates for
    reclaim-under-pressure."""

    ROOT = -1

    def __init__(self) -> None:
        self._intern: dict = {}           # (parent_key, tokens) -> key_id
        self._entries: "OrderedDict[int, int]" = OrderedDict()  # key -> block
        self._by_block: dict = {}         # block -> key_id

    def __len__(self) -> int:
        return len(self._entries)

    def key(self, parent: int, tokens: Sequence[int]) -> int:
        """Intern the rolling key for a block holding ``tokens`` whose
        predecessor block has key ``parent`` (``ROOT`` for block 0)."""
        k = (int(parent), tuple(int(t) for t in tokens))
        kid = self._intern.get(k)
        if kid is None:
            kid = len(self._intern)
            self._intern[k] = kid
        return kid

    def lookup(self, key_id: int) -> Optional[int]:
        """Physical block published under ``key_id`` (-> MRU), else None."""
        blk = self._entries.get(key_id)
        if blk is not None:
            self._entries.move_to_end(key_id)
        return blk

    def insert(self, key_id: int, block: int) -> None:
        """Publish ``block`` under ``key_id``.  The caller must hold a pool
        reference on ``block`` on the cache's behalf (and must have checked
        ``lookup`` first — double publication is a bug)."""
        if key_id in self._entries:
            raise ValueError(f"prefix key {key_id} already published")
        if block in self._by_block:
            raise ValueError(f"block {block} already published")
        self._entries[key_id] = block
        self._by_block[block] = key_id

    def holds_block(self, block: int) -> bool:
        return block in self._by_block

    def drop_block(self, block: int) -> bool:
        """Un-publish the entry pointing at ``block`` (before the block
        mutates, or to reclaim it).  Returns True if an entry was dropped;
        the caller then releases the cache's pool reference."""
        kid = self._by_block.pop(block, None)
        if kid is None:
            return False
        del self._entries[kid]
        return True

    def lru_blocks(self) -> List[int]:
        """Published blocks, least-recently-used first (snapshot)."""
        return list(self._entries.values())
