"""Slot-based KV cache management for continuous batching.

The pooled decode cache is the ordinary ``transformer.init_cache`` pytree
with ``batch == num_slots``: every leaf carries the slot axis at position 1
((L, B, ...) for dense/ssm leaves, (n_groups, B, ...) for hybrid attention
leaves).  That uniformity is what makes slot management a handful of pure tree ops:

* ``scatter_rows``  — batched admission (the scheduler's production path):
  write A request rows into their (distinct) slots in one scatter, with
  invalid rows degenerating to exact no-ops so a fixed-width program admits
  any number <= A of requests;
* ``evict_slot``    — zero slot ``s`` (optional hygiene: stale rows above a
  slot's ``cur_len`` are already invisible, because ``decode_attention``
  masks keys past ``kv_len`` and overwrites position ``cur_len`` before
  attending over it);
* ``insert_slot`` / ``slot_view`` / ``insert_prefill_kv`` — the single-slot
  primitives (scatter_rows restricted to A=1). The scheduler admits through
  scatter_rows only; these exist for per-slot manipulation by tooling and
  the ROADMAP sharded-slots follow-on (where a slot migrates between hosts
  one at a time), and are pinned by tests/test_scheduler.py.

All three take the slot index as a *traced* scalar, so one compiled program
serves every slot — no shape depends on which slot is being filled.

Host-side bookkeeping lives in ``SlotPool`` (free-list) and
``PromptBuckets`` (fixed prompt-length buckets so prefill compiles once per
bucket, never per request length).
"""
from __future__ import annotations

from typing import Any, List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

__all__ = [
    "insert_slot",
    "insert_prefill_kv",
    "scatter_rows",
    "evict_slot",
    "slot_view",
    "PromptBuckets",
    "SlotPool",
]


# ---------------------------------------------------------------------------
# Pure cache-tree ops (jit-friendly, slot index traced)
# ---------------------------------------------------------------------------


def insert_slot(cache: Any, slot_cache: Any, slot: jax.Array) -> Any:
    """Write a batch-1 cache pytree (leaves (L, 1, ...)) into slot ``slot``
    of the pooled cache (leaves (L, B, ...))."""
    return jax.tree.map(
        lambda full, one: jax.lax.dynamic_update_slice_in_dim(
            full, one.astype(full.dtype), slot, axis=1
        ),
        cache,
        slot_cache,
    )


def slot_view(cache: Any, slot: jax.Array) -> Any:
    """Batch-1 view of one slot (leaves (L, 1, ...))."""
    return jax.tree.map(
        lambda a: jax.lax.dynamic_slice_in_dim(a, slot, 1, axis=1), cache
    )


def evict_slot(cache: Any, slot: jax.Array) -> Any:
    """Zero one slot's rows across every leaf. Correctness never requires
    this (see module docstring); it exists for hygiene/debugging and is
    exercised by the scheduler's ``zero_on_evict`` option."""
    return jax.tree.map(
        lambda a: jax.lax.dynamic_update_slice_in_dim(
            a, jnp.zeros((a.shape[0], 1) + a.shape[2:], a.dtype), slot, axis=1
        ),
        cache,
    )


def scatter_rows(
    full: jax.Array,
    part: jax.Array,
    slots: jax.Array,
    valid: jax.Array,
    s_cap: Optional[int] = None,
) -> jax.Array:
    """Write ``part`` (lead, A, [S,] ...) into batch rows ``slots`` of
    ``full`` (lead, B, [Smax,] ...) — the batched-admission primitive.

    ``slots`` must hold distinct row ids (the scheduler passes a permutation
    of range(B)); rows with ``valid == False`` rewrite the values they
    gathered — an exact no-op — which is how ONE fixed-width compiled
    program admits any number <= A of requests.  ``s_cap`` restricts the
    write to sequence positions [0, s_cap) (fused-prefill K/V, where
    ``part`` covers only the prompt bucket)."""
    vb = valid.reshape((1, -1) + (1,) * (full.ndim - 2))
    if s_cap is None:
        cur = full[:, slots]
        part = jnp.where(vb, part.astype(full.dtype), cur)
        return full.at[:, slots].set(part)
    cur = full[:, slots, :s_cap]
    part = jnp.where(vb, part.astype(full.dtype), cur)
    return full.at[:, slots, :s_cap].set(part)


def insert_prefill_kv(cache: Any, kvs: Tuple[jax.Array, jax.Array], slot: jax.Array) -> Any:
    """Write fused-prefill K/V stacks (each (L, 1, S_bucket, Hkv, hd), from
    ``forward(..., return_kv=True)`` on a batch-1 prompt) into positions
    [0, S_bucket) of slot ``slot``.  Attention-family caches only."""
    k, v = kvs
    zeros = (0,) * (cache["k"].ndim - 2)
    start = (0, slot) + zeros

    def write(full, part):
        return jax.lax.dynamic_update_slice(full, part.astype(full.dtype), start)

    return dict(cache, k=write(cache["k"], k), v=write(cache["v"], v))


# ---------------------------------------------------------------------------
# Host-side bookkeeping
# ---------------------------------------------------------------------------


class PromptBuckets:
    """Fixed prompt-length buckets: prefill compiles once per bucket size,
    so no request length ever triggers a new compile."""

    def __init__(self, sizes: Sequence[int]):
        if not sizes:
            raise ValueError("need at least one prompt bucket")
        self.sizes: Tuple[int, ...] = tuple(sorted(set(int(s) for s in sizes)))
        if self.sizes[0] < 1:
            raise ValueError(f"bucket sizes must be >= 1, got {self.sizes}")

    @property
    def max_size(self) -> int:
        return self.sizes[-1]

    def bucket(self, prompt_len: int) -> int:
        """Smallest bucket >= prompt_len."""
        for s in self.sizes:
            if prompt_len <= s:
                return s
        raise ValueError(
            f"prompt_len={prompt_len} exceeds largest bucket {self.sizes[-1]}"
        )

    def pad(self, prompt: np.ndarray, pad_id: int = 0) -> np.ndarray:
        """(S0,) -> (1, bucket) int32, zero-padded on the right.  Pad tokens
        sit at positions >= prompt_len: causality keeps them out of every
        real position's receptive field, and decode masks/overwrites their
        cache rows before ever attending over them."""
        n = int(prompt.shape[0])
        b = self.bucket(n)
        out = np.full((1, b), pad_id, np.int32)
        out[0, :n] = prompt
        return out


class SlotPool:
    """Free-list over ``num_slots`` decode slots."""

    def __init__(self, num_slots: int):
        if num_slots < 1:
            raise ValueError(f"num_slots must be >= 1, got {num_slots}")
        self.num_slots = num_slots
        self._free: List[int] = list(range(num_slots))

    @property
    def free_count(self) -> int:
        return len(self._free)

    @property
    def busy_count(self) -> int:
        return self.num_slots - len(self._free)

    def acquire(self) -> Optional[int]:
        return self._free.pop(0) if self._free else None

    def release(self, slot: int) -> None:
        if slot in self._free:
            raise ValueError(f"slot {slot} double-released")
        if not 0 <= slot < self.num_slots:
            raise ValueError(f"slot {slot} out of range")
        self._free.append(slot)
        self._free.sort()
