"""Batched serving engine: prefill + greedy decode over a static KV cache.

``prefill_step`` / ``serve_step`` are the functions the dry-run lowers for
the inference shapes (prefill_32k lowers ``prefill_step``; decode_32k /
long_500k lower ``serve_step`` — one new token against a seq_len cache).
"""
from __future__ import annotations

import functools
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models.transformer import decode_step, forward, init_cache

__all__ = ["prefill_step", "serve_step", "greedy_generate"]


def prefill_step(cfg: ModelConfig, params, batch) -> jax.Array:
    """Full-sequence forward (logits only; cache seeding is fused into the
    layer scan on real deployments — here prefill cost is what we measure)."""
    logits, _ = forward(cfg, params, batch)
    return logits


def serve_step(
    cfg: ModelConfig,
    params,
    cache: Dict[str, jax.Array],
    batch: Dict[str, jax.Array],
    cur_len: jax.Array,
) -> Tuple[jax.Array, Dict[str, jax.Array]]:
    """One decode step: (B,1) token (or embedding) -> (B,1,V) logits + cache."""
    return decode_step(cfg, params, cache, batch, cur_len)


def greedy_generate(
    cfg: ModelConfig,
    params,
    prompt_tokens: jax.Array,        # (B, S0) int32 (embed_input archs)
    *,
    max_new: int = 16,
    max_len: Optional[int] = None,
    dtype=jnp.float32,
) -> jax.Array:
    """Simple batched greedy decoding used by examples/tests."""
    B, S0 = prompt_tokens.shape
    max_len = max_len or (S0 + max_new)
    cache = init_cache(cfg, B, max_len, dtype)

    step = jax.jit(functools.partial(serve_step, cfg))

    # teacher-forced prefill through the decode path (exercises the cache)
    cur = jnp.zeros((B,), jnp.int32)
    last = None
    for i in range(S0):
        last, cache = step(params, cache, {"tokens": prompt_tokens[:, i : i + 1]}, cur)
        cur = cur + 1
    out = [prompt_tokens]
    tok = jnp.argmax(last[:, -1], axis=-1).astype(jnp.int32)[:, None]
    for _ in range(max_new - 1):
        out.append(tok)
        last, cache = step(params, cache, {"tokens": tok}, cur)
        cur = cur + 1
        tok = jnp.argmax(last[:, -1], axis=-1).astype(jnp.int32)[:, None]
    out.append(tok)
    return jnp.concatenate(out, axis=1)
