"""Batched serving engine: fused prefill + single-jit ``lax.scan`` decode.

The engine compiles ONE program per (config, generation-shape) pair:

* **prefill** seeds the whole KV cache in one fused full-sequence pass
  (``forward(..., return_kv=True)`` + ``seed_cache``) instead of S0
  teacher-forced decode dispatches; SSM/hybrid families (whose caches carry
  conv/ssm state, not K/V) transparently fall back to a scan-based
  teacher-forced prefill — still inside the same jit;
* **decode** runs ``max_new`` steps under ``lax.scan`` over a
  ``GenerationState`` carry, so serving costs one dispatch per request
  instead of one per token;
* **sampling** is configured by a static ``SamplingConfig`` (greedy,
  temperature, top-k, stop-on-eos via masking — finished rows emit
  ``eos_id`` and keep shapes static);
* **execution mode** comes from ``ModelConfig.approx``:
  ``resolve_execution_mode`` maps the serving-level names (``exact`` /
  ``exact_quant`` / ``approx`` / ``approx_lowrank``) onto the paper's
  multiplier pipeline, with ``approx`` dispatching every projection matmul
  to the Pallas approximate-matmul kernel (interpret mode off-TPU);
* ``freeze_params`` pre-quantizes matmul weights to uint8 ``QWeight``s so
  quantized serving skips per-step weight calibration.

``prefill_step`` / ``serve_step`` are the functions the dry-run lowers for
the inference shapes (prefill_32k lowers ``prefill_step``; decode_32k /
long_500k lower ``serve_step`` — one new token against a seq_len cache).

``greedy_generate`` keeps its historical signature as a thin wrapper over
``generate``; ``greedy_generate_legacy`` preserves the original per-token
Python loop as the parity/throughput baseline (tests/test_engine.py,
benchmarks/kernel_bench.py).
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Any, Dict, NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.core.approx import ApproxConfig, prequantize_tree
from repro.models.transformer import (
    decode_step,
    forward,
    init_cache,
    seed_cache,
)

__all__ = [
    "SamplingConfig",
    "GenerationState",
    "generate",
    "greedy_generate",
    "greedy_generate_legacy",
    "prefill_step",
    "serve_step",
    "select_token",
    "resolve_execution_mode",
    "draft_config",
    "freeze_params",
    "EXECUTION_MODES",
]


# ---------------------------------------------------------------------------
# Execution modes (serving-level names for the paper's multiplier pipeline)
# ---------------------------------------------------------------------------

EXECUTION_MODES = ("exact", "exact_quant", "approx", "approx_lowrank", "approx_msr")


def resolve_execution_mode(
    mode: str, multiplier: str = "mul8x8_2", *, act_per_row: bool = False
) -> ApproxConfig:
    """Map a serving execution mode onto an ``ApproxConfig``.

    exact          float matmuls (baseline)
    exact_quant    uint8 affine quantization, exact integer matmul
    approx         named approximate multiplier through the fused Pallas
                   kernel (interpret mode off-TPU — bit-exact to the LUT)
    approx_lowrank same semantics via the XLA low-rank path (fast on CPU)
    approx_msr     the fixed-shift MSR truncation family through the same
                   Pallas kernel (default rung ``mul8x8_msr4`` unless an
                   ``mul8x8_msr*`` name is passed) — the cheapest rung of
                   the serving quality ladder

    ``act_per_row`` selects per-row (per-token) activation scales so a
    row's outputs never depend on its batch neighbours — mixed-tier
    serving relies on this for bit-identical per-request parity.
    """
    if mode == "exact":
        return ApproxConfig(mode="float")
    if mode == "exact_quant":
        return ApproxConfig(multiplier="exact", mode="exact_quant",
                            act_per_row=act_per_row)
    if mode == "approx":
        return ApproxConfig(multiplier=multiplier, mode="pallas",
                            act_per_row=act_per_row)
    if mode == "approx_lowrank":
        return ApproxConfig(multiplier=multiplier, mode="lowrank",
                            act_per_row=act_per_row)
    if mode == "approx_msr":
        from repro.core.multipliers import MSR_SPECS

        msr = multiplier if multiplier in MSR_SPECS else "mul8x8_msr4"
        return ApproxConfig(multiplier=msr, mode="pallas",
                            act_per_row=act_per_row)
    raise ValueError(f"execution mode {mode!r} not in {EXECUTION_MODES}")


def draft_config(cfg: ModelConfig, draft_mode: str,
                 multiplier: str = "mul8x8_2") -> ModelConfig:
    """The self-speculative DRAFT model's config: the verifier's ``cfg``
    with only ``approx`` swapped for ``draft_mode``'s execution pipeline.

    This is the whole parameter dispatch of self-speculative decoding —
    draft and verifier share every weight; what differs is which multiplier
    path the projection matmuls route through (``layers.dense`` reads
    ``cfg.approx`` at trace time, so the swap costs one extra compiled
    decode program and zero extra parameter memory).  The accept rate the
    scheduler then measures is a live end-to-end readout of the paper's
    error-rate claim for ``multiplier``.

    ``draft_mode`` may be any execution mode, including ``"exact"`` (a
    self-test: the draft then *is* the verifier and every token must be
    accepted).  The returned config is hashable and therefore usable as a
    static jit argument, same as ``cfg`` itself."""
    return dataclasses.replace(
        cfg, approx=resolve_execution_mode(draft_mode, multiplier)
    )


def freeze_params(cfg: ModelConfig, params):
    """Pre-quantize matmul weights to frozen uint8 ``QWeight``s for serving
    (1 byte/element weight reads, no per-step weight calibration). No-op for
    float execution."""
    if not cfg.approx.is_quantized:
        return params
    return prequantize_tree(params, cfg.approx)


# ---------------------------------------------------------------------------
# Dry-run entry points (unchanged shapes)
# ---------------------------------------------------------------------------


def prefill_step(cfg: ModelConfig, params, batch) -> jax.Array:
    """Full-sequence forward (logits only; cache seeding is fused into the
    layer scan — see ``generate``'s fused prefill — here prefill cost is what
    we measure)."""
    logits, _ = forward(cfg, params, batch)
    return logits


def serve_step(
    cfg: ModelConfig,
    params,
    cache: Dict[str, jax.Array],
    batch: Dict[str, jax.Array],
    cur_len: jax.Array,
) -> Tuple[jax.Array, Dict[str, jax.Array]]:
    """One decode step: (B,1) token (or embedding) -> (B,1,V) logits + cache."""
    return decode_step(cfg, params, cache, batch, cur_len)


_serve_step_jit = jax.jit(serve_step, static_argnums=(0,))


# ---------------------------------------------------------------------------
# Generation API
# ---------------------------------------------------------------------------


class SamplingConfig(NamedTuple):
    """Static sampling parameters (part of the jit cache key).

    temperature <= 0 selects greedy argmax; top_k == 0 disables top-k
    filtering; eos_id < 0 disables stop-on-eos."""

    temperature: float = 0.0
    top_k: int = 0
    eos_id: int = -1


class GenerationState(NamedTuple):
    """The scan carry of the decode loop."""

    cache: Any                 # transformer.init_cache pytree
    cur_len: jax.Array         # (B,) int32 — next cache write position
    last_token: jax.Array      # (B,) int32 — token to feed next step
    done: jax.Array            # (B,) bool — row hit eos (masking, not exit)
    rng: jax.Array             # PRNG key threaded through sampling


def select_token(logits: jax.Array, sampling: SamplingConfig, rng) -> jax.Array:
    """(B, V) logits -> (B,) int32 next tokens under the static sampling
    config (python branches are resolved at trace time)."""
    if sampling.temperature <= 0.0:
        return jnp.argmax(logits, axis=-1).astype(jnp.int32)
    scaled = logits / jnp.float32(sampling.temperature)
    if sampling.top_k > 0:
        kth = jax.lax.top_k(scaled, sampling.top_k)[0][..., -1:]
        scaled = jnp.where(scaled < kth, -1e30, scaled)
    return jax.random.categorical(rng, scaled, axis=-1).astype(jnp.int32)


# historical private name (tests/test_engine.py pokes it directly)
_select_token = select_token


def _prefill_fused(cfg: ModelConfig, params, prompt_tokens, cache):
    """One full-sequence pass: last-position logits + fully seeded KV cache."""
    logits, _, kvs = forward(cfg, params, {"tokens": prompt_tokens}, return_kv=True)
    return logits[:, -1, :], seed_cache(cfg, cache, kvs)


def _prefill_decode(cfg: ModelConfig, params, prompt_tokens, cache):
    """Teacher-forced prefill as a scan over prompt positions (SSM/hybrid
    caches, or when bit-identical parity with step-wise decode is wanted)."""
    B, _ = prompt_tokens.shape
    Vp = cfg.padded_vocab

    def body(carry, tok):
        cache, cur, _ = carry
        logits, cache = decode_step(cfg, params, cache, {"tokens": tok[:, None]}, cur)
        return (cache, cur + 1, logits[:, 0, :]), None

    init = (cache, jnp.zeros((B,), jnp.int32), jnp.zeros((B, Vp), jnp.float32))
    (cache, _, last_logits), _ = jax.lax.scan(body, init, prompt_tokens.T)
    return last_logits, cache


@functools.partial(
    jax.jit,
    static_argnames=("cfg", "max_new", "max_len", "sampling", "prefill_mode", "cache_dtype"),
)
def _generate_jit(
    cfg: ModelConfig,
    params,
    prompt_tokens: jax.Array,
    rng: jax.Array,
    *,
    max_new: int,
    max_len: int,
    sampling: SamplingConfig,
    prefill_mode: str,
    cache_dtype,
) -> Tuple[jax.Array, jax.Array]:
    B, S0 = prompt_tokens.shape
    cache = init_cache(cfg, B, max_len, jnp.dtype(cache_dtype))
    if prefill_mode == "fused":
        last_logits, cache = _prefill_fused(cfg, params, prompt_tokens, cache)
    else:
        last_logits, cache = _prefill_decode(cfg, params, prompt_tokens, cache)

    eos = sampling.eos_id
    rng, k0 = jax.random.split(rng)
    tok0 = _select_token(last_logits, sampling, k0)
    done0 = (tok0 == eos) if eos >= 0 else jnp.zeros((B,), bool)
    state = GenerationState(
        cache=cache,
        cur_len=jnp.full((B,), S0, jnp.int32),
        last_token=tok0,
        done=done0,
        rng=rng,
    )

    def step(state: GenerationState, _):
        logits, cache = decode_step(
            cfg, params, state.cache, {"tokens": state.last_token[:, None]}, state.cur_len
        )
        rng, sub = jax.random.split(state.rng)
        tok = _select_token(logits[:, 0, :], sampling, sub)
        if eos >= 0:
            tok = jnp.where(state.done, jnp.int32(eos), tok)
            done = state.done | (tok == eos)
        else:
            done = state.done
        return GenerationState(cache, state.cur_len + 1, tok, done, rng), tok

    if max_new > 1:
        state, rest = jax.lax.scan(step, state, None, length=max_new - 1)
        new_tokens = jnp.concatenate([tok0[:, None], rest.swapaxes(0, 1)], axis=1)
    else:
        new_tokens = tok0[:, None]
    return jnp.concatenate([prompt_tokens, new_tokens], axis=1), state.done


def generate(
    cfg: ModelConfig,
    params,
    prompt_tokens: jax.Array,          # (B, S0) int32
    *,
    max_new: int = 16,
    sampling: Optional[SamplingConfig] = None,
    max_len: Optional[int] = None,
    cache_dtype=jnp.float32,
    rng: Optional[jax.Array] = None,
    prefill_mode: str = "fused",       # fused | decode
) -> jax.Array:
    """Batched generation in a single compiled program.

    Returns (B, S0 + max_new) int32 tokens (prompt included); rows that hit
    ``sampling.eos_id`` are padded with it. ``prefill_mode="decode"``
    teacher-forces the prompt through the decode path (required for
    SSM/hybrid caches — selected automatically — and used by the parity
    tests); ``"fused"`` seeds the KV cache in one full-sequence pass.
    """
    if not cfg.embed_input:
        raise ValueError(f"{cfg.name}: token generation requires an embed-input arch")
    if prefill_mode not in ("fused", "decode"):
        raise ValueError(f"prefill_mode {prefill_mode!r} not in ('fused', 'decode')")
    sampling = sampling if sampling is not None else SamplingConfig()
    if rng is None:
        rng = jax.random.PRNGKey(0)
    if max_new < 1:
        raise ValueError(f"max_new must be >= 1, got {max_new}")
    B, S0 = prompt_tokens.shape
    max_len = max_len or (S0 + max_new)
    if max_len < S0 + max_new:
        # decode writes clamp at max_len-1 under jit and would silently
        # overwrite the last cache slot — fail loudly instead
        raise ValueError(f"max_len={max_len} < prompt_len + max_new = {S0 + max_new}")
    if cfg.family in ("ssm", "hybrid"):
        prefill_mode = "decode"
    tokens, _ = _generate_jit(
        cfg,
        params,
        prompt_tokens,
        rng,
        max_new=max_new,
        max_len=max_len,
        sampling=sampling,
        prefill_mode=prefill_mode,
        cache_dtype=jnp.dtype(cache_dtype).name,
    )
    return tokens


def greedy_generate(
    cfg: ModelConfig,
    params,
    prompt_tokens: jax.Array,        # (B, S0) int32 (embed_input archs)
    *,
    max_new: int = 16,
    max_len: Optional[int] = None,
    dtype=jnp.float32,
) -> jax.Array:
    """Historical entry point: batched greedy decoding (now scan-based)."""
    return generate(
        cfg,
        params,
        prompt_tokens,
        max_new=max_new,
        max_len=max_len,
        cache_dtype=dtype,
    )


def greedy_generate_legacy(
    cfg: ModelConfig,
    params,
    prompt_tokens: jax.Array,
    *,
    max_new: int = 16,
    max_len: Optional[int] = None,
    dtype=jnp.float32,
) -> jax.Array:
    """The original per-token Python loop (one dispatch per token,
    teacher-forced prefill through the decode path). Kept as the parity
    oracle and throughput baseline for the scan engine."""
    B, S0 = prompt_tokens.shape
    max_len = max_len or (S0 + max_new)
    cache = init_cache(cfg, B, max_len, dtype)

    # module-level jit so repeat calls (benchmarks) reuse the compile cache
    step = functools.partial(_serve_step_jit, cfg)

    cur = jnp.zeros((B,), jnp.int32)
    last = None
    for i in range(S0):
        last, cache = step(params, cache, {"tokens": prompt_tokens[:, i : i + 1]}, cur)
        cur = cur + 1
    out = [prompt_tokens]
    tok = jnp.argmax(last[:, -1], axis=-1).astype(jnp.int32)[:, None]
    for _ in range(max_new - 1):
        out.append(tok)
        last, cache = step(params, cache, {"tokens": tok}, cur)
        cur = cur + 1
        tok = jnp.argmax(last[:, -1], axis=-1).astype(jnp.int32)[:, None]
    out.append(tok)
    return jnp.concatenate(out, axis=1)
