"""Deterministic synthetic datasets (the container is offline).

* ``image_dataset``: class-conditional template + noise images with MNIST /
  CIFAR10 shapes. A CNN genuinely has to learn the templates, so exact-vs-
  approximate-multiplier accuracy deltas (DAL) and retraining recovery are
  measurable — the paper's Table VIII protocol on matched-shape data.
* ``token_dataset``: order-1 Markov token streams for LM training examples.
"""
from __future__ import annotations

import dataclasses
from typing import Iterator, Tuple

import numpy as np

__all__ = ["image_dataset", "token_batches", "ImageData"]


@dataclasses.dataclass
class ImageData:
    x_train: np.ndarray
    y_train: np.ndarray
    x_test: np.ndarray
    y_test: np.ndarray


def image_dataset(
    dataset: str = "mnist",
    *,
    n_train: int = 2048,
    n_test: int = 512,
    num_classes: int = 10,
    noise: float = 0.35,
    seed: int = 0,
) -> ImageData:
    shape = (28, 28, 1) if dataset == "mnist" else (32, 32, 3)
    rng = np.random.default_rng(seed)
    # smooth class templates: low-frequency random fields
    k = 6
    freq = rng.normal(size=(num_classes, k, k, shape[2]))
    temps = []
    for c in range(num_classes):
        t = np.kron(freq[c], np.ones((shape[0] // k + 1, shape[1] // k + 1, 1)))
        temps.append(t[: shape[0], : shape[1], :])
    temps = np.stack(temps)                     # (C, H, W, ch)
    temps = temps / np.abs(temps).max()

    def make(n, salt):
        r = np.random.default_rng(seed + salt)
        y = r.integers(0, num_classes, n)
        x = temps[y] + noise * r.normal(size=(n, *shape))
        return np.clip(x * 0.5 + 0.5, 0, 1).astype(np.float32), y.astype(np.int32)

    xtr, ytr = make(n_train, 1)
    xte, yte = make(n_test, 2)
    return ImageData(xtr, ytr, xte, yte)


def token_batches(
    vocab: int, batch: int, seq: int, *, seed: int = 0
) -> Iterator[Tuple[np.ndarray, np.ndarray]]:
    """Endless stream of (tokens, labels) with order-1 Markov structure."""
    rng = np.random.default_rng(seed)
    # sparse transition structure: each token has 8 likely successors
    succ = rng.integers(0, vocab, size=(vocab, 8))
    while True:
        t = np.empty((batch, seq + 1), np.int32)
        t[:, 0] = rng.integers(0, vocab, batch)
        for i in range(seq):
            pick = succ[t[:, i], rng.integers(0, 8, batch)]
            flip = rng.random(batch) < 0.1
            t[:, i + 1] = np.where(flip, rng.integers(0, vocab, batch), pick)
        yield t[:, :-1], t[:, 1:]
