"""Distributed data loading: deterministic per-host sharding.

On a real cluster every host must draw a disjoint slice of the global batch
while staying bitwise deterministic under restarts and ELASTIC resizes. The
loader derives each batch purely from (seed, step, host_slice), so a resumed
or re-sliced job regenerates exactly the stream it would have seen.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Iterator, Tuple

import numpy as np

__all__ = ["ShardedTokenLoader"]


@dataclasses.dataclass(frozen=True)
class ShardedTokenLoader:
    """Deterministic synthetic token stream, sharded by host.

    global_batch rows are split evenly over ``num_hosts``; host ``host_id``
    materializes only its rows. ``batch_at(step)`` is a pure function — the
    basis for checkpoint-restart and elastic-resize determinism (tested in
    tests/test_loader.py).
    """

    vocab: int
    global_batch: int
    seq_len: int
    num_hosts: int = 1
    host_id: int = 0
    seed: int = 0

    def __post_init__(self):
        assert self.global_batch % self.num_hosts == 0
        assert 0 <= self.host_id < self.num_hosts

    @property
    def host_batch(self) -> int:
        return self.global_batch // self.num_hosts

    def _row(self, step: int, row: int) -> np.ndarray:
        """One (seq_len+1,) token row, derived only from (seed, step, row)."""
        rng = np.random.default_rng(
            np.random.SeedSequence([self.seed, step, row])
        )
        succ = rng.integers(0, self.vocab, size=8)
        t = np.empty(self.seq_len + 1, np.int64)
        t[0] = rng.integers(0, self.vocab)
        picks = rng.integers(0, 8, self.seq_len)
        flips = rng.random(self.seq_len) < 0.1
        rand = rng.integers(0, self.vocab, self.seq_len)
        for i in range(self.seq_len):
            t[i + 1] = rand[i] if flips[i] else (t[i] + succ[picks[i]]) % self.vocab
        return t

    def batch_at(self, step: int) -> Dict[str, np.ndarray]:
        """The host-local slice of the global batch for ``step``."""
        lo = self.host_id * self.host_batch
        rows = np.stack([self._row(step, lo + r) for r in range(self.host_batch)])
        return {
            "tokens": rows[:, :-1].astype(np.int32),
            "labels": rows[:, 1:].astype(np.int32),
        }

    def __iter__(self) -> Iterator[Dict[str, np.ndarray]]:
        step = 0
        while True:
            yield self.batch_at(step)
            step += 1
