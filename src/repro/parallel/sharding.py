"""Sharding rules: parameter/batch/cache PartitionSpecs for the production
mesh (DP over pod x data, TP/EP over model, FSDP parameter sharding over
data, SP fallback for long sequences / few KV heads).

Rules are path-based over the param pytree and divisibility-checked against
the actual mesh: a dim is only sharded if its size divides the axis product
(GSPMD would pad otherwise; for *parameters* we keep shards exact so that
checkpoints reshard cleanly across cluster sizes — elastic restore).
"""
from __future__ import annotations

import re
from typing import Any, Dict, Optional, Tuple

import jax
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.configs.base import ModelConfig
from repro.launch.mesh import batch_axes

__all__ = [
    "param_pspec",
    "param_shardings",
    "batch_pspecs",
    "cache_pspecs",
    "constrain",
    "mesh_axis_size",
    "current_mesh",
]


def current_mesh() -> Optional[Mesh]:
    """The physical mesh installed by ``with mesh:`` (None outside)."""
    from jax._src.mesh import thread_resources

    m = thread_resources.env.physical_mesh
    return None if m.empty else m


def mesh_axis_size(name: str) -> int:
    m = current_mesh()
    return int(m.shape[name]) if m is not None and name in m.axis_names else 1


def constrain(x: jax.Array, axes: Tuple[Any, ...]) -> jax.Array:
    """with_sharding_constraint that degrades gracefully: no mesh -> no-op;
    per-dim axis entries are dropped when missing from the mesh or when the
    dim size does not divide the axis size. ``"batch"`` resolves to the DP
    axes ``("pod", "data")`` present in the mesh."""
    m = current_mesh()
    if m is None:
        return x
    spec = []
    for dim, ax in zip(x.shape, axes):
        if ax is None:
            spec.append(None)
            continue
        names = tuple(a for a in ("pod", "data") if a in m.axis_names) if ax == "batch" \
            else tuple(a for a in (ax if isinstance(ax, tuple) else (ax,)) if a in m.axis_names)
        size = int(np.prod([m.shape[a] for a in names])) if names else 1
        if names and dim % size == 0:
            spec.append(names if len(names) > 1 else names[0])
        else:
            spec.append(None)
    return jax.lax.with_sharding_constraint(x, NamedSharding(m, P(*spec)))

# (regex on path, (dim -> axis name) from the END of the shape)
# axis names: "fsdp" -> data, "tp" -> model; resolved per-mesh.
_RULES: Tuple[Tuple[str, Dict[int, str]], ...] = (
    # attention / dense projections: (…, d_in, d_out)
    (r"\.wq$|\.wk$|\.wv$|w_gate$|w_up$|shared_gate$|shared_up$", {-2: "fsdp", -1: "tp"}),
    (r"\.wo$|w_down$|shared_down$", {-2: "tp", -1: "fsdp"}),
    (r"router$|shared_router$", {-2: "fsdp"}),
    # embeddings / head
    (r"^\['embed'\]$", {-2: "tp", -1: "fsdp"}),
    (r"^\['lm_head'\]$", {-2: "fsdp", -1: "tp"}),
    # mamba
    (r"\.in_proj$|\.x_proj$", {-2: "fsdp", -1: "tp"}),
    (r"\.out_proj$", {-2: "tp", -1: "fsdp"}),
    (r"\.dt_proj$", {-1: "tp"}),
    (r"\.conv_w$|\.conv_b$|\.a_log$|\.d_skip$|\.dt_bias$|\.norm_g$", {-1: "tp"}),
    # everything else (norm scales, biases): replicated
)

_MOE_EP_RULES: Tuple[Tuple[str, Dict[int, str]], ...] = (
    # expert-parallel: experts dim over model axis
    (r"\['moe'\]\.w_gate$|\['moe'\]\.w_up$", {-3: "tp", -2: "fsdp"}),
    (r"\['moe'\]\.w_down$", {-3: "tp", -1: "fsdp"}),
)


def _axis_size(mesh: Mesh, name: Optional[str]) -> int:
    return int(mesh.shape[name]) if name in mesh.axis_names else 1


def param_pspec(
    path: str,
    shape: Tuple[int, ...],
    cfg: ModelConfig,
    mesh: Mesh,
) -> P:
    # frozen QWeight leaves: codes shard like the original weight; the small
    # per-channel scale/zero-point/col-sum tensors replicate
    if path.endswith((".scale", ".zero_point", ".col_sum")):
        return P()
    if path.endswith(".codes"):
        path = path[: -len(".codes")]

    fsdp_ax = "data" if "data" in mesh.axis_names else None
    tp_ax = "model" if "model" in mesh.axis_names else None
    alias = {"fsdp": fsdp_ax, "tp": tp_ax}

    rules = _RULES
    if cfg.family == "moe" and cfg.moe_experts % _axis_size(mesh, tp_ax) == 0:
        rules = _MOE_EP_RULES + _RULES   # EP when experts divide the TP axis

    for pat, dims in rules:
        if re.search(pat, path):
            spec = [None] * len(shape)
            for rel, ax_alias in dims.items():
                ax = alias[ax_alias]
                idx = len(shape) + rel
                if ax is None or idx < 0:
                    continue
                if shape[idx] % mesh.shape[ax] == 0:
                    spec[idx] = ax
            # never shard the stacked-layer leading axis
            return P(*spec)
    return P()


def param_shardings(cfg: ModelConfig, params_shape: Any, mesh: Mesh) -> Any:
    """Map a params pytree (arrays or ShapeDtypeStructs) -> NamedShardings."""

    def one(path, leaf):
        spec = param_pspec(jax.tree_util.keystr(path), leaf.shape, cfg, mesh)
        return NamedSharding(mesh, spec)

    return jax.tree_util.tree_map_with_path(one, params_shape)


def batch_pspecs(cfg: ModelConfig, mesh: Mesh, kind: str) -> Dict[str, P]:
    """PartitionSpecs for input batches by shape kind."""
    b = P(batch_axes(mesh))
    specs: Dict[str, P] = {}
    if cfg.embed_input:
        specs["tokens"] = b
    else:
        specs["embeddings"] = b
    if kind == "train":
        specs["labels"] = b
    if cfg.pos_embedding == "m_rope":
        specs["positions_thw"] = b
    if kind == "decode":
        specs["cur_len"] = b
    return specs


def prune_pspec(mesh: Mesh, spec: P, shape: Tuple[int, ...]) -> P:
    """Drop per-dim axes whose size does not divide the dim (e.g. batch=1
    for long_500k): jit in_shardings require exact divisibility."""
    out = []
    for dim, entry in zip(shape, tuple(spec) + (None,) * (len(shape) - len(spec))):
        if entry is None:
            out.append(None)
            continue
        names = entry if isinstance(entry, tuple) else (entry,)
        names = tuple(a for a in names if a in mesh.axis_names)
        size = int(np.prod([mesh.shape[a] for a in names])) if names else 1
        if names and dim % size == 0:
            out.append(names if len(names) > 1 else names[0])
        else:
            out.append(None)
    return P(*out)


def safe_sharding(mesh: Mesh, spec: P, leaf) -> NamedSharding:
    return NamedSharding(mesh, prune_pspec(mesh, spec, leaf.shape))


def cache_pspecs(cfg: ModelConfig, mesh: Mesh, cache_shape: Any, *, layout: str = "slots") -> Any:
    """Decode-cache shardings.

    ``layout="slots"`` (per-slot stripes, leaves ``(L, B, S, Hkv, hd)``):
    batch over DP axes; KV heads over model when divisible, otherwise
    sequence-parallel (SP) over model.

    ``layout="paged"`` (block pool, leaves ``(L, num_blocks, block_size,
    Hkv, hd)``): block *contents* shard along the KV-head dim over model —
    each shard holds ``Hkv/tp`` heads of every block, so the host-global
    block tables index all shards identically. The block dim is never
    sharded (tables are host state) and there is no SP fallback: splitting
    ``block_size`` would partition the softmax *within* single blocks. When
    ``Hkv`` does not divide the model axis the pool simply replicates.
    """
    if layout not in ("slots", "paged"):
        raise ValueError(f"cache_pspecs: unknown layout {layout!r}")
    dp = batch_axes(mesh)
    tp = "model" if "model" in mesh.axis_names else None
    tp_size = _axis_size(mesh, tp)

    def paged_one(path, leaf):
        ks = jax.tree_util.keystr(path)
        shape = leaf.shape
        spec = [None] * len(shape)
        if (ks.endswith("['k']") or ks.endswith("['v']")) and tp and shape[3] % tp_size == 0:
            spec[3] = tp
        return NamedSharding(mesh, prune_pspec(mesh, P(*spec), shape))

    if layout == "paged":
        return jax.tree_util.tree_map_with_path(paged_one, cache_shape)

    def one(path, leaf):
        ks = jax.tree_util.keystr(path)
        shape = leaf.shape
        spec = [None] * len(shape)
        spec[1] = dp  # (L_or_groups, B, ...)
        if ks.endswith("['k']") or ks.endswith("['v']"):
            # (L, B, S, Hkv, hd)
            if tp and shape[3] % tp_size == 0:
                spec[3] = tp
            elif tp and shape[2] % tp_size == 0:
                spec[2] = tp          # SP over cache length
        elif ks.endswith("['ssm']"):
            # mamba1 (L,B,di,N) / mamba2 (L,B,nh,hd,N)
            if tp and shape[2] % tp_size == 0:
                spec[2] = tp
        elif ks.endswith("['conv']"):
            if tp and shape[3] % tp_size == 0:
                spec[3] = tp
        return NamedSharding(mesh, prune_pspec(mesh, P(*spec), shape))

    return jax.tree_util.tree_map_with_path(one, cache_shape)


# Optimizer-state shardings mirror parameter shardings structurally
# ({"m": params-like, "v": params-like, "step": scalar}); constructed in
# train/optim.py::opt_state_shardings.
