"""Pallas TPU kernel: fused approximate-multiplier matmul.

Computes  out[m, n] = sum_k LUT[a[m, k], b[k, n]]  for any registered
multiplier family (aggregated MUL8x8_1/2/3, PKM, ETM, fixed-shift MSR)
WITHOUT any per-MAC gather, using the exact decomposition (core/lowrank.py):

    out = A @ B - sum_f  v_f(A) @ u_f(B)

* the exact dot rides the MXU;
* u_f / v_f are elementwise shift/mask/compare maps computed IN-KERNEL from
  the uint8 code tiles, so HBM traffic is identical to an exact int8 matmul
  (the features never touch HBM);
* per-(bk<=256) tile, every dot's magnitude stays below 2^24, so f32 MXU
  accumulation is exact; cross-tile accumulation is int32 in VMEM scratch.

Grid is (M/bm, N/bn, K/bk) with k innermost ("arbitrary"); m/n parallel.

VMEM budget at the default bm=bn=128, bk=256 (uint8 codes in HBM):
  A tile 32 KiB + B tile 32 KiB + acc 64 KiB + feature temporaries ~ 256 KiB
  << 16 MiB v5e VMEM; the MXU sees (1 + F) fused (128,256)x(256,128) dots.
"""
from __future__ import annotations

import functools
from typing import Sequence, Tuple

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.core import lowrank as lr

__all__ = ["approx_matmul_kernel_call", "FeatureMeta", "features_meta"]

# Static per-feature metadata consumed by the kernel body:
#   (kind, u_shift, u_bits, residue, v_terms, u_terms)
_Terms = Tuple[Tuple[int, int, Tuple[int, ...]], ...]
FeatureMeta = Tuple[str, int, int, int, _Terms, _Terms]


def features_meta(corr: lr.LowRankCorrection) -> Tuple[FeatureMeta, ...]:
    return tuple(
        (f.kind, f.u_shift, f.u_bits, f.residue, f.v_terms, f.u_terms)
        for f in corr.features
    )


# feature maps shared with the XLA path: pure shift/mask/compare, no gathers
_u_map = lr.u_map_jnp
_v_map = lr.v_map_jnp


def _kernel(a_ref, b_ref, out_ref, acc_ref, *, features: Tuple[FeatureMeta, ...], k_steps: int):
    k = pl.program_id(2)

    @pl.when(k == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    a = a_ref[...].astype(jnp.int32)          # (bm, bk) codes
    b = b_ref[...].astype(jnp.int32)          # (bk, bn) codes
    af = a.astype(jnp.float32)
    bf = b.astype(jnp.float32)
    tile = jax.lax.dot_general(
        af, bf, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32
    )
    for (kind, u_shift, u_bits, residue, v_terms, u_terms) in features:
        v_a = _v_map(a, v_terms)              # (bm, bk) lhs-side table values
        u_b = _u_map(b, kind, u_shift, u_bits, residue, u_terms)  # (bk, bn)
        tile -= jax.lax.dot_general(
            v_a, u_b, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32
        )
    acc_ref[...] += tile.astype(jnp.int32)

    @pl.when(k == k_steps - 1)
    def _flush():
        out_ref[...] = acc_ref[...]


@functools.partial(
    jax.jit,
    static_argnames=("multiplier", "lhs_max", "rhs_max", "bm", "bn", "bk", "interpret"),
)
def approx_matmul_kernel_call(
    a_codes: jax.Array,
    b_codes: jax.Array,
    *,
    multiplier: str = "mul8x8_2",
    lhs_max: int = 255,
    rhs_max: int = 255,
    bm: int = 128,
    bn: int = 128,
    bk: int = 256,
    interpret: bool = False,
) -> jax.Array:
    """2-D core: a (M, K) codes, b (K, N) codes -> (M, N) int32.

    Shapes must be multiples of the block sizes (ops.py pads; zero codes are
    error-free for aggregated multipliers so padding is semantically inert).
    """
    M, K = a_codes.shape
    K2, N = b_codes.shape
    assert K == K2, (K, K2)
    assert M % bm == 0 and N % bn == 0 and K % bk == 0, (M, N, K, bm, bn, bk)
    assert bk <= 256, "per-tile f32 dot exactness requires bk <= 256"

    corr = lr.build_correction(
        multiplier, side="rhs", lhs_max=lhs_max, rhs_max=rhs_max
    )
    feats = features_meta(corr)
    k_steps = K // bk

    grid = (M // bm, N // bn, k_steps)
    kernel = functools.partial(_kernel, features=feats, k_steps=k_steps)
    kwargs = {}
    if not interpret:
        kwargs["compiler_params"] = pltpu.CompilerParams(
            dimension_semantics=("parallel", "parallel", "arbitrary"),
        )
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((bm, bk), lambda m, n, k: (m, k)),
            pl.BlockSpec((bk, bn), lambda m, n, k: (k, n)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda m, n, k: (m, n)),
        out_shape=jax.ShapeDtypeStruct((M, N), jnp.int32),
        scratch_shapes=[pltpu.VMEM((bm, bn), jnp.int32)],
        interpret=interpret,
        **kwargs,
    )(a_codes, b_codes)
