"""Pure-jnp oracle for the approximate-multiplier matmul.

This is the paper-faithful simulation: every scalar MAC goes through the
256x256 multiplier LUT (exactly what the authors' "extended DNN platform"
does when it swaps the exact multiplier for an approximate one).  It is the
correctness reference for the Pallas kernel and the low-rank MXU path — and
it is also the *performance baseline* recorded in EXPERIMENTS.md §Perf (a
LUT gather per MAC is the mechanical port of the circuit; the low-rank path
is the TPU-native re-expression).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

__all__ = ["approx_matmul_ref", "approx_mul_elementwise"]


def approx_mul_elementwise(a: jax.Array, b: jax.Array, lut: jax.Array) -> jax.Array:
    """LUT[a, b] elementwise (broadcasting); codes int in [0, 255]."""
    flat = lut.reshape(-1)
    return flat[a.astype(jnp.int32) * 256 + b.astype(jnp.int32)]


def approx_matmul_ref(
    a_codes: jax.Array, b_codes: jax.Array, lut: jax.Array, *, block_k: int = 512
) -> jax.Array:
    """sum_k LUT[a[.., m, k], b[k, n]] with int32 accumulation.

    a_codes: (..., M, K) ints in [0,255]; b_codes: (K, N).  Materializes
    (..., M, block_k, N) gathers — use small shapes (tests) or accept the
    memory cost (it IS the mechanical baseline).
    """
    a32 = a_codes.astype(jnp.int32)
    b32 = b_codes.astype(jnp.int32)
    flat = lut.reshape(-1).astype(jnp.int32)
    K = a32.shape[-1]

    def chunk(acc_and_k, _):
        acc, k0 = acc_and_k
        ak = jax.lax.dynamic_slice_in_dim(a32, k0, block_k, axis=a32.ndim - 1)
        bk = jax.lax.dynamic_slice_in_dim(b32, k0, block_k, axis=0)
        prod = flat[ak[..., :, :, None] * 256 + bk[None, :, :]]
        return (acc + jnp.sum(prod, axis=-2), k0 + block_k), None

    if K % block_k != 0:
        # un-scanned fallback for ragged K (small test shapes)
        prod = flat[a32[..., :, :, None] * 256 + b32[None, :, :]]
        return jnp.sum(prod, axis=-2, dtype=jnp.int32)

    *lead, M, _ = a32.shape
    N = b32.shape[1]
    acc0 = jnp.zeros((*lead, M, N), jnp.int32)
    (acc, _), _ = jax.lax.scan(
        chunk, (acc0, jnp.int32(0)), None, length=K // block_k
    )
    return acc
