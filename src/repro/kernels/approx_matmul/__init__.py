from repro.kernels.approx_matmul.ops import approx_matmul_pallas
from repro.kernels.approx_matmul.ref import approx_matmul_ref

__all__ = ["approx_matmul_pallas", "approx_matmul_ref"]
