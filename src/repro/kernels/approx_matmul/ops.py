"""Public jit'd wrapper around the Pallas approx-matmul kernel.

Handles leading batch dimensions, pads (M, N, K) up to block multiples
(K padding only ever pairs zero codes with zero codes and every registered
LUT maps (0, 0) -> 0; padded M/N rows are sliced off), and auto-selects
interpret mode off-TPU.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.kernels.approx_matmul.kernel import approx_matmul_kernel_call
from repro.kernels.interpret import default_interpret as _default_interpret

__all__ = ["approx_matmul_pallas", "select_blocks"]


def _pad_to(x: jax.Array, axis: int, mult: int) -> jax.Array:
    size = x.shape[axis]
    rem = (-size) % mult
    if rem == 0:
        return x
    pads = [(0, 0)] * x.ndim
    pads[axis] = (0, rem)
    return jnp.pad(x, pads)


def _round_up(x: int, mult: int) -> int:
    return -(-x // mult) * mult


def select_blocks(
    M: int, N: int, K: int, *, bm: int = 128, bn: int = 128, bk: int = 256
) -> tuple[tuple[int, int, int], tuple[int, int, int]]:
    """Block sizes and padded problem dims for an (M, K) x (K, N) call.

    Problems smaller than a block shrink the block to the TPU-aligned
    minimum that covers them — a multiple of 8 on the sublane (M) axis, a
    multiple of 128 on the lane (N/K) axes — instead of the old
    next-power-of-two rounding, which over-padded every non-pow2 row count
    (M=24 slots padded to 32, M=65 to 128; M=1 decode rows pad to 8, the
    sublane floor, not to bm=128).  Returns ``((bm_, bn_, bk_),
    (Mp, Np, Kp))`` with each padded dim a multiple of its block.
    """
    bm_ = bm if M >= bm else max(8, _round_up(M, 8))
    bn_ = bn if N >= bn else max(128, _round_up(N, 128))
    bk_ = bk if K >= bk else max(128, _round_up(K, 128))
    return (bm_, bn_, bk_), (_round_up(M, bm_), _round_up(N, bn_), _round_up(K, bk_))


def approx_matmul_pallas(
    a_codes: jax.Array,
    b_codes: jax.Array,
    *,
    multiplier: str = "mul8x8_2",
    lhs_max: int = 255,
    rhs_max: int = 255,
    bm: int = 128,
    bn: int = 128,
    bk: int = 256,
    interpret: bool | None = None,
) -> jax.Array:
    """a (..., M, K) codes x b (K, N) codes -> (..., M, N) int32 under the
    named approximate multiplier (bit-exact to the LUT oracle)."""
    if interpret is None:
        interpret = _default_interpret()
    *lead, M, K = a_codes.shape
    Kb, N = b_codes.shape
    assert K == Kb, (K, Kb)
    a2 = a_codes.reshape(-1, K) if lead else a_codes
    # shrink blocks for small problems (decode M rows), keeping TPU minima
    (bm_, bn_, bk_), _ = select_blocks(a2.shape[0], N, K, bm=bm, bn=bn, bk=bk)
    a2 = _pad_to(_pad_to(a2, 0, bm_), 1, bk_)
    b2 = _pad_to(_pad_to(b_codes, 0, bk_), 1, bn_)
    out = approx_matmul_kernel_call(
        a2,
        b2,
        multiplier=multiplier,
        lhs_max=lhs_max,
        rhs_max=rhs_max,
        bm=bm_,
        bn=bn_,
        bk=bk_,
        interpret=interpret,
    )
    out = out[: (a_codes.reshape(-1, K).shape[0] if lead else M), :N]
    if lead:
        out = out.reshape(*lead, M, N)
    return out
