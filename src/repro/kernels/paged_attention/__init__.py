from repro.kernels.paged_attention.ops import paged_attention_pallas, validate_tp_heads
from repro.kernels.paged_attention.ref import paged_attention_ref

__all__ = ["paged_attention_pallas", "paged_attention_ref", "validate_tp_heads"]
