"""Public wrapper around the Pallas paged decode-attention kernel.

Validates shapes, normalizes index dtypes, and auto-selects interpret mode
off-TPU (``REPRO_FORCE_INTERPRET=1`` forces it anywhere — the CPU CI path,
which runs the real kernel body through the Pallas interpreter).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.kernels.interpret import default_interpret as _default_interpret
from repro.kernels.paged_attention.kernel import paged_attention_kernel_call

__all__ = ["paged_attention_pallas", "validate_tp_heads"]


def validate_tp_heads(num_heads: int, num_kv_heads: int, tp: int) -> None:
    """Reject head counts that cannot shard over a ``tp``-way model axis.

    The kernel is mapped per-shard under tensor parallelism (``shard_map``
    over the head dims of q/k/v and the pool), so each shard must hold an
    integral number of query AND KV heads — otherwise the per-shard
    ``H % n_kv`` group structure (each KV head serving ``H // n_kv`` query
    heads) would differ across shards and the grid would be ragged."""
    if tp < 1:
        raise ValueError(f"tp must be >= 1, got {tp}")
    if num_heads % tp or num_kv_heads % tp:
        raise ValueError(
            f"pallas paged attention under tp={tp} needs per-shard integral "
            f"head counts: num_heads={num_heads}, num_kv_heads={num_kv_heads} "
            f"must both divide by tp"
        )
    if (num_heads // tp) % (num_kv_heads // tp):
        raise ValueError(
            f"per-shard group structure broken: {num_heads // tp} query heads "
            f"not a multiple of {num_kv_heads // tp} KV heads per shard"
        )


def paged_attention_pallas(
    q: jax.Array,            # (B, H, hd) post-rope queries, one decode step
    k_new: jax.Array,        # (B, Hkv, hd) new token K (post-rope)
    v_new: jax.Array,        # (B, Hkv, hd) new token V
    k_pool: jax.Array,       # (num_blocks, block_size, Hkv, hd) one layer
    v_pool: jax.Array,
    block_table: jax.Array,  # (B, W) physical block ids, sentinel == num_blocks
    cur_len: jax.Array,      # (B,) new-token positions
    *,
    block_size: int,
    interpret: bool | None = None,
) -> jax.Array:
    """(B, H, hd) attention outputs in the caller's query dtype.

    The pool operands are READ-ONLY: the new token is fused into the
    current block's VMEM tile inside the kernel, and persisting it to the
    pool for the next step is the caller's scatter (see
    ``models.attention.paged_decode_attention``).
    """
    if interpret is None:
        interpret = _default_interpret()
    B, H, hd = q.shape
    num_blocks, bs, n_kv, hd_k = k_pool.shape
    if bs != block_size:
        raise ValueError(f"pool block_size {bs} != block_size arg {block_size}")
    if v_pool.shape != k_pool.shape:
        raise ValueError(f"k/v pool shapes differ: {k_pool.shape} vs {v_pool.shape}")
    if hd != hd_k or H % n_kv:
        raise ValueError(
            f"q heads/dim {(H, hd)} incompatible with pool {(n_kv, hd_k)}"
        )
    if k_new.shape != (B, n_kv, hd) or v_new.shape != (B, n_kv, hd):
        raise ValueError(
            f"new-token K/V must be {(B, n_kv, hd)}, got "
            f"{k_new.shape} / {v_new.shape}"
        )
    if block_table.ndim != 2 or block_table.shape[0] != B or cur_len.shape != (B,):
        raise ValueError(
            f"block_table {block_table.shape} / cur_len {cur_len.shape} "
            f"inconsistent with batch {B}"
        )
    out = paged_attention_kernel_call(
        q,
        k_new,
        v_new,
        k_pool,
        v_pool,
        block_table.astype(jnp.int32),
        cur_len.astype(jnp.int32),
        block_size=block_size,
        interpret=interpret,
    )
    return out.astype(q.dtype)
