"""Pure-JAX oracle for the paged decode-attention kernel.

Same *semantics* as the kernel — walk the block table, fuse the new token
at ``cur_len``, skip sentinel blocks, mask positions past ``cur_len`` — but
computed the straightforward way: gather every table entry (clamped), mask,
one exact fused softmax.  This is the reference the property tests
difference the kernel against (``tests/test_kernels_property.py``); it is
deliberately independent of ``models.attention`` so a bug in the serving
path cannot hide a matching bug here.

Exactness contract: the kernel's online softmax reorders the f32
reductions, so kernel-vs-ref agreement is to f32 roundoff (~1e-6), not
bitwise; masked positions carry softmax weight exactly 0.0 in both.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

__all__ = ["paged_attention_ref"]

_NEG = -1e30


def paged_attention_ref(
    q: jax.Array,            # (B, H, hd)
    k_new: jax.Array,        # (B, Hkv, hd)
    v_new: jax.Array,        # (B, Hkv, hd)
    k_pool: jax.Array,       # (num_blocks, block_size, Hkv, hd)
    v_pool: jax.Array,
    block_table: jax.Array,  # (B, W) int32, sentinel == num_blocks
    cur_len: jax.Array,      # (B,) int32
    *,
    block_size: int,
) -> jax.Array:
    """Exact-softmax paged GQA; (B, H, hd) f32.  Rows with no valid
    position (every block sentinel) return zeros, matching the kernel's
    empty-row flush."""
    B, H, hd = q.shape
    num_blocks, bs, n_kv, _ = k_pool.shape
    W = block_table.shape[1]
    g = H // n_kv
    S = W * block_size

    clamped = jnp.minimum(block_table, num_blocks - 1)
    kg = k_pool[clamped].reshape(B, S, n_kv, hd).astype(jnp.float32)
    vg = v_pool[clamped].reshape(B, S, n_kv, hd).astype(jnp.float32)

    pos = jnp.arange(S, dtype=jnp.int32)
    at_cur = pos[None, :] == cur_len[:, None]                    # (B, S)
    kg = jnp.where(at_cur[..., None, None], k_new.astype(jnp.float32)[:, None], kg)
    vg = jnp.where(at_cur[..., None, None], v_new.astype(jnp.float32)[:, None], vg)

    # a position is attended iff it is <= cur AND its block is allocated
    blk_alloc = block_table < num_blocks                         # (B, W)
    pos_alloc = jnp.repeat(blk_alloc, block_size, axis=1)        # (B, S)
    valid = (pos[None, :] <= cur_len[:, None]) & pos_alloc

    scale = 1.0 / jnp.sqrt(jnp.float32(hd))
    qg = (q.astype(jnp.float32) * scale).reshape(B, n_kv, g, hd)
    s = jnp.einsum("bhgd,bshd->bhgs", qg, kg, preferred_element_type=jnp.float32)
    s = jnp.where(valid[:, None, None, :], s, _NEG)
    p = jax.nn.softmax(s, axis=-1)
    out = jnp.einsum("bhgs,bshd->bhgd", p, vg, preferred_element_type=jnp.float32)
    any_valid = jnp.any(valid, axis=1)                           # (B,)
    return jnp.where(any_valid[:, None, None], out.reshape(B, H, hd), 0.0)
