"""Pallas TPU kernel: paged decode attention over the global block pool.

One decode step of GQA against the paged KV cache (``cache_layout="paged"``)
WITHOUT ever materializing the per-request block gather: the XLA path builds
a transient ``(B, W*block_size, Hkv, hd)`` view of every request's blocks
per layer per step, which is the dominant per-tick HBM traffic once the
host side is hidden (PR 4).  This kernel instead walks each request's block
table and streams K/V blocks from the pool straight into VMEM tiles:

* grid ``(B, W)`` with the table walk innermost; the block index maps read
  the scalar-prefetched ``block_table``, so grid step ``(b, w)`` DMAs
  physical block ``block_table[b, w]`` — the pool is indexed where it
  lives, and only blocks a request actually holds ever cross HBM->VMEM;
* the new token's K/V (``k_new``/``v_new``, already rotary-embedded at
  ``cur_len``) is fused into the current block's VMEM tile at offset
  ``cur_len % block_size`` before the QK^T — attention never waits on the
  pool scatter, which the caller runs in parallel to persist the token for
  the NEXT step;
* per-block scores feed a running online softmax (``m``/``l``/``acc``
  scratch carried across the ``w`` walk, flushed at ``w == W - 1``);
* sentinel table entries (``id >= num_blocks``: unallocated / padding
  rows) are SKIPPED — ``@pl.when`` drops the tile's compute, and the index
  map re-maps invalid steps to the row's last valid block so Pallas's
  consecutive-same-block dedup elides their DMAs too, where the gather
  path had to clamp, gather garbage, and rely on the kv_len mask.  Rows
  with no valid block (inactive slots) flush exactly zero.

Numerics: scores/softmax/AV all accumulate in f32 exactly like
``attention_core``; masked in-block tail positions sit at -1e30, so their
softmax weight underflows to exactly 0.0 — but the ONLINE softmax sums in
block order, not the fused-softmax reduction order, so attention outputs
agree with the gather oracle to f32 roundoff (~1e-7 relative), not
bitwise.  Greedy ARGMAX outputs stay bit-identical across serve traces
(asserted in tests/test_paged.py); ``ref.py`` is the exact-math oracle the
property tests difference against.

TPU tiling note: tiles are ``(block_size, Hkv, hd)``; compiled mode wants
``hd`` a multiple of 128 and ``block_size`` a multiple of the sublane
count.  Interpret mode (CPU CI, ``REPRO_FORCE_INTERPRET=1``) has no such
constraint and runs this exact kernel body.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

__all__ = ["paged_attention_kernel_call"]

_NEG = -1e30


def _kernel(
    tbl_ref,      # (B, W) int32 scalar-prefetch: physical block ids
    len_ref,      # (B,)  int32 scalar-prefetch: new-token positions
    q_ref,        # (1, H, hd) this row's query
    kn_ref,       # (1, Hkv, hd) new token K (post-rope)
    vn_ref,       # (1, Hkv, hd) new token V
    k_ref,        # (1, block_size, Hkv, hd) pool block block_table[b, w]
    v_ref,
    out_ref,      # (1, H, hd)
    m_ref,        # (H, 1) f32 scratch: running max
    l_ref,        # (H, 1) f32 scratch: running normalizer
    acc_ref,      # (H, hd) f32 scratch: running weighted V sum
    *,
    block_size: int,
    num_blocks: int,
    n_kv: int,
    W: int,
):
    b = pl.program_id(0)
    w = pl.program_id(1)

    @pl.when(w == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, _NEG)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    cur = len_ref[b]
    entry = tbl_ref[b, w]
    # process only blocks that are allocated AND hold >= 1 valid position
    # (position w*block_size <= cur); everything else contributes nothing —
    # this predicate is the in-place analogue of the gather path's
    # clamp-then-mask, and it is also what keeps HBM reads proportional to
    # the ACTUAL context instead of the table width
    valid = (entry < num_blocks) & (w * block_size <= cur)

    @pl.when(valid)
    def _block():
        H, hd = q_ref.shape[1], q_ref.shape[2]
        g = H // n_kv
        q = q_ref[0].astype(jnp.float32)                 # (H, hd)
        k = k_ref[0].astype(jnp.float32)                 # (bs, Hkv, hd)
        v = v_ref[0].astype(jnp.float32)
        # fused token append: overwrite row `off` of the CURRENT block's
        # VMEM tile with the new K/V — the HBM pool still holds last step's
        # contents, and never needs to be read-after-written within a step
        off = cur % block_size
        row = jax.lax.broadcasted_iota(jnp.int32, (block_size, 1, 1), 0)
        sel = (row == off) & (w == cur // block_size)
        k = jnp.where(sel, kn_ref[0].astype(jnp.float32)[None], k)
        v = jnp.where(sel, vn_ref[0].astype(jnp.float32)[None], v)

        scale = 1.0 / jnp.sqrt(jnp.float32(hd))
        qg = (q * scale).reshape(n_kv, g, hd)
        s = jnp.einsum(
            "hgd,thd->hgt", qg, k, preferred_element_type=jnp.float32
        )
        pos = w * block_size + jax.lax.broadcasted_iota(
            jnp.int32, (1, 1, block_size), 2
        )
        s = jnp.where(pos <= cur, s, _NEG).reshape(H, block_size)

        # online softmax: rescale the running sums by exp(m_prev - m_new);
        # masked positions underflow to weight exactly 0.0
        m_prev, l_prev = m_ref[...], l_ref[...]
        m_new = jnp.maximum(m_prev, jnp.max(s, axis=1, keepdims=True))
        alpha = jnp.exp(m_prev - m_new)
        p = jnp.exp(s - m_new)                           # (H, bs)
        l_ref[...] = l_prev * alpha + jnp.sum(p, axis=1, keepdims=True)
        pv = jnp.einsum(
            "hgt,thd->hgd", p.reshape(n_kv, g, block_size), v,
            preferred_element_type=jnp.float32,
        ).reshape(H, hd)
        acc_ref[...] = acc_ref[...] * alpha + pv
        m_ref[...] = m_new

    @pl.when(w == W - 1)
    def _flush():
        l = l_ref[...]
        # l == 0 <=> no valid block at all (inactive / all-sentinel row):
        # emit zeros rather than 0/0 NaNs
        out_ref[0] = jnp.where(l > 0.0, acc_ref[...] / jnp.where(l > 0.0, l, 1.0), 0.0)


@functools.partial(jax.jit, static_argnames=("block_size", "interpret"))
def paged_attention_kernel_call(
    q: jax.Array,            # (B, H, hd)
    k_new: jax.Array,        # (B, Hkv, hd)
    v_new: jax.Array,        # (B, Hkv, hd)
    k_pool: jax.Array,       # (num_blocks, block_size, Hkv, hd)
    v_pool: jax.Array,
    block_table: jax.Array,  # (B, W) int32, sentinel == num_blocks
    cur_len: jax.Array,      # (B,) int32
    *,
    block_size: int,
    interpret: bool = False,
) -> jax.Array:
    """One decode step of paged GQA: (B, H, hd) f32 attention outputs.

    Table/length *contents* are traced data (scalar-prefetch operands), so
    one compiled program serves every context layout — same discipline as
    the gather path.  The pool operands are read-only: persisting the new
    token is the caller's (cheap, O(B*Hkv*hd)) scatter, free to run in
    parallel with this kernel.
    """
    B, H, hd = q.shape
    num_blocks, bs, n_kv, hd_k = k_pool.shape
    assert bs == block_size, (bs, block_size)
    assert hd == hd_k and H % n_kv == 0, (q.shape, k_pool.shape)
    W = block_table.shape[1]

    def pool_index(b, w, tbl, lens):
        # The paged indirection.  A BlockSpec index map always implies a
        # fetch, so a sentinel entry cannot simply be "skipped" here — the
        # predicate in the kernel body skips the COMPUTE, and this map
        # makes the skip real for the DMA too by re-mapping every invalid
        # step to the row's last valid block (block 0 for all-sentinel
        # rows): Pallas elides the copy when consecutive grid steps map to
        # the same block, so sentinel runs issue no extra HBM traffic.
        row = tbl[b]                                     # (W,) entries
        js = jax.lax.broadcasted_iota(jnp.int32, (W, 1), 0)[:, 0]
        ok = (row < num_blocks) & (js <= w)
        j_star = jnp.max(jnp.where(ok, js, -1))
        entry = row[jnp.maximum(j_star, 0)]
        return (jnp.where(j_star >= 0, entry, 0), 0, 0, 0)

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=2,
        grid=(B, W),
        in_specs=[
            pl.BlockSpec((1, H, hd), lambda b, w, tbl, lens: (b, 0, 0)),
            pl.BlockSpec((1, n_kv, hd), lambda b, w, tbl, lens: (b, 0, 0)),
            pl.BlockSpec((1, n_kv, hd), lambda b, w, tbl, lens: (b, 0, 0)),
            pl.BlockSpec((1, block_size, n_kv, hd), pool_index),
            pl.BlockSpec((1, block_size, n_kv, hd), pool_index),
        ],
        out_specs=pl.BlockSpec((1, H, hd), lambda b, w, tbl, lens: (b, 0, 0)),
        scratch_shapes=[
            pltpu.VMEM((H, 1), jnp.float32),
            pltpu.VMEM((H, 1), jnp.float32),
            pltpu.VMEM((H, hd), jnp.float32),
        ],
    )
    kernel = functools.partial(
        _kernel, block_size=block_size, num_blocks=num_blocks, n_kv=n_kv, W=W
    )
    kwargs = {}
    if not interpret:
        # jax 0.4.x names this TPUCompilerParams; never touched off-TPU
        params_cls = getattr(pltpu, "CompilerParams", None) or pltpu.TPUCompilerParams
        kwargs["compiler_params"] = params_cls(
            dimension_semantics=("parallel", "arbitrary"),
        )
    return pl.pallas_call(
        kernel,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((B, H, hd), jnp.float32),
        interpret=interpret,
        **kwargs,
    )(block_table, cur_len, q, k_new, v_new, k_pool, v_pool)
