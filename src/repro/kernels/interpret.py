"""Shared interpret-mode policy for every Pallas kernel family.

One definition so the kernel families (approx_matmul, approx_mul_eltwise,
paged_attention) and the benches can never drift: interpret off-TPU, and
``REPRO_FORCE_INTERPRET=1`` (set by the test session fixture) forces the
interpreter regardless of backend — CPU CI runs the real kernel bodies.
"""
from __future__ import annotations

import os

import jax

__all__ = ["default_interpret"]


def default_interpret() -> bool:
    if os.environ.get("REPRO_FORCE_INTERPRET", "") == "1":
        return True
    return jax.default_backend() != "tpu"
