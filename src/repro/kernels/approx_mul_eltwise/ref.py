"""Oracle for the elementwise approximate multiplier: the 256x256 LUT."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core import multipliers as M

__all__ = ["approx_mul_eltwise_ref"]


def approx_mul_eltwise_ref(a: jax.Array, b: jax.Array, multiplier: str = "mul8x8_2") -> jax.Array:
    """LUT[a, b] elementwise (uint8-valued ints in, int32 out)."""
    lut = jnp.asarray(M.mul8x8_table(multiplier)).reshape(-1)
    return lut[a.astype(jnp.int32) * 256 + b.astype(jnp.int32)]
