"""jit'd wrapper for the elementwise approximate-multiply kernel."""
from __future__ import annotations

import jax

from repro.kernels.approx_mul_eltwise.kernel import approx_mul_eltwise_call
from repro.kernels.interpret import default_interpret

__all__ = ["approx_mul_eltwise_pallas"]


def approx_mul_eltwise_pallas(
    a: jax.Array,
    b: jax.Array,
    *,
    multiplier: str = "mul8x8_2",
    block: int = 1024,
    interpret: bool | None = None,
) -> jax.Array:
    if interpret is None:
        interpret = default_interpret()
    return approx_mul_eltwise_call(
        a, b, multiplier=multiplier, block=block, interpret=interpret
    )
