"""Pallas TPU kernel: elementwise approximate multiply, gather-free.

Evaluates the aggregated 8x8 approximate product with pure VPU bit logic
(core/logic.py): shifts/masks/compares — no 64 KiB LUT in VMEM and no
per-element gather. Used by the CNN platform when simulating the multiplier
on arbitrary elementwise products (e.g. quantized depthwise ops) and as an
independent cross-check of the matmul kernel's semantics.

Tiles: (bm, bn) VMEM blocks of the flattened operands; purely elementwise,
so the grid is embarrassingly parallel.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.core.logic import approx_mul8x8_bitwise

__all__ = ["approx_mul_eltwise_call"]

_DESIGN = {"mul8x8_1": (1, False), "mul8x8_2": (2, False), "mul8x8_3": (2, True)}


def _kernel(a_ref, b_ref, o_ref, *, design: int, removed_m2: bool):
    a = a_ref[...].astype(jnp.int32)
    b = b_ref[...].astype(jnp.int32)
    o_ref[...] = approx_mul8x8_bitwise(a, b, design, removed_m2).astype(jnp.int32)


@functools.partial(jax.jit, static_argnames=("multiplier", "block", "interpret"))
def approx_mul_eltwise_call(
    a: jax.Array,
    b: jax.Array,
    *,
    multiplier: str = "mul8x8_2",
    block: int = 1024,
    interpret: bool = False,
) -> jax.Array:
    """a, b: equal-shape uint8-valued arrays -> int32 approximate products."""
    design, removed = _DESIGN[multiplier]
    flat_a = a.reshape(-1)
    flat_b = b.reshape(-1)
    n = flat_a.shape[0]
    pad = (-n) % block
    if pad:
        flat_a = jnp.pad(flat_a, (0, pad))
        flat_b = jnp.pad(flat_b, (0, pad))
    grid = (flat_a.shape[0] // block,)
    out = pl.pallas_call(
        functools.partial(_kernel, design=design, removed_m2=removed),
        grid=grid,
        in_specs=[
            pl.BlockSpec((block,), lambda i: (i,)),
            pl.BlockSpec((block,), lambda i: (i,)),
        ],
        out_specs=pl.BlockSpec((block,), lambda i: (i,)),
        out_shape=jax.ShapeDtypeStruct(flat_a.shape, jnp.int32),
        interpret=interpret,
    )(flat_a, flat_b)
    return out[:n].reshape(a.shape)
