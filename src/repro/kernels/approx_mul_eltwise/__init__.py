from repro.kernels.approx_mul_eltwise.ops import approx_mul_eltwise_pallas
from repro.kernels.approx_mul_eltwise.ref import approx_mul_eltwise_ref

__all__ = ["approx_mul_eltwise_pallas", "approx_mul_eltwise_ref"]
