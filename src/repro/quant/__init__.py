from repro.quant.affine import QuantParams, calibrate, dequantize, quantize
from repro.quant.qat import band_regularizer, fake_quant

__all__ = [
    "QuantParams",
    "calibrate",
    "quantize",
    "dequantize",
    "fake_quant",
    "band_regularizer",
]
