"""Quantization-aware training utilities (the paper's retraining platform).

* ``fake_quant``: quantize->dequantize with a straight-through estimator
  (gradient passes where the value was inside the clip range).
* ``band_regularizer``: the paper's "retraining by regularization" — a penalty
  that pushes weight codes into a target band (e.g. (0, 31)) so that the
  aggressive MUL8x8_3 multiplier (removed M2 partial product) stays accurate.
"""
from __future__ import annotations

from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from repro.quant.affine import QuantParams, calibrate, dequantize, quantize

__all__ = ["fake_quant", "band_regularizer"]


def fake_quant(x: jax.Array, qp: QuantParams) -> jax.Array:
    """Straight-through fake-quantization via stop_gradient algebra:
    forward = dequantize(quantize(x)), backward = identity. Expressed
    without custom_vjp so it stays transparent to remat/scan/vmap (the
    out-of-band pull the clipped-STE variant provides is supplied by
    ``band_regularizer`` instead — the paper's retraining mechanism)."""
    sg = jax.lax.stop_gradient
    zp = qp.zero_point.astype(x.dtype)
    q = jnp.clip(jnp.round(x / qp.scale) + zp, 0, qp.qmax)
    fq = (q - zp) * qp.scale
    return x + sg(fq.astype(x.dtype) - x)


def band_regularizer(
    w: jax.Array,
    qp: QuantParams,
    *,
    band: Tuple[int, int] = (0, 31),
) -> jax.Array:
    """Mean squared excursion of weight codes outside ``band``.

    The code positions are computed with the real-valued (non-rounded) affine
    map so the penalty is differentiable; minimizing it concentrates the
    retrained weights in the band — the paper's hardware-driven
    co-optimization that legitimizes removing the M2 partial product.
    """
    lo, hi = band
    soft_code = w / qp.scale + qp.zero_point.astype(w.dtype)
    under = jnp.maximum(float(lo) - soft_code, 0.0)
    over = jnp.maximum(soft_code - float(hi), 0.0)
    return jnp.mean(under**2 + over**2)
