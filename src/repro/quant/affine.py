"""Unsigned 8-bit affine quantization (paper Section IV platform substrate).

The paper's multipliers are *unsigned* 8x8; real-valued tensors map onto
uint8 codes via the standard affine scheme (Jacob et al., CVPR'18 — the
paper's ref [15]):

    x ~ s * (q - z),   q = clip(round(x / s) + z, 0, qmax)

``qmax`` is configurable (< 255) to express the paper's co-optimization:
retraining weights into the (0, 31) code band means quantizing with
``qmax = 31`` so every weight code has its top three bits clear and the
MUL8x8_3 removed-product path is error-free.
"""
from __future__ import annotations

import dataclasses
from typing import Optional, Tuple

import jax
import jax.numpy as jnp

__all__ = ["QuantParams", "calibrate", "quantize", "dequantize"]


@jax.tree_util.register_dataclass
@dataclasses.dataclass(frozen=True)
class QuantParams:
    """Affine quantization parameters. ``scale``/``zero_point`` broadcast
    against the tensor (per-tensor: scalars; per-channel: shaped)."""

    scale: jax.Array
    zero_point: jax.Array            # int32, same shape as scale
    qmax: int = dataclasses.field(default=255, metadata=dict(static=True))


def calibrate(
    x: jax.Array,
    *,
    axis: Optional[Tuple[int, ...]] = None,
    qmax: int = 255,
    eps: float = 1e-8,
) -> QuantParams:
    """Min/max affine calibration. ``axis=None`` -> per-tensor; otherwise the
    reduction axes (remaining axes are per-channel)."""
    lo = jnp.minimum(jnp.min(x, axis=axis, keepdims=axis is not None), 0.0)
    hi = jnp.maximum(jnp.max(x, axis=axis, keepdims=axis is not None), 0.0)
    scale = jnp.maximum((hi - lo) / float(qmax), eps).astype(jnp.float32)
    zp = jnp.clip(jnp.round(-lo / scale), 0, qmax).astype(jnp.int32)
    return QuantParams(scale=scale, zero_point=zp, qmax=qmax)


def quantize(x: jax.Array, qp: QuantParams) -> jax.Array:
    """Real -> uint8 codes in [0, qmax] (1-byte storage: HBM-roofline relevant)."""
    q = jnp.round(x / qp.scale) + qp.zero_point
    return jnp.clip(q, 0, qp.qmax).astype(jnp.uint8)


def dequantize(q: jax.Array, qp: QuantParams) -> jax.Array:
    return (q.astype(jnp.float32) - qp.zero_point.astype(jnp.float32)) * qp.scale
