"""Optimizers (AdamW, SGD+momentum), LR schedules, global-norm clipping.

Implemented directly on pytrees (no optax dependency). Optimizer state
mirrors the parameter tree structurally, so ZeRO-style sharded optimizer
state falls out of the parameter shardings for free.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

__all__ = [
    "OptConfig",
    "init_opt_state",
    "opt_state_shardings",
    "apply_updates",
    "global_norm",
    "clip_by_global_norm",
    "cosine_schedule",
]


@dataclasses.dataclass(frozen=True)
class OptConfig:
    kind: str = "adamw"             # adamw | sgd
    lr: float = 3e-4
    beta1: float = 0.9
    beta2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.01
    momentum: float = 0.9           # sgd
    clip_norm: float = 1.0          # 0 disables
    warmup_steps: int = 100
    total_steps: int = 10000


def cosine_schedule(cfg: OptConfig, step: jax.Array) -> jax.Array:
    warm = jnp.minimum(step / jnp.maximum(cfg.warmup_steps, 1), 1.0)
    t = jnp.clip(
        (step - cfg.warmup_steps) / max(cfg.total_steps - cfg.warmup_steps, 1), 0, 1
    )
    return cfg.lr * warm * 0.5 * (1 + jnp.cos(jnp.pi * t))


def init_opt_state(cfg: OptConfig, params: Any) -> Dict[str, Any]:
    zeros = lambda: jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)
    state: Dict[str, Any] = {"step": jnp.zeros((), jnp.int32)}
    if cfg.kind == "adamw":
        state["m"] = zeros()
        state["v"] = zeros()
    elif cfg.kind == "sgd":
        state["m"] = zeros()
    else:
        raise ValueError(cfg.kind)
    return state


def opt_state_shardings(cfg: OptConfig, param_sh: Any, mesh) -> Dict[str, Any]:
    from jax.sharding import NamedSharding, PartitionSpec

    rep = NamedSharding(mesh, PartitionSpec())
    out: Dict[str, Any] = {"step": rep}
    if cfg.kind == "adamw":
        out["m"] = param_sh
        out["v"] = param_sh
    else:
        out["m"] = param_sh
    return out


def global_norm(tree: Any) -> jax.Array:
    return jnp.sqrt(
        sum(jnp.sum(jnp.square(x.astype(jnp.float32))) for x in jax.tree.leaves(tree))
    )


def clip_by_global_norm(tree: Any, max_norm: float) -> Tuple[Any, jax.Array]:
    gn = global_norm(tree)
    scale = jnp.minimum(1.0, max_norm / (gn + 1e-9))
    return jax.tree.map(lambda x: x * scale, tree), gn


def apply_updates(
    cfg: OptConfig, params: Any, grads: Any, state: Dict[str, Any]
) -> Tuple[Any, Dict[str, Any], Dict[str, jax.Array]]:
    """One optimizer step. Returns (new_params, new_state, metrics)."""
    if cfg.clip_norm > 0:
        grads, gn = clip_by_global_norm(grads, cfg.clip_norm)
    else:
        gn = global_norm(grads)
    step = state["step"] + 1
    lr = cosine_schedule(cfg, step)

    if cfg.kind == "adamw":
        b1, b2 = cfg.beta1, cfg.beta2
        m = jax.tree.map(lambda m_, g: b1 * m_ + (1 - b1) * g.astype(jnp.float32), state["m"], grads)
        v = jax.tree.map(lambda v_, g: b2 * v_ + (1 - b2) * jnp.square(g.astype(jnp.float32)), state["v"], grads)
        c1 = 1 - b1 ** step.astype(jnp.float32)
        c2 = 1 - b2 ** step.astype(jnp.float32)

        def upd(p, m_, v_):
            u = (m_ / c1) / (jnp.sqrt(v_ / c2) + cfg.eps)
            u = u + cfg.weight_decay * p.astype(jnp.float32)
            return (p.astype(jnp.float32) - lr * u).astype(p.dtype)

        new_params = jax.tree.map(upd, params, m, v)
        new_state = {"step": step, "m": m, "v": v}
    else:  # sgd + momentum
        m = jax.tree.map(
            lambda m_, g: cfg.momentum * m_ + g.astype(jnp.float32), state["m"], grads
        )
        new_params = jax.tree.map(
            lambda p, m_: (p.astype(jnp.float32) - lr * m_).astype(p.dtype), params, m
        )
        new_state = {"step": step, "m": m}

    return new_params, new_state, {"grad_norm": gn, "lr": lr}
