"""Training step and loop: QAT with approximate multipliers as the forward
semantics, microbatched gradient accumulation, band regularization (the
paper's retraining co-optimization), optional int8 gradient compression.
"""
from __future__ import annotations

import dataclasses
import functools
import time
from typing import Any, Callable, Dict, Iterable, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models.transformer import forward, init_params
from repro.quant.affine import calibrate
from repro.quant.qat import band_regularizer
from repro.train import optim as O
from repro.train.compression import compress_decompress

__all__ = ["TrainState", "cross_entropy", "make_loss_fn", "make_train_step", "train_loop"]


TrainState = Dict[str, Any]   # {"params": ..., "opt": ..., "step": int array}


def cross_entropy(logits: jax.Array, labels: jax.Array) -> jax.Array:
    """Mean token CE; logits (B,S,V) f32, labels (B,S) int32.

    The gold logit is extracted with an iota-compare masked sum instead of
    take_along_axis: a gather over the model-sharded vocab axis would force
    GSPMD to all-gather the full logits."""
    logz = jax.nn.logsumexp(logits, axis=-1)
    V = logits.shape[-1]
    onehot = jnp.arange(V) == labels[..., None]
    gold = jnp.sum(jnp.where(onehot, logits, 0.0), axis=-1)
    return jnp.mean(logz - gold)


def _band_reg_term(cfg: ModelConfig, params) -> jax.Array:
    """The paper's weight-band regularizer applied to every 2-D+ weight."""
    a = cfg.approx
    if a.band_reg <= 0:
        return jnp.float32(0)
    total, n = jnp.float32(0), 0
    for path, leaf in jax.tree_util.tree_flatten_with_path(params)[0]:
        if leaf.ndim >= 2 and leaf.shape[-1] > 1:
            qp = calibrate(leaf, axis=(leaf.ndim - 2,), qmax=a.w_qmax)
            total = total + band_regularizer(leaf, qp, band=(0, 31))
            n += 1
    return a.band_reg * total / max(n, 1)


def make_loss_fn(cfg: ModelConfig, aux_weight: float = 0.01) -> Callable:
    def loss_fn(params, batch):
        logits, aux = forward(cfg, params, batch)
        ce = cross_entropy(logits, batch["labels"])
        reg = _band_reg_term(cfg, params)
        loss = ce + aux_weight * aux + reg
        return loss, {"ce": ce, "aux": aux, "band_reg": reg}

    return loss_fn


def make_train_step(
    cfg: ModelConfig,
    opt_cfg: O.OptConfig,
    *,
    microbatch: int = 0,
    grad_compression: bool = False,
) -> Callable:
    """Returns train_step(state, batch) -> (state, metrics).

    ``microbatch``: if > 0, split the batch into that many accumulation steps
    (sequential lax.scan — overlap-friendly: each microbatch's backward
    all-reduces overlap the next microbatch's compute under XLA's latency-
    hiding scheduler).
    """
    loss_fn = make_loss_fn(cfg)
    grad_fn = jax.value_and_grad(loss_fn, has_aux=True)

    def compute_grads(params, batch):
        if microbatch <= 1:
            (loss, m), grads = grad_fn(params, batch)
            return loss, m, grads

        def split(x):
            return x.reshape(microbatch, x.shape[0] // microbatch, *x.shape[1:])

        mb = jax.tree.map(split, batch)

        def body(carry, mbatch):
            acc, loss_acc = carry
            (loss, m), grads = grad_fn(params, mbatch)
            acc = jax.tree.map(jnp.add, acc, grads)
            return (acc, loss_acc + loss), m

        zero = jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)
        (gsum, loss_sum), ms = jax.lax.scan(body, (zero, jnp.float32(0)), mb)
        grads = jax.tree.map(lambda g: g / microbatch, gsum)
        m = jax.tree.map(lambda x: x[-1], ms)
        return loss_sum / microbatch, m, grads

    def train_step(state: TrainState, batch) -> Tuple[TrainState, Dict[str, jax.Array]]:
        loss, m, grads = compute_grads(state["params"], batch)
        if grad_compression:
            grads, state_err = compress_decompress(grads, state.get("grad_err"))
        else:
            state_err = state.get("grad_err")
        params, opt, om = O.apply_updates(opt_cfg, state["params"], grads, state["opt"])
        new_state = {"params": params, "opt": opt}
        if state_err is not None:
            new_state["grad_err"] = state_err
        return new_state, {"loss": loss, **m, **om}

    return train_step


def init_state(
    cfg: ModelConfig, opt_cfg: O.OptConfig, key, *, grad_compression: bool = False
) -> TrainState:
    params = init_params(cfg, key)
    state: TrainState = {"params": params, "opt": O.init_opt_state(opt_cfg, params)}
    if grad_compression:
        state["grad_err"] = jax.tree.map(
            lambda p: jnp.zeros(p.shape, jnp.float32), params
        )
    return state


def train_loop(
    cfg: ModelConfig,
    opt_cfg: O.OptConfig,
    batches: Iterable,
    *,
    steps: int,
    key=None,
    state: Optional[TrainState] = None,
    hooks: Tuple[Callable, ...] = (),
    jit: bool = True,
) -> Tuple[TrainState, Dict[str, list]]:
    """Single-host convenience loop used by examples/tests; the cluster path
    is launch/train.py (pjit + checkpoint/restart + fault monitor)."""
    key = key if key is not None else jax.random.PRNGKey(0)
    if state is None:
        state = init_state(cfg, opt_cfg, key)
    step_fn = make_train_step(cfg, opt_cfg)
    if jit:
        step_fn = jax.jit(step_fn)
    history: Dict[str, list] = {"loss": [], "step_time": []}
    it = iter(batches)
    for i in range(steps):
        batch = next(it)
        t0 = time.perf_counter()
        state, metrics = step_fn(state, batch)
        metrics["loss"].block_until_ready()
        dt = time.perf_counter() - t0
        history["loss"].append(float(metrics["loss"]))
        history["step_time"].append(dt)
        for h in hooks:
            h(i, state, metrics, dt)
    return state, history
