"""Fault-tolerance runtime pieces for 1000+-node operation:

* ``StragglerMonitor`` — EWMA step-time watchdog. On real pods the step time
  is a collective barrier, so one slow host inflates everyone's step; the
  monitor flags sustained outliers (policy hook decides: re-slice, evict,
  or alert). Here the policy hook is injectable for tests.
* ``PreemptionGuard`` — SIGTERM/SIGINT handler that requests a final
  checkpoint flush + clean exit at the next step boundary (the GKE/Borg
  maintenance-event pattern).
* ``run_with_restarts`` — supervisor that restarts a training function from
  the latest checkpoint after a (simulated or real) failure, up to a retry
  budget: checkpoint/restart fault tolerance in one callable.
"""
from __future__ import annotations

import dataclasses
import signal
import time
from typing import Any, Callable, List, Optional

__all__ = ["StragglerMonitor", "PreemptionGuard", "run_with_restarts"]


@dataclasses.dataclass
class StragglerMonitor:
    """Flags steps slower than ``threshold`` x the EWMA step time."""

    alpha: float = 0.1
    threshold: float = 2.0
    warmup: int = 5
    on_straggler: Optional[Callable[[int, float, float], None]] = None

    _ewma: float = 0.0
    _n: int = 0
    events: List[int] = dataclasses.field(default_factory=list)

    def record(self, step: int, dt: float) -> bool:
        self._n += 1
        if self._n <= self.warmup:
            self._ewma = dt if self._ewma == 0 else (1 - self.alpha) * self._ewma + self.alpha * dt
            return False
        is_straggler = dt > self.threshold * self._ewma
        if is_straggler:
            self.events.append(step)
            if self.on_straggler:
                self.on_straggler(step, dt, self._ewma)
        else:
            # only fold non-outlier samples into the EWMA
            self._ewma = (1 - self.alpha) * self._ewma + self.alpha * dt
        return is_straggler

    @property
    def ewma(self) -> float:
        return self._ewma


class PreemptionGuard:
    """Install as a context manager; ``should_stop`` flips on SIGTERM/SIGINT
    so the training loop can flush a checkpoint and exit cleanly."""

    def __init__(self, signals=(signal.SIGTERM,)):
        self._signals = signals
        self._old = {}
        self.should_stop = False

    def _handler(self, signum, frame):
        self.should_stop = True

    def __enter__(self):
        for s in self._signals:
            self._old[s] = signal.signal(s, self._handler)
        return self

    def __exit__(self, *exc):
        for s, h in self._old.items():
            signal.signal(s, h)
        return False


def run_with_restarts(
    fn: Callable[[int], Any],
    *,
    max_restarts: int = 3,
    backoff_s: float = 0.0,
    on_restart: Optional[Callable[[int, BaseException], None]] = None,
) -> Any:
    """Run ``fn(attempt)`` restarting on exceptions (node failure model).
    ``fn`` is expected to resume from the latest checkpoint internally."""
    attempt = 0
    while True:
        try:
            return fn(attempt)
        except KeyboardInterrupt:
            raise
        except BaseException as e:  # noqa: BLE001 - supervisor catches all
            attempt += 1
            if attempt > max_restarts:
                raise
            if on_restart:
                on_restart(attempt, e)
            if backoff_s:
                time.sleep(backoff_s)
