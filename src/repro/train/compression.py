"""int8 gradient compression with error feedback.

Models the compressed data-parallel all-reduce used at 1000+-node scale:
gradients are quantized to int8 (per-leaf symmetric scale) before the
all-reduce and dequantized after; the quantization residual is carried in an
error-feedback buffer so the bias vanishes over steps (Seide et al. 2014,
1-bit SGD lineage). Under pjit the quantize->psum->dequantize pattern is
expressed here as quantize->dequantize around the (XLA-inserted) all-reduce;
bytes on the wire shrink 4x (f32->int8), which is what the collective
roofline term sees.
"""
from __future__ import annotations

from typing import Any, Optional, Tuple

import jax
import jax.numpy as jnp

__all__ = ["compress_decompress"]


def _q8(x: jax.Array) -> Tuple[jax.Array, jax.Array]:
    scale = jnp.maximum(jnp.max(jnp.abs(x)), 1e-12) / 127.0
    q = jnp.clip(jnp.round(x / scale), -127, 127).astype(jnp.int8)
    return q, scale


def compress_decompress(
    grads: Any, err: Optional[Any]
) -> Tuple[Any, Any]:
    """Returns (decompressed grads, new error buffers)."""
    if err is None:
        err = jax.tree.map(lambda g: jnp.zeros(g.shape, jnp.float32), grads)

    def deq_of(g, e):
        gf = g.astype(jnp.float32) + e
        q, s = _q8(gf)
        return (q.astype(jnp.float32) * s).astype(g.dtype)

    def err_of(g, e):
        gf = g.astype(jnp.float32) + e
        q, s = _q8(gf)
        return gf - q.astype(jnp.float32) * s

    # two passes (XLA CSEs the shared subexpressions under jit)
    new_g = jax.tree.map(deq_of, grads, err)
    new_e = jax.tree.map(err_of, grads, err)
    return new_g, new_e
