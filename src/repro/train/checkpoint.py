"""Fault-tolerant checkpointing: atomic sharded npz snapshots, keep-k
retention, auto-resume, and ELASTIC restore (a checkpoint written under one
mesh/device-count restores onto any other — leaves are stored logically and
re-sharded on load).

Layout:
  <dir>/step_000123.tmp-<nonce>/   (staging)
  <dir>/step_000123/
      manifest.json                {step, leaf paths, shapes, dtypes}
      arrays.npz                   one entry per leaf (flattened path key)
  <dir>/LATEST                     text file: "step_000123"

On a multi-host cluster each process writes its local shards (process-local
npz named by process index) and process 0 writes the manifest; this container
is single-process so there is one shard file. The atomic rename + LATEST
protocol is the same either way.
"""
from __future__ import annotations

import json
import os
import re
import shutil
import tempfile
import time
from typing import Any, Dict, List, Optional, Tuple

import jax
import numpy as np

__all__ = ["save_checkpoint", "restore_checkpoint", "latest_step", "list_steps"]

_SAFE = re.compile(r"[^A-Za-z0-9_.]+")


def _flatten(tree: Any) -> List[Tuple[str, Any]]:
    out = []
    for path, leaf in jax.tree_util.tree_flatten_with_path(tree)[0]:
        key = _SAFE.sub("/", jax.tree_util.keystr(path)).strip("/")
        out.append((key, leaf))
    return out


def save_checkpoint(directory: str, step: int, tree: Any, *, keep: int = 3) -> str:
    os.makedirs(directory, exist_ok=True)
    name = f"step_{step:09d}"
    stage = tempfile.mkdtemp(prefix=name + ".tmp-", dir=directory)
    try:
        leaves = _flatten(tree)
        arrays = {k: np.asarray(jax.device_get(v)) for k, v in leaves}
        np.savez(os.path.join(stage, "arrays.npz"), **arrays)
        manifest = {
            "step": step,
            "time": time.time(),
            "leaves": {k: {"shape": list(a.shape), "dtype": str(a.dtype)} for k, a in arrays.items()},
        }
        with open(os.path.join(stage, "manifest.json"), "w") as f:
            json.dump(manifest, f)
        final = os.path.join(directory, name)
        if os.path.exists(final):
            shutil.rmtree(final)
        os.rename(stage, final)                      # atomic publish
    except BaseException:
        shutil.rmtree(stage, ignore_errors=True)
        raise
    with open(os.path.join(directory, "LATEST.tmp"), "w") as f:
        f.write(name)
    os.replace(os.path.join(directory, "LATEST.tmp"), os.path.join(directory, "LATEST"))
    _gc(directory, keep)
    return os.path.join(directory, name)


def _gc(directory: str, keep: int) -> None:
    steps = list_steps(directory)
    for s in steps[:-keep]:
        shutil.rmtree(os.path.join(directory, f"step_{s:09d}"), ignore_errors=True)


def list_steps(directory: str) -> List[int]:
    if not os.path.isdir(directory):
        return []
    out = []
    for n in os.listdir(directory):
        m = re.fullmatch(r"step_(\d+)", n)
        if m:
            out.append(int(m.group(1)))
    return sorted(out)


def latest_step(directory: str) -> Optional[int]:
    """Prefer the LATEST pointer; fall back to directory scan (crash-safe)."""
    p = os.path.join(directory, "LATEST")
    if os.path.exists(p):
        with open(p) as f:
            m = re.fullmatch(r"step_(\d+)", f.read().strip())
        if m and os.path.isdir(os.path.join(directory, f"step_{int(m.group(1)):09d}")):
            return int(m.group(1))
    steps = list_steps(directory)
    return steps[-1] if steps else None


def restore_checkpoint(
    directory: str,
    target_tree: Any,
    *,
    step: Optional[int] = None,
    shardings: Optional[Any] = None,
) -> Tuple[Any, int]:
    """Restore into the structure of ``target_tree`` (arrays or
    ShapeDtypeStructs). ``shardings``: optional pytree of NamedShardings —
    the elastic path: device_put each leaf under the *new* mesh regardless of
    the mesh it was saved under."""
    if step is None:
        step = latest_step(directory)
        if step is None:
            raise FileNotFoundError(f"no checkpoints under {directory}")
    path = os.path.join(directory, f"step_{step:09d}")
    data = np.load(os.path.join(path, "arrays.npz"))
    keys = [k for k, _ in _flatten(target_tree)]
    leaves = []
    for k in keys:
        if k not in data:
            raise KeyError(f"checkpoint missing leaf {k!r}")
        leaves.append(data[k])
    treedef = jax.tree_util.tree_structure(target_tree)
    tree = jax.tree_util.tree_unflatten(treedef, leaves)
    if shardings is not None:
        tree = jax.tree.map(lambda a, s: jax.device_put(a, s), tree, shardings)
    else:
        tree = jax.tree.map(jax.numpy.asarray, tree)
    return tree, step
