from repro.configs.base import (
    ARCH_REGISTRY,
    ModelConfig,
    ShapeConfig,
    SHAPES,
    get_config,
    list_archs,
    reduced_config,
)

__all__ = [
    "ModelConfig",
    "ShapeConfig",
    "SHAPES",
    "ARCH_REGISTRY",
    "get_config",
    "list_archs",
    "reduced_config",
]
