"""qwen2-moe-a2.7b [moe]: 24L d_model=2048 16H (kv=16) expert d_ff=1408
vocab=151936, 60 routed experts top-4 + shared expert (4x1408=5632).
[hf:Qwen/Qwen1.5-MoE-A2.7B]."""
from repro.configs.base import ModelConfig, register

register(
    ModelConfig(
        name="qwen2-moe-a2.7b",
        family="moe",
        num_layers=24,
        d_model=2048,
        num_heads=16,
        num_kv_heads=16,
        d_ff=1408,
        vocab_size=151936,
        moe_experts=60,
        moe_top_k=4,
        moe_shared_ff=5632,
        source="hf:Qwen/Qwen1.5-MoE-A2.7B",
    )
)
