"""Model/shape configuration system.

Every assigned architecture registers a ``ModelConfig`` (exact public spec)
via ``src/repro/configs/<arch>.py``; shapes (train_4k / prefill_32k /
decode_32k / long_500k) are global ``ShapeConfig``s. ``reduced_config``
derives the CPU-smoke-test variant of any arch (same family/topology, tiny
dims).
"""
from __future__ import annotations

import dataclasses
import importlib
from typing import Dict, Optional, Tuple

from repro.core.approx import ApproxConfig

__all__ = [
    "ModelConfig",
    "ShapeConfig",
    "SHAPES",
    "ARCH_REGISTRY",
    "register",
    "get_config",
    "list_archs",
    "reduced_config",
]


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str                       # dense | moe | ssm | hybrid | vlm | audio
    num_layers: int
    d_model: int
    num_heads: int
    num_kv_heads: int
    d_ff: int
    vocab_size: int
    head_dim: int = 0                 # 0 -> d_model // num_heads
    # --- MoE ---
    moe_experts: int = 0
    moe_top_k: int = 0
    moe_shared_ff: int = 0
    capacity_factor: float = 1.25
    # --- SSM ---
    ssm_state: int = 0
    d_inner: int = 0                  # 0 -> 2*d_model for ssm/hybrid
    dt_rank: int = 0                  # 0 -> ceil(d_model/16)
    conv_width: int = 4
    # --- hybrid (zamba2-style) ---
    attn_every: int = 0               # shared attn block after every k ssm layers
    # --- positions / input ---
    pos_embedding: str = "rope"       # rope | m_rope | sinusoidal
    rope_theta: float = 10000.0
    m_rope_sections: Tuple[int, ...] = ()
    embed_input: bool = True          # False: input_specs provides embeddings (vlm/audio stubs)
    # --- the paper's feature ---
    approx: ApproxConfig = ApproxConfig(mode="float")
    # --- numerics / structure ---
    dtype: str = "bfloat16"
    q_chunk: int = 512
    ssm_chunk: int = 256
    scan_layers: bool = True
    unroll_experts: bool = False      # cost-extraction lowering (dryrun)
    remat: bool = True
    # --- perf levers (EXPERIMENTS.md §Perf) ---
    fuse_qkv: bool = False            # one quant+feature pass for q/k/v
    fuse_gate_up: bool = False        # one quant+feature pass for gate/up
    param_dtype: str = "float32"      # bf16 halves FSDP gather wire + memory
    source: str = ""                  # citation tag

    def __post_init__(self):
        if self.head_dim == 0 and self.num_heads:
            object.__setattr__(self, "head_dim", self.d_model // self.num_heads)
        if self.family in ("ssm", "hybrid"):
            if self.d_inner == 0:
                object.__setattr__(self, "d_inner", 2 * self.d_model)
            if self.dt_rank == 0:
                object.__setattr__(self, "dt_rank", -(-self.d_model // 16))

    @property
    def padded_vocab(self) -> int:
        """LM-head columns padded to a 512 multiple so the (B,S,V) logits —
        the largest activation — shard evenly over the model axis. Padded
        columns are masked to -inf; embeddings stay at the true vocab."""
        return -(-self.vocab_size // 512) * 512

    @property
    def ssm_heads(self) -> int:
        """Mamba-2 head count (d_inner / 64-dim heads, zamba2 convention)."""
        return max(1, self.d_inner // 64)

    @property
    def supports_long_context(self) -> bool:
        return self.family in ("ssm", "hybrid")

    def param_count(self) -> int:
        """Approximate parameter count (used for MODEL_FLOPS = 6*N*D)."""
        d, L = self.d_model, self.num_layers
        n = 0
        if self.embed_input:
            n += self.vocab_size * d
        n += self.vocab_size * d                       # lm head
        if self.family in ("dense", "moe", "vlm", "audio"):
            attn = d * self.num_heads * self.head_dim * 2 + d * self.num_kv_heads * self.head_dim * 2
            if self.family == "moe":
                ffn = self.moe_experts * 3 * d * self.d_ff + d * self.moe_experts
                ffn += 3 * d * self.moe_shared_ff
            else:
                ffn = 3 * d * self.d_ff
            n += L * (attn + ffn)
        elif self.family == "ssm":
            di, N, dtr = self.d_inner, self.ssm_state, self.dt_rank
            n += L * (d * 2 * di + di * (dtr + 2 * N) + dtr * di + di * N + di * d)
        elif self.family == "hybrid":
            di, N, nh = self.d_inner, self.ssm_state, self.ssm_heads
            per = d * (2 * di + 2 * N + nh) + di * d
            n += L * per
            attn = d * self.num_heads * self.head_dim * 2 + d * self.num_kv_heads * self.head_dim * 2
            n += attn + 3 * d * self.d_ff               # shared block (once)
        return n

    def active_param_count(self) -> int:
        """Active params per token (MoE: top_k + shared experts only)."""
        if self.family != "moe":
            return self.param_count()
        d, L = self.d_model, self.num_layers
        n = 2 * self.vocab_size * d
        attn = d * self.num_heads * self.head_dim * 2 + d * self.num_kv_heads * self.head_dim * 2
        ffn = self.moe_top_k * 3 * d * self.d_ff + 3 * d * self.moe_shared_ff + d * self.moe_experts
        return n + L * (attn + ffn)


@dataclasses.dataclass(frozen=True)
class ShapeConfig:
    name: str
    seq_len: int
    global_batch: int
    kind: str                        # train | prefill | decode


SHAPES: Dict[str, ShapeConfig] = {
    "train_4k": ShapeConfig("train_4k", 4096, 256, "train"),
    "prefill_32k": ShapeConfig("prefill_32k", 32768, 32, "prefill"),
    "decode_32k": ShapeConfig("decode_32k", 32768, 128, "decode"),
    "long_500k": ShapeConfig("long_500k", 524288, 1, "decode"),
}

ARCH_REGISTRY: Dict[str, ModelConfig] = {}

_ARCH_MODULES = (
    "musicgen_large",
    "yi_34b",
    "granite_3_2b",
    "deepseek_7b",
    "deepseek_coder_33b",
    "falcon_mamba_7b",
    "qwen2_moe_a2_7b",
    "grok_1_314b",
    "qwen2_vl_2b",
    "zamba2_2_7b",
    "paper_cnns",
)


def register(cfg: ModelConfig) -> ModelConfig:
    ARCH_REGISTRY[cfg.name] = cfg
    return cfg


def _load_all():
    for m in _ARCH_MODULES:
        importlib.import_module(f"repro.configs.{m}")


def get_config(name: str) -> ModelConfig:
    if not ARCH_REGISTRY:
        _load_all()
    key = name.replace("-", "_")
    for k, v in ARCH_REGISTRY.items():
        if k.replace("-", "_") == key:
            return v
    raise KeyError(f"unknown arch {name!r}; have {sorted(ARCH_REGISTRY)}")


def list_archs():
    if not ARCH_REGISTRY:
        _load_all()
    return sorted(k for k in ARCH_REGISTRY if not k.startswith("cnn/"))


def reduced_config(cfg: ModelConfig, **over) -> ModelConfig:
    """Tiny same-family variant for CPU smoke tests."""
    kw = dict(
        num_layers=min(cfg.num_layers, 2 if cfg.family != "hybrid" else 4),
        d_model=128,
        num_heads=4,
        num_kv_heads=min(cfg.num_kv_heads, 2) if cfg.num_kv_heads else 0,
        head_dim=32,
        d_ff=256,
        vocab_size=min(cfg.vocab_size, 512),
        moe_experts=min(cfg.moe_experts, 4),
        moe_top_k=min(cfg.moe_top_k, 2),
        moe_shared_ff=128 if cfg.moe_shared_ff else 0,
        ssm_state=min(cfg.ssm_state, 16) if cfg.ssm_state else 0,
        d_inner=256 if cfg.family in ("ssm", "hybrid") else 0,
        dt_rank=8 if cfg.family == "ssm" else 0,
        attn_every=2 if cfg.attn_every else 0,
        m_rope_sections=(4, 6, 6) if cfg.m_rope_sections else (),
        dtype="float32",
        q_chunk=64,
        ssm_chunk=32,
        scan_layers=cfg.scan_layers,
        remat=False,
    )
    kw.update(over)
    return dataclasses.replace(cfg, name=cfg.name + "-smoke", **kw)
