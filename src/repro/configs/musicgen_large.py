"""musicgen-large [audio]: 48L d_model=2048 32H (kv=32) d_ff=8192 vocab=2048.
Decoder-only over EnCodec tokens [arXiv:2306.05284; hf]. Backbone only —
the EnCodec frontend is a stub: input_specs() provides frame embeddings."""
from repro.configs.base import ModelConfig, register

register(
    ModelConfig(
        name="musicgen-large",
        family="audio",
        num_layers=48,
        d_model=2048,
        num_heads=32,
        num_kv_heads=32,
        d_ff=8192,
        vocab_size=2048,
        pos_embedding="sinusoidal",
        embed_input=False,
        source="arXiv:2306.05284; hf",
    )
)
