"""qwen2-vl-2b [vlm]: 28L d_model=1536 12H (GQA kv=2) d_ff=8960 vocab=151936.
M-RoPE, dynamic resolution [arXiv:2409.12191; hf]. Backbone only — the vision
frontend is a stub: input_specs() provides patch embeddings."""
from repro.configs.base import ModelConfig, register

register(
    ModelConfig(
        name="qwen2-vl-2b",
        family="vlm",
        num_layers=28,
        d_model=1536,
        num_heads=12,
        num_kv_heads=2,
        d_ff=8960,
        vocab_size=151936,
        pos_embedding="m_rope",
        m_rope_sections=(16, 24, 24),   # head_dim=128 -> hd/2=64 split t/h/w
        rope_theta=1000000.0,
        embed_input=False,
        source="arXiv:2409.12191; hf",
    )
)
