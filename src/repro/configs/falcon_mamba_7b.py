"""falcon-mamba-7b [ssm]: 64L d_model=4096 (attn-free) vocab=65024,
ssm_state=16. Mamba-1 arch [arXiv:2410.05355; unverified]."""
from repro.configs.base import ModelConfig, register

register(
    ModelConfig(
        name="falcon-mamba-7b",
        family="ssm",
        num_layers=64,
        d_model=4096,
        num_heads=0,
        num_kv_heads=0,
        d_ff=0,
        vocab_size=65024,
        ssm_state=16,
        conv_width=4,
        source="arXiv:2410.05355",
    )
)
