"""The paper's own evaluation networks (Table VIII): LeNet / LeNet+ /
AlexNet / VGG16 / ResNet-19 over MNIST- and CIFAR10-shaped inputs.

These are not LM ``ModelConfig``s; they are consumed by benchmarks/table_viii
and examples/lenet_mnist_qat.py via repro.models.cnn.
"""
from __future__ import annotations

import dataclasses
from typing import Tuple

__all__ = ["CNNSpec", "CNN_SPECS"]


@dataclasses.dataclass(frozen=True)
class CNNSpec:
    name: str
    dataset: str                 # mnist | cifar10
    in_shape: Tuple[int, int, int]
    num_classes: int = 10


CNN_SPECS = {
    "lenet-mnist": CNNSpec("lenet", "mnist", (28, 28, 1)),
    "lenet_plus-mnist": CNNSpec("lenet_plus", "mnist", (28, 28, 1)),
    "lenet-cifar10": CNNSpec("lenet", "cifar10", (32, 32, 3)),
    "lenet_plus-cifar10": CNNSpec("lenet_plus", "cifar10", (32, 32, 3)),
    "alexnet-cifar10": CNNSpec("alexnet", "cifar10", (32, 32, 3)),
    "vgg16-cifar10": CNNSpec("vgg16", "cifar10", (32, 32, 3)),
    "resnet19-cifar10": CNNSpec("resnet19", "cifar10", (32, 32, 3)),
}
