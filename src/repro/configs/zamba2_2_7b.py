"""zamba2-2.7b [hybrid]: 54L d_model=2560 32H (kv=32) d_ff=10240,
vocab=32000, ssm_state=64. Mamba-2 blocks + weight-shared attention block
applied every 6 layers (Zamba2 concatenates original embeddings into the
shared block; we apply it on the residual stream — noted simplification).
[arXiv:2411.15242; hf]."""
from repro.configs.base import ModelConfig, register

register(
    ModelConfig(
        name="zamba2-2.7b",
        family="hybrid",
        num_layers=54,
        d_model=2560,
        num_heads=32,
        num_kv_heads=32,
        d_ff=10240,
        vocab_size=32000,
        ssm_state=64,
        attn_every=6,
        source="arXiv:2411.15242; hf",
    )
)
