"""Serving launcher: batched greedy decoding on the local mesh.

    PYTHONPATH=src python -m repro.launch.serve --arch granite-3-2b --reduced \
        --batch 4 --new 8
"""
from __future__ import annotations

import argparse
import dataclasses
import time

import jax
import jax.numpy as jnp

from repro.configs import get_config, reduced_config
from repro.core.approx import ApproxConfig
from repro.models.transformer import init_params
from repro.serve.engine import greedy_generate


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="granite-3-2b")
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=8)
    ap.add_argument("--new", type=int, default=8)
    ap.add_argument("--multiplier", default="mul8x8_2")
    ap.add_argument("--mode", default="lowrank")
    args = ap.parse_args(argv)

    cfg = get_config(args.arch)
    if args.reduced:
        cfg = dataclasses.replace(reduced_config(cfg), remat=False, q_chunk=64)
    cfg = dataclasses.replace(cfg, approx=ApproxConfig(multiplier=args.multiplier, mode=args.mode))
    if not cfg.embed_input:
        raise SystemExit(f"{args.arch} takes embedding inputs (frontend stub); "
                         "use an embed-input arch for token serving")
    params = init_params(cfg, jax.random.PRNGKey(0))
    prompt = jax.random.randint(jax.random.PRNGKey(1), (args.batch, args.prompt_len), 0, cfg.vocab_size)
    t0 = time.perf_counter()
    out = greedy_generate(cfg, params, prompt, max_new=args.new)
    jax.block_until_ready(out)
    dt = time.perf_counter() - t0
    print(f"generated {args.batch}x{args.new} tokens in {dt:.2f}s "
          f"({args.batch*args.new/dt:.1f} tok/s)")
    print("sample:", out[0].tolist())


if __name__ == "__main__":
    main()
