"""Serving launcher: batched decoding through the scan engine on the local
mesh.

    PYTHONPATH=src python -m repro.launch.serve --arch granite-3-2b --reduced \
        --batch 4 --new 8 --exec approx_lowrank
    PYTHONPATH=src python -m repro.launch.serve --arch granite-3-2b --reduced \
        --engine continuous --requests 16 --num-slots 4
    PYTHONPATH=src python -m repro.launch.serve --arch granite-3-2b --reduced \
        --engine continuous --cache-layout paged --block-size 8 \
        --num-slots 8 --num-blocks 64 --policy sjf

``--exec`` selects the execution mode (exact / exact_quant / approx /
approx_lowrank — see ``repro.serve.engine.resolve_execution_mode``);
``--engine legacy`` runs the per-token Python loop baseline for comparison;
``--engine continuous`` serves a mixed-length synthetic trace through the
continuous-batching scheduler (``repro.serve.scheduler``) — slot-striped KV
by default, or the paged block-table cache with ``--cache-layout paged``
(``--num-blocks`` caps KV memory independently of ``--num-slots``;
``--policy`` picks the admission order; ``--attn-impl pallas`` swaps the
per-layer block gather for the in-place Pallas paged-attention kernel).  ``--loop`` selects the host loop
(async double-buffered pipeline by default; ``sync`` is the PR-3 baseline),
and ``--prefill-decode-ratio`` / ``--prefill-token-budget`` rate-limit
admitted prefill tokens against resident decode work so long-prompt bursts
cannot starve active decodes (see docs/serving.md); ``--chunked-prefill``
additionally splits each prompt into ``--prefill-chunk``-wide chunks
interleaved with decode across steps, tightening the decode stall bound
from one prompt bucket to one chunk.  ``--prefix-sharing``
turns on refcounted copy-on-write prefix sharing over the block pool and
``--preemption`` replaces the worst-case block reservation with
oversubscription + evict-and-replay; ``--pad-id`` sets the model's real pad
token for bucketed prefill rows.  ``--tiers`` serves a quality ladder
(comma-separated execution modes, e.g. ``exact,approx_lowrank,approx_msr``):
each synthetic request is routed to a random rung, and the
``--shed-queue-depth`` / ``--shed-gap-ticks`` thresholds arm the
load-adaptive shedder that demotes new admissions down the ladder under
pressure (see docs/serving.md "Quality tiers").
"""
from __future__ import annotations

import argparse
import dataclasses
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config, reduced_config
from repro.serve.scheduler import (
    ADMISSION_POLICIES,
    ATTN_IMPLS,
    CACHE_LAYOUTS,
    SERVE_LOOPS,
)
from repro.serve.engine import (
    EXECUTION_MODES,
    SamplingConfig,
    freeze_params,
    generate,
    greedy_generate_legacy,
    resolve_execution_mode,
)


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="granite-3-2b")
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=8)
    ap.add_argument("--new", type=int, default=8)
    ap.add_argument("--multiplier", default="mul8x8_2")
    ap.add_argument("--exec", dest="exec_mode", default="approx_lowrank",
                    choices=EXECUTION_MODES)
    ap.add_argument("--engine", default="scan", choices=("scan", "legacy", "continuous"))
    ap.add_argument("--temperature", type=float, default=0.0)
    ap.add_argument("--top-k", type=int, default=0)
    ap.add_argument("--eos-id", type=int, default=-1)
    ap.add_argument("--freeze-weights", action="store_true",
                    help="pre-quantize matmul weights to uint8 QWeights")
    ap.add_argument("--num-slots", type=int, default=4,
                    help="continuous engine: decode slot pool size")
    ap.add_argument("--requests", type=int, default=16,
                    help="continuous engine: synthetic trace length")
    ap.add_argument("--max-len", type=int, default=128,
                    help="continuous engine: per-request cache capacity")
    ap.add_argument("--cache-layout", default="slots", choices=CACHE_LAYOUTS,
                    help="continuous engine: per-slot max_len stripes, or a "
                         "paged block-table KV cache")
    ap.add_argument("--block-size", type=int, default=16,
                    help="paged layout: KV rows per block")
    ap.add_argument("--num-blocks", type=int, default=None,
                    help="paged layout: global block-pool size (default "
                         "matches the slot layout's HBM)")
    ap.add_argument("--attn-impl", default="gather", choices=ATTN_IMPLS,
                    help="paged layout: decode-attention path — the XLA "
                         "block gather (oracle) or the in-place Pallas "
                         "block-pool kernel (interpret mode off-TPU)")
    ap.add_argument("--prefix-sharing", action="store_true",
                    help="paged layout: refcounted copy-on-write prefix "
                         "sharing — requests whose prompts share leading "
                         "blocks map them to the same physical blocks")
    ap.add_argument("--preemption", action="store_true",
                    help="paged layout: drop the worst-case block "
                         "reservation and oversubscribe the pool; on "
                         "exhaustion the least-important resident request "
                         "is evicted and replayed (bit-identical)")
    ap.add_argument("--pad-id", type=int, default=0,
                    help="continuous engine: pad token id for bucketed "
                         "prefill rows (the model's real pad token)")
    ap.add_argument("--policy", default="priority", choices=ADMISSION_POLICIES,
                    help="continuous engine: admission order")
    ap.add_argument("--loop", default="async", choices=SERVE_LOOPS,
                    help="continuous engine: async double-buffered pipeline "
                         "(default) or the strictly-alternating sync loop")
    ap.add_argument("--prefill-decode-ratio", type=float, default=None,
                    help="continuous engine: admit at most RATIO * resident "
                         "decode tokens of bucketed prefill per step")
    ap.add_argument("--prefill-token-budget", type=int, default=None,
                    help="continuous engine: flat per-step prefill token "
                         "budget (alternative to --prefill-decode-ratio)")
    ap.add_argument("--chunked-prefill", action="store_true",
                    help="paged layout: split each prompt's prefill into "
                         "--prefill-chunk-wide chunks dispatched across "
                         "successive steps and interleaved with decode "
                         "under the prefill budget — a long prompt then "
                         "stalls decode by at most one chunk, not one "
                         "prompt bucket (outputs stay bit-identical)")
    ap.add_argument("--prefill-chunk", type=int, default=None,
                    help="chunked prefill: chunk width (must be one of the "
                         "prompt buckets; default: the largest bucket)")
    ap.add_argument("--spec-decode", action="store_true",
                    help="paged layout: self-speculative decoding — each "
                         "tick runs --draft-k steps through the "
                         "approximate draft path, then one exact verify "
                         "pass accepts the longest matching prefix "
                         "(outputs bit-identical to non-speculative)")
    ap.add_argument("--draft-k", type=int, default=4,
                    help="spec decode: drafted positions per verify")
    ap.add_argument("--draft-mode", default="approx",
                    choices=EXECUTION_MODES,
                    help="spec decode: the draft path's execution mode "
                         "(the draft multiplier reuses --multiplier; "
                         "'exact' is the every-token-accepts self-test)")
    ap.add_argument("--dynamic-draft-k", action="store_true",
                    help="spec decode: self-tune the draft window down/up "
                         "a warmed --draft-k -> 1 halving ladder around "
                         "the break-even accept rate 1/--draft-cost-ratio")
    ap.add_argument("--draft-cost-ratio", type=float, default=4.0,
                    help="dynamic draft: verify-position cost over "
                         "draft-step cost; its inverse is the break-even "
                         "accept rate")
    ap.add_argument("--draft-window", type=int, default=32,
                    help="dynamic draft: rolling (drafted, accepted) "
                         "chunks judged before each ladder move")
    ap.add_argument("--tiers", default=None,
                    help="continuous engine: comma-separated execution-mode "
                         "quality ladder (best first), e.g. "
                         "'exact,approx_lowrank,approx_msr'; requests are "
                         "routed per-rung with bit-identical per-request "
                         "outputs and zero recompiles after warmup")
    ap.add_argument("--tier-multiplier", default="mul8x8_2",
                    help="tiers: multiplier for approx rungs (MSR rungs "
                         "fall back to mul8x8_msr4 unless an MSR name is "
                         "given)")
    ap.add_argument("--shed-queue-depth", type=int, default=None,
                    help="tiers: demote new admissions one rung when the "
                         "ready queue exceeds this depth")
    ap.add_argument("--shed-gap-ticks", type=int, default=None,
                    help="tiers: demote new admissions one rung when the "
                         "live decode gap exceeds this many work ticks")
    ap.add_argument("--shed-hold-steps", type=int, default=8,
                    help="shedder: consecutive healthy steps before "
                         "restoring one rung")
    ap.add_argument("--shed-restore-fraction", type=float, default=0.5,
                    help="shedder: healthy = load below this fraction of "
                         "the shed thresholds (hysteresis)")
    ap.add_argument("--tp", type=int, default=0,
                    help="continuous engine: tensor-parallel degree — "
                         "serve under a (tp,)-device 'model' mesh with "
                         "params Megatron-split and the paged KV pool "
                         "sharded along the KV-head dim (requires "
                         "--cache-layout paged; on CPU force devices with "
                         "XLA_FLAGS=--xla_force_host_platform_device_count=N)")
    args = ap.parse_args(argv)

    cfg = get_config(args.arch)
    if args.reduced:
        cfg = dataclasses.replace(reduced_config(cfg), remat=False, q_chunk=64)
    cfg = dataclasses.replace(
        cfg, approx=resolve_execution_mode(args.exec_mode, args.multiplier)
    )
    if not cfg.embed_input:
        raise SystemExit(f"{args.arch} takes embedding inputs (frontend stub); "
                         "use an embed-input arch for token serving")
    from repro.models.transformer import init_params

    params = init_params(cfg, jax.random.PRNGKey(0))
    if args.freeze_weights:
        params = freeze_params(cfg, params)
    prompt = jax.random.randint(
        jax.random.PRNGKey(1), (args.batch, args.prompt_len), 0, cfg.vocab_size
    )
    sampling = SamplingConfig(
        temperature=args.temperature, top_k=args.top_k, eos_id=args.eos_id
    )
    if args.engine == "legacy" and sampling != SamplingConfig():
        print("warning: --engine legacy is greedy-only; "
              "--temperature/--top-k/--eos-id are ignored")

    if args.engine == "continuous":
        from repro.serve.scheduler import ServeSession

        rng = np.random.default_rng(0)
        # bucket set covers --prompt-len; cache covers the longest request.
        # Preemption replays prompt + accepted tokens through prefill, so
        # the buckets must also cover the longest possible replay prompt —
        # unless chunked prefill is on, which chunks any replay length
        # through the existing buckets and needs no wider top.
        top = args.prompt_len
        if args.preemption and not args.chunked_prefill:
            top = args.prompt_len + args.new - 1
        buckets = [8]
        while buckets[-1] < top:
            buckets.append(buckets[-1] * 2)
        max_len = max(args.max_len, buckets[-1] + args.new)
        if args.cache_layout == "paged" and max_len % args.block_size:
            max_len += args.block_size - max_len % args.block_size
        mesh = None
        if args.tp:
            if args.cache_layout != "paged":
                raise SystemExit("--tp requires --cache-layout paged")
            if args.tp > jax.device_count():
                raise SystemExit(
                    f"--tp {args.tp} > {jax.device_count()} visible devices "
                    "(on CPU: XLA_FLAGS="
                    f"--xla_force_host_platform_device_count={args.tp})"
                )
            mesh = jax.make_mesh((args.tp,), ("model",))
        tiers = None
        if args.tiers:
            tiers = tuple(t.strip() for t in args.tiers.split(",") if t.strip())
        sess = ServeSession(
            cfg, params, num_slots=args.num_slots, max_len=max_len,
            prompt_buckets=tuple(buckets), sampling=sampling,
            cache_layout=args.cache_layout, block_size=args.block_size,
            num_blocks=args.num_blocks, policy=args.policy, loop=args.loop,
            prefill_decode_ratio=args.prefill_decode_ratio,
            prefill_token_budget=args.prefill_token_budget,
            chunked_prefill=args.chunked_prefill,
            prefill_chunk=args.prefill_chunk,
            attn_impl=args.attn_impl, pad_id=args.pad_id,
            prefix_sharing=args.prefix_sharing, preemption=args.preemption,
            spec_decode=args.spec_decode, draft_k=args.draft_k,
            draft_mode=args.draft_mode, draft_multiplier=args.multiplier,
            dynamic_draft_k=args.dynamic_draft_k,
            draft_cost_ratio=args.draft_cost_ratio,
            draft_window=args.draft_window,
            tiers=tiers, tier_multiplier=args.tier_multiplier,
            shed_queue_depth=args.shed_queue_depth,
            shed_gap_ticks=args.shed_gap_ticks,
            shed_hold_steps=args.shed_hold_steps,
            shed_restore_fraction=args.shed_restore_fraction,
            mesh=mesh,
        )
        sess.warmup()
        for _ in range(args.requests):
            plen = int(rng.integers(min(2, args.prompt_len), args.prompt_len + 1))
            prompt = rng.integers(0, cfg.vocab_size, plen)
            lo = min(max(2, args.new // 4), args.new)
            max_new = int(rng.integers(lo, args.new + 1))
            tier = str(rng.choice(tiers)) if tiers is not None else None
            sess.submit(prompt, max_new=max_new, tier=tier)
        t0 = time.perf_counter()
        results = sess.run()
        dt = time.perf_counter() - t0
        generated = sum(len(r.tokens) for r in results.values())
        st = sess.stats
        print(f"[continuous/{args.exec_mode}/{args.cache_layout}/{args.loop}] "
              f"{len(results)} requests, "
              f"{generated} tokens in {dt:.3f}s ({generated/dt:.1f} tok/s, "
              f"post-compile), slot utilization {st.slot_utilization*100:.1f}% "
              f"over {st.ticks} ticks x {args.num_slots} slots")
        print(f"  ttft p50/p95 = {st.ttft_p50:.0f}/{st.ttft_p95:.0f} ticks, "
              f"latency p50/p95 = {st.latency_p50:.0f}/{st.latency_p95:.0f} "
              f"ticks, peak concurrency {st.peak_active}")
        print(f"  host/device overlap {st.overlap_fraction*100:.0f}% of wall, "
              f"decode-gap gauge {st.max_decode_gap_ticks} work ticks, "
              f"prefill stalls {st.prefill_stall_ticks}")
        if args.cache_layout == "paged":
            print(f"  KV pool: {sess.num_blocks} x {args.block_size}-row "
                  f"blocks, peak in use {st.peak_blocks_in_use}, "
                  f"attention impl {st.attn_impl}")
            if args.prefix_sharing or args.preemption:
                print(f"  sharing: {st.prefix_hit_blocks} prefix-hit blocks, "
                      f"{st.cow_forks} CoW forks, "
                      f"{st.preemptions} preemptions")
            if args.tp:
                print(f"  tensor parallel: tp={st.tp} over {st.devices} "
                      f"devices, peak KV "
                      f"{st.peak_block_bytes_per_device/2**20:.2f} MiB/device")
        if tiers is not None:
            served = {t: 0 for t in tiers}
            for r in results.values():
                served[r.tier] = served.get(r.tier, 0) + 1
            print(f"  tiers {','.join(tiers)}: served " +
                  " ".join(f"{t}={n}" for t, n in served.items()) +
                  f", demotions {st.tier_demotions}, "
                  f"restorations {st.tier_restorations}, "
                  f"shed level now {st.shed_level}")
        if args.spec_decode:
            print(f"  spec decode: draft {args.draft_mode}/{args.multiplier} "
                  f"k={args.draft_k}, accept rate {st.accept_rate*100:.1f}% "
                  f"({st.accepted_tokens}/{st.draft_tokens} drafted tokens "
                  f"over {st.verify_calls} verifies)")
            if args.dynamic_draft_k:
                print(f"  dynamic draft: k now {st.draft_k_current} "
                      f"({st.draft_k_shrinks} shrinks, "
                      f"{st.draft_k_grows} grows)")
        first = results[min(results)]
        print("sample:", first.full_sequence.tolist())
        return

    def run():
        if args.engine == "legacy":
            return greedy_generate_legacy(cfg, params, prompt, max_new=args.new)
        return generate(cfg, params, prompt, max_new=args.new, sampling=sampling)

    jax.block_until_ready(run())                 # compile once
    t0 = time.perf_counter()
    out = run()
    jax.block_until_ready(out)
    dt = time.perf_counter() - t0
    print(f"[{args.engine}/{args.exec_mode}] generated {args.batch}x{args.new} tokens "
          f"in {dt:.3f}s ({args.batch*args.new/dt:.1f} tok/s, post-compile)")
    print("sample:", out[0].tolist())


if __name__ == "__main__":
    main()
