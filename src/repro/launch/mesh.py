"""Production mesh construction.

Defined as FUNCTIONS (never module-level constants) so importing this module
never touches jax device state — required so tests/benches see 1 CPU device
while dryrun.py sees 512 forced host devices.
"""
from __future__ import annotations

from typing import Tuple

import jax

__all__ = ["make_production_mesh", "batch_axes", "mesh_tag"]


def make_production_mesh(*, multi_pod: bool = False):
    """Single pod: (16, 16) ("data", "model") = 256 chips (TPU v5e pod).
    Multi-pod: (2, 16, 16) ("pod", "data", "model") = 512 chips."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def batch_axes(mesh) -> Tuple[str, ...]:
    """Mesh axes that carry the global batch (DP)."""
    return tuple(a for a in mesh.axis_names if a in ("pod", "data"))


def mesh_tag(mesh) -> str:
    return "x".join(str(s) for s in mesh.devices.shape)
