import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Multi-pod dry-run: lower + compile every (arch x shape x mesh) cell and
extract memory / cost / collective statistics for the roofline analysis.

The two lines above MUST stay the first statements in this module: jax locks
the device count at first backend init, and the production meshes need 512
placeholder host devices. Nothing else in the repo sets this flag (tests and
benchmarks see the real single CPU device).

Usage:
  PYTHONPATH=src python -m repro.launch.dryrun --arch yi-34b --shape train_4k
  PYTHONPATH=src python -m repro.launch.dryrun --all --mesh both
"""
import argparse
import dataclasses
import functools
import json
import sys
import time
import traceback
from typing import Any, Dict, Optional

import jax
import jax.numpy as jnp

from repro.configs import SHAPES, get_config, list_archs
from repro.configs.base import ModelConfig, ShapeConfig
from repro.core.approx import ApproxConfig
from repro.launch import roofline as R
from repro.launch.mesh import batch_axes, make_production_mesh, mesh_tag
from repro.launch.specs import cache_specs, input_specs, params_specs, state_specs
from repro.parallel.sharding import (
    batch_pspecs,
    cache_pspecs,
    param_shardings,
    prune_pspec,
)
from repro.serve.engine import prefill_step, serve_step
from repro.train import optim as O
from repro.train.loop import make_train_step
from jax.sharding import NamedSharding, PartitionSpec as P

DEFAULT_OUT = os.path.join(os.path.dirname(__file__), "..", "..", "..", "results", "dryrun")


def _named(mesh, pspec_tree, shape_tree):
    return jax.tree.map(
        lambda ps: NamedSharding(mesh, ps),
        pspec_tree,
        is_leaf=lambda x: isinstance(x, P),
    )


def cell_supported(cfg: ModelConfig, shape: ShapeConfig) -> Optional[str]:
    """None if runnable; otherwise the documented skip reason."""
    if shape.name == "long_500k" and not cfg.supports_long_context:
        return "quadratic attention at 524k ctx: skipped for pure full-attention archs (DESIGN.md)"
    return None


def auto_microbatch(cfg: ModelConfig, shape: ShapeConfig, mesh, budget_bytes=4e9) -> int:
    """Grad-accumulation split keeping the per-device remat carry stack
    (L x B_mb/dp x S x d bf16) under ~4 GB."""
    dp = 1
    for a in batch_axes(mesh):
        dp *= int(mesh.shape[a])
    per_seq = cfg.num_layers * shape.seq_len * cfg.d_model * 2
    budget_seqs = max(1, int(budget_bytes // max(per_seq, 1)))
    b_per_dev = max(1, shape.global_batch // dp)
    mb = 1
    while b_per_dev // mb > budget_seqs and mb < b_per_dev:
        mb *= 2
    return mb


def build_lowerable(cfg: ModelConfig, shape: ShapeConfig, mesh, opt_cfg: O.OptConfig,
                    *, microbatch: Optional[int] = None,
                    frozen_weights: bool = False,
                    grad_compression: bool = False):
    """Returns (jitted_fn, example_args as ShapeDtypeStructs)."""
    binputs = input_specs(cfg, shape)
    bspec = batch_pspecs(cfg, mesh, shape.kind)
    bshard = {
        k: NamedSharding(mesh, prune_pspec(mesh, bspec.get(k, P()), binputs[k].shape))
        for k in binputs
    }

    if shape.kind == "train":
        sspecs = state_specs(cfg, opt_cfg)
        psh = param_shardings(cfg, sspecs["params"], mesh)
        ssh = {"params": psh, "opt": O.opt_state_shardings(opt_cfg, psh, mesh)}
        if grad_compression:
            from repro.train.loop import init_state  # structure only

            sspecs = dict(sspecs)
            sspecs["grad_err"] = jax.tree.map(
                lambda p: jax.ShapeDtypeStruct(p.shape, jnp.float32), sspecs["params"]
            )
            ssh = dict(ssh)
            ssh["grad_err"] = psh
        if microbatch is None:
            microbatch = auto_microbatch(cfg, shape, mesh)
        fn = make_train_step(cfg, opt_cfg, microbatch=microbatch,
                             grad_compression=grad_compression)
        jfn = jax.jit(fn, in_shardings=(ssh, bshard), donate_argnums=(0,))
        return jfn, (sspecs, binputs)

    pspecs = params_specs(cfg, frozen=frozen_weights and cfg.approx.is_quantized)
    psh = param_shardings(cfg, pspecs, mesh)

    if shape.kind == "prefill":
        fn = functools.partial(prefill_step, cfg)
        jfn = jax.jit(fn, in_shardings=(psh, bshard))
        return jfn, (pspecs, binputs)

    # decode
    cspecs = cache_specs(cfg, shape)
    csh = cache_pspecs(cfg, mesh, cspecs)
    lens = jax.ShapeDtypeStruct((shape.global_batch,), jnp.int32)
    lsh = NamedSharding(mesh, prune_pspec(mesh, P(batch_axes(mesh)), lens.shape))
    fn = functools.partial(serve_step, cfg)
    jfn = jax.jit(fn, in_shardings=(psh, csh, bshard, lsh), donate_argnums=(1,))
    return jfn, (pspecs, cspecs, binputs, lens)


def _measure(cfg, shape, mesh, opt_cfg, *, microbatch, frozen_weights=False,
             grad_compression=False):
    """Lower+compile one variant; return (flops, bytes, wire)/device + times."""
    t0 = time.time()
    with mesh:
        jfn, args = build_lowerable(cfg, shape, mesh, opt_cfg, microbatch=microbatch,
                                    frozen_weights=frozen_weights,
                                    grad_compression=grad_compression)
        lowered = jfn.lower(*args)
        compiled = lowered.compile()
    cost = compiled.cost_analysis() or {}
    if isinstance(cost, (list, tuple)):
        cost = cost[0] if cost else {}
    hlo = compiled.as_text()
    coll = R.parse_collectives(hlo)
    return {
        "flops": float(cost.get("flops", 0.0)),
        "bytes": R.estimate_hbm_bytes(hlo),
        "bytes_raw": float(cost.get("bytes accessed", 0.0)),
        "wire": coll.total_bytes,
        "wire_by_op": coll.per_op,
        "coll_counts": coll.counts,
        "wall_s": time.time() - t0,
        "compiled": compiled,
    }


def extract_costs(cfg: ModelConfig, shape: ShapeConfig, mesh, opt_cfg,
                  *, frozen_weights: bool = False, grad_compression: bool = False,
                  microbatch_override: Optional[int] = None) -> Dict[str, Any]:
    """Two-point extrapolated per-device costs.

    HLO cost analysis counts while-loop bodies ONCE, so the production
    lowering (layer-scan x microbatch-scan x chunk-scans) undercounts. We
    therefore lower two UNROLLED reduced-depth variants (1 and 2 layer
    units, chunk scans disabled, experts unrolled, microbatch=1 at the
    per-microbatch batch size) and extrapolate linearly in depth:

        cost(L) = fixed + units(L) * per_unit     (exact: depth-linear HLO)
        total   = n_microbatches * cost(L_full)

    Collective bytes and HBM bytes extrapolate the same way.
    """
    unit = cfg.attn_every if cfg.family == "hybrid" else 1
    mb = auto_microbatch(cfg, shape, mesh) if shape.kind == "train" else 1
    if microbatch_override is not None:
        mb = microbatch_override
    b_mb = max(1, shape.global_batch // mb)
    small = dict(
        scan_layers=False,
        unroll_experts=True,
        q_chunk=shape.seq_len if shape.kind != "decode" else cfg.q_chunk,
        ssm_chunk=shape.seq_len if shape.kind != "decode" else cfg.ssm_chunk,
    )
    cfg1 = dataclasses.replace(cfg, num_layers=unit, **small)
    cfg2 = dataclasses.replace(cfg, num_layers=2 * unit, **small)
    shape_mb = dataclasses.replace(shape, global_batch=b_mb)
    m1 = _measure(cfg1, shape_mb, mesh, opt_cfg, microbatch=1,
                  frozen_weights=frozen_weights, grad_compression=grad_compression)
    m2 = _measure(cfg2, shape_mb, mesh, opt_cfg, microbatch=1,
                  frozen_weights=frozen_weights, grad_compression=grad_compression)
    n_units = cfg.num_layers // unit
    out: Dict[str, Any] = {"microbatches": mb, "n_units": n_units}
    for key in ("flops", "bytes", "bytes_raw", "wire"):
        per_unit = m2[key] - m1[key]
        fixed = m1[key] - per_unit
        out[key] = mb * (fixed + n_units * per_unit)
        out[f"{key}_per_unit"] = per_unit
        out[f"{key}_fixed"] = fixed
    out["wire_by_op"] = {
        k: m1["wire_by_op"][k]
        + (m2["wire_by_op"][k] - m1["wire_by_op"][k]) * (n_units - 1)
        for k in m1["wire_by_op"]
    }
    out["cost_extraction_wall_s"] = m1["wall_s"] + m2["wall_s"]
    return out


def run_cell(
    arch: str,
    shape_name: str,
    *,
    multi_pod: bool,
    approx_mode: str = "lowrank",
    multiplier: str = "mul8x8_2",
    act_qmax: int = 255,
    w_qmax: int = 255,
    opt_kind: str = "adamw",
    print_analysis: bool = True,
    compute_costs: bool = True,
    frozen_weights: bool = False,
    grad_compression: bool = False,
    microbatch_override: Optional[int] = None,
    cfg_overrides: Optional[Dict[str, Any]] = None,
) -> Dict[str, Any]:
    cfg = get_config(arch)
    cfg = dataclasses.replace(
        cfg,
        approx=ApproxConfig(
            multiplier=multiplier, mode=approx_mode, act_qmax=act_qmax, w_qmax=w_qmax
        ),
        **(cfg_overrides or {}),
    )
    shape = SHAPES[shape_name]
    skip = cell_supported(cfg, shape)
    if skip:
        return {"arch": arch, "shape": shape_name, "skipped": skip}

    mesh = make_production_mesh(multi_pod=multi_pod)
    opt_cfg = O.OptConfig(kind=opt_kind)

    # 1) production lowering: proves shardability + gives per-device memory
    t0 = time.time()
    with mesh:
        jfn, args = build_lowerable(cfg, shape, mesh, opt_cfg,
                                    frozen_weights=frozen_weights,
                                    grad_compression=grad_compression,
                                    microbatch=microbatch_override)
        lowered = jfn.lower(*args)
        t_lower = time.time() - t0
        compiled = lowered.compile()
        t_compile = time.time() - t0 - t_lower
    mem = compiled.memory_analysis()
    n_dev = mesh.devices.size

    # 2) cost extraction: two-point unrolled extrapolation (scan bodies are
    #    counted once by HLO cost analysis — see extract_costs docstring).
    #    The roofline table is single-pod only (assignment); the multi-pod
    #    pass proves the "pod"-axis sharding compiles (--no-costs).
    if not compute_costs:
        result: Dict[str, Any] = {
            "arch": arch, "shape": shape_name, "mesh": mesh_tag(mesh),
            "n_devices": mesh.devices.size, "approx_mode": approx_mode,
            "multiplier": multiplier, "kind": shape.kind,
            "lower_s": t_lower, "compile_s": t_compile,
            "compiled_ok": True,
        }
        if mem is not None:
            for attr in ("argument_size_in_bytes", "output_size_in_bytes",
                         "temp_size_in_bytes"):
                try:
                    result[attr] = int(getattr(mem, attr))
                except Exception:
                    pass
        if print_analysis:
            print(f"== {arch} {shape_name} mesh={result['mesh']} compile-only ==")
            print("memory_analysis:", mem)
        return result

    costs = extract_costs(cfg, shape, mesh, opt_cfg, frozen_weights=frozen_weights,
                          grad_compression=grad_compression,
                          microbatch_override=microbatch_override)
    flops_dev = costs["flops"]
    bytes_dev = costs["bytes"]
    wire_dev = costs["wire"]

    # model flops: 6*N*D train, 2*N*D forward-only
    n_params = cfg.active_param_count() if cfg.family == "moe" else cfg.param_count()
    tokens = shape.global_batch * (shape.seq_len if shape.kind != "decode" else 1)
    mf = (6 if shape.kind == "train" else 2) * n_params * tokens

    terms = R.roofline_terms(
        flops_per_device=flops_dev,
        bytes_per_device=bytes_dev,
        wire_bytes_per_device=wire_dev,
        n_devices=n_dev,
        model_flops_global=float(mf),
    )

    result: Dict[str, Any] = {
        "arch": arch,
        "shape": shape_name,
        "mesh": mesh_tag(mesh),
        "n_devices": n_dev,
        "approx_mode": approx_mode,
        "multiplier": multiplier,
        "act_qmax": act_qmax,
        "w_qmax": w_qmax,
        "kind": shape.kind,
        "lower_s": t_lower,
        "compile_s": t_compile,
        "params": n_params,
        "tokens": tokens,
        "microbatches": costs["microbatches"],
        "collectives": {"bytes_per_device_by_op": costs["wire_by_op"]},
        "cost_extraction_wall_s": costs["cost_extraction_wall_s"],
        "cost_breakdown": {
            k: costs[k]
            for k in costs
            if k.endswith(("_per_unit", "_fixed")) or k in ("bytes_raw", "n_units")
        },
        **terms,
    }
    if mem is not None:
        for attr in (
            "argument_size_in_bytes",
            "output_size_in_bytes",
            "temp_size_in_bytes",
            "generated_code_size_in_bytes",
        ):
            try:
                result[attr] = int(getattr(mem, attr))
            except Exception:
                pass

    if print_analysis:
        print(f"== {arch} {shape_name} mesh={result['mesh']} mode={approx_mode} ==")
        print("memory_analysis:", mem)
        print("cost_analysis flops/device: %.3e  bytes/device: %.3e" % (flops_dev, bytes_dev))
        print(
            "roofline: compute %.4fs  memory %.4fs  collective %.4fs  -> %s-bound"
            % (terms["t_compute_s"], terms["t_memory_s"], terms["t_collective_s"], terms["bound"])
        )
        print(
            "useful-flop fraction %.3f  roofline fraction %.4f"
            % (terms["useful_flop_fraction"], terms.get("roofline_fraction", 0.0))
        )
    return result


def cell_list(archs, shapes):
    for a in archs:
        cfg = get_config(a)
        for s in shapes:
            yield a, s


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="all")
    ap.add_argument("--shape", default="all")
    ap.add_argument("--mesh", choices=["single", "multi", "both"], default="both")
    ap.add_argument("--approx-mode", default="lowrank",
                    choices=["float", "exact_quant", "lut", "lowrank", "pallas"])
    ap.add_argument("--multiplier", default="mul8x8_2")
    ap.add_argument("--act-qmax", type=int, default=255)
    ap.add_argument("--w-qmax", type=int, default=255)
    ap.add_argument("--out", default=os.environ.get("DRYRUN_OUT", "results/dryrun"))
    ap.add_argument("--force", action="store_true")
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--no-costs", action="store_true",
                    help="compile-only (shardability proof; used for multi-pod)")
    args = ap.parse_args(argv)

    archs = list_archs() if args.arch == "all" else [args.arch]
    shapes = list(SHAPES) if args.shape == "all" else [args.shape]
    meshes = {"single": [False], "multi": [True], "both": [False, True]}[args.mesh]
    os.makedirs(args.out, exist_ok=True)

    failures = []
    for arch, shape in cell_list(archs, shapes):
        for mp in meshes:
            tag = f"{arch}__{shape}__{'2x16x16' if mp else '16x16'}__{args.approx_mode}"
            if args.act_qmax != 255 or args.w_qmax != 255:
                tag += f"__a{args.act_qmax}w{args.w_qmax}"
            path = os.path.join(args.out, tag + ".json")
            if os.path.exists(path) and not args.force:
                print("cached:", tag)
                continue
            try:
                res = run_cell(
                    arch, shape, multi_pod=mp, approx_mode=args.approx_mode,
                    multiplier=args.multiplier, act_qmax=args.act_qmax,
                    w_qmax=args.w_qmax, compute_costs=not args.no_costs,
                )
            except Exception as e:  # noqa: BLE001
                traceback.print_exc()
                failures.append((tag, repr(e)))
                continue
            with open(path, "w") as f:
                json.dump(res, f, indent=1)
            print("wrote:", path)

    if failures:
        print("\nFAILED CELLS:")
        for t, e in failures:
            print(" ", t, e)
        sys.exit(1)
    print("\nall requested cells passed")


if __name__ == "__main__":
    main()
