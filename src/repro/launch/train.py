"""Cluster training launcher: pjit + sharded state + checkpoint/restart +
fault monitoring. On real pods each host runs this under its own process
(jax.distributed.initialize); in the container it runs on the local device
mesh. The dry-run (dryrun.py) is the 512-device rehearsal of exactly the
jit/sharding construction used here.

    PYTHONPATH=src python -m repro.launch.train --arch granite-3-2b \
        --reduced --steps 20 --batch 8 --seq 64
"""
from __future__ import annotations

import argparse
import dataclasses
import os
import time

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.configs import SHAPES, get_config, reduced_config
from repro.core.approx import ApproxConfig
from repro.data.synthetic import token_batches
from repro.launch.mesh import batch_axes
from repro.parallel.sharding import batch_pspecs, param_shardings, prune_pspec
from repro.train import optim as O
from repro.train.checkpoint import latest_step, restore_checkpoint, save_checkpoint
from repro.train.fault import PreemptionGuard, StragglerMonitor, run_with_restarts
from repro.train.loop import init_state, make_train_step
from repro.launch.specs import state_specs


def make_mesh_from_args(spec: str):
    devs = np.array(jax.devices())
    if spec == "auto":
        return jax.make_mesh((len(devs), 1), ("data", "model"))
    dims = tuple(int(x) for x in spec.split("x"))
    axes = ("pod", "data", "model")[-len(dims):]
    return jax.make_mesh(dims, axes)


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="granite-3-2b")
    ap.add_argument("--reduced", action="store_true", help="CPU-sized variant")
    ap.add_argument("--steps", type=int, default=50)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=64)
    ap.add_argument("--mesh", default="auto", help='"auto" or e.g. "16x16"')
    ap.add_argument("--microbatch", type=int, default=1)
    ap.add_argument("--multiplier", default="mul8x8_2")
    ap.add_argument("--mode", default="lowrank")
    ap.add_argument("--grad-compression", action="store_true")
    ap.add_argument("--ckpt", default="/tmp/repro_train_ckpt")
    ap.add_argument("--ckpt-every", type=int, default=20)
    ap.add_argument("--max-restarts", type=int, default=2)
    args = ap.parse_args(argv)

    cfg = get_config(args.arch)
    if args.reduced:
        cfg = reduced_config(cfg)
    cfg = dataclasses.replace(
        cfg, approx=ApproxConfig(multiplier=args.multiplier, mode=args.mode, band_reg=1e-4)
    )
    mesh = make_mesh_from_args(args.mesh)
    opt = O.OptConfig(lr=3e-4, total_steps=args.steps)

    def job(attempt: int):
        state = init_state(cfg, opt, jax.random.PRNGKey(0),
                           grad_compression=args.grad_compression)
        start = 0
        if latest_step(args.ckpt) is not None:
            state, start = restore_checkpoint(args.ckpt, jax.eval_shape(lambda: state))
            print(f"[attempt {attempt}] resumed at step {start}")

        with mesh:
            psh = param_shardings(cfg, state["params"], mesh)
            ssh = {"params": psh, "opt": O.opt_state_shardings(opt, psh, mesh)}
            if "grad_err" in state:
                ssh["grad_err"] = psh
            state = jax.tree.map(
                lambda a, s: jax.device_put(a, s), state, ssh
            )
            bspec = batch_pspecs(cfg, mesh, "train")
            step_fn = jax.jit(
                make_train_step(cfg, opt, microbatch=args.microbatch,
                                grad_compression=args.grad_compression),
                donate_argnums=(0,),
            )
            mon = StragglerMonitor(threshold=3.0)
            batches = token_batches(cfg.vocab_size, args.batch, args.seq, seed=start)
            with PreemptionGuard() as guard:
                for i in range(start, args.steps):
                    toks, labels = next(batches)
                    batch = {
                        "tokens": jax.device_put(
                            jnp.asarray(toks),
                            NamedSharding(mesh, prune_pspec(mesh, bspec["tokens"], toks.shape)),
                        ),
                        "labels": jnp.asarray(labels),
                    }
                    t0 = time.perf_counter()
                    state, m = step_fn(state, batch)
                    jax.block_until_ready(m["loss"])
                    mon.record(i, time.perf_counter() - t0)
                    if i % 10 == 0:
                        print(f"step {i:4d} loss {float(m['loss']):.4f} "
                              f"gnorm {float(m['grad_norm']):.3f}")
                    if (i + 1) % args.ckpt_every == 0 or guard.should_stop:
                        save_checkpoint(args.ckpt, i + 1, state, keep=3)
                        if guard.should_stop:
                            print("preempted: checkpoint flushed")
                            return state
        save_checkpoint(args.ckpt, args.steps, state, keep=3)
        return state

    run_with_restarts(job, max_restarts=args.max_restarts,
                      on_restart=lambda a, e: print(f"restart {a} after {e!r}"))
    print("training complete")


if __name__ == "__main__":
    main()
