"""ShapeDtypeStruct stand-ins for every model input: the dry-run lowers
against these (weak-type-correct, shardable, zero allocation).
"""
from __future__ import annotations

import functools
from typing import Any, Dict, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig, ShapeConfig
from repro.models.transformer import init_cache, init_params
from repro.train import optim as O

__all__ = ["input_specs", "params_specs", "cache_specs", "state_specs"]


def _sds(shape, dtype):
    return jax.ShapeDtypeStruct(tuple(shape), jnp.dtype(dtype))


def input_specs(cfg: ModelConfig, shape: ShapeConfig) -> Dict[str, Any]:
    """Batch inputs for the given shape kind.

    train/prefill: full (B, S); decode: (B, 1) new token with (B,) lengths.
    Stub-frontend archs (vlm/audio) get precomputed embeddings (B, S, d).
    """
    B, S = shape.global_batch, shape.seq_len
    s_in = 1 if shape.kind == "decode" else S
    specs: Dict[str, Any] = {}
    if cfg.embed_input:
        specs["tokens"] = _sds((B, s_in), jnp.int32)
    else:
        specs["embeddings"] = _sds((B, s_in, cfg.d_model), jnp.dtype(cfg.dtype))
    if cfg.pos_embedding == "m_rope" and shape.kind != "decode":
        specs["positions_thw"] = _sds((B, 3, s_in), jnp.int32)
    if shape.kind == "train":
        specs["labels"] = _sds((B, S), jnp.int32)
    return specs


def params_specs(cfg: ModelConfig, *, frozen: bool = False) -> Any:
    """Parameter ShapeDtypeStructs via eval_shape (no allocation).
    ``frozen``: serving layout — matmul weights pre-quantized to QWeight."""
    def build():
        p = init_params(cfg, jax.random.PRNGKey(0))
        if frozen:
            from repro.core.approx import prequantize_tree

            p = prequantize_tree(p, cfg.approx)
        return p

    return jax.eval_shape(build)


def cache_specs(cfg: ModelConfig, shape: ShapeConfig) -> Any:
    return jax.eval_shape(
        lambda: init_cache(cfg, shape.global_batch, shape.seq_len, jnp.dtype(cfg.dtype))
    )


def state_specs(cfg: ModelConfig, opt_cfg: O.OptConfig) -> Any:
    p = params_specs(cfg)
    return {
        "params": p,
        "opt": jax.eval_shape(functools.partial(O.init_opt_state, opt_cfg), p),
    }
