"""Roofline analysis from compiled dry-run artifacts (no hardware needed).

Three terms per (arch x shape x mesh), all in seconds:

    compute    = HLO_FLOPs      / (chips * PEAK_FLOPS)
    memory     = HLO_bytes      / (chips * HBM_BW)
    collective = wire_bytes     / (chips * LINK_BW)

``compiled.cost_analysis()`` reports per-device flops/bytes for the SPMD
module, so per-device values divided by per-chip peaks ARE the global terms.
Collective bytes are parsed from the optimized HLO: every all-gather /
all-reduce / reduce-scatter / all-to-all / collective-permute result shape,
weighted by the standard ring factors using the op's replica-group size.

Hardware model (TPU v5e, per chip): 197 TFLOP/s bf16, 819 GB/s HBM,
~50 GB/s/link ICI (assignment-provided constants).
"""
from __future__ import annotations

import dataclasses
import re
from typing import Dict, List, Optional, Tuple

__all__ = [
    "PEAK_FLOPS",
    "HBM_BW",
    "LINK_BW",
    "CollectiveStats",
    "parse_collectives",
    "roofline_terms",
]

PEAK_FLOPS = 197e12     # bf16 FLOP/s per chip
HBM_BW = 819e9          # bytes/s per chip
LINK_BW = 50e9          # bytes/s per ICI link

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "f16": 2, "bf16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8, "c64": 8,
    "c128": 16, "s4": 1, "u4": 1, "f8e4m3fn": 1, "f8e5m2": 1,
}

_COLL_OPS = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all", "collective-permute")
_SHAPE_RE = re.compile(r"([a-z0-9]+)\[([0-9,]*)\]")
_GROUPS_BRACE_RE = re.compile(r"replica_groups=\{\{([0-9, ]+)\}")
_GROUPS_IOTA_RE = re.compile(r"replica_groups=\[(\d+),(\d+)\]")


def _shape_bytes(segment: str) -> int:
    total = 0
    for dt, dims in _SHAPE_RE.findall(segment):
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


def _group_size(line: str) -> int:
    m = _GROUPS_BRACE_RE.search(line)
    if m:
        return len([x for x in m.group(1).split(",") if x.strip()])
    m = _GROUPS_IOTA_RE.search(line)
    if m:
        return int(m.group(2))          # [n_groups, group_size]
    return 1


# Ops that necessarily touch HBM on a well-fused TPU pipeline. Pure
# elementwise arithmetic is EXCLUDED (assumed fused into producers/consumers
# — XLA:TPU does this; XLA:CPU barely fuses, so its raw `bytes accessed`
# overcounts HBM traffic by ~5-10x and is kept only as `bytes_raw`).
_MEM_OPS = (
    "dot", "convolution", "fusion", "reduce", "reduce-window", "scatter",
    "gather", "sort", "dynamic-slice", "dynamic-update-slice", "copy",
    "transpose", "concatenate", "pad", "select-and-scatter", "custom-call",
    "all-gather", "all-reduce", "reduce-scatter", "all-to-all",
    "collective-permute", "rng", "rng-bit-generator", "cholesky",
    "triangular-solve",
)
_INSTR_RE = re.compile(r"^\s*(?:ROOT\s+)?%([\w.\-]+)\s*=\s*(\(?[^=]*?)\s+([\w\-]+)\(")
_OPERAND_RE = re.compile(r"%([\w.\-]+)")


def estimate_hbm_bytes(hlo_text: str) -> float:
    """Fusion-aware HBM traffic model: sum operand+result bytes over ops
    that roundtrip HBM on TPU (dots, reduces, data movement, collectives,
    fusions), resolving operand shapes through a name->bytes symbol table.
    While-loop bodies appear once (handled by the caller's two-point
    depth extrapolation)."""
    sizes: Dict[str, int] = {}
    total = 0.0
    for line in hlo_text.splitlines():
        m = _INSTR_RE.match(line)
        if not m:
            continue
        name, result_seg, op = m.group(1), m.group(2), m.group(3)
        b = _shape_bytes(result_seg)
        sizes[name] = b
        base = op[:-6] if op.endswith("-start") else op
        if base not in _MEM_OPS:
            continue
        # operand bytes: resolve %refs inside the call parens
        call = line.split(f"{op}(", 1)[1] if f"{op}(" in line else ""
        call = call.split(")", 1)[0]
        refs = _OPERAND_RE.findall(call)
        if base == "dynamic-update-slice":
            # in-place aliased update: traffic = read+write of the UPDATE
            # slice (operand 1), not the whole buffer
            upd = sizes.get(refs[1], 0) if len(refs) > 1 else 0
            total += 2 * upd
            continue
        if base == "dynamic-slice":
            # reads only the slice, not the sliced-from buffer
            total += 2 * b
            continue
        if base == "scatter":
            # traffic ~ indices + 2x updates (gather-modify-write of slices)
            upd = sum(sizes.get(r, 0) for r in refs[1:])
            total += 2 * upd
            continue
        opb = sum(sizes.get(r, 0) for r in refs)
        total += b + opb
    return total


@dataclasses.dataclass
class CollectiveStats:
    per_op: Dict[str, float]            # wire bytes per device, by op kind
    counts: Dict[str, int]

    @property
    def total_bytes(self) -> float:
        return sum(self.per_op.values())


def parse_collectives(hlo_text: str) -> CollectiveStats:
    """Per-device wire bytes from an SPMD-partitioned optimized HLO module."""
    per_op: Dict[str, float] = {k: 0.0 for k in _COLL_OPS}
    counts: Dict[str, int] = {k: 0 for k in _COLL_OPS}
    for line in hlo_text.splitlines():
        ls = line.strip()
        if "=" not in ls:
            continue
        hit = None
        for op in _COLL_OPS:
            # match ` op(`, ` op-start(` but not `-done(`
            if f" {op}(" in ls or f" {op}-start(" in ls:
                hit = op
                break
        if hit is None:
            continue
        _, rhs = ls.split("=", 1)
        n = _group_size(ls)
        if n <= 1:
            continue
        # result type sits between '=' and the op name: `%x = f32[..] op(..)`
        seg = rhs.split(f" {hit}", 1)[0]
        b = _shape_bytes(seg)
        if f"{hit}-start(" in ls:
            # async start results are (operand_buf, result_buf[, ...]) tuples
            b = b / 2
        if hit == "all-reduce":
            wire = 2.0 * (n - 1) / n * b
        elif hit == "collective-permute":
            wire = float(b)
        else:  # all-gather result / reduce-scatter input / all-to-all
            wire = (n - 1) / n * b
        per_op[hit] += wire
        counts[hit] += 1
    return CollectiveStats(per_op=per_op, counts=counts)


def roofline_terms(
    *,
    flops_per_device: float,
    bytes_per_device: float,
    wire_bytes_per_device: float,
    n_devices: int,
    model_flops_global: Optional[float] = None,
) -> Dict[str, float]:
    t_c = flops_per_device / PEAK_FLOPS
    t_m = bytes_per_device / HBM_BW
    t_x = wire_bytes_per_device / LINK_BW
    dominant = max(("compute", t_c), ("memory", t_m), ("collective", t_x), key=lambda kv: kv[1])
    out = {
        "t_compute_s": t_c,
        "t_memory_s": t_m,
        "t_collective_s": t_x,
        "bound": dominant[0],
        "t_bound_s": dominant[1],
        "hlo_flops_global": flops_per_device * n_devices,
        "hlo_bytes_global": bytes_per_device * n_devices,
        "wire_bytes_global": wire_bytes_per_device * n_devices,
    }
    if model_flops_global:
        out["model_flops_global"] = model_flops_global
        out["useful_flop_fraction"] = model_flops_global / max(out["hlo_flops_global"], 1.0)
        # roofline fraction: useful model flops per second at the bound vs peak
        t = max(dominant[1], 1e-30)
        out["model_flops_per_s"] = model_flops_global / t / n_devices
        out["roofline_fraction"] = out["model_flops_per_s"] / PEAK_FLOPS
    return out


def suggest(bound: str) -> str:
    return {
        "compute": "reduce arithmetic: fewer correction features (range pruning), bf16 exact path, larger fused tiles",
        "memory": "cut HBM traffic: fuse feature maps into the matmul kernel, int8/uint8 storage, remat policy tuning",
        "collective": "re-shard to shrink all-gathers: FSDP prefetch overlap, 2D sharding of big projections, gradient compression",
    }[bound]
