"""Arithmetic error metrics for approximate multipliers (paper Section III.A).

ED    = |Value' - Value|                              (eq. 1)
MED   = mean(ED) over the full input domain           (eq. 2)
ER    = fraction of inputs with ED != 0               (eq. 3)
NMED  = MED / (2**n - 1)**2                           (eq. 10)
MRED  = mean(ED / Value) over inputs with Value > 0   (eq. 11, conventional
        form; the paper's printed denominator ``Value' * 2**n`` does not
        reproduce its own Table V, the conventional mean-relative-ED does)
DAL   = DNN accuracy loss: accuracy(exact) - accuracy(approx).
"""
from __future__ import annotations

import dataclasses
from typing import Dict

import numpy as np

__all__ = ["MultiplierMetrics", "multiplier_metrics", "dal"]


@dataclasses.dataclass(frozen=True)
class MultiplierMetrics:
    name: str
    er: float      # percent
    med: float
    nmed: float    # percent
    mred: float    # percent
    max_ed: int

    def as_dict(self) -> Dict[str, float]:
        return {
            "er_pct": self.er,
            "med": self.med,
            "nmed_pct": self.nmed,
            "mred_pct": self.mred,
            "max_ed": float(self.max_ed),
        }


def multiplier_metrics(table: np.ndarray, name: str = "") -> MultiplierMetrics:
    """Compute ER/MED/NMED/MRED over the multiplier's full input domain."""
    n_bits = int(np.log2(table.shape[0]))
    exact = (
        np.arange(table.shape[0], dtype=np.int64)[:, None]
        * np.arange(table.shape[1], dtype=np.int64)[None, :]
    )
    ed = np.abs(table.astype(np.int64) - exact)
    er = 100.0 * float(np.count_nonzero(ed)) / ed.size
    med = float(ed.mean())
    nmed = 100.0 * med / float((2**n_bits - 1) ** 2)
    nz = exact > 0
    mred = 100.0 * float((ed[nz] / exact[nz]).mean())
    return MultiplierMetrics(
        name=name, er=er, med=med, nmed=nmed, mred=mred, max_ed=int(ed.max())
    )


def dal(exact_accuracy: float, approx_accuracy: float) -> float:
    """DNN accuracy loss in percentage points."""
    return exact_accuracy - approx_accuracy
