"""Gather-free bitwise evaluation of the paper's approximate multipliers.

The printed Boolean expressions (4)-(9) in the paper's text do NOT reproduce
the paper's own Table II under our best-effort transcription (the overbars
are garbled in the source; e.g. eq. (5)'s `a1·~a0·b1` term fires on
(a,b)=(2,2) where the exact O1 bit is 0). We therefore evaluate the
*K-map semantics* directly: exact product minus the six-row correction —
pure compare/mask arithmetic, no table gathers, exactly the structure the
Pallas kernels evaluate on the VPU. Equivalence to the truth-table LUTs is
asserted in tests/test_logic.py.
"""
from __future__ import annotations

import numpy as np

try:  # jnp-compatible: works on numpy and jax arrays alike
    import jax.numpy as jnp
except Exception:  # pragma: no cover
    jnp = np

__all__ = ["approx_mul3x3", "approx_mul8x8_bitwise"]


def approx_mul3x3(a, b, design: int = 1):
    """Bitwise 3x3 approximate product (MUL3x3_1 or _2), gather-free.

    design 1: the six rows with product > 31 are rewritten so O5 = 0
      (Table II): (5,7)/(7,5) -> -8; (6,6),(6,7),(7,6) -> -12; (7,7) -> -20.
    design 2: prediction unit restores O5=1/O4=0 on the a2a1b2b1 rows
      (Table III): (5,7)/(7,5) -> -8; (6,6),(6,7),(7,6) -> +4; (7,7) -> -4.
    """
    exact = a * b
    m57 = ((a == 5) & (b == 7)) | ((a == 7) & (b == 5))
    m66 = (a == 6) & (b == 6)
    m67 = ((a == 6) & (b == 7)) | ((a == 7) & (b == 6))
    m77 = (a == 7) & (b == 7)
    if design == 1:
        return exact - 8 * m57 - 12 * m66 - 12 * m67 - 20 * m77
    return exact - 8 * m57 + 4 * (m66 + m67) - 4 * m77


def approx_mul8x8_bitwise(a, b, design: int = 2, removed_m2: bool = False):
    """Elementwise aggregated 8x8 approximate product via bit logic only.

    a, b: uint8-valued integer arrays. ``removed_m2``: MUL8x8_3 semantics
    (drop M2 = A[2:0]*B[7:6] and its shifter). Bit-identical to
    ``multipliers.mul8x8_table(...)`` (tests/test_logic.py).
    """
    a = a.astype(jnp.int32)
    b = b.astype(jnp.int32)
    alo, amid, ahi = a & 7, (a >> 3) & 7, (a >> 6) & 3
    blo, bmid, bhi = b & 7, (b >> 3) & 7, (b >> 6) & 3
    m = lambda x, y: approx_mul3x3(x, y, design)
    out = (
        m(alo, blo)
        + (m(alo, bmid) << 3) + (m(amid, blo) << 3)
        + (m(amid, bmid) << 6)
        + (m(amid, bhi) << 9) + (m(ahi, bmid) << 9)
        + ((ahi * bhi) << 12)                    # exact 2x2 (M8)
        + (m(ahi, blo) << 6)
    )
    if not removed_m2:
        out = out + (m(alo, bhi) << 6)
    return out
