"""ApproxConfig — the framework-level switch for the paper's technique.

Every matmul-bearing layer in the model zoo routes through
``approx_dense`` below; the config selects the multiplier, the simulation
mode (paper-faithful LUT vs TPU-native low-rank vs the Pallas kernel), the
quantization bands, and the co-optimization range profile.

Simulation modes (all bit-exact to the multiplier LUT semantics):
  float       no quantization at all (fp baseline)
  exact_quant uint8 affine quantization with an exact integer matmul
  lut         paper-faithful LUT-gather simulation (the reference/baseline)
  lowrank     exact MXU form: A@B - U(A)@V(B)   (see core/lowrank.py)
  pallas      fused Pallas TPU kernel of the lowrank form
"""
from __future__ import annotations

import dataclasses
import functools
from typing import NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import lowrank as lr
from repro.core import multipliers as mul
from repro.quant.affine import QuantParams, calibrate, dequantize, quantize

__all__ = [
    "ApproxConfig",
    "approx_dense",
    "quantized_matmul",
    "QWeight",
    "prequantize_tree",
]

Modes = ("float", "exact_quant", "lut", "lowrank", "pallas")


@dataclasses.dataclass(frozen=True)
class ApproxConfig:
    """Static (hashable) configuration of the approximate-multiplier feature."""

    multiplier: str = "mul8x8_2"       # exact | mul8x8_1/2/3 | pkm | etm | mul8x8_msr*
    mode: str = "lowrank"              # one of Modes
    act_qmax: int = 255                # activation code band (paper: inputs in (0,31) -> 31)
    w_qmax: int = 255                  # weight code band (co-optimized: 31)
    w_per_channel: bool = True         # per-output-channel weight scales
    band_reg: float = 0.0              # weight band-regularizer strength (retraining)
    act_per_row: bool = False          # per-row (per-token) activation scales:
    #   each flattened (M, K) row calibrates independently, so a row's codes
    #   (and therefore its outputs) do not depend on which other rows share
    #   the batch — required for bit-identical mixed-tier serving, where
    #   rows of one batch run under different tier configs across ticks.

    def __post_init__(self):
        if self.mode not in Modes:
            raise ValueError(f"mode {self.mode!r} not in {Modes}")
        if self.mode in ("lut", "lowrank", "pallas"):
            mul.mul8x8_table(self.multiplier)  # validate name

    @property
    def is_quantized(self) -> bool:
        return self.mode != "float"


# Default config used by model constructors unless overridden.
FLOAT = ApproxConfig(mode="float")


@functools.lru_cache(maxsize=None)
def _correction(multiplier: str, lhs_max: int, rhs_max: int) -> lr.LowRankCorrection:
    """Cached factorization with indicator features on the rhs (weights) side
    — weights are static at inference so u(W) precomputes, and the paper's
    co-optimized weight band (0,31) prunes rhs rows hardest."""
    return lr.build_correction(multiplier, side="rhs", lhs_max=lhs_max, rhs_max=rhs_max)


def quantized_matmul(
    a_codes: jax.Array,
    b_codes: jax.Array,
    cfg: ApproxConfig,
) -> jax.Array:
    """Integer matmul of uint8 codes under the configured multiplier semantics.

    a_codes: (..., M, K) int32 in [0, act_qmax]; b_codes: (K, N) int32 in
    [0, w_qmax].  Returns (..., M, N) int32 equal (bit-exactly) to
    ``sum_k LUT[a, b]``.
    """
    if cfg.mode == "exact_quant" or cfg.multiplier == "exact":
        return _int_dot(a_codes, b_codes)
    if cfg.mode == "lut":
        from repro.kernels.approx_matmul.ref import approx_matmul_ref

        lut = jnp.asarray(mul.mul8x8_table(cfg.multiplier))
        return approx_matmul_ref(a_codes, b_codes, lut)
    if cfg.mode == "lowrank":
        return _lowrank_matmul(a_codes, b_codes, cfg)
    if cfg.mode == "pallas":
        from repro.kernels.approx_matmul.ops import approx_matmul_pallas

        return approx_matmul_pallas(
            a_codes,
            b_codes,
            multiplier=cfg.multiplier,
            lhs_max=cfg.act_qmax,
            rhs_max=cfg.w_qmax,
        )
    raise ValueError(cfg.mode)


def _int_dot(a: jax.Array, b: jax.Array) -> jax.Array:
    """Exact integer matmul (int32 accumulation), MXU int8-friendly on TPU."""
    return jax.lax.dot_general(
        a.astype(jnp.int32),
        b.astype(jnp.int32),
        (((a.ndim - 1,), (0,)), ((), ())),
        preferred_element_type=jnp.int32,
    )


def _bf16_dot(a: jax.Array, b: jax.Array) -> jax.Array:
    """Code matmul in MXU-native bf16 with f32 accumulation. uint8 codes and
    all phi/psi table values are bf16-exact (<= 8 significant bits, verified
    in tests), so each product is exact; accumulation is f32 (exact below
    2^24 per reduction — the Pallas kernel's int32-tiled path is the fully
    bit-exact production route; see kernels/approx_matmul)."""
    return jax.lax.dot_general(
        a.astype(jnp.bfloat16),
        b.astype(jnp.bfloat16),
        (((a.ndim - 1,), (0,)), ((), ())),
        preferred_element_type=jnp.float32,
    )


def _lowrank_matmul(a_codes: jax.Array, b_codes: jax.Array, cfg: ApproxConfig) -> jax.Array:
    """approx = A@B - sum_f v_f(A) @ u_f(B): (1+F) MXU dots.

    Feature maps are pure shift/mask/compare ops on the uint8 codes (no
    gathers, no (M,K,F) materialization — one (M,K)/(K,N) bf16 transient per
    dot; all table values are bf16-exact, see tests/test_lowrank.py)."""
    corr = _correction(cfg.multiplier, cfg.act_qmax, cfg.w_qmax)
    out = _bf16_dot(a_codes, b_codes)
    for f in corr.features:
        va = lr.v_map_jnp(a_codes, f.v_terms)                     # lhs tables
        ub = lr.u_map_jnp(
            b_codes, f.kind, f.u_shift, f.u_bits, f.residue, f.u_terms
        )
        out = out - _bf16_dot(va, ub)
    return out


# ---------------------------------------------------------------------------
# Frozen pre-quantized weights (serving path)
# ---------------------------------------------------------------------------


class QWeight(NamedTuple):
    """A weight matrix frozen to uint8 codes at load time. Serving reads 1
    byte/element instead of 4 (f32 master) and skips per-step calibration —
    the weight-side precompute of DESIGN.md §7."""

    codes: jax.Array        # (K, N) uint8
    scale: jax.Array        # per-channel (1, N) or scalar, f32
    zero_point: jax.Array   # int32, same shape as scale
    col_sum: jax.Array      # (1, N) f32: sum_k codes (precomputed zp term)


_PREQUANT_LEAVES = (
    ".wq", ".wk", ".wv", ".wo",
    ".w_gate", ".w_up", ".w_down",
    "shared_gate", "shared_up", "shared_down",
    ".in_proj", ".x_proj", ".dt_proj", ".out_proj",
    "['lm_head']",
)


def w_dim(w, i: int) -> int:
    """Shape accessor that works for float weights and frozen QWeights."""
    return (w.codes if isinstance(w, QWeight) else w).shape[i]


def concat_weights(ws, axis: int = 1):
    """Concatenate weights along the output-channel axis; QWeights stay
    frozen (per-channel scales concatenate losslessly)."""
    if any(isinstance(w, QWeight) for w in ws):
        assert all(isinstance(w, QWeight) for w in ws), "mixed frozen/float concat"
        return QWeight(
            codes=jnp.concatenate([w.codes for w in ws], axis=axis),
            scale=jnp.concatenate([jnp.broadcast_to(w.scale, (1, w_dim(w, -1))) for w in ws], axis=-1),
            zero_point=jnp.concatenate(
                [jnp.broadcast_to(w.zero_point, (1, w_dim(w, -1))) for w in ws], axis=-1
            ),
            col_sum=jnp.concatenate([w.col_sum for w in ws], axis=-1),
        )
    return jnp.concatenate(ws, axis=axis)


def prequantize_tree(params, cfg: "ApproxConfig"):
    """Freeze every matmul weight to a QWeight (embeddings, norms, convs and
    the MoE router stay float)."""

    def one(path, leaf):
        ks = jax.tree_util.keystr(path)
        if leaf.ndim >= 2 and any(ks.endswith(s) or s in ks for s in _PREQUANT_LEAVES):
            qp = calibrate(leaf, axis=(leaf.ndim - 2,) if cfg.w_per_channel else None,
                           qmax=cfg.w_qmax)
            codes = quantize(leaf, qp)
            return QWeight(
                codes=codes,
                scale=qp.scale,
                zero_point=qp.zero_point,
                col_sum=jnp.sum(codes, axis=-2, keepdims=True, dtype=jnp.float32),
            )
        return leaf

    return jax.tree_util.tree_map_with_path(one, params)


# ---------------------------------------------------------------------------
# Real-valued dense layer with approximate-multiplier semantics + QAT STE
# ---------------------------------------------------------------------------


def approx_dense(x: jax.Array, w: jax.Array, cfg: ApproxConfig) -> jax.Array:
    """y = x @ w computed through the approximate-multiplier pipeline.

    x: (..., K) float; w: (K, N) float.  Forward quantizes both operands to
    unsigned codes (dynamic per-tensor activation scale, per-channel weight
    scales), runs the configured integer multiplier simulation, applies the
    standard zero-point corrections, and dequantizes.

    The QAT straight-through estimator is expressed with ``stop_gradient``
    algebra instead of ``custom_vjp``:

        y = y_lin + stop_grad(y_int - y_lin),   y_lin = fq(x) @ fq(w)

    so the forward VALUE is the bit-faithful integer simulation while the
    gradient flows through the differentiable fake-quantized matmul. Zero
    custom_vjp keeps the whole layer transparent to remat/scan/vmap — this
    is what lets 60-layer scan-with-checkpoint models keep per-layer
    residuals at one bf16 carry instead of stacked f32 custom_vjp residuals.

    ``w`` may be a frozen ``QWeight`` (serving): activation quantization
    stays dynamic; weight codes are read directly (uint8 — 4x less HBM than
    the f32 master), calibration and the STE matmul are skipped.
    """
    if isinstance(w, QWeight):
        return _approx_dense_frozen(x, w, cfg)
    if cfg.mode == "float":
        return jnp.einsum(
            "...k,kn->...n", x, w.astype(x.dtype), preferred_element_type=jnp.float32
        ).astype(x.dtype)
    sg = jax.lax.stop_gradient
    x2 = x.reshape(-1, x.shape[-1])
    qp_x = calibrate(sg(x2), axis=(1,) if cfg.act_per_row else None,
                     qmax=cfg.act_qmax)
    qp_w = calibrate(sg(w), axis=(0,) if cfg.w_per_channel else None, qmax=cfg.w_qmax)
    qx = quantize(sg(x2), qp_x)                   # (M, K) uint8
    qw = quantize(sg(w), qp_w)                    # (K, N) uint8

    # differentiable STE path (bf16 MXU matmul of fake-quantized operands)
    x_fq = x2 + sg(dequantize(qx, qp_x).astype(x2.dtype) - x2)
    w_fq = w + sg(dequantize(qw, qp_w).astype(w.dtype) - w)
    y_lin = jax.lax.dot_general(
        x_fq.astype(jnp.bfloat16),
        w_fq.astype(jnp.bfloat16),
        (((1,), (0,)), ((), ())),
        preferred_element_type=jnp.float32,
    )

    # integer simulation (value path, gradient-free)
    raw = quantized_matmul(qx, qw, cfg).astype(jnp.float32)   # sum_k mul(qx, qw)
    K = x2.shape[-1]
    zx = qp_x.zero_point.astype(jnp.float32)
    zw = qp_w.zero_point.astype(jnp.float32)      # (1, N) or scalar
    row_x = jnp.sum(qx, axis=-1, keepdims=True, dtype=jnp.float32)
    col_w = jnp.sum(qw, axis=0, keepdims=True, dtype=jnp.float32)
    acc = raw - zx * col_w - row_x * zw + K * zx * zw
    y_int = acc * (qp_x.scale * qp_w.scale)

    y = y_lin + sg(y_int - y_lin)
    return y.reshape(*x.shape[:-1], w.shape[-1])


def _approx_dense_frozen(x: jax.Array, w: QWeight, cfg: ApproxConfig) -> jax.Array:
    """Inference dense against frozen uint8 weight codes (no calibration of
    w, no STE dot; gradient-free — serving path)."""
    x2 = x.reshape(-1, x.shape[-1])
    qp_x = calibrate(x2, axis=(1,) if cfg.act_per_row else None,
                     qmax=cfg.act_qmax)
    qx = quantize(x2, qp_x)
    raw = quantized_matmul(qx, w.codes, cfg).astype(jnp.float32)
    K = x2.shape[-1]
    zx = qp_x.zero_point.astype(jnp.float32)
    zw = w.zero_point.astype(jnp.float32)
    row_x = jnp.sum(qx, axis=-1, keepdims=True, dtype=jnp.float32)
    acc = raw - zx * w.col_sum - row_x * zw + K * zx * zw
    y = acc * (qp_x.scale * w.scale)
    return y.reshape(*x.shape[:-1], w.codes.shape[-1]).astype(x.dtype)
