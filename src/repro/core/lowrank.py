"""Exact low-rank decomposition of approximate-multiplier error -> MXU form.

The paper's aggregated 8x8 multipliers satisfy, bit-exactly,

    approx(a, b) = a * b - err(a, b)
    err(a, b)    = sum_{(pa, pb)} E[pa, pb][ piece_pa(a), piece_pb(b) ] << (s_pa + s_pb)

where each per-piece-pair error LUT ``E`` is nonzero on at most three rows
(the K-map rewrites need both 3-bit operands >= 5), and a *removed* partial
product (MUL8x8_3) contributes the exact piece product (a rank-1 term).

This module factors ``err`` into a sum of F separable features

    err(a, b) = sum_f  u_f(a) * v_f(b)

so that a whole approximate matmul becomes two MXU matmuls:

    approx_matmul(A, B) = A @ B - U(A) @ V(B)        # U: (M, K*F), V: (K*F, N)

with ``u_f`` / ``v_f`` elementwise (indicator bits / tiny LUT sums -- VPU-cheap,
expressible with shifts+compares inside a Pallas kernel; no gathers needed).

Feature construction (indicators on the ``side`` operand):
  * indicator feature (piece pa, residue x):  u = 1[piece_pa(a) == x],
    v = sum_pb 2^{s_pa+s_pb} * E[pa,pb][x, piece_pb(b)]
  * linear feature (piece pa, for removed exact products):  u = piece_pa(a)*2^{s_pa},
    v = sum_{pb removed with pa} piece_pb(b) * 2^{s_pb}

Co-optimization-aware **range pruning**: if operands are known to satisfy
``a <= lhs_max`` / ``b <= rhs_max`` (e.g. the paper's retrained weights in
(0,31)), features whose ``u`` or ``v`` vanish on the restricted domain are
dropped — F falls from 6 to 3 for MUL8x8_2 with weights < 32, and the
MUL8x8_3 rank-1 term vanishes entirely.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Sequence, Tuple

import numpy as np

from repro.core import multipliers as mul

__all__ = [
    "Feature",
    "LowRankCorrection",
    "build_correction",
    "piece_max",
    "u_map_jnp",
    "v_map_jnp",
]


def u_map_jnp(x, kind: str, shift: int, bits: int, residue: int, u_terms=()):
    """Indicator/linear/lut feature map as pure shift/mask/compare jnp ops
    (f32 out; no gathers — shared by the Pallas kernel and the XLA path).

    ``kind == "lut"`` evaluates a term list of the same shape as ``v_terms``
    (see ``v_map_jnp``) — used by the non-aggregated families (PKM / ETM /
    MSR) whose u-side maps are not a single indicator or bit-field."""
    import jax
    import jax.numpy as jnp

    if kind == "lut":
        return v_map_jnp(x, u_terms)
    piece = jax.lax.shift_right_logical(x.astype(jnp.int32), shift) & ((1 << bits) - 1)
    if kind == "indicator":
        return (piece == residue).astype(jnp.float32)
    return (piece << shift).astype(jnp.float32)


def v_map_jnp(x, v_terms):
    """Small-LUT sum via compares+selects (f32 out)."""
    import jax
    import jax.numpy as jnp

    xi = x.astype(jnp.int32)
    out = jnp.zeros(x.shape, jnp.float32)
    for (shift, bits, row) in v_terms:
        piece = jax.lax.shift_right_logical(xi, shift) & ((1 << bits) - 1)
        for y, coef in enumerate(row):
            if coef != 0:
                out += jnp.where(piece == y, jnp.float32(coef), 0.0)
    return out


def piece_max(piece: mul.Piece, operand_max: int) -> int:
    """Maximum value the piece can take when the operand is <= operand_max."""
    full = (1 << piece.bits) - 1
    if operand_max >= 255:
        return full
    # piece values are <= operand_max >> shift, but can reach ``full`` whenever
    # operand_max >= (full << shift); tightest simple bound:
    return min(full, operand_max >> piece.shift if operand_max < ((full << piece.shift) | ((1 << piece.shift) - 1)) else full)


@dataclasses.dataclass(frozen=True)
class Feature:
    """One separable error feature: err contribution = u_tab[a] * v_tab[b]."""

    kind: str                  # "indicator" | "linear" | "lut"
    piece: str                 # A-side piece name carrying u
    residue: int               # indicator residue (-1 for linear/lut)
    u_tab: np.ndarray          # int32[256], elementwise map of the indicator side
    v_tab: np.ndarray          # int32[256], elementwise map of the other side
    # Structured form for in-kernel computation (no 256-gathers):
    u_shift: int               # piece LSB position
    u_bits: int                # piece width
    v_terms: Tuple[Tuple[int, int, Tuple[int, ...]], ...]
    # each v term: (pb_shift, pb_bits, row) with
    #   v(b) = sum_terms row[(b >> pb_shift) & mask]
    # "lut" features carry the u side in the same term form (see u_map_jnp):
    u_terms: Tuple[Tuple[int, int, Tuple[int, ...]], ...] = ()


@dataclasses.dataclass(frozen=True)
class LowRankCorrection:
    """err(a,b) = sum_f u_f(a)*v_f(b); ``side`` says which matmul operand the
    indicator (u) features are computed from ("lhs" or "rhs")."""

    multiplier: str
    side: str
    lhs_max: int
    rhs_max: int
    features: Tuple[Feature, ...]

    @property
    def num_features(self) -> int:
        return len(self.features)

    def u_stack(self) -> np.ndarray:
        """(F, 256) int32 stack of u tables."""
        if not self.features:
            return np.zeros((0, 256), np.int32)
        return np.stack([f.u_tab for f in self.features])

    def v_stack(self) -> np.ndarray:
        if not self.features:
            return np.zeros((0, 256), np.int32)
        return np.stack([f.v_tab for f in self.features])

    def error_table(self) -> np.ndarray:
        """Reconstructed 256x256 err LUT: err[a, b] for lhs value a, rhs b."""
        a = np.arange(256)
        b = np.arange(256)
        out = np.zeros((256, 256), np.int64)
        for f in self.features:
            if self.side == "lhs":
                out += f.u_tab[a][:, None].astype(np.int64) * f.v_tab[b][None, :]
            else:
                out += f.v_tab[a][:, None].astype(np.int64) * f.u_tab[b][None, :]
        return out.astype(np.int32)


def _error_tables_for_side(
    spec: mul.AggregationSpec, side: str
) -> Dict[Tuple[str, str], np.ndarray]:
    """Piece error tables keyed (indicator_piece, other_piece), transposed so
    the indicator side is always axis 0."""
    errs = mul.piece_error_tables(spec)
    if side == "lhs":
        return dict(errs)
    return {(pb, pa): e.T for (pa, pb), e in errs.items()}


def _terms_tab(terms) -> np.ndarray:
    """Dense int64[256] table of a term-list map (numpy mirror of v_map_jnp)."""
    x = np.arange(256, dtype=np.int64)
    out = np.zeros(256, np.int64)
    for (shift, bits, row) in terms:
        out += np.asarray(row, np.int64)[(x >> shift) & ((1 << bits) - 1)]
    return out


def _linear_terms(width: int, chunk: int = 4):
    """Term list computing ``x & (2**width - 1)`` in <= ``chunk``-bit pieces
    (each term has only 2**chunk - 1 nonzero coefficients -> cheap selects)."""
    terms = []
    s = 0
    while s < width:
        w = min(chunk, width - s)
        terms.append((s, w, tuple(y << s for y in range(1 << w))))
        s += w
    return terms


def _dense_term(tab: np.ndarray):
    """A single full-width term for an arbitrary 256-entry map."""
    return (0, 8, tuple(int(v) for v in np.asarray(tab, np.int64)))


def _generic_feature_pairs(name: str):
    """Exact separable factorizations  err(a, b) = sum_f A_f(a) * B_f(b)  for
    the non-aggregated families, as (a_terms, b_terms) pairs.

    * **PKM** is rank 1: every 2x2 Kulkarni cell errs by -2 exactly on the
      (3, 3) input, so  err(a, b) = u(a) * 2*u(b)  with
      ``u(x) = sum_i 4**i * [pair_i(x) == 3]`` over the four 2-bit pairs.
    * **ETM** (split 4, Z(x) = [x < 16], al/ah = low/high nibble): seven
      rank-1 features covering the cross terms, the dropped exact-low region
      and the all-ones LSB saturation.
    * **MSR** is rank 1:  err(a, b) = a * d(b)  with ``d(b) = b - msr(b)``
      (the truncated low bits).  ``d`` splits as a linear bit-field base plus
      a sparse dense-row correction so the in-kernel map stays select-cheap.
    """
    r16 = tuple(range(16))
    if name == "pkm":
        pair3 = lambda i, c: (2 * i, 2, (0, 0, 0, c))
        return [(
            [pair3(i, 4 ** i) for i in range(4)],
            [pair3(i, 2 * 4 ** i) for i in range(4)],
        )]
    if name == "etm":
        lo_lin = [(0, 4, r16)]
        hi_lin4 = [(4, 4, tuple(y << 4 for y in r16))]
        full_lin = lo_lin + hi_lin4
        below16 = lambda c: np.array([c * (0 < y < 16) for y in range(256)])
        x_below16 = lambda c: np.array([c * y * (y < 16) for y in range(256)])
        return [
            (full_lin, lo_lin),                                   # a * bl
            (lo_lin, hi_lin4),                                    # al * (bh<<4)
            ([_dense_term(x_below16(-1))], [_dense_term(x_below16(1))]),
            ([(0, 4, (0,) + (-240,) * 15)], [(0, 0, (1,))]),      # -240[al>0]
            ([(0, 4, (-240,) + (0,) * 15)], [(0, 4, (0,) + (1,) * 15)]),
            ([_dense_term(below16(240))], [(4, 4, (1,) + (0,) * 15)]),
            ([(0, 8, (240,) + (0,) * 255)], [_dense_term(below16(1))]),
        ]
    if name in mul.MSR_SPECS:
        spec = mul.MSR_SPECS[name]
        b = np.arange(256, dtype=np.int64)
        d = b - spec.truncate(b)
        base_terms = _linear_terms(spec.shifts[-1])
        resid = d - _terms_tab(base_terms)
        b_terms = base_terms + ([_dense_term(resid)] if np.any(resid) else [])
        return [(_linear_terms(8), b_terms)]
    raise KeyError(f"no generic factorization for {name!r}")


def _build_generic_correction(
    name: str, *, side: str, lhs_max: int, rhs_max: int
) -> LowRankCorrection:
    """Feature set for a non-aggregated family, verified exact at build time
    on the restricted domain (the factorizations above are hand-derived, so
    the reconstruction assert is the safety net, not a formality)."""
    ind_max = rhs_max if side == "rhs" else lhs_max
    oth_max = lhs_max if side == "rhs" else rhs_max
    features: List[Feature] = []
    for a_terms, b_terms in _generic_feature_pairs(name):
        a_tab, b_tab = _terms_tab(a_terms), _terms_tab(b_terms)
        if side == "rhs":
            u_tab, v_tab, u_terms, v_terms = b_tab, a_tab, b_terms, a_terms
        else:
            u_tab, v_tab, u_terms, v_terms = a_tab, b_tab, a_terms, b_terms
        # Range pruning: a feature vanishing on either restricted operand
        # domain contributes nothing (MSR goes fully exact for
        # rhs_max < 2**keep_bits — the identity tap always wins).
        if not np.any(u_tab[: ind_max + 1]) or not np.any(v_tab[: oth_max + 1]):
            continue
        features.append(
            Feature(
                kind="lut",
                piece="lut",
                residue=-1,
                u_tab=u_tab.astype(np.int32),
                v_tab=v_tab.astype(np.int32),
                u_shift=0,
                u_bits=0,
                v_terms=tuple(v_terms),
                u_terms=tuple(u_terms),
            )
        )
    corr = LowRankCorrection(
        multiplier=name,
        side=side,
        lhs_max=lhs_max,
        rhs_max=rhs_max,
        features=tuple(features),
    )
    want = (
        mul.exact_table(8, 8).astype(np.int64) - mul.mul8x8_table(name)
    )[: lhs_max + 1, : rhs_max + 1]
    got = corr.error_table()[: lhs_max + 1, : rhs_max + 1]
    assert np.array_equal(got, want), (
        f"generic factorization for {name!r} is not exact on "
        f"[0,{lhs_max}]x[0,{rhs_max}]"
    )
    return corr


def build_correction(
    multiplier: str,
    *,
    side: str = "rhs",
    lhs_max: int = 255,
    rhs_max: int = 255,
) -> LowRankCorrection:
    """Build the exact feature factorization for a named multiplier.

    ``side``: which matmul operand carries the 0/1 indicator features.  Use
    "rhs" when the rhs (weights) is static so U(W) can be precomputed, or when
    the weights are range-constrained by co-optimization (fewer rows survive).
    ``lhs_max``/``rhs_max``: known value bounds (inclusive) used for pruning.
    The result is exact on the restricted domain [0, lhs_max] x [0, rhs_max].

    Aggregated designs (exact / mul8x8_*) factor through their per-piece error
    tables; PKM / ETM / MSR take the generic hand-derived factorizations in
    ``_generic_feature_pairs`` (build-time verified).
    """
    if side not in ("lhs", "rhs"):
        raise ValueError(side)
    lname = multiplier.lower()
    if lname in ("pkm", "etm") or lname in mul.MSR_SPECS:
        return _build_generic_correction(
            lname, side=side, lhs_max=lhs_max, rhs_max=rhs_max
        )
    spec = mul.aggregation_spec(multiplier)
    pieces = {p.name: p for p in spec.pieces}
    ind_max = rhs_max if side == "rhs" else lhs_max   # bound on indicator operand
    oth_max = lhs_max if side == "rhs" else rhs_max   # bound on the other operand
    errs = _error_tables_for_side(spec, side)
    removed = {
        (pa, pb) if side == "lhs" else (pb, pa): True for (pa, pb) in spec.removed
    }

    vals = np.arange(256, dtype=np.int64)
    features: List[Feature] = []

    # --- rank-1 linear features for removed exact partial products ----------
    lin_pairs = [k for k in errs if removed.get(k)]
    for pa_name in sorted({pa for pa, _ in lin_pairs}):
        pa = pieces[pa_name]
        pa_cap = piece_max(pa, ind_max)
        if pa_cap == 0:
            continue  # u identically zero on restricted domain
        v_tab = np.zeros(256, np.int64)
        v_terms: List[Tuple[int, int, Tuple[int, ...]]] = []
        for (qa, qb) in lin_pairs:
            if qa != pa_name:
                continue
            pb = pieces[qb]
            if piece_max(pb, oth_max) == 0:
                continue  # v contribution identically zero
            v_tab += pb.extract(vals) << pb.shift
            row = tuple(int(y) << pb.shift for y in range(1 << pb.bits))
            v_terms.append((pb.shift, pb.bits, row))
        if not v_terms:
            continue
        u_tab = (pa.extract(vals) << pa.shift).astype(np.int32)
        features.append(
            Feature(
                kind="linear",
                piece=pa_name,
                residue=-1,
                u_tab=u_tab,
                v_tab=v_tab.astype(np.int32),
                u_shift=pa.shift,
                u_bits=pa.bits,
                v_terms=tuple(v_terms),
            )
        )

    # --- indicator features for approximate (LUT-error) partial products ----
    lut_pairs = [k for k in errs if not removed.get(k)]
    by_pa: Dict[str, List[Tuple[str, np.ndarray]]] = {}
    for (pa_name, pb_name) in lut_pairs:
        by_pa.setdefault(pa_name, []).append((pb_name, errs[(pa_name, pb_name)]))
    for pa_name in sorted(by_pa):
        pa = pieces[pa_name]
        pa_cap = piece_max(pa, ind_max)
        for x in range(1 << pa.bits):
            if x > pa_cap:
                continue
            v_tab = np.zeros(256, np.int64)
            v_terms = []
            for pb_name, e in by_pa[pa_name]:
                pb = pieces[pb_name]
                row = e[x].astype(np.int64) << (pa.shift + pb.shift)
                pb_cap = piece_max(pb, oth_max)
                if not np.any(row[: pb_cap + 1]):
                    continue
                v_tab += row[pb.extract(vals)]
                v_terms.append((pb.shift, pb.bits, tuple(int(r) for r in row)))
            if not v_terms:
                continue
            u_tab = (pa.extract(vals) == x).astype(np.int32)
            features.append(
                Feature(
                    kind="indicator",
                    piece=pa_name,
                    residue=x,
                    u_tab=u_tab,
                    v_tab=v_tab.astype(np.int32),
                    u_shift=pa.shift,
                    u_bits=pa.bits,
                    v_terms=tuple(v_terms),
                )
            )

    return LowRankCorrection(
        multiplier=multiplier,
        side=side,
        lhs_max=lhs_max,
        rhs_max=rhs_max,
        features=tuple(features),
    )
