"""Hardware cost model (paper Tables VI / VII + system-level roll-ups).

The container has no EDA tools, so the ASAP7 Synopsys-DC numbers from the
paper are carried as data and complemented by a technology-independent
unit-gate model estimated from the multipliers' logic structure — the model
reproduces the paper's *trend* (MUL3x3_1 < MUL3x3_2 < exact; MUL8x8_3 <
MUL8x8_1 < MUL8x8_2 < exact) and lets us roll up accelerator-level savings
(e.g. a 128x128 MAC systolic array) for the DNN platform report.
"""
from __future__ import annotations

import dataclasses
import math
from typing import Dict

import numpy as np

from repro.core import multipliers as mul

__all__ = [
    "SynthesisResult",
    "PAPER_TABLE_VI",
    "PAPER_TABLE_VII",
    "COST_TABLE",
    "mac_cost",
    "unit_gate_estimate",
    "systolic_array_cost",
]


@dataclasses.dataclass(frozen=True)
class SynthesisResult:
    area_um2: float
    power_mw: float
    delay_ns: float

    def improvement_over(self, base: "SynthesisResult") -> Dict[str, float]:
        return {
            "area_pct": 100 * (1 - self.area_um2 / base.area_um2),
            "power_pct": 100 * (1 - self.power_mw / base.power_mw),
            "delay_pct": 100 * (1 - self.delay_ns / base.delay_ns),
        }


#: Paper Table VI (3x3 multipliers, ASAP7, Synopsys DC).
PAPER_TABLE_VI: Dict[str, SynthesisResult] = {
    "exact3x3": SynthesisResult(67.68, 3.73, 0.45),
    "mul3x3_1": SynthesisResult(43.20, 2.40, 0.26),
    "mul3x3_2": SynthesisResult(46.44, 2.36, 0.26),
}

#: Paper Table VII (8x8 multipliers).
PAPER_TABLE_VII: Dict[str, SynthesisResult] = {
    "exact8x8": SynthesisResult(744.59, 58.12, 1.58),
    "mul8x8_1": SynthesisResult(596.16, 45.66, 1.29),
    "mul8x8_2": SynthesisResult(646.92, 50.84, 1.41),
    "mul8x8_3": SynthesisResult(571.32, 42.28, 1.29),
    "siei": SynthesisResult(579.51, 39.57, 1.37),
    "pkm": SynthesisResult(564.76, 37.87, 1.28),
}


def _truth_table_literal_cost(table: np.ndarray) -> float:
    """Crude unit-gate complexity proxy: per output bit, an espresso-free
    estimate of minterm structure — number of (input, output-bit) transitions
    in the Karnaugh-adjacent walk of the truth table. Deterministic, cheap,
    and monotone with the actual DC area across the paper's designs."""
    na, nb = table.shape
    bits = int(np.ceil(np.log2(table.max() + 1))) if table.max() > 0 else 1
    cost = 0.0
    for o in range(bits):
        plane = (table >> o) & 1
        # transition count along gray-adjacent rows/cols ~ literal count
        cost += np.abs(np.diff(plane, axis=0)).sum()
        cost += np.abs(np.diff(plane, axis=1)).sum()
        cost += 0.25 * plane.sum()               # implicant body cost
    return float(cost)


def unit_gate_estimate(name: str) -> Dict[str, float]:
    """Relative area/power estimate normalized so exact == 1.0.

    3x3 designs: literal-cost proxy of the (K-map-simplified) truth table.
    Aggregated 8x8 designs: COMPOSITIONAL — the aggregation is eight 3x3
    multipliers + one exact 2x2 + a Wallace adder tree (a fixed share), so
    the estimate is the piece-cost roll-up; MUL8x8_3 drops one 3x3 instance
    + its shifter.  Non-aggregated 8x8 designs (PKM, ETM, the MSR
    fixed-shift family) have no 3x3 piece structure, so their estimate is
    the literal-cost ratio of the full 8x8 truth table against the exact
    one — the same proxy, applied whole.
    """
    if name in ("pkm", "etm") or name in mul.MSR_SPECS:
        c8 = _truth_table_literal_cost(mul.exact_table(8, 8))
        r = _truth_table_literal_cost(mul.mul8x8_table(name)) / c8
        return {"relative_area": r, "relative_power": r}
    c3_exact = _truth_table_literal_cost(mul.exact_table(3, 3))
    if name in ("mul3x3_1", "mul3x3_2", "exact3x3"):
        t = {
            "exact3x3": mul.exact_table(3, 3),
            "mul3x3_1": mul.mul3x3_1_table(),
            "mul3x3_2": mul.mul3x3_2_table(),
        }[name]
        r = _truth_table_literal_cost(t) / c3_exact
        return {"relative_area": r, "relative_power": r}
    c2 = _truth_table_literal_cost(mul.exact_table(2, 2))
    adders = 4.0 * c3_exact            # adder-tree share (fixed across designs)
    piece = {
        "exact8x8": (8, c3_exact),
        "mul8x8_1": (8, _truth_table_literal_cost(mul.mul3x3_1_table())),
        "mul8x8_2": (8, _truth_table_literal_cost(mul.mul3x3_2_table())),
        "mul8x8_3": (7, _truth_table_literal_cost(mul.mul3x3_2_table())),
    }[name if name != "exact" else "exact8x8"]
    n, c3 = piece
    cost = n * c3 + c2 + adders * (n / 8.0 if n < 8 else 1.0)
    base = 8 * c3_exact + c2 + adders
    return {"relative_area": cost / base, "relative_power": cost / base}


# Partial-product row counts for the delay model below: the MSR fixed-shift
# truncation leaves at most keep_bits significant operand bits (the shift is
# a static mux, not a runtime leading-one detector), so its add tree has
# keep_bits rows; ETM's lower-half truncation halves the effective rows;
# the paper designs keep the full 8-row array.
_PP_ROWS: Dict[str, int] = {"etm": 4}
_PP_ROWS.update({n: s.keep_bits for n, s in mul.MSR_SPECS.items()})


def _estimated_row(name: str) -> SynthesisResult:
    """Synthesized-cost ESTIMATE for a design the paper did not take through
    Synopsys DC (no EDA tools in this container): area/power scale the paper
    exact8x8 anchor by the unit-gate literal-cost ratio, and delay scales the
    anchor by relative add-tree depth (log2 of partial-product rows, plus a
    fixed wire/CPA share).  Estimates, not silicon numbers — tests pin only
    completeness and the orderings the model guarantees."""
    base = PAPER_TABLE_VII["exact8x8"]
    r = unit_gate_estimate(name)["relative_area"]
    depth = (math.log2(_PP_ROWS[name]) + 2.0) / (math.log2(8) + 2.0)
    return SynthesisResult(
        area_um2=round(base.area_um2 * r, 2),
        power_mw=round(base.power_mw * r, 2),
        delay_ns=round(base.delay_ns * depth, 2),
    )


#: Canonical per-MAC cost row for EVERY name in ``multipliers.MULTIPLIERS``:
#: paper Table VII rows where the paper synthesized the design, unit-gate
#: estimates (``_estimated_row``) for ETM and the MSR family.  This is the
#: table serve-time quality tiers and the tier bench read their modeled
#: hardware throughput from.
COST_TABLE: Dict[str, SynthesisResult] = {
    "exact": PAPER_TABLE_VII["exact8x8"],
    "mul8x8_1": PAPER_TABLE_VII["mul8x8_1"],
    "mul8x8_2": PAPER_TABLE_VII["mul8x8_2"],
    "mul8x8_3": PAPER_TABLE_VII["mul8x8_3"],
    "pkm": PAPER_TABLE_VII["pkm"],
    "etm": _estimated_row("etm"),
    "mul8x8_msr2": _estimated_row("mul8x8_msr2"),
    "mul8x8_msr4": _estimated_row("mul8x8_msr4"),
    "mul8x8_msr6": _estimated_row("mul8x8_msr6"),
}


def mac_cost(multiplier: str) -> SynthesisResult:
    """Per-MAC multiplier cost for any registered name (``"exact8x8"``
    normalizes to the ``"exact"`` registry name)."""
    return COST_TABLE[multiplier if multiplier != "exact8x8" else "exact"]


def systolic_array_cost(
    multiplier: str, *, rows: int = 128, cols: int = 128
) -> Dict[str, float]:
    """Accelerator-level roll-up: a rows x cols MAC array where each MAC's
    multiplier is replaced by the approximate design (``COST_TABLE`` rows —
    paper Table VII where available, unit-gate estimates otherwise);
    adders/accumulators assumed unchanged (~35% of MAC area, a standard
    split for 8-bit MACs)."""
    mult = mac_cost(multiplier)
    base = PAPER_TABLE_VII["exact8x8"]
    adder_area = 0.35 * base.area_um2 / 0.65     # fixed non-multiplier share
    n = rows * cols
    area = n * (mult.area_um2 + adder_area)
    area_base = n * (base.area_um2 + adder_area)
    power = n * mult.power_mw
    power_base = n * base.power_mw
    return {
        "macs": n,
        "area_mm2": area / 1e6,
        "area_saving_pct": 100 * (1 - area / area_base),
        "power_w": power / 1e3,
        "power_saving_pct": 100 * (1 - power / power_base),
        "critical_path_ns": mult.delay_ns,
        "delay_saving_pct": 100 * (1 - mult.delay_ns / base.delay_ns),
    }
