"""Approximate multiplier library (Lu et al., ISCAS 2022).

This module is the bit-exact functional model of the paper's circuits:

* Two approximate 3x3 multipliers, ``MUL3x3_1`` and ``MUL3x3_2``, defined by
  K-map modifications of the exact 3x3 truth table (paper Tables II / III).
* An 8x8 aggregation scheme (paper Fig. 1): each 8-bit operand is split into
  3+3+2-bit pieces ``lo = x[2:0]``, ``mid = x[5:3]``, ``hi = x[7:6]``; the nine
  partial products are produced by eight 3x3 multipliers (2-bit pieces are
  zero-extended) and one exact 2x2 multiplier for ``hi*hi``.
* Three 8x8 approximate multipliers (paper Table IV):
    - MUL8x8_1: all 3x3 pieces use MUL3x3_1, hi*hi exact 2x2.
    - MUL8x8_2: all 3x3 pieces use MUL3x3_2, hi*hi exact 2x2.
    - MUL8x8_3: MUL8x8_2 with the partial product M2 and its shifter removed.
      With row-major indexing M_{3i+j} over (lo, mid, hi) pieces, M2 =
      A[2:0] * B[7:6] (involves B[7:6]) and M6 = A[7:6] * B[2:0] (involves
      A[7:6]) -- exactly the paper's "A[7:6] or B[7:6] is 00, so that we can
      remove M2 or M6".  Weights (retrained into (0,31)) sit on the RHS here,
      so MUL8x8_3 removes M2 = A_lo x B_hi.

Fidelity note (see DESIGN.md): the paper's own 3x3 metrics (ER 9.375%, MED
1.125 / 0.5) are reproduced exactly by this module.  The 8x8 rows of paper
Table V are *not* reachable from the described disjoint 3+3+2 aggregation --
with sign-consistent piece errors MED(MUL8x8_1) = 1.125 * sum(2^shifts) <=
91.125 < the printed 137.04 -- while our exhaustive PKM/ETM baselines do land
close to the paper's printed values.  We therefore report exhaustive-domain
metrics of the architecture-faithful aggregation (which are strictly better
than Table V's printed values).
* Literature baselines used in the paper's comparison: PKM (Kulkarni 2x2
  underdesigned multiplier aggregated to 8x8) and ETM (error-tolerant
  multiplier, Kyaw et al.).

Everything is expressed as dense lookup tables (LUTs) over the full input
domain, so downstream layers (quantized matmul simulation, Pallas kernels,
low-rank MXU decomposition) can consume exact semantics.
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Dict, Mapping, Sequence, Tuple

import numpy as np

__all__ = [
    "MUL3X3_1_OVERRIDES",
    "MUL3X3_2_OVERRIDES",
    "exact_table",
    "table_from_overrides",
    "mul3x3_1_table",
    "mul3x3_2_table",
    "Piece",
    "PIECES_332",
    "AggregationSpec",
    "aggregate_8x8",
    "piece_error_tables",
    "mul8x8_table",
    "pkm_2x2_table",
    "pkm_8x8_table",
    "etm_8x8_table",
    "MSRSpec",
    "MSR_SPECS",
    "msr_8x8_table",
    "MULTIPLIERS",
    "get_multiplier",
]

# ---------------------------------------------------------------------------
# 3x3 approximate multipliers (paper Section II.A)
# ---------------------------------------------------------------------------

#: Paper Table II: the six truth-table rows of the exact 3x3 multiplier whose
#: product exceeds 31 are rewritten so that O5 = 0 (output width shrinks to 5).
MUL3X3_1_OVERRIDES: Dict[Tuple[int, int], int] = {
    (5, 7): 27,
    (6, 6): 24,
    (6, 7): 30,
    (7, 5): 27,
    (7, 6): 30,
    (7, 7): 29,
}

#: Paper Table III: MUL3x3_2 adds a prediction unit.  For the four rows with
#: a2*a1*b2*b1 == 1 it forces O5=1, O4=0 on top of the MUL3x3_1 encoding,
#: halving the MED (1.125 -> 0.5).  Note: Table III's printed Value' of 38 for
#: (7,6) is inconsistent with its own O-bits (101110 = 46); the bit pattern
#: (and the claimed MED of 0.5) is authoritative, giving 46.
MUL3X3_2_OVERRIDES: Dict[Tuple[int, int], int] = {
    (5, 7): 27,
    (7, 5): 27,
    (6, 6): 40,   # 24 + 32 (O5=1, O4=0)
    (6, 7): 46,   # 30 + 32 - 16
    (7, 6): 46,
    (7, 7): 45,   # 29 + 32 - 16
}


def exact_table(bits_a: int, bits_b: int) -> np.ndarray:
    """Dense exact product LUT of shape (2**bits_a, 2**bits_b), int32."""
    a = np.arange(2 ** bits_a, dtype=np.int64)
    b = np.arange(2 ** bits_b, dtype=np.int64)
    return (a[:, None] * b[None, :]).astype(np.int32)


def table_from_overrides(
    bits: int, overrides: Mapping[Tuple[int, int], int]
) -> np.ndarray:
    """Exact ``bits x bits`` LUT with the given truth-table rows replaced."""
    t = exact_table(bits, bits)
    for (x, y), v in overrides.items():
        t[x, y] = v
    return t


@functools.lru_cache(maxsize=None)
def mul3x3_1_table() -> np.ndarray:
    return table_from_overrides(3, MUL3X3_1_OVERRIDES)


@functools.lru_cache(maxsize=None)
def mul3x3_2_table() -> np.ndarray:
    return table_from_overrides(3, MUL3X3_2_OVERRIDES)


# ---------------------------------------------------------------------------
# 8x8 aggregation (paper Section II.B, Fig. 1)
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class Piece:
    """A bit-field slice of an 8-bit operand."""

    name: str
    shift: int   # LSB position
    bits: int    # field width

    def extract(self, x: np.ndarray) -> np.ndarray:
        return (x >> self.shift) & ((1 << self.bits) - 1)


#: The paper's 3+3+2 split.
PIECES_332: Tuple[Piece, ...] = (
    Piece("lo", 0, 3),
    Piece("mid", 3, 3),
    Piece("hi", 6, 2),
)


@dataclasses.dataclass(frozen=True)
class AggregationSpec:
    """Which low-bit-width multiplier serves each partial product.

    ``removed`` lists (a_piece_name, b_piece_name) partial products that are
    physically removed from the array (paper's MUL8x8_3: M2 + shifter gone).
    """

    name: str
    mul3x3: str                    # "mul3x3_1" | "mul3x3_2" | "exact"
    removed: Tuple[Tuple[str, str], ...] = ()
    pieces: Tuple[Piece, ...] = PIECES_332

    def table3(self) -> np.ndarray:
        if self.mul3x3 == "mul3x3_1":
            return mul3x3_1_table()
        if self.mul3x3 == "mul3x3_2":
            return mul3x3_2_table()
        if self.mul3x3 == "exact":
            return exact_table(3, 3)
        raise ValueError(self.mul3x3)


def aggregate_8x8(spec: AggregationSpec) -> np.ndarray:
    """Build the dense 256x256 LUT of the aggregated 8x8 multiplier.

    The nine piece-products: both-3-bit pieces and mixed 3/2-bit pieces go
    through the (possibly approximate) 3x3 LUT with the 2-bit piece
    zero-extended (values <= 3 never trigger the K-map error cases, so mixed
    products are exact regardless); hi*hi goes through an exact 2x2 multiplier.
    """
    t3 = spec.table3()
    t2 = exact_table(2, 2)
    A = np.arange(256, dtype=np.int64)
    B = np.arange(256, dtype=np.int64)
    out = np.zeros((256, 256), dtype=np.int64)
    for pa in spec.pieces:
        xa = pa.extract(A)
        for pb in spec.pieces:
            if (pa.name, pb.name) in spec.removed:
                continue
            xb = pb.extract(B)
            if pa.bits == 2 and pb.bits == 2:
                pp = t2[xa[:, None], xb[None, :]].astype(np.int64)
            else:
                pp = t3[xa[:, None], xb[None, :]].astype(np.int64)
            out += pp << (pa.shift + pb.shift)
    return out.astype(np.int32)


def piece_error_tables(spec: AggregationSpec) -> Dict[Tuple[str, str], np.ndarray]:
    """Per-piece-pair error LUTs: err[x, y] = exact(x*y) - approx_piece(x, y).

    For a removed partial product the error is the full exact piece product.
    Shapes are (2**bits_a, 2**bits_b).  The total multiplier error decomposes
    exactly as  err8x8(A, B) = sum_{pa,pb} err[pa,pb][a_pa, b_pb] << (sa+sb),
    which is the basis of the low-rank MXU correction (core/lowrank.py).
    """
    t3 = spec.table3()
    t2 = exact_table(2, 2)
    errs: Dict[Tuple[str, str], np.ndarray] = {}
    for pa in spec.pieces:
        for pb in spec.pieces:
            na, nb = 2 ** pa.bits, 2 ** pb.bits
            exact = exact_table(pa.bits, pb.bits).astype(np.int64)
            if (pa.name, pb.name) in spec.removed:
                err = exact
            elif pa.bits == 2 and pb.bits == 2:
                err = exact - t2[:na, :nb]
            else:
                err = exact - t3[:na, :nb].astype(np.int64)
            if np.any(err):
                errs[(pa.name, pb.name)] = err.astype(np.int32)
    return errs


# ---------------------------------------------------------------------------
# Named designs
# ---------------------------------------------------------------------------

SPEC_EXACT = AggregationSpec("exact8x8", "exact")
SPEC_MUL8X8_1 = AggregationSpec("mul8x8_1", "mul3x3_1")
SPEC_MUL8X8_2 = AggregationSpec("mul8x8_2", "mul3x3_2")
#: M2 = the A[2:0] x B[7:6] partial product (see module docstring / DESIGN.md).
SPEC_MUL8X8_3 = AggregationSpec("mul8x8_3", "mul3x3_2", removed=(("lo", "hi"),))


# ---------------------------------------------------------------------------
# Literature baselines reproduced for the paper's comparison tables
# ---------------------------------------------------------------------------


@functools.lru_cache(maxsize=None)
def pkm_2x2_table() -> np.ndarray:
    """Kulkarni et al. underdesigned 2x2 multiplier: 3*3 -> 7 (0b111)."""
    t = exact_table(2, 2)
    t[3, 3] = 7
    return t


def _aggregate_from_2x2(t2: np.ndarray) -> np.ndarray:
    """Recursive 2x2 -> 4x4 -> 8x8 aggregation used by PKM."""

    def up(t: np.ndarray, bits: int) -> np.ndarray:
        n = 2 ** bits
        half = bits // 2
        mask = (1 << half) - 1
        x = np.arange(n, dtype=np.int64)
        lo, hi = x & mask, x >> half
        tl = t.astype(np.int64)
        return (
            tl[lo[:, None], lo[None, :]]
            + (tl[hi[:, None], lo[None, :]] << half)
            + (tl[lo[:, None], hi[None, :]] << half)
            + (tl[hi[:, None], hi[None, :]] << (2 * half))
        )

    t4 = up(t2, 4)
    t8 = up(t4, 8)
    return t8.astype(np.int32)


@functools.lru_cache(maxsize=None)
def pkm_8x8_table() -> np.ndarray:
    return _aggregate_from_2x2(pkm_2x2_table())


@functools.lru_cache(maxsize=None)
def etm_8x8_table(split: int = 4) -> np.ndarray:
    """Error-tolerant multiplier (Kyaw et al.): exact multiplication on the
    MSB halves when either MSB half is non-zero, otherwise a non-multiplication
    LSB approximation.  This is the standard ETM model used in comparison
    surveys: if A[7:4] == 0 and B[7:4] == 0 -> exact LSB product; else
    multiply MSB halves exactly, and saturate every LSB product bit to 1.
    """
    A = np.arange(256, dtype=np.int64)
    a_hi, a_lo = A >> split, A & ((1 << split) - 1)
    out = np.zeros((256, 256), dtype=np.int64)
    lsb_ones = (1 << split) - 1  # all-ones LSB approximation
    for i in range(256):
        ah, al = int(a_hi[i]), int(a_lo[i])
        bh, bl = A >> split, A & ((1 << split) - 1)
        msb_zero = (ah == 0) & (bh == 0)
        exact_lo = al * bl
        approx = (ah * bh) << (2 * split)
        approx = approx | ((lsb_ones << split) * ((al > 0) | (bl > 0)))
        out[i] = np.where(msb_zero, exact_lo, approx)
    return out.astype(np.int32)


# ---------------------------------------------------------------------------
# MSR fixed-shift truncation family (ROADMAP: Most-Significant-Run)
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class MSRSpec:
    """Most-Significant-Run fixed-shift truncation of the weight operand.

    DRUM-style designs keep a ``keep_bits``-wide window below the leading
    one, which needs a runtime leading-one detector and a barrel shifter.
    The MSR observation: in a two's-complement weight the run of identical
    sign bits below the MSB carries one bit of information, so the window
    start can be quantized to a SMALL FIXED set of shifts ``shifts`` —
    each shift is a hard-wired tap, selected by a priority encoder over
    ``len(shifts)`` range comparators instead of a full LOD + barrel
    shifter.  For an (unsigned, post-affine-quant) operand ``b`` the
    selected shift is the least ``s`` with ``b < 2**(keep_bits + s)`` and
    the low ``s`` bits are truncated::

        msr(b) = b & ~((1 << s) - 1)

    ``keep_bits + max(shifts)`` must cover the full operand width so every
    value selects a tap.  The multiplier then computes ``a * msr(b)``: a
    ``keep_bits``-wide multiplier plus the fixed shift network, in place
    of a full-width array.
    """

    keep_bits: int
    shifts: Tuple[int, ...]

    def __post_init__(self) -> None:
        if tuple(sorted(self.shifts)) != self.shifts or 0 not in self.shifts:
            raise ValueError("shifts must be ascending and include 0")
        if self.keep_bits + self.shifts[-1] < 8:
            raise ValueError("keep_bits + max shift must cover 8 bits")

    def shift_of(self, b: np.ndarray) -> np.ndarray:
        """Per-value selected shift: least s with b < 2**(keep_bits+s)."""
        b = np.asarray(b, dtype=np.int64)
        s = np.full(b.shape, self.shifts[-1], dtype=np.int64)
        for cand in reversed(self.shifts):
            s = np.where(b < (1 << (self.keep_bits + cand)), cand, s)
        return s

    def truncate(self, b: np.ndarray) -> np.ndarray:
        """msr(b): b with the selected shift's low bits cleared."""
        b = np.asarray(b, dtype=np.int64)
        return b & ~((1 << self.shift_of(b)) - 1)


#: The registered rungs.  msr4 is the serving-tier default: one comparator
#: (b < 16) picks between the identity tap and a single 4-bit truncation.
MSR_SPECS: Dict[str, MSRSpec] = {
    "mul8x8_msr2": MSRSpec(keep_bits=2, shifts=(0, 2, 4, 6)),
    "mul8x8_msr4": MSRSpec(keep_bits=4, shifts=(0, 4)),
    "mul8x8_msr6": MSRSpec(keep_bits=6, shifts=(0, 2)),
}


@functools.lru_cache(maxsize=None)
def msr_8x8_table(name: str) -> np.ndarray:
    """Dense 256x256 LUT of ``a * msr(b)`` for a registered MSR rung.

    Truncation is applied to the RHS operand only — weights sit on the RHS
    throughout this repo (see MUL8x8_3's M2-removal rationale above).
    """
    spec = MSR_SPECS[name.lower()]
    a = np.arange(256, dtype=np.int64)
    return (a[:, None] * spec.truncate(np.arange(256))[None, :]).astype(np.int32)


# ---------------------------------------------------------------------------
# Registry
# ---------------------------------------------------------------------------


@functools.lru_cache(maxsize=None)
def mul8x8_table(name: str) -> np.ndarray:
    """256x256 int32 LUT for a named 8x8 multiplier."""
    name = name.lower()
    if name in ("exact", "exact8x8"):
        return exact_table(8, 8)
    if name == "mul8x8_1":
        return aggregate_8x8(SPEC_MUL8X8_1)
    if name == "mul8x8_2":
        return aggregate_8x8(SPEC_MUL8X8_2)
    if name == "mul8x8_3":
        return aggregate_8x8(SPEC_MUL8X8_3)
    if name == "pkm":
        return pkm_8x8_table()
    if name == "etm":
        return etm_8x8_table()
    if name in MSR_SPECS:
        return msr_8x8_table(name)
    raise KeyError(f"unknown multiplier {name!r}")


MULTIPLIERS: Tuple[str, ...] = (
    "exact",
    "mul8x8_1",
    "mul8x8_2",
    "mul8x8_3",
    "pkm",
    "etm",
    "mul8x8_msr2",
    "mul8x8_msr4",
    "mul8x8_msr6",
)


def get_multiplier(name: str) -> np.ndarray:
    return mul8x8_table(name)


def aggregation_spec(name: str) -> AggregationSpec:
    name = name.lower()
    return {
        "exact": SPEC_EXACT,
        "exact8x8": SPEC_EXACT,
        "mul8x8_1": SPEC_MUL8X8_1,
        "mul8x8_2": SPEC_MUL8X8_2,
        "mul8x8_3": SPEC_MUL8X8_3,
    }[name]
