"""Grouped-query attention: chunked-causal for train/prefill (memory-bounded,
exact softmax), plus single-token decode against a static KV cache.

K/V are never head-repeated: scores are computed with grouped einsums
(q reshaped to (B, S, Hkv, group, hd)), so KV-cache HBM footprint stays at
``n_kv`` heads — this is what makes decode_32k x batch 128 fit.

All projections route through ``layers.dense`` (approximate-multiplier aware).
The score/AV einsums stay exact float — the paper approximates the MAC arrays
of conv/fc layers, and projection matmuls are the analogous LM hot spots;
see DESIGN.md §5.
"""
from __future__ import annotations

from typing import NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.core.approx import ApproxConfig, concat_weights, w_dim
from repro.models import layers as L

__all__ = [
    "AttnParams",
    "ATTN_IMPLS",
    "init_attn",
    "attention_core",
    "self_attention",
    "decode_attention",
    "paged_decode_attention",
    "paged_verify_attention",
    "paged_chunk_prefill_attention",
    "seed_kv_cache",
]

_NEG = -1e30

# paged decode-attention implementations: the XLA clamp-gather-mask path
# (the exact parity oracle) and the Pallas in-place block-pool kernel
# (kernels/paged_attention; interpret mode off-TPU)
ATTN_IMPLS = ("gather", "pallas")


class AttnParams(NamedTuple):
    wq: jax.Array   # (d, Hq*hd)
    wk: jax.Array   # (d, Hkv*hd)
    wv: jax.Array   # (d, Hkv*hd)
    wo: jax.Array   # (Hq*hd, d)


def init_attn(key, d_model: int, n_heads: int, n_kv: int, head_dim: int) -> AttnParams:
    k1, k2, k3, k4 = jax.random.split(key, 4)
    return AttnParams(
        wq=L.init_dense(k1, d_model, n_heads * head_dim),
        wk=L.init_dense(k2, d_model, n_kv * head_dim),
        wv=L.init_dense(k3, d_model, n_kv * head_dim),
        wo=L.init_dense(k4, n_heads * head_dim, d_model),
    )


def attention_core(
    q: jax.Array,            # (B, Sq, H, hd)
    k: jax.Array,            # (B, Sk, Hkv, hd)
    v: jax.Array,            # (B, Sk, Hkv, hd)
    *,
    causal: bool,
    q_offset: int | jax.Array = 0,
    kv_len: Optional[jax.Array] = None,   # (B,) valid cache lengths for decode
    q_chunk: int = 512,
) -> jax.Array:
    """Exact softmax GQA, scanned over query chunks (O(Sq*chunk*Sk) transient).

    Sharding strategy (TP): when the flat head count divides the "model"
    axis, heads are repeated and head-sharded (scores (B,H,c,Sk)/tp per
    device); otherwise K/V are sequence-sharded over "model" (SP) and GSPMD
    inserts the softmax all-reduce.
    """
    from repro.parallel.sharding import constrain, mesh_axis_size

    B, Sq, H, hd = q.shape
    Sk, Hkv = k.shape[1], k.shape[2]
    g = H // Hkv
    tp = mesh_axis_size("model")
    head_sharded = H % tp == 0
    scale = 1.0 / jnp.sqrt(jnp.float32(hd))

    H_orig = H
    if Sq > 1 and g > 1:
        # train/prefill: repeat KV to full heads (cheap vs activations) so one
        # einsum over the flat, shardable head axis does the work
        b_, s_, h_, d_ = k.shape
        k = jnp.broadcast_to(k[:, :, :, None, :], (b_, s_, h_, g, d_)).reshape(b_, s_, H, d_)
        v = jnp.broadcast_to(v[:, :, :, None, :], (b_, s_, h_, g, d_)).reshape(b_, s_, H, d_)
        Hkv_eff = H
    else:
        Hkv_eff = Hkv

    if Sq > 1 and not head_sharded and tp > 1 and Hkv_eff == H:
        # Indivisible head counts (e.g. 56 heads on a 16-way model axis) make
        # GSPMD flip between partial-head and sequence shardings with
        # "involuntary full rematerialization" copies. Pad the head axis to
        # the next multiple of tp (zero heads are pure overhead of H_pad/H-1,
        # far cheaper than replicated score tensors) and slice afterwards.
        H = -(-H // tp) * tp
        pad = [(0, 0), (0, 0), (0, H - H_orig), (0, 0)]
        q = jnp.pad(q, pad)
        k = jnp.pad(k, pad)
        v = jnp.pad(v, pad)
        Hkv_eff = H
        head_sharded = True

    if Sq == 1 and Hkv_eff % tp != 0:
        # decode against a grouped cache whose KV heads don't divide the TP
        # axis: head-sharding q would make GSPMD all-gather the whole KV
        # cache per layer (~1 GB/layer at 32k ctx). Keep the cache
        # sequence-sharded and let the scores/AV contraction stay on S with
        # a tiny (B,H,1) softmax all-reduce instead.  [§Perf C4]
        head_sharded = False
        q = constrain(q, ("batch", None, None, None))
        k = constrain(k, ("batch", "model", None, None))
        v = constrain(v, ("batch", "model", None, None))
    elif head_sharded:
        q = constrain(q, ("batch", None, "model", None))
        if Hkv_eff % tp == 0:
            k = constrain(k, ("batch", None, "model", None))
            v = constrain(v, ("batch", None, "model", None))
    else:
        # SP fallback: shard the KV sequence axis
        k = constrain(k, ("batch", "model", None, None))
        v = constrain(v, ("batch", "model", None, None))

    ge = H // Hkv_eff
    kt = k.swapaxes(1, 2)                        # (B, Hkv_eff, Sk, hd) bf16
    vt = v.swapaxes(1, 2)
    kv_pos = jnp.arange(Sk)

    def one_chunk(q_blk: jax.Array, blk_start) -> jax.Array:
        c = q_blk.shape[1]
        qt = (q_blk * scale.astype(q.dtype)).reshape(B, c, Hkv_eff, ge, hd)
        # (B, Hkv_eff, g, c, Sk): bf16 operands, f32 accumulation
        scores = jnp.einsum(
            "bchgd,bhkd->bhgck", qt, kt, preferred_element_type=jnp.float32
        )
        # masks are ADDITIVE on small pre-broadcast shapes: jnp.where on the
        # full score tensor would pin a full-size pred residual for backward
        if causal:
            q_pos = blk_start + q_offset + jnp.arange(c)
            neg = jnp.where(q_pos[:, None] >= kv_pos, 0.0, _NEG)     # (c, Sk)
            scores = scores + neg[None, None, None, :, :]
        if kv_len is not None:
            neg = jnp.where(kv_pos[None, :] < kv_len[:, None], 0.0, _NEG)  # (B, Sk)
            scores = scores + neg[:, None, None, None, :]
        probs = jax.nn.softmax(scores, axis=-1).astype(vt.dtype)
        out = jnp.einsum(
            "bhgck,bhkd->bchgd", probs, vt, preferred_element_type=jnp.float32
        )
        return out.reshape(B, c, H, hd).astype(q.dtype)

    def unpad(o):
        return o[:, :, :H_orig] if H != H_orig else o

    if Sq <= q_chunk or Sq % q_chunk != 0:
        return unpad(one_chunk(q, 0))

    n_blk = Sq // q_chunk
    qb = q.reshape(B, n_blk, q_chunk, H, hd).swapaxes(0, 1)  # (n, B, c, H, hd)

    def body(start, q_blk):
        return start + q_chunk, one_chunk(q_blk, start)

    _, ob = jax.lax.scan(body, 0, qb)
    return unpad(ob.swapaxes(0, 1).reshape(B, Sq, H, hd))


def self_attention(
    x: jax.Array,                 # (B, S, d)
    p: AttnParams,
    *,
    n_heads: int,
    n_kv: int,
    cfg: ApproxConfig,
    positions: Optional[jax.Array] = None,        # (B, S) rope positions
    m_rope: Optional[Tuple[jax.Array, Tuple[int, ...]]] = None,
    rope_theta: float = 10000.0,
    use_rope: bool = True,
    q_chunk: int = 512,
    fuse_qkv: bool = False,
) -> Tuple[jax.Array, Tuple[jax.Array, jax.Array]]:
    """Training/prefill self-attention. Returns (out, (k, v)) so callers can
    seed a decode cache from prefill."""
    B, S, d = x.shape
    hd = w_dim(p.wq, 1) // n_heads
    if fuse_qkv:
        # §Perf lever: one activation-quantization + one feature-map pass
        # feeding a single wide dot (per-output-channel weight scales make
        # the fused quantization bit-identical to the separate one)
        wqkv = concat_weights([p.wq, p.wk, p.wv], axis=1)
        qkv = L.dense(x, wqkv, cfg)
        nq = n_heads * hd
        nk = n_kv * hd
        q, k, v = qkv[..., :nq], qkv[..., nq : nq + nk], qkv[..., nq + nk :]
        q = q.reshape(B, S, n_heads, hd)
        k = k.reshape(B, S, n_kv, hd)
        v = v.reshape(B, S, n_kv, hd)
    else:
        q = L.dense(x, p.wq, cfg).reshape(B, S, n_heads, hd)
        k = L.dense(x, p.wk, cfg).reshape(B, S, n_kv, hd)
        v = L.dense(x, p.wv, cfg).reshape(B, S, n_kv, hd)
    if use_rope:
        if m_rope is not None:
            pos_thw, sections = m_rope
            q, k = L.apply_m_rope(q, k, pos_thw, sections, theta=rope_theta)
        else:
            if positions is None:
                positions = jnp.broadcast_to(jnp.arange(S)[None, :], (B, S))
            q, k = L.apply_rope(q, k, positions, theta=rope_theta)
    out = attention_core(q, k, v, causal=True, q_chunk=q_chunk)
    out = L.dense(out.reshape(B, S, n_heads * hd), p.wo, cfg)
    return out, (k, v)


def seed_kv_cache(
    k_cache: jax.Array,           # (B, Smax, Hkv, hd)
    v_cache: jax.Array,
    k: jax.Array,                 # (B, S0, Hkv, hd) prefill keys (post-rope)
    v: jax.Array,
) -> Tuple[jax.Array, jax.Array]:
    """Write one layer's prefill K/V into positions [0, S0) of its decode
    cache. The K returned by ``self_attention`` is already rotary-embedded at
    positions 0..S0-1 — exactly what ``decode_attention`` would have written
    step by step, so fused prefill and teacher-forced prefill seed identical
    caches (tests/test_engine.py)."""
    return (
        jax.lax.dynamic_update_slice(k_cache, k.astype(k_cache.dtype), (0, 0, 0, 0)),
        jax.lax.dynamic_update_slice(v_cache, v.astype(v_cache.dtype), (0, 0, 0, 0)),
    )


def _decode_qkv(
    x: jax.Array,                 # (B, 1, d)
    p: AttnParams,
    cur_len: jax.Array,           # (B,) new-token positions
    *,
    n_heads: int,
    n_kv: int,
    cfg: ApproxConfig,
    rope_theta: float,
    use_rope: bool,
) -> Tuple[jax.Array, jax.Array, jax.Array]:
    """Shared decode prologue: project the new token's q/k/v through
    ``layers.dense`` (approximate-multiplier aware) and rotate q/k at each
    row's ``cur_len``.  ``decode_attention`` and ``paged_decode_attention``
    differ only in how the K/V *cache* is laid out — this prologue is
    layout-independent and deliberately single-sourced so every execution
    mode change applies to both."""
    B = x.shape[0]
    hd = w_dim(p.wq, 1) // n_heads
    q = L.dense(x, p.wq, cfg).reshape(B, 1, n_heads, hd)
    k = L.dense(x, p.wk, cfg).reshape(B, 1, n_kv, hd)
    v = L.dense(x, p.wv, cfg).reshape(B, 1, n_kv, hd)
    if use_rope:
        q, k = L.apply_rope(q, k, cur_len[:, None], theta=rope_theta)
    return q, k, v


def decode_attention(
    x: jax.Array,                 # (B, 1, d)
    p: AttnParams,
    k_cache: jax.Array,           # (B, Smax, Hkv, hd)
    v_cache: jax.Array,
    cur_len: jax.Array,           # (B,) current lengths (new token index)
    *,
    n_heads: int,
    n_kv: int,
    cfg: ApproxConfig,
    rope_theta: float = 10000.0,
    use_rope: bool = True,
) -> Tuple[jax.Array, Tuple[jax.Array, jax.Array]]:
    """One decode step: append K/V at ``cur_len``, attend over the cache."""
    B = x.shape[0]
    q, k, v = _decode_qkv(
        x, p, cur_len, n_heads=n_heads, n_kv=n_kv, cfg=cfg,
        rope_theta=rope_theta, use_rope=use_rope,
    )
    hd = q.shape[3]
    # scatter new kv at cur_len (per-batch dynamic index)
    b_idx = jnp.arange(B)
    k_cache = k_cache.at[b_idx, cur_len].set(k[:, 0].astype(k_cache.dtype))
    v_cache = v_cache.at[b_idx, cur_len].set(v[:, 0].astype(v_cache.dtype))
    out = attention_core(q, k_cache, v_cache, causal=False, kv_len=cur_len + 1, q_chunk=1)
    out = L.dense(out.reshape(B, 1, n_heads * hd), p.wo, cfg)
    return out, (k_cache, v_cache)


def paged_decode_attention(
    x: jax.Array,                 # (B, 1, d)
    p: AttnParams,
    k_blocks: jax.Array,          # (num_blocks, block_size, Hkv, hd) one layer
    v_blocks: jax.Array,
    block_table: jax.Array,       # (B, W) int32 physical block ids
    cur_len: jax.Array,           # (B,) current lengths (new token index)
    *,
    block_size: int,
    n_heads: int,
    n_kv: int,
    cfg: ApproxConfig,
    rope_theta: float = 10000.0,
    use_rope: bool = True,
    attn_impl: str = "gather",
) -> Tuple[jax.Array, Tuple[jax.Array, jax.Array]]:
    """``decode_attention`` against a paged KV cache: append K/V into the
    request's current block, attend over its blocks via the block table.

    Row ``b``'s logical position ``pos`` lives at offset ``pos % block_size``
    of physical block ``block_table[b, pos // block_size]``.  The table is
    fixed-width (``W = max_len // block_size``) with unallocated entries set
    to the sentinel ``num_blocks``, so ONE compiled program serves any
    context layout; table *contents* are traced data.  That content-
    agnosticism is what makes the scheduler's copy-on-write prefix sharing
    free at this layer: several rows' tables may point at the SAME physical
    block (a shared prompt prefix) and both impls below just walk them —
    neither reads which request owns a block, and the scheduler guarantees a
    shared block is never written while shared (writes fork first), so no
    read-path change is needed (pinned by tests/test_prefix_sharing.py
    under both impls).

    * the append scatter targets the sentinel for rows past their allocated
      blocks (or past the table) — out-of-bounds scatter updates are DROPPED
      under jit (dynamic_update_slice would CLAMP; do not swap the write
      path), so overshoot and inactive rows write nothing;
    * ``attn_impl="gather"`` (the parity oracle): ``k_blocks[block_table]``
      materializes a transient (B, W*block_size, Hkv, hd) view — sentinel
      entries clamp to the last real block, bounded garbage the ``kv_len``
      mask zeroes *exactly* (scores at ~-1e30, softmax probability 0.0, AV
      bit-identical to the slot layout's in-place cache);
    * ``attn_impl="pallas"`` streams blocks from the pool straight into
      VMEM tiles (``kernels.paged_attention``): the transient never exists
      in HBM, sentinel blocks are skipped by predicate, and the new token
      is fused into the current block's tile — the kernel reads the
      *pre-scatter* pool, so attention and the persistence scatter run in
      parallel.  Attention floats agree with the gather path to f32
      roundoff (online vs fused softmax reduction order); greedy tokens are
      bit-identical across serve traces (tests/test_paged.py).  That token
      contract assumes an f32 pool: under reduced cache dtypes the gather
      path additionally rounds its softmax *probs* to the cache dtype
      (``attention_core``) while the kernel keeps them f32, so bf16-cache
      parity is statistical — same discipline as the quantized modes.

    Projections route through ``layers.dense`` exactly as in
    ``decode_attention`` — every execution mode (incl. the Pallas
    approx-matmul kernel) is layout- and impl-agnostic."""
    if attn_impl not in ATTN_IMPLS:
        raise ValueError(f"attn_impl {attn_impl!r} not in {ATTN_IMPLS}")
    B = x.shape[0]
    q, k, v = _decode_qkv(
        x, p, cur_len, n_heads=n_heads, n_kv=n_kv, cfg=cfg,
        rope_theta=rope_theta, use_rope=use_rope,
    )
    hd = q.shape[3]
    num_blocks = k_blocks.shape[0]
    W = block_table.shape[1]
    blk = cur_len // block_size
    off = cur_len % block_size
    phys = jnp.take_along_axis(
        block_table, jnp.minimum(blk, W - 1)[:, None], axis=1
    )[:, 0]
    phys = jnp.where(blk < W, phys, num_blocks)      # past-table -> dropped
    new_k = k_blocks.at[phys, off].set(k[:, 0].astype(k_blocks.dtype))
    new_v = v_blocks.at[phys, off].set(v[:, 0].astype(v_blocks.dtype))
    if attn_impl == "pallas":
        from repro.kernels.paged_attention import (
            paged_attention_pallas,
            validate_tp_heads,
        )
        from repro.parallel.sharding import current_mesh, mesh_axis_size

        # pre-scatter pool operands on purpose: the kernel fuses the new
        # token in VMEM, so the scatter above only persists it for the
        # NEXT step and never serializes with this step's attention.  The
        # fused token is cast to the POOL dtype first — the kernel must
        # attend the same rounded value every later step will read back
        def call(qh, kh, vh, kp, vp, bt, cl):
            return paged_attention_pallas(
                qh, kh, vh, kp, vp, bt, cl, block_size=block_size
            )

        mesh = current_mesh()
        tp = mesh_axis_size("model")
        if mesh is not None and tp > 1:
            # pallas_call is not partitioned by GSPMD — map it per shard.
            # Each shard runs the unmodified kernel over its Hkv/tp pool
            # heads and H/tp query heads (group structure preserved, see
            # validate_tp_heads); the block table and lengths replicate, so
            # every shard walks the same host-global table.
            from jax.experimental.shard_map import shard_map
            from jax.sharding import PartitionSpec as P

            validate_tp_heads(n_heads, n_kv, tp)
            hspec = P(None, "model", None)
            pspec = P(None, None, "model", None)
            call = shard_map(
                call,
                mesh=mesh,
                in_specs=(hspec, hspec, hspec, pspec, pspec,
                          P(None, None), P(None)),
                out_specs=hspec,
                check_rep=False,
            )
        out = call(
            q[:, 0],
            k[:, 0].astype(k_blocks.dtype), v[:, 0].astype(v_blocks.dtype),
            k_blocks, v_blocks,
            block_table, cur_len,
        )[:, None]
    else:
        kg = new_k[block_table].reshape(B, W * block_size, n_kv, hd)
        vg = new_v[block_table].reshape(B, W * block_size, n_kv, hd)
        out = attention_core(q, kg, vg, causal=False, kv_len=cur_len + 1, q_chunk=1)
    out = L.dense(out.reshape(B, 1, n_heads * hd), p.wo, cfg)
    return out, (new_k, new_v)


def paged_verify_attention(
    x: jax.Array,                 # (B, S, d) — S = draft_k + 1 verify positions
    p: AttnParams,
    k_blocks: jax.Array,          # (num_blocks, block_size, Hkv, hd) one layer
    v_blocks: jax.Array,
    block_table: jax.Array,       # (B, W) int32 physical block ids
    cur_len: jax.Array,           # (B,) position of the FIRST verify token
    *,
    block_size: int,
    n_heads: int,
    n_kv: int,
    cfg: ApproxConfig,
    rope_theta: float = 10000.0,
    use_rope: bool = True,
) -> Tuple[jax.Array, Tuple[jax.Array, jax.Array]]:
    """Multi-position decode attention for speculative verification: score
    ``S`` consecutive tokens of row ``b`` at cache positions ``cur_len[b] +
    j`` in ONE pass against the paged pool.

    Projections and rope run batched over the S positions (per-position
    math is independent, so float results match the single-token path
    bit-for-bit); K/V for all S positions are scattered through the block
    table first (sentinel/out-of-table targets dropped, exactly as in
    ``paged_decode_attention``), and then each position attends with its
    own ragged causal horizon ``kv_len = cur_len + j + 1``.  The attention
    itself deliberately reuses ``attention_core`` once per verify position
    (Sq == 1), NOT one batched Sq == S call: that makes every position's
    score/softmax/AV reduction the exact instruction sequence of the
    sequential decode oracle, so greedy verification is bit-identical *by
    construction* rather than by numerical accident.  S is the (small)
    draft depth, so the unrolled loop costs S tiny einsums against the one
    shared block gather — the gather transient, the dominant term, is
    materialized once.

    Always the gather read path: the Pallas paged-attention kernel's tile
    schedule is single-query (see ROADMAP TPU hardening); since gather and
    kernel greedy tokens are bit-identical, a kernel session can draft
    through the kernel and verify through this path without breaking the
    exactness contract."""
    B, S, _ = x.shape
    hd = w_dim(p.wq, 1) // n_heads
    q = L.dense(x, p.wq, cfg).reshape(B, S, n_heads, hd)
    k = L.dense(x, p.wk, cfg).reshape(B, S, n_kv, hd)
    v = L.dense(x, p.wv, cfg).reshape(B, S, n_kv, hd)
    pos = cur_len[:, None] + jnp.arange(S, dtype=cur_len.dtype)[None, :]
    if use_rope:
        q, k = L.apply_rope(q, k, pos, theta=rope_theta)
    num_blocks = k_blocks.shape[0]
    W = block_table.shape[1]
    blk = pos // block_size                      # (B, S)
    off = pos % block_size
    phys = jnp.take_along_axis(block_table, jnp.minimum(blk, W - 1), axis=1)
    phys = jnp.where(blk < W, phys, num_blocks)  # past-table -> dropped
    new_k = k_blocks.at[phys, off].set(k.astype(k_blocks.dtype))
    new_v = v_blocks.at[phys, off].set(v.astype(v_blocks.dtype))
    kg = new_k[block_table].reshape(B, W * block_size, n_kv, hd)
    vg = new_v[block_table].reshape(B, W * block_size, n_kv, hd)
    outs = [
        attention_core(
            q[:, j : j + 1], kg, vg, causal=False,
            kv_len=cur_len + j + 1, q_chunk=1,
        )
        for j in range(S)
    ]
    out = jnp.concatenate(outs, axis=1)          # (B, S, H, hd)
    out = L.dense(out.reshape(B, S, n_heads * hd), p.wo, cfg)
    return out, (new_k, new_v)


def paged_chunk_prefill_attention(*args, **kwargs):
    """Chunk-prefill attention: score one chunk of a prompt at cache
    positions ``cur_len[b] + j`` while reading the already-prefilled prefix
    *through the block table* — the attention seam of chunked prefill.

    This IS ``paged_verify_attention``: the verify pass already does exactly
    what a prefill chunk needs (scatter the chunk's K/V through the table
    first — sentinel-tail entries of a partially-filled table drop the
    writes — then attend each position with its own causal horizon
    ``kv_len = cur_len + j + 1``), and because every position reuses the
    decode oracle's instruction sequence, a chunked prefill is bit-identical
    to the fused one-shot prefill by construction, not by numerical
    accident.  Padding positions past the chunk's real length write garbage
    K/V *inside* the row's own allocated blocks only; those positions are
    overwritten by the next chunk's scatter-before-gather (or by decode's
    write-before-attend at position ``prompt_len``) before any horizon can
    read them — the same PR-6 write-skip discipline that makes partial
    tables safe.

    Both session attention impls route chunk reads through this gather path:
    the Pallas paged-attention kernel's tile schedule is single-query, and
    gather/pallas greedy parity is already pinned, so a pallas session
    chunk-prefills through gather and decodes through the kernel without
    breaking the exactness contract."""
    return paged_verify_attention(*args, **kwargs)
