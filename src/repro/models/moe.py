"""Mixture-of-Experts FFN: shared experts + routed top-k with capacity.

Dispatch is sort-based with static shapes (dry-run friendly): token->expert
assignments are sorted, each token takes a rank-within-expert slot, tokens
past the expert capacity are dropped (GShard semantics). Expert weights are
stacked (E, ...) so the experts axis shards over the "model" mesh axis (EP);
GSPMD turns the gather/scatter into all-to-alls.

Expert matmuls run through the approximate-multiplier pipeline via a
lax.scan over experts (each step is a plain ``dense``). The router stays in
float — it is a control path, quantizing it is not part of the paper.
"""
from __future__ import annotations

from typing import NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.core.approx import ApproxConfig, w_dim
from repro.models import layers as L

__all__ = ["MoEParams", "init_moe", "moe_ffn", "load_balance_loss"]


class MoEParams(NamedTuple):
    router: jax.Array            # (d, E)
    w_gate: jax.Array            # (E, d, ff)
    w_up: jax.Array              # (E, d, ff)
    w_down: jax.Array            # (E, ff, d)
    shared_gate: Optional[jax.Array]   # (d, sff) or None
    shared_up: Optional[jax.Array]
    shared_down: Optional[jax.Array]   # (sff, d)
    shared_router: Optional[jax.Array] # (d, 1) sigmoid gate (qwen2-moe style)


def init_moe(
    key,
    d_model: int,
    d_ff: int,
    n_experts: int,
    *,
    shared_d_ff: int = 0,
) -> MoEParams:
    ks = jax.random.split(key, 8)
    def ed(k, i, o):
        return L.truncated_normal_init(k, (n_experts, i, o))
    return MoEParams(
        router=L.init_dense(ks[0], d_model, n_experts),
        w_gate=ed(ks[1], d_model, d_ff),
        w_up=ed(ks[2], d_model, d_ff),
        w_down=ed(ks[3], d_ff, d_model),
        shared_gate=L.init_dense(ks[4], d_model, shared_d_ff) if shared_d_ff else None,
        shared_up=L.init_dense(ks[5], d_model, shared_d_ff) if shared_d_ff else None,
        shared_down=L.init_dense(ks[6], shared_d_ff, d_model) if shared_d_ff else None,
        shared_router=L.init_dense(ks[7], d_model, 1) if shared_d_ff else None,
    )


def load_balance_loss(router_probs: jax.Array, expert_mask: jax.Array) -> jax.Array:
    """Switch-style aux loss: E * sum_e f_e * P_e."""
    E = router_probs.shape[-1]
    f = jnp.mean(expert_mask, axis=0)           # fraction routed per expert
    p = jnp.mean(router_probs, axis=0)          # mean router prob per expert
    return jnp.float32(E) * jnp.sum(f * p)


def moe_ffn(
    x: jax.Array,                # (T, d) tokens
    p: MoEParams,
    *,
    top_k: int,
    cfg: ApproxConfig,
    capacity_factor: float = 1.25,
    unroll_experts: bool = False,   # cost-extraction lowering (dryrun)
) -> Tuple[jax.Array, jax.Array]:
    """Returns (out (T, d), aux_loss)."""
    T, d = x.shape
    E = p.router.shape[-1]
    ff = w_dim(p.w_gate, -1)
    capacity = int(max(top_k * T * capacity_factor / E, 1))
    capacity = min(capacity, T)
    # round capacity to a multiple of 8 for tiling friendliness
    capacity = max(8, (capacity // 8) * 8)

    logits = (x.astype(jnp.float32)) @ p.router.astype(jnp.float32)   # (T, E)
    probs = jax.nn.softmax(logits, axis=-1)
    top_p, top_e = jax.lax.top_k(probs, top_k)                        # (T, k)
    top_p = top_p / jnp.sum(top_p, axis=-1, keepdims=True)            # renorm

    # ---- sort-based dispatch with capacity ---------------------------------
    flat_e = top_e.reshape(-1)                                        # (T*k,)
    flat_t = jnp.repeat(jnp.arange(T), top_k)
    flat_w = top_p.reshape(-1)
    order = jnp.argsort(flat_e, stable=True)
    se, st, sw = flat_e[order], flat_t[order], flat_w[order]
    # rank within expert: position - index of first occurrence of that expert
    counts = jnp.bincount(flat_e, length=E)
    starts = jnp.concatenate([jnp.zeros(1, counts.dtype), jnp.cumsum(counts)[:-1]])
    rank = jnp.arange(T * top_k) - starts[se]
    keep = rank < capacity
    slot = se * capacity + jnp.where(keep, rank, 0)                   # (T*k,)

    buf = jnp.zeros((E * capacity, d), x.dtype)
    buf = buf.at[slot].add(jnp.where(keep[:, None], x[st], 0))
    buf = buf.reshape(E, capacity, d)

    # ---- expert FFN (scan over experts; approx-multiplier matmuls) ---------
    def one_expert(_, ws):
        wg, wu, wd, xb = ws
        h = jax.nn.silu(L.dense(xb, wg, cfg)) * L.dense(xb, wu, cfg)
        return None, L.dense(h, wd, cfg)

    if unroll_experts:
        sl = lambda w, e: jax.tree.map(lambda a: a[e], w)   # QWeight-safe slice
        outs = [
            one_expert(None, (sl(p.w_gate, e), sl(p.w_up, e), sl(p.w_down, e), buf[e]))[1]
            for e in range(E)
        ]
        out_buf = jnp.stack(outs)
    else:
        _, out_buf = jax.lax.scan(one_expert, None, (p.w_gate, p.w_up, p.w_down, buf))
    out_buf = out_buf.reshape(E * capacity, d)

    # ---- combine ------------------------------------------------------------
    gathered = out_buf[slot] * jnp.where(keep, sw, 0.0)[:, None].astype(x.dtype)
    out = jnp.zeros((T, d), x.dtype).at[st].add(gathered)

    # ---- shared experts (qwen2-moe style, sigmoid-gated) --------------------
    if p.shared_gate is not None:
        h = jax.nn.silu(L.dense(x, p.shared_gate, cfg)) * L.dense(x, p.shared_up, cfg)
        sh = L.dense(h, p.shared_down, cfg)
        gate = jax.nn.sigmoid((x.astype(jnp.float32)) @ p.shared_router.astype(jnp.float32))
        out = out + sh * gate.astype(x.dtype)

    mask = jnp.zeros((T, E), jnp.float32).at[flat_t, flat_e].max(
        jnp.ones_like(flat_w, jnp.float32)
    )
    aux = load_balance_loss(probs, mask)
    return out, aux
