"""Shared neural-net layers. Every matmul routes through ``dense`` below,
which applies the approximate-multiplier pipeline when configured — this is
how the paper's technique becomes a first-class, model-wide feature."""
from __future__ import annotations

import dataclasses
from typing import Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.approx import ApproxConfig, QWeight, approx_dense

__all__ = [
    "dense",
    "init_dense",
    "rms_norm",
    "layer_norm",
    "rotary",
    "apply_rope",
    "apply_m_rope",
    "sinusoidal_at",
    "sinusoidal_positions",
    "truncated_normal_init",
]


def truncated_normal_init(key, shape, scale: float = 1.0, dtype=jnp.float32):
    fan_in = shape[0] if len(shape) >= 2 else max(shape[0], 1)
    std = scale / np.sqrt(fan_in)
    return std * jax.random.truncated_normal(key, -2.0, 2.0, shape, dtype)


def init_dense(key, d_in: int, d_out: int, scale: float = 1.0) -> jax.Array:
    return truncated_normal_init(key, (d_in, d_out), scale)


def dense(x: jax.Array, w, cfg: ApproxConfig) -> jax.Array:
    """x (..., K) @ w (K, N) under the configured multiplier semantics:
    ``cfg.mode`` selects float, exact-quant, LUT, low-rank or the Pallas
    kernel (the serving engine's ``exact``/``approx`` execution modes resolve
    to these). ``w`` may be a frozen ``QWeight`` (serving path)."""
    if isinstance(w, QWeight):
        return approx_dense(x, w, cfg).astype(x.dtype)
    if cfg.mode == "float":
        return jnp.einsum("...k,kn->...n", x, w.astype(x.dtype))
    return approx_dense(x, w, cfg).astype(x.dtype)


def rms_norm(x: jax.Array, gamma: jax.Array, eps: float = 1e-6) -> jax.Array:
    var = jnp.mean(jnp.square(x.astype(jnp.float32)), axis=-1, keepdims=True)
    return (x * jax.lax.rsqrt(var + eps).astype(x.dtype)) * gamma.astype(x.dtype)


def layer_norm(x, gamma, beta, eps: float = 1e-5):
    xf = x.astype(jnp.float32)
    mu = jnp.mean(xf, axis=-1, keepdims=True)
    var = jnp.var(xf, axis=-1, keepdims=True)
    y = (xf - mu) * jax.lax.rsqrt(var + eps)
    return (y * gamma + beta).astype(x.dtype)


def rotary(positions: jax.Array, dim: int, theta: float = 10000.0) -> Tuple[jax.Array, jax.Array]:
    """cos/sin tables, (..., dim//2)."""
    inv = 1.0 / (theta ** (jnp.arange(0, dim, 2, dtype=jnp.float32) / dim))
    ang = positions.astype(jnp.float32)[..., None] * inv
    return jnp.cos(ang), jnp.sin(ang)


def _rope_rotate(x: jax.Array, cos: jax.Array, sin: jax.Array) -> jax.Array:
    x1, x2 = jnp.split(x, 2, axis=-1)
    return jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)


def apply_rope(q, k, positions, theta: float = 10000.0):
    """q/k: (B, S, H, hd); positions: (B, S)."""
    hd = q.shape[-1]
    cos, sin = rotary(positions, hd, theta)          # (B, S, hd/2)
    cos = cos[:, :, None, :].astype(q.dtype)
    sin = sin[:, :, None, :].astype(q.dtype)
    return _rope_rotate(q, cos, sin), _rope_rotate(k, cos, sin)


def apply_m_rope(
    q, k, positions_thw: jax.Array, sections: Sequence[int], theta: float = 1000000.0
):
    """Qwen2-VL multimodal RoPE: ``positions_thw`` (B, 3, S) temporal/height/
    width position ids; ``sections`` split head_dim//2 into 3 groups, each
    rotated by its own position stream."""
    hd = q.shape[-1]
    assert sum(sections) == hd // 2, (sections, hd)
    cos_parts, sin_parts = [], []
    start = 0
    for i, sec in enumerate(sections):
        inv = 1.0 / (
            theta ** (jnp.arange(start, start + sec, dtype=jnp.float32) * 2.0 / hd)
        )
        ang = positions_thw[:, i, :].astype(jnp.float32)[..., None] * inv
        cos_parts.append(jnp.cos(ang))
        sin_parts.append(jnp.sin(ang))
        start += sec
    cos = jnp.concatenate(cos_parts, axis=-1)[:, :, None, :].astype(q.dtype)
    sin = jnp.concatenate(sin_parts, axis=-1)[:, :, None, :].astype(q.dtype)
    return _rope_rotate(q, cos, sin), _rope_rotate(k, cos, sin)


def sinusoidal_at(positions: jax.Array, dim: int) -> jax.Array:
    """(...,) int positions -> (..., dim) sinusoidal embeddings (jnp-native,
    never a compile-time constant)."""
    inv = 1.0 / (10000 ** (jnp.arange(0, dim, 2, dtype=jnp.float32) / dim))
    ang = positions.astype(jnp.float32)[..., None] * inv        # (..., dim/2)
    out = jnp.stack([jnp.sin(ang), jnp.cos(ang)], axis=-1)      # (..., dim/2, 2)
    return out.reshape(*positions.shape, dim)


def sinusoidal_positions(seq_len: int, dim: int, offset: int = 0) -> jax.Array:
    return sinusoidal_at(jnp.arange(offset, offset + seq_len), dim)
