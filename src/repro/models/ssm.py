"""State-space sequence layers: Mamba-1 selective scan and Mamba-2 SSD.

Both use chunked time processing so the (B, S, d_inner, N) discretized-state
tensor never materializes for the full sequence:
  * Mamba-1: lax.scan over time chunks, associative scan within a chunk.
  * Mamba-2 (SSD): intra-chunk quadratic form + inter-chunk scalar-decay
    recurrence (the minimal SSD algorithm from the Mamba-2 paper).

Projections go through the approximate-multiplier ``dense``; the recurrence
itself is elementwise/scan arithmetic (no multiplier arrays to approximate —
noted in DESIGN.md §Arch-applicability).
"""
from __future__ import annotations

from typing import NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.core.approx import ApproxConfig, w_dim
from repro.models import layers as L

__all__ = [
    "Mamba1Params", "init_mamba1", "mamba1_forward", "mamba1_decode_step",
    "Mamba2Params", "init_mamba2", "mamba2_forward", "mamba2_decode_step",
]


# ---------------------------------------------------------------------------
# Mamba-1 (falcon-mamba-7b)
# ---------------------------------------------------------------------------


class Mamba1Params(NamedTuple):
    in_proj: jax.Array     # (d, 2*di)
    conv_w: jax.Array      # (cw, di) depthwise causal conv
    conv_b: jax.Array      # (di,)
    x_proj: jax.Array      # (di, dt_rank + 2*N)
    dt_proj: jax.Array     # (dt_rank, di)
    dt_bias: jax.Array     # (di,)
    a_log: jax.Array       # (di, N)
    d_skip: jax.Array      # (di,)
    out_proj: jax.Array    # (di, d)


def init_mamba1(key, d_model: int, d_inner: int, n_state: int, dt_rank: int, conv_w: int = 4) -> Mamba1Params:
    ks = jax.random.split(key, 6)
    return Mamba1Params(
        in_proj=L.init_dense(ks[0], d_model, 2 * d_inner),
        conv_w=0.1 * jax.random.normal(ks[1], (conv_w, d_inner)),
        conv_b=jnp.zeros((d_inner,)),
        x_proj=L.init_dense(ks[2], d_inner, dt_rank + 2 * n_state),
        dt_proj=L.init_dense(ks[3], dt_rank, d_inner),
        dt_bias=jnp.full((d_inner,), -4.6),  # softplus^-1(0.01)
        a_log=jnp.log(
            jnp.broadcast_to(jnp.arange(1, n_state + 1, dtype=jnp.float32), (d_inner, n_state))
        ),
        d_skip=jnp.ones((d_inner,)),
        out_proj=L.init_dense(ks[5], d_inner, d_model),
    )


def _causal_depthwise_conv(x: jax.Array, w: jax.Array, b: jax.Array, state: Optional[jax.Array] = None):
    """x (B, S, di); w (cw, di). Returns (y, new_state) with state (B, cw-1, di)."""
    cw = w.shape[0]
    wd = w.astype(x.dtype)
    if state is None:
        state = jnp.zeros((x.shape[0], cw - 1, x.shape[-1]), x.dtype)
    xp = jnp.concatenate([state.astype(x.dtype), x], axis=1)
    y = sum(xp[:, i : i + x.shape[1], :] * wd[i] for i in range(cw)) + b.astype(x.dtype)
    return y, xp[:, -(cw - 1) :, :]


def _selective_scan_chunked(dA: jax.Array, dBx: jax.Array, h0: jax.Array, chunk: int):
    """Linear recurrence h_t = dA_t * h_{t-1} + dBx_t over axis 1.

    dA/dBx: (B, S, di, N); h0: (B, di, N).  Returns (h_all (B,S,di,N), h_last).
    Chunked: sequential lax.scan over S/chunk blocks, associative scan inside.
    """
    B, S, di, N = dA.shape
    nc = S // chunk
    dA_c = dA.reshape(B, nc, chunk, di, N).swapaxes(0, 1)
    dBx_c = dBx.reshape(B, nc, chunk, di, N).swapaxes(0, 1)

    def combine(a, b):
        (a1, b1), (a2, b2) = a, b
        return a1 * a2, a2 * b1 + b2

    def body(h, blk):
        da, dbx = blk
        aa, bb = jax.lax.associative_scan(combine, (da, dbx), axis=1)
        h_blk = aa * h[:, None] + bb            # (B, chunk, di, N)
        return h_blk[:, -1], h_blk

    h_last, h_all = jax.lax.scan(body, h0, (dA_c, dBx_c))
    h_all = h_all.swapaxes(0, 1).reshape(B, S, di, N)
    return h_all, h_last


def _mamba1_core(xz, p: Mamba1Params, cfg, conv_state, h0, chunk):
    """Shared between train and decode. xz: (B, S, 2*di)."""
    B, S, _ = xz.shape
    di = w_dim(p.out_proj, 0)
    N = p.a_log.shape[1]
    dt_rank = w_dim(p.dt_proj, 0)
    x, z = jnp.split(xz, 2, axis=-1)
    x, conv_state = _causal_depthwise_conv(x, p.conv_w, p.conv_b, conv_state)
    x = jax.nn.silu(x)
    proj = L.dense(x, p.x_proj, cfg)
    dt, Bc, Cc = jnp.split(proj, [dt_rank, dt_rank + N], axis=-1)
    dt = jax.nn.softplus(L.dense(dt, p.dt_proj, cfg) + p.dt_bias.astype(x.dtype))  # (B,S,di)
    A = -jnp.exp(p.a_log.astype(jnp.float32))                       # (di, N)
    dA = jnp.exp(dt.astype(jnp.float32)[..., None] * A)             # (B,S,di,N)
    dBx = (dt * x).astype(jnp.float32)[..., None] * Bc.astype(jnp.float32)[..., None, :]
    if S == 1:
        h = dA[:, 0] * h0 + dBx[:, 0]
        h_all, h_last = h[:, None], h
    else:
        h_all, h_last = _selective_scan_chunked(dA, dBx, h0, min(chunk, S))
    y = jnp.einsum("bsdn,bsn->bsd", h_all, Cc.astype(jnp.float32)).astype(x.dtype)
    y = y + p.d_skip.astype(x.dtype) * x
    y = y * jax.nn.silu(z)
    return L.dense(y, p.out_proj, cfg), conv_state, h_last


def mamba1_forward(x: jax.Array, p: Mamba1Params, *, cfg: ApproxConfig, chunk: int = 256):
    """x (B, S, d) -> (y, (conv_state, ssm_state)) for cache seeding."""
    B, S, _ = x.shape
    di = w_dim(p.out_proj, 0)
    N = p.a_log.shape[1]
    xz = L.dense(x, p.in_proj, cfg)
    h0 = jnp.zeros((B, di, N), jnp.float32)
    y, conv_state, h_last = _mamba1_core(xz, p, cfg, None, h0, chunk)
    return y, (conv_state, h_last)


def mamba1_decode_step(x, p: Mamba1Params, state, *, cfg: ApproxConfig):
    """x (B, 1, d); state = (conv_state (B,cw-1,di), h (B,di,N))."""
    conv_state, h = state
    xz = L.dense(x, p.in_proj, cfg)
    y, conv_state, h = _mamba1_core(xz, p, cfg, conv_state, h, 1)
    return y, (conv_state, h)


# ---------------------------------------------------------------------------
# Mamba-2 / SSD (zamba2)
# ---------------------------------------------------------------------------


class Mamba2Params(NamedTuple):
    in_proj: jax.Array    # (d, 2*di + 2*N + nh)   -> x, z, B, C, dt
    conv_w: jax.Array     # (cw, di + 2*N)
    conv_b: jax.Array     # (di + 2*N,)
    dt_bias: jax.Array    # (nh,)
    a_log: jax.Array      # (nh,)
    d_skip: jax.Array     # (nh,)
    norm_g: jax.Array     # (di,) gated RMSNorm
    out_proj: jax.Array   # (di, d)


def init_mamba2(key, d_model: int, d_inner: int, n_state: int, n_heads: int, conv_w: int = 4) -> Mamba2Params:
    ks = jax.random.split(key, 4)
    conv_dim = d_inner + 2 * n_state
    return Mamba2Params(
        in_proj=L.init_dense(ks[0], d_model, 2 * d_inner + 2 * n_state + n_heads),
        conv_w=0.1 * jax.random.normal(ks[1], (conv_w, conv_dim)),
        conv_b=jnp.zeros((conv_dim,)),
        dt_bias=jnp.zeros((n_heads,)),
        a_log=jnp.zeros((n_heads,)),
        d_skip=jnp.ones((n_heads,)),
        norm_g=jnp.ones((d_inner,)),
        out_proj=L.init_dense(ks[3], d_inner, d_model),
    )


def _segsum(x: jax.Array) -> jax.Array:
    """(..., c) log-decays -> (..., c, c) lower-tri cumulative sums."""
    c = x.shape[-1]
    cs = jnp.cumsum(x, axis=-1)
    seg = cs[..., :, None] - cs[..., None, :]
    mask = jnp.tril(jnp.ones((c, c), bool))
    return jnp.where(mask, seg, -jnp.inf)


def ssd_chunked(X, a_log_dt, Bm, Cm, h0, chunk: int):
    """Minimal SSD (Mamba-2) over chunks.

    X: (B, S, nh, hd); a_log_dt: (B, S, nh) per-step log decay (negative);
    Bm/Cm: (B, S, N); h0: (B, nh, hd, N). Returns (Y, h_last).
    """
    Bsz, S, nh, hd = X.shape
    N = Bm.shape[-1]
    nc = S // chunk
    Xc = X.reshape(Bsz, nc, chunk, nh, hd)
    Ac = a_log_dt.reshape(Bsz, nc, chunk, nh)
    Bc = Bm.reshape(Bsz, nc, chunk, N)
    Cc = Cm.reshape(Bsz, nc, chunk, N)

    Acs = jnp.cumsum(Ac, axis=2)                                  # (B,nc,c,nh)
    # intra-chunk (quadratic within chunk)
    Lmat = jnp.exp(_segsum(Ac.swapaxes(2, 3)))                    # (B,nc,nh,c,c)
    scores = jnp.einsum("bzin,bzjn->bzij", Cc, Bc)                # (B,nc,c,c)
    Y_intra = jnp.einsum("bzhij,bzij,bzjhd->bzihd", Lmat, scores, Xc)
    # chunk-end states
    decay_to_end = jnp.exp(Acs[:, :, -1:, :] - Acs)               # (B,nc,c,nh)
    states = jnp.einsum("bzch,bzcn,bzchd->bzhdn", decay_to_end, Bc, Xc)
    # inter-chunk recurrence over z
    chunk_decay = jnp.exp(Acs[:, :, -1, :])                       # (B,nc,nh)

    def body(h, blk):
        st, dec = blk                                             # (B,nh,hd,N), (B,nh)
        h_new = h * dec[..., None, None] + st
        return h_new, h
    h_last, h_prevs = jax.lax.scan(
        body, h0, (states.swapaxes(0, 1), chunk_decay.swapaxes(0, 1))
    )
    h_prevs = h_prevs.swapaxes(0, 1)                              # (B,nc,nh,hd,N)
    in_decay = jnp.exp(Acs)                                       # decay from chunk start
    Y_inter = jnp.einsum("bzch,bzcn,bzhdn->bzchd", in_decay, Cc, h_prevs)
    Y = (Y_intra + Y_inter).reshape(Bsz, S, nh, hd)
    return Y, h_last


def _mamba2_split(p: Mamba2Params, proj):
    di = w_dim(p.out_proj, 0)
    N = (p.conv_w.shape[1] - di) // 2
    nh = p.a_log.shape[0]
    z, xBC, dt = jnp.split(proj, [di, 2 * di + 2 * N], axis=-1)
    return z, xBC, dt, di, N, nh


def mamba2_forward(x: jax.Array, p: Mamba2Params, *, cfg: ApproxConfig, chunk: int = 256):
    B, S, _ = x.shape
    proj = L.dense(x, p.in_proj, cfg)
    z, xBC, dt, di, N, nh = _mamba2_split(p, proj)
    hd = di // nh
    xBC, conv_state = _causal_depthwise_conv(xBC, p.conv_w, p.conv_b, None)
    xBC = jax.nn.silu(xBC)
    xs, Bm, Cm = jnp.split(xBC, [di, di + N], axis=-1)
    dt = jax.nn.softplus(dt.astype(jnp.float32) + p.dt_bias)      # (B,S,nh)
    a = -jnp.exp(p.a_log.astype(jnp.float32))                     # (nh,)
    Xh = (xs.reshape(B, S, nh, hd).astype(jnp.float32)) * dt[..., None]
    h0 = jnp.zeros((B, nh, hd, N), jnp.float32)
    ck = min(chunk, S)
    if S % ck != 0 or S == 1:
        ck = 1 if S == 1 else S
    Y, h_last = ssd_chunked(Xh, dt * a, Bm.astype(jnp.float32), Cm.astype(jnp.float32), h0, ck)
    Y = Y + p.d_skip.astype(jnp.float32)[None, None, :, None] * xs.reshape(B, S, nh, hd).astype(jnp.float32)
    y = Y.reshape(B, S, di).astype(x.dtype)
    y = L.rms_norm(y * jax.nn.silu(z), p.norm_g)
    return L.dense(y, p.out_proj, cfg), (conv_state, h_last)


def mamba2_decode_step(x, p: Mamba2Params, state, *, cfg: ApproxConfig):
    """x (B, 1, d); state = (conv_state, h (B,nh,hd,N))."""
    conv_state, h = state
    B = x.shape[0]
    proj = L.dense(x, p.in_proj, cfg)
    z, xBC, dt, di, N, nh = _mamba2_split(p, proj)
    hd = di // nh
    xBC, conv_state = _causal_depthwise_conv(xBC, p.conv_w, p.conv_b, conv_state)
    xBC = jax.nn.silu(xBC)
    xs, Bm, Cm = jnp.split(xBC, [di, di + N], axis=-1)
    dt = jax.nn.softplus(dt.astype(jnp.float32) + p.dt_bias)[:, 0]    # (B,nh)
    a = -jnp.exp(p.a_log.astype(jnp.float32))
    dA = jnp.exp(dt * a)                                              # (B,nh)
    Xh = xs[:, 0].reshape(B, nh, hd).astype(jnp.float32) * dt[..., None]
    h = h * dA[..., None, None] + jnp.einsum(
        "bhd,bn->bhdn", Xh, Bm[:, 0].astype(jnp.float32)
    )
    Y = jnp.einsum("bhdn,bn->bhd", h, Cm[:, 0].astype(jnp.float32))
    Y = Y + p.d_skip.astype(jnp.float32)[None, :, None] * xs[:, 0].reshape(B, nh, hd).astype(jnp.float32)
    y = Y.reshape(B, 1, di).astype(x.dtype)
    y = L.rms_norm(y * jax.nn.silu(z), p.norm_g)
    return L.dense(y, p.out_proj, cfg), (conv_state, h)
