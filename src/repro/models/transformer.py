"""Unified decoder stack for all assigned architecture families.

* dense / vlm / audio : pre-RMSNorm GQA + SwiGLU FFN
* moe                 : GQA + (shared + routed top-k) MoE FFN
* ssm                 : Mamba-1 blocks
* hybrid              : Mamba-2 blocks + a weight-shared attention block
                        applied every ``attn_every`` layers (Zamba2-style)

Layer parameters are stacked on a leading axis and executed with
``lax.scan`` (optionally remat'd) so the compiled HLO is layer-count
independent — essential for 512-device dry-run compiles of 60+-layer models.

Caches are pytrees stacked the same way; ``decode_step`` scans over
(params, cache) jointly.
"""
from __future__ import annotations

import functools
from typing import Any, Dict, NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.core.approx import ApproxConfig, concat_weights, w_dim
from repro.models import layers as L
from repro.models import ssm as S
from repro.models.attention import (
    AttnParams,
    decode_attention,
    init_attn,
    paged_decode_attention,
    paged_verify_attention,
    seed_kv_cache,
    self_attention,
)
from repro.models.moe import MoEParams, init_moe, moe_ffn

__all__ = [
    "init_params",
    "forward",
    "init_cache",
    "init_paged_cache",
    "seed_cache",
    "decode_step",
    "paged_decode_step",
    "paged_verify_step",
    "paged_chunk_prefill_step",
    "FFNParams",
]


class FFNParams(NamedTuple):
    w_gate: jax.Array
    w_up: jax.Array
    w_down: jax.Array


def _init_ffn(key, d: int, ff: int) -> FFNParams:
    k1, k2, k3 = jax.random.split(key, 3)
    return FFNParams(
        w_gate=L.init_dense(k1, d, ff),
        w_up=L.init_dense(k2, d, ff),
        w_down=L.init_dense(k3, ff, d),
    )


def _ffn(x, p: FFNParams, cfg: ApproxConfig, fuse_gate_up: bool = False):
    # Megatron split: gate/up are column-parallel, down is row-parallel —
    # pinning the hidden activation head-sharded over "model" keeps the whole
    # MLP local per shard with a single psum after w_down (no-op off-mesh).
    from repro.parallel.sharding import constrain

    if fuse_gate_up:
        # §Perf lever: gate & up share one quant + feature pass / wide dot
        w = concat_weights([p.w_gate, p.w_up], axis=1)
        gu = L.dense(x, w, cfg)
        ff = w_dim(p.w_gate, 1)
        h = jax.nn.silu(gu[..., :ff]) * gu[..., ff:]
    else:
        h = jax.nn.silu(L.dense(x, p.w_gate, cfg)) * L.dense(x, p.w_up, cfg)
    h = constrain(h, ("batch",) + (None,) * (h.ndim - 2) + ("model",))
    return L.dense(h, p.w_down, cfg)


# ---------------------------------------------------------------------------
# Parameter init
# ---------------------------------------------------------------------------


def _init_layer(cfg: ModelConfig, key) -> Dict[str, Any]:
    d = cfg.d_model
    if cfg.family == "ssm":
        k1 = key
        return {
            "ln": jnp.ones((d,)),
            "mamba": S.init_mamba1(k1, d, cfg.d_inner, cfg.ssm_state, cfg.dt_rank, cfg.conv_width),
        }
    if cfg.family == "hybrid":
        return {
            "ln": jnp.ones((d,)),
            "mamba": S.init_mamba2(key, d, cfg.d_inner, cfg.ssm_state, cfg.ssm_heads, cfg.conv_width),
        }
    k1, k2, k3, k4 = jax.random.split(key, 4)
    layer = {
        "ln1": jnp.ones((d,)),
        "ln2": jnp.ones((d,)),
        "attn": init_attn(k1, d, cfg.num_heads, cfg.num_kv_heads, cfg.head_dim),
    }
    if cfg.family == "moe":
        layer["moe"] = init_moe(
            k2, d, cfg.d_ff, cfg.moe_experts, shared_d_ff=cfg.moe_shared_ff
        )
    else:
        layer["ffn"] = _init_ffn(k2, d, cfg.d_ff)
    return layer


def init_params(cfg: ModelConfig, key) -> Dict[str, Any]:
    keys = jax.random.split(key, 4)
    layer_keys = jax.random.split(keys[0], cfg.num_layers)
    stacked = jax.vmap(lambda k: _init_layer(cfg, k))(layer_keys)
    params: Dict[str, Any] = {"layers": stacked}
    if cfg.embed_input:
        params["embed"] = L.truncated_normal_init(keys[1], (cfg.vocab_size, cfg.d_model))
    params["final_norm"] = jnp.ones((cfg.d_model,))
    params["lm_head"] = L.init_dense(keys[2], cfg.d_model, cfg.padded_vocab)
    if cfg.family == "hybrid":
        k1, k2 = jax.random.split(keys[3])
        params["shared_attn"] = {
            "ln1": jnp.ones((cfg.d_model,)),
            "ln2": jnp.ones((cfg.d_model,)),
            "attn": init_attn(k1, cfg.d_model, cfg.num_heads, cfg.num_kv_heads, cfg.head_dim),
            "ffn": _init_ffn(k2, cfg.d_model, cfg.d_ff),
        }
    if cfg.param_dtype != "float32":
        pd = jnp.dtype(cfg.param_dtype)
        params = jax.tree.map(
            lambda a: a.astype(pd) if a.dtype == jnp.float32 else a, params
        )
    return params


# ---------------------------------------------------------------------------
# Blocks
# ---------------------------------------------------------------------------


def _attn_block(cfg: ModelConfig, x, layer, m_rope_pos=None):
    a = cfg.approx
    h, kv = self_attention(
        L.rms_norm(x, layer["ln1"]),
        layer["attn"],
        n_heads=cfg.num_heads,
        n_kv=cfg.num_kv_heads,
        cfg=a,
        m_rope=(m_rope_pos, cfg.m_rope_sections) if (cfg.pos_embedding == "m_rope" and m_rope_pos is not None) else None,
        rope_theta=cfg.rope_theta,
        use_rope=cfg.pos_embedding in ("rope", "m_rope"),
        q_chunk=cfg.q_chunk,
        fuse_qkv=cfg.fuse_qkv,
    )
    x = x + h
    aux = jnp.float32(0)
    if cfg.family == "moe":
        B, Sq, d = x.shape
        h2, aux = moe_ffn(
            L.rms_norm(x, layer["ln2"]).reshape(B * Sq, d),
            layer["moe"],
            top_k=cfg.moe_top_k,
            cfg=a,
            capacity_factor=cfg.capacity_factor,
            unroll_experts=cfg.unroll_experts,
        )
        x = x + h2.reshape(B, Sq, d)
    else:
        x = x + _ffn(L.rms_norm(x, layer["ln2"]), layer["ffn"], a, cfg.fuse_gate_up)
    return x, kv, aux


def _layer_slice(stacked, i):
    return jax.tree.map(lambda a: a[i], stacked)


def _run_dense_like(cfg: ModelConfig, params, x, m_rope_pos=None, collect_kv: bool = False):
    """Scan over stacked layers (or unroll when cfg.scan_layers=False — used
    by the dry-run's cost-extraction lowering); returns (x, aux_sum) or, with
    ``collect_kv``, (x, aux_sum, (k, v)) with k/v stacked (L, B, S, Hkv, hd)
    — the fused-prefill cache seed."""

    def body(carry, layer):
        x, aux = carry
        x, kv, a = _attn_block(cfg, x, layer, m_rope_pos)
        return (x, aux + a), (kv if collect_kv else None)

    fn = jax.checkpoint(body) if cfg.remat else body
    if cfg.scan_layers:
        (x, aux), kvs = jax.lax.scan(fn, (x, jnp.float32(0)), params["layers"])
        return (x, aux, kvs) if collect_kv else (x, aux)
    carry = (x, jnp.float32(0))
    kv_list = []
    for i in range(cfg.num_layers):
        carry, kv = fn(carry, _layer_slice(params["layers"], i))
        kv_list.append(kv)
    x, aux = carry
    if collect_kv:
        kvs = jax.tree.map(lambda *xs: jnp.stack(xs), *kv_list)
        return x, aux, kvs
    return x, aux


def _run_ssm(cfg: ModelConfig, params, x):
    def body(carry, layer):
        x = carry
        h, _ = S.mamba1_forward(
            L.rms_norm(x, layer["ln"]), layer["mamba"], cfg=cfg.approx, chunk=cfg.ssm_chunk
        )
        return x + h, None

    fn = jax.checkpoint(body) if cfg.remat else body
    if cfg.scan_layers:
        x, _ = jax.lax.scan(fn, x, params["layers"])
        return x, jnp.float32(0)
    for i in range(cfg.num_layers):
        x, _ = fn(x, _layer_slice(params["layers"], i))
    return x, jnp.float32(0)


def _shared_attn_apply(cfg: ModelConfig, shared, x):
    h, kv = self_attention(
        L.rms_norm(x, shared["ln1"]),
        shared["attn"],
        n_heads=cfg.num_heads,
        n_kv=cfg.num_kv_heads,
        cfg=cfg.approx,
        rope_theta=cfg.rope_theta,
        q_chunk=cfg.q_chunk,
    )
    x = x + h
    x = x + _ffn(L.rms_norm(x, shared["ln2"]), shared["ffn"], cfg.approx, cfg.fuse_gate_up)
    return x, kv


def _group_layers(cfg: ModelConfig):
    k = cfg.attn_every
    assert cfg.num_layers % k == 0, (cfg.num_layers, k)
    return cfg.num_layers // k, k


def _run_hybrid(cfg: ModelConfig, params, x):
    """Groups of ``attn_every`` Mamba-2 layers, then the weight-shared
    attention block (Zamba2-style)."""
    n_groups, k = _group_layers(cfg)
    stacked = jax.tree.map(
        lambda a: a.reshape(n_groups, k, *a.shape[1:]), params["layers"]
    )
    shared = params["shared_attn"]

    def group_body(x, group_params):
        def inner(x, layer):
            h, _ = S.mamba2_forward(
                L.rms_norm(x, layer["ln"]), layer["mamba"], cfg=cfg.approx, chunk=cfg.ssm_chunk
            )
            return x + h, None

        x, _ = jax.lax.scan(inner, x, group_params)
        x, _ = _shared_attn_apply(cfg, shared, x)
        return x, None

    fn = jax.checkpoint(group_body) if cfg.remat else group_body
    if cfg.scan_layers:
        x, _ = jax.lax.scan(fn, x, stacked)
        return x, jnp.float32(0)
    for i in range(n_groups):
        x, _ = fn(x, _layer_slice(stacked, i))
    return x, jnp.float32(0)


# ---------------------------------------------------------------------------
# Forward (train / prefill)
# ---------------------------------------------------------------------------


def forward(
    cfg: ModelConfig,
    params: Dict[str, Any],
    batch: Dict[str, jax.Array],
    *,
    return_kv: bool = False,
):
    """batch: {"tokens": (B,S) int32} or {"embeddings": (B,S,d)} (+ optional
    "positions_thw" (B,3,S) for m_rope). Returns (logits (B,S,V), aux_loss),
    or with ``return_kv`` (attention families only) (logits, aux, (k, v))
    where k/v are stacked (L, B, S, Hkv, hd) — feed to ``seed_cache`` so
    prefill seeds the decode cache in one fused pass."""
    from repro.parallel.sharding import constrain

    dtype = jnp.dtype(cfg.dtype)
    if cfg.embed_input:
        x = params["embed"][batch["tokens"]].astype(dtype)
    else:
        x = batch["embeddings"].astype(dtype)
    if cfg.pos_embedding == "sinusoidal":
        x = x + L.sinusoidal_positions(x.shape[1], cfg.d_model).astype(dtype)
    x = constrain(x, ("batch", None, None))

    m_rope_pos = batch.get("positions_thw") if cfg.pos_embedding == "m_rope" else None
    if cfg.pos_embedding == "m_rope" and m_rope_pos is None:
        S_ = x.shape[1]
        m_rope_pos = jnp.broadcast_to(jnp.arange(S_)[None, None, :], (x.shape[0], 3, S_))

    kvs = None
    if cfg.family == "ssm":
        if return_kv:
            raise NotImplementedError("ssm has no attention KV; use decode-mode prefill")
        x, aux = _run_ssm(cfg, params, x)
    elif cfg.family == "hybrid":
        if return_kv:
            raise NotImplementedError("hybrid prefill needs conv/ssm state; use decode-mode prefill")
        x, aux = _run_hybrid(cfg, params, x)
    elif return_kv:
        x, aux, kvs = _run_dense_like(cfg, params, x, m_rope_pos, collect_kv=True)
    else:
        x, aux = _run_dense_like(cfg, params, x, m_rope_pos)

    x = L.rms_norm(x, params["final_norm"])
    logits = _mask_pad(cfg, L.dense(x, params["lm_head"], cfg.approx))
    # keep the vocab axis model-sharded: the (B,S,V) f32 logits are the
    # single largest activation at 50k-150k vocabs
    logits = constrain(logits, ("batch", None, "model"))
    logits = logits.astype(jnp.float32)
    return (logits, aux, kvs) if return_kv else (logits, aux)


# ---------------------------------------------------------------------------
# Decode (serve_step)
# ---------------------------------------------------------------------------


def init_cache(cfg: ModelConfig, batch: int, max_len: int, dtype=jnp.bfloat16):
    """Stacked per-layer cache pytree."""
    if cfg.family == "ssm":
        di, N, cw = cfg.d_inner, cfg.ssm_state, cfg.conv_width
        return {
            "conv": jnp.zeros((cfg.num_layers, batch, cw - 1, di), dtype),
            "ssm": jnp.zeros((cfg.num_layers, batch, di, N), jnp.float32),
        }
    if cfg.family == "hybrid":
        n_groups, k = cfg.num_layers // cfg.attn_every, cfg.attn_every
        di, N, nh = cfg.d_inner, cfg.ssm_state, cfg.ssm_heads
        conv_dim = di + 2 * N
        return {
            "conv": jnp.zeros((cfg.num_layers, batch, cfg.conv_width - 1, conv_dim), dtype),
            "ssm": jnp.zeros((cfg.num_layers, batch, nh, di // nh, N), jnp.float32),
            "k": jnp.zeros((n_groups, batch, max_len, cfg.num_kv_heads, cfg.head_dim), dtype),
            "v": jnp.zeros((n_groups, batch, max_len, cfg.num_kv_heads, cfg.head_dim), dtype),
        }
    return {
        "k": jnp.zeros((cfg.num_layers, batch, max_len, cfg.num_kv_heads, cfg.head_dim), dtype),
        "v": jnp.zeros((cfg.num_layers, batch, max_len, cfg.num_kv_heads, cfg.head_dim), dtype),
    }


def init_paged_cache(cfg: ModelConfig, num_blocks: int, block_size: int, dtype=jnp.bfloat16):
    """Paged KV cache: a global pool of ``num_blocks`` fixed-size blocks per
    layer instead of a per-request ``max_len`` stripe.  Total HBM is
    ``num_blocks * block_size`` KV rows per layer regardless of how many
    requests are resident — the block table (see ``serve.scheduler``) maps
    each request's logical positions onto its owned blocks.

    Attention families only: SSM/hybrid decode state is O(1) per request
    (conv tap + ssm state, no sequence axis), so there is nothing to page —
    those families keep the slot layout."""
    if cfg.family in ("ssm", "hybrid"):
        raise NotImplementedError(
            f"{cfg.family} caches carry per-request conv/ssm state with no "
            "sequence axis; the paged layout applies to attention-family "
            "KV caches only"
        )
    shape = (cfg.num_layers, num_blocks, block_size, cfg.num_kv_heads, cfg.head_dim)
    return {"k": jnp.zeros(shape, dtype), "v": jnp.zeros(shape, dtype)}


def seed_cache(cfg: ModelConfig, cache, kvs) -> Dict[str, jax.Array]:
    """Write fused-prefill K/V (from ``forward(..., return_kv=True)``) into a
    fresh ``init_cache`` pytree at positions [0, S0) for every layer."""
    k, v = kvs                                   # (L, B, S0, Hkv, hd)
    kc, vc = jax.vmap(seed_kv_cache)(cache["k"], cache["v"], k, v)
    return dict(cache, k=kc, v=vc)


def decode_step(
    cfg: ModelConfig,
    params: Dict[str, Any],
    cache: Dict[str, jax.Array],
    batch: Dict[str, jax.Array],
    cur_len: jax.Array,                 # (B,)
) -> Tuple[jax.Array, Dict[str, jax.Array]]:
    """One-token decode. batch: {"tokens": (B,1)} or {"embeddings": (B,1,d)}.
    Returns (logits (B,1,V), new_cache)."""
    dtype = jnp.dtype(cfg.dtype)
    if cfg.embed_input:
        x = params["embed"][batch["tokens"]].astype(dtype)
    else:
        x = batch["embeddings"].astype(dtype)
    if cfg.pos_embedding == "sinusoidal":
        x = x + L.sinusoidal_at(cur_len, cfg.d_model)[:, None, :].astype(dtype)

    a = cfg.approx

    if cfg.family == "ssm":
        def body(x, scanned):
            layer, conv, h = scanned
            y, (conv, h) = S.mamba1_decode_step(
                L.rms_norm(x, layer["ln"]), layer["mamba"], (conv, h), cfg=a
            )
            return x + y, (conv, h)

        x, (conv_new, ssm_new) = _scan_decode(
            body, x, (params["layers"], cache["conv"], cache["ssm"]), cfg.scan_layers
        )
        return _head(cfg, params, x), {"conv": conv_new, "ssm": ssm_new}

    if cfg.family == "hybrid":
        n_groups, k = _group_layers(cfg)
        grouped = jax.tree.map(
            lambda t: t.reshape(n_groups, k, *t.shape[1:]),
            (params["layers"], cache["conv"], cache["ssm"]),
        )
        shared = params["shared_attn"]

        def group_body(carry, scanned):
            x = carry
            (layers_g, conv_g, ssm_g), kc, vc = scanned

            def inner(x, sc):
                layer, conv, h = sc
                y, (conv, h) = S.mamba2_decode_step(
                    L.rms_norm(x, layer["ln"]), layer["mamba"], (conv, h), cfg=a
                )
                return x + y, (conv, h)

            x, (conv_g, ssm_g) = _scan_decode(inner, x, (layers_g, conv_g, ssm_g))
            h2, kv = decode_attention(
                L.rms_norm(x, shared["ln1"]), shared["attn"], kc, vc, cur_len,
                n_heads=cfg.num_heads, n_kv=cfg.num_kv_heads, cfg=a,
                rope_theta=cfg.rope_theta,
            )
            x = x + h2
            x = x + _ffn(L.rms_norm(x, shared["ln2"]), shared["ffn"], a, cfg.fuse_gate_up)
            return x, ((conv_g, ssm_g), kv[0], kv[1])

        x, ((conv_new, ssm_new), k_new, v_new) = _scan_decode(
            group_body, x, (grouped, cache["k"], cache["v"]), cfg.scan_layers
        )
        unstack = lambda t: t.reshape(cfg.num_layers, *t.shape[2:])
        return _head(cfg, params, x), {
            "conv": unstack(conv_new),
            "ssm": unstack(ssm_new),
            "k": k_new,
            "v": v_new,
        }

    # dense / moe / vlm / audio
    def body(x, scanned):
        layer, kc, vc = scanned
        h, (kc, vc) = decode_attention(
            L.rms_norm(x, layer["ln1"]), layer["attn"], kc, vc, cur_len,
            n_heads=cfg.num_heads, n_kv=cfg.num_kv_heads, cfg=a,
            rope_theta=cfg.rope_theta,
            use_rope=cfg.pos_embedding in ("rope", "m_rope"),
        )
        return _decode_mlp(cfg, x + h, layer, a), (kc, vc)

    x, (k_new, v_new) = _scan_decode(
        body, x, (params["layers"], cache["k"], cache["v"]), cfg.scan_layers
    )
    return _head(cfg, params, x), {"k": k_new, "v": v_new}


def _decode_mlp(cfg: ModelConfig, x, layer, a: ApproxConfig):
    """The post-attention half of a decode-path attention-family block."""
    if cfg.family == "moe":
        B = x.shape[0]
        h2, _ = moe_ffn(
            L.rms_norm(x, layer["ln2"]).reshape(B, cfg.d_model),
            layer["moe"], top_k=cfg.moe_top_k, cfg=a,
            capacity_factor=cfg.capacity_factor,
            unroll_experts=cfg.unroll_experts,
        )
        return x + h2.reshape(B, 1, cfg.d_model)
    return x + _ffn(L.rms_norm(x, layer["ln2"]), layer["ffn"], a, cfg.fuse_gate_up)


def paged_decode_step(
    cfg: ModelConfig,
    params: Dict[str, Any],
    cache: Dict[str, jax.Array],
    batch: Dict[str, jax.Array],
    cur_len: jax.Array,                 # (B,)
    block_tables: jax.Array,            # (B, W) int32
    *,
    block_size: int,
    attn_impl: str = "gather",
) -> Tuple[jax.Array, Dict[str, jax.Array]]:
    """``decode_step`` against an ``init_paged_cache`` pytree: identical
    math, but each row's K/V reads and the new token's write are routed
    through its block table (``attention.paged_decode_attention``).  The
    table is shared across layers — block ``b`` of layer ``l`` lives at
    ``cache["k"][l, table[row, pos // block_size]]``.  ``attn_impl``
    selects the per-layer attention path: the XLA block gather
    (``"gather"``, the oracle) or the in-place Pallas block-pool kernel
    (``"pallas"``)."""
    if cfg.family in ("ssm", "hybrid"):
        raise NotImplementedError("paged decode applies to attention-family caches only")
    from repro.parallel.sharding import constrain

    dtype = jnp.dtype(cfg.dtype)
    if cfg.embed_input:
        x = params["embed"][batch["tokens"]].astype(dtype)
    else:
        x = batch["embeddings"].astype(dtype)
    if cfg.pos_embedding == "sinusoidal":
        x = x + L.sinusoidal_at(cur_len, cfg.d_model)[:, None, :].astype(dtype)
    # TP: the residual stream stays replicated over "model" — each layer's
    # row-parallel wo/w_down psum re-materializes it (no-op off-mesh)
    x = constrain(x, ("batch", None, None))

    a = cfg.approx

    def body(x, scanned):
        layer, kc, vc = scanned
        h, (kc, vc) = paged_decode_attention(
            L.rms_norm(x, layer["ln1"]), layer["attn"], kc, vc,
            block_tables, cur_len,
            block_size=block_size,
            n_heads=cfg.num_heads, n_kv=cfg.num_kv_heads, cfg=a,
            rope_theta=cfg.rope_theta,
            use_rope=cfg.pos_embedding in ("rope", "m_rope"),
            attn_impl=attn_impl,
        )
        return _decode_mlp(cfg, x + h, layer, a), (kc, vc)

    x, (k_new, v_new) = _scan_decode(
        body, x, (params["layers"], cache["k"], cache["v"]), cfg.scan_layers
    )
    return _head(cfg, params, x), {"k": k_new, "v": v_new}


def paged_verify_step(
    cfg: ModelConfig,
    params: Dict[str, Any],
    cache: Dict[str, jax.Array],
    batch: Dict[str, jax.Array],
    cur_len: jax.Array,                 # (B,) position of the first token
    block_tables: jax.Array,            # (B, W) int32
    *,
    block_size: int,
) -> Tuple[jax.Array, Dict[str, jax.Array]]:
    """Speculative-decoding verify pass: score ``S = draft_k + 1``
    consecutive tokens per row against the paged cache in ONE dispatch.

    ``batch["tokens"]`` is (B, S): row ``b``'s token ``j`` sits at cache
    position ``cur_len[b] + j``.  Returns (logits (B, S, V), new_cache):
    ``logits[:, j]`` is the next-token distribution *after* token ``j`` —
    what a sequential ``paged_decode_step`` at ``cur_len + j`` would have
    produced — and the cache holds this pass's K/V (computed under
    ``cfg.approx``, i.e. the verifier's exact path) at positions
    ``[cur_len, cur_len + S)``, overwriting whatever the draft pass wrote
    there.  Position/rope/masking per verify slot are exactly the
    single-token decode path's (see ``paged_verify_attention``), so greedy
    acceptance against this pass is bit-identical to sequential decoding.

    Dense-like attention families only: MoE routing is capacity-coupled
    across the token batch, so a (B*S)-token verify would route
    differently than B sequential single-token steps and the acceptance
    rule would lose its exactness contract."""
    if cfg.family in ("ssm", "hybrid"):
        raise NotImplementedError("paged verify applies to attention-family caches only")
    if cfg.family == "moe":
        raise NotImplementedError(
            "moe routing is capacity-coupled across the token batch — a "
            "batched verify pass routes differently than sequential decode, "
            "breaking the speculative acceptance contract"
        )
    from repro.parallel.sharding import constrain

    dtype = jnp.dtype(cfg.dtype)
    if cfg.embed_input:
        x = params["embed"][batch["tokens"]].astype(dtype)
    else:
        x = batch["embeddings"].astype(dtype)
    S = x.shape[1]
    if cfg.pos_embedding == "sinusoidal":
        pos = cur_len[:, None] + jnp.arange(S, dtype=cur_len.dtype)[None, :]
        x = x + L.sinusoidal_at(pos.reshape(-1), cfg.d_model).reshape(
            x.shape[0], S, cfg.d_model
        ).astype(dtype)
    x = constrain(x, ("batch", None, None))

    a = cfg.approx

    def body(x, scanned):
        layer, kc, vc = scanned
        h, (kc, vc) = paged_verify_attention(
            L.rms_norm(x, layer["ln1"]), layer["attn"], kc, vc,
            block_tables, cur_len,
            block_size=block_size,
            n_heads=cfg.num_heads, n_kv=cfg.num_kv_heads, cfg=a,
            rope_theta=cfg.rope_theta,
            use_rope=cfg.pos_embedding in ("rope", "m_rope"),
        )
        x = x + h
        return x + _ffn(L.rms_norm(x, layer["ln2"]), layer["ffn"], a,
                        cfg.fuse_gate_up), (kc, vc)

    x, (k_new, v_new) = _scan_decode(
        body, x, (params["layers"], cache["k"], cache["v"]), cfg.scan_layers
    )
    return _head(cfg, params, x), {"k": k_new, "v": v_new}


def paged_chunk_prefill_step(
    cfg: ModelConfig,
    params: Dict[str, Any],
    cache: Dict[str, jax.Array],
    batch: Dict[str, jax.Array],
    prefill_pos: jax.Array,             # (B,) cursor: tokens already prefilled
    block_tables: jax.Array,            # (B, W) int32, sentinel-tailed
    *,
    block_size: int,
) -> Tuple[jax.Array, Dict[str, jax.Array]]:
    """Chunked-prefill step: teacher-force one (B, C) chunk of each row's
    prompt into the paged cache at positions ``[prefill_pos, prefill_pos +
    C)``, reading the already-written prefix *through the block table*.

    This IS ``paged_verify_step`` — the verify pass already has the exact
    semantics a prefill chunk needs (scatter this chunk's K/V through the
    table before any gather; attend each position at its own causal
    horizon), and reusing it makes the chunked prefill bit-identical to the
    fused one-shot prefill by construction: ``logits[:, j]`` of the final
    chunk's last real position is bitwise the fused prefill's last-position
    logits, and the pool K/V after the final chunk is bitwise the
    one-shot-scattered pool (pinned by ``tests/test_chunked_prefill.py``).

    Contract for partial tables (the PR-6 invariant the chunks lean on):

    * table entries covering ``[0, prefill_pos + C)`` must name real blocks;
      *tail* entries may still be the sentinel ``num_blocks`` — the scatter
      drops writes through them, and positions ``>= kv_len`` never enter any
      horizon, so an unallocated tail is indistinguishable from an absent
      one;
    * rows padded past their real chunk length write garbage K/V only at
      positions ``>= prefill_pos + chunk_len`` inside their own blocks —
      overwritten by the next chunk's scatter-before-gather or by decode's
      write-before-attend, and masked by ``kv_len`` until then.

    Same family gates as the verify pass: attention families only, and moe
    is excluded because its routing is capacity-coupled across the token
    batch (a chunked prefill would route differently than the fused
    oracle)."""
    return paged_verify_step(
        cfg, params, cache, batch, prefill_pos, block_tables,
        block_size=block_size,
    )


def cache_max_len(cfg: ModelConfig, cache) -> int:
    if "k" in cache:
        return cache["k"].shape[2] if cfg.family != "hybrid" else cache["k"].shape[2]
    return 1 << 20


def _scan_decode(body, x, scanned, scan_layers: bool = True):
    if scan_layers:
        return jax.lax.scan(body, x, scanned)
    n = jax.tree.leaves(scanned)[0].shape[0]
    outs = []
    for i in range(n):
        x, o = body(x, _layer_slice(scanned, i))
        outs.append(o)
    stacked = jax.tree.map(lambda *xs: jnp.stack(xs), *outs)
    return x, stacked


def _mask_pad(cfg: ModelConfig, logits):
    """-inf on padded vocab columns (additive, broadcast from (Vp,))."""
    V, Vp = cfg.vocab_size, cfg.padded_vocab
    if Vp == V:
        return logits
    neg = jnp.where(jnp.arange(Vp) < V, 0.0, -1e30).astype(logits.dtype)
    return logits + neg


def _head(cfg: ModelConfig, params, x):
    from repro.parallel.sharding import constrain

    x = L.rms_norm(x, params["final_norm"])
    logits = _mask_pad(cfg, L.dense(x, params["lm_head"], cfg.approx)).astype(jnp.float32)
    # TP: lm_head is column-parallel, so logits stay vocab-sharded; sampling
    # reduces them to token ids and only THOSE replicate back to the host
    return constrain(logits, ("batch",) + (None,) * (logits.ndim - 2) + ("model",))
