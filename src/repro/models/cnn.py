"""The paper's evaluation DNNs: LeNet, LeNet+, AlexNet, VGG16, ResNet-19.

Convolutions are lowered to im2col patches + ``dense`` so that *every MAC*
goes through the configured approximate multiplier — exactly the paper's
platform semantics (approximate multipliers inside conv/FC arrays).

ResNet-19 follows the CIFAR variant common in the literature the paper draws
from: stem conv + 3 stages of basic blocks ({3,3,2} blocks, channels
128/256/512) + 2 FC layers = 19 weight layers.
"""
from __future__ import annotations

import functools
from typing import Any, Dict, List, Sequence, Tuple

import jax
import jax.numpy as jnp

from repro.core.approx import ApproxConfig
from repro.models import layers as L

__all__ = ["CNN_NAMES", "init_cnn", "cnn_forward"]

CNN_NAMES = ("lenet", "lenet_plus", "alexnet", "vgg16", "resnet19")


def conv2d(x: jax.Array, w: jax.Array, b, *, stride=1, padding="SAME", cfg: ApproxConfig):
    """x (B,H,W,C) * w (kh,kw,C,O) via im2col + approximate dense."""
    kh, kw, C, O = w.shape
    patches = jax.lax.conv_general_dilated_patches(
        x,
        filter_shape=(kh, kw),
        window_strides=(stride, stride),
        padding=padding,
        dimension_numbers=("NHWC", "HWIO", "NHWC"),
    )  # (B, H', W', C*kh*kw) with channel-slowest flattening
    wmat = jnp.transpose(w, (2, 0, 1, 3)).reshape(C * kh * kw, O)
    y = L.dense(patches, wmat, cfg)
    return y + b


def max_pool(x, window=2, stride=2):
    return jax.lax.reduce_window(
        x, -jnp.inf, jax.lax.max, (1, window, window, 1), (1, stride, stride, 1), "VALID"
    )


def avg_pool(x, window=2, stride=2):
    s = jax.lax.reduce_window(
        x, 0.0, jax.lax.add, (1, window, window, 1), (1, stride, stride, 1), "VALID"
    )
    return s / float(window * window)


def batch_norm(x, gamma, beta, eps=1e-5):
    mu = jnp.mean(x, axis=(0, 1, 2), keepdims=True)
    var = jnp.var(x, axis=(0, 1, 2), keepdims=True)
    return gamma * (x - mu) * jax.lax.rsqrt(var + eps) + beta


# ---------------------------------------------------------------------------
# init helpers
# ---------------------------------------------------------------------------


def _conv_init(key, kh, kw, c, o):
    fan_in = kh * kw * c
    return jax.random.truncated_normal(key, -2, 2, (kh, kw, c, o)) * (2.0 / fan_in) ** 0.5


def _layer_defs(name: str, in_ch: int, num_classes: int):
    """Declarative layer list: (kind, args...)."""
    if name == "lenet":
        return [
            ("conv", 5, 6, 1, "SAME"), ("relu",), ("avgpool",),
            ("conv", 5, 16, 1, "VALID"), ("relu",), ("avgpool",),
            ("flatten",), ("fc", 120), ("relu",), ("fc", 84), ("relu",), ("fc", num_classes),
        ]
    if name == "lenet_plus":   # paper's LeNet+ (extra conv layer)
        return [
            ("conv", 5, 6, 1, "SAME"), ("relu",), ("avgpool",),
            ("conv", 5, 16, 1, "VALID"), ("relu",),
            ("conv", 3, 32, 1, "SAME"), ("relu",), ("avgpool",),
            ("flatten",), ("fc", 120), ("relu",), ("fc", 84), ("relu",), ("fc", num_classes),
        ]
    if name == "alexnet":      # CIFAR-adapted AlexNet
        return [
            ("conv", 3, 64, 1, "SAME"), ("relu",), ("maxpool",),
            ("conv", 3, 192, 1, "SAME"), ("relu",), ("maxpool",),
            ("conv", 3, 384, 1, "SAME"), ("relu",),
            ("conv", 3, 256, 1, "SAME"), ("relu",),
            ("conv", 3, 256, 1, "SAME"), ("relu",), ("maxpool",),
            ("flatten",), ("fc", 1024), ("relu",), ("fc", 512), ("relu",), ("fc", num_classes),
        ]
    if name == "vgg16":
        cfgs = [64, 64, "M", 128, 128, "M", 256, 256, 256, "M", 512, 512, 512, "M", 512, 512, 512, "M"]
        out: List[tuple] = []
        for c in cfgs:
            if c == "M":
                out.append(("maxpool",))
            else:
                out += [("conv", 3, c, 1, "SAME"), ("bn",), ("relu",)]
        out += [("flatten",), ("fc", 512), ("relu",), ("fc", 512), ("relu",), ("fc", num_classes)]
        return out
    if name == "resnet19":
        out = [("conv", 3, 128, 1, "SAME"), ("bn",), ("relu",)]
        for (blocks, ch, stride) in [(3, 128, 1), (3, 256, 2), (2, 512, 2)]:
            for b in range(blocks):
                out.append(("resblock", ch, stride if b == 0 else 1))
        out += [("gap",), ("fc", 256), ("relu",), ("fc", num_classes)]
        return out
    raise KeyError(name)


def init_cnn(name: str, key, *, in_shape=(32, 32, 3), num_classes: int = 10) -> Dict[str, Any]:
    """Shape-inferring init. Returns {"layers": [per-layer param dicts]}."""
    defs = _layer_defs(name, in_shape[-1], num_classes)
    params: List[Dict[str, Any]] = []
    h, w, c = in_shape
    for d in defs:
        key, sub = jax.random.split(key)
        kind = d[0]
        if kind == "conv":
            ksz, o, stride, pad = d[1], d[2], d[3], d[4]
            params.append({"w": _conv_init(sub, ksz, ksz, c, o), "b": jnp.zeros((o,))})
            h = h // stride if pad == "SAME" else (h - ksz) // stride + 1
            w = w // stride if pad == "SAME" else (w - ksz) // stride + 1
            c = o
        elif kind == "bn":
            params.append({"gamma": jnp.ones((c,)), "beta": jnp.zeros((c,))})
        elif kind == "resblock":
            ch, stride = d[1], d[2]
            k1, k2, k3 = jax.random.split(sub, 3)
            blk = {
                "w1": _conv_init(k1, 3, 3, c, ch), "b1": jnp.zeros((ch,)),
                "g1": jnp.ones((ch,)), "be1": jnp.zeros((ch,)),
                "w2": _conv_init(k2, 3, 3, ch, ch), "b2": jnp.zeros((ch,)),
                "g2": jnp.ones((ch,)), "be2": jnp.zeros((ch,)),
            }
            if stride != 1 or c != ch:
                blk["wp"] = _conv_init(k3, 1, 1, c, ch)
                blk["bp"] = jnp.zeros((ch,))
            params.append(blk)
            h, w, c = h // stride, w // stride, ch
        elif kind in ("maxpool", "avgpool"):
            params.append({})
            h, w = h // 2, w // 2
        elif kind == "gap":
            params.append({})
            h = w = 1
        elif kind == "flatten":
            params.append({})
            c = h * w * c
            h = w = 1
        elif kind == "fc":
            o = d[1]
            params.append({"w": L.init_dense(sub, c, o), "b": jnp.zeros((o,))})
            c = o
        elif kind == "relu":
            params.append({})
        else:
            raise KeyError(kind)
    return {"name": name, "layers": params, "defs": defs}


def cnn_forward(model: Dict[str, Any], x: jax.Array, cfg: ApproxConfig) -> jax.Array:
    """x (B,H,W,C) float -> logits (B, classes)."""
    for d, p in zip(model["defs"], model["layers"]):
        kind = d[0]
        if kind == "conv":
            x = conv2d(x, p["w"], p["b"], stride=d[3], padding=d[4], cfg=cfg)
        elif kind == "bn":
            x = batch_norm(x, p["gamma"], p["beta"])
        elif kind == "relu":
            x = jax.nn.relu(x)
        elif kind == "maxpool":
            x = max_pool(x)
        elif kind == "avgpool":
            x = avg_pool(x)
        elif kind == "gap":
            x = jnp.mean(x, axis=(1, 2), keepdims=False)
        elif kind == "flatten":
            x = x.reshape(x.shape[0], -1)
        elif kind == "fc":
            x = L.dense(x, p["w"], cfg) + p["b"]
        elif kind == "resblock":
            stride = d[2]
            h = conv2d(x, p["w1"], p["b1"], stride=stride, padding="SAME", cfg=cfg)
            h = jax.nn.relu(batch_norm(h, p["g1"], p["be1"]))
            h = conv2d(h, p["w2"], p["b2"], stride=1, padding="SAME", cfg=cfg)
            h = batch_norm(h, p["g2"], p["be2"])
            sc = x
            if "wp" in p:
                sc = conv2d(x, p["wp"], p["bp"], stride=stride, padding="SAME", cfg=cfg)
            x = jax.nn.relu(h + sc)
        else:
            raise KeyError(kind)
    return x
