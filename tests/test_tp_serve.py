"""Tensor-parallel serving: parity with the single-device paged oracle.

Every end-to-end test runs in a subprocess with
``XLA_FLAGS=--xla_force_host_platform_device_count=8`` so the forced device
count never leaks into this process.  The contract (ISSUE PR-8): on a
``(tp,)``-device ``"model"`` mesh the greedy tokens are bit-identical to the
no-mesh session, the jit caches see zero recompiles after warmup, and the
per-device KV-pool footprint scales as ``1/tp``.
"""
import json
import os
import subprocess
import sys
import textwrap

import pytest

from repro.kernels.paged_attention import validate_tp_heads

_REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))

_HARNESS = textwrap.dedent(
    """
    import os
    if "xla_force_host_platform_device_count" not in os.environ.get("XLA_FLAGS", ""):
        os.environ["XLA_FLAGS"] = (os.environ.get("XLA_FLAGS", "")
                                   + " --xla_force_host_platform_device_count=8").strip()
    import dataclasses, json
    import numpy as np
    import jax
    from repro.configs import get_config, reduced_config
    from repro.models.transformer import init_params
    from repro.serve.scheduler import ServeSession, scheduler_compile_stats

    cfg = dataclasses.replace(
        reduced_config(get_config("granite-3-2b")),
        num_layers=2, d_model=64, num_heads=4, num_kv_heads=4, head_dim=16,
        d_ff=128, vocab_size=512, remat=False, q_chunk=64, dtype="float32",
    )
    params = init_params(cfg, jax.random.PRNGKey(0))

    def trace():
        rng = np.random.default_rng(0)
        return [(rng.integers(0, 512, int(rng.integers(4, 14))).astype(np.int32), 8)
                for _ in range(4)]

    def serve(mesh, **kw):
        sess = ServeSession(cfg, params, num_slots=2, max_len=64,
                            prompt_buckets=(16,), cache_layout="paged",
                            block_size=8, num_blocks=32, mesh=mesh, **kw)
        sess.warmup()
        for i, (p, n) in enumerate(trace()):
            sess.submit(p, max_new=n, req_id=i)
        before = sum(scheduler_compile_stats().values())
        res = sess.run()
        rec = sum(scheduler_compile_stats().values()) - before
        return res, rec, sess
    """
)


def _run(script, timeout=420):
    env = dict(os.environ, PYTHONPATH="src")
    out = subprocess.run(
        [sys.executable, "-c", script], capture_output=True, text=True,
        env=env, cwd=_REPO, timeout=timeout,
    )
    assert out.returncode == 0, out.stderr[-2000:]
    return json.loads(out.stdout.strip().splitlines()[-1])


def _compare_script(arms_kw: str, tps=(2,)) -> str:
    return _HARNESS + textwrap.dedent(
        f"""
        rows = []
        for kw in {arms_kw}:
            r0, rec0, s0 = serve(None, **kw)
            for tp in {tuple(tps)}:
                mesh = jax.make_mesh((tp,), ("model",))
                r, rec, s = serve(mesh, **kw)
                mm = sum(int(not np.array_equal(r0[i].tokens, r[i].tokens))
                         for i in r0)
                rows.append(dict(
                    tp=tp, kw=repr(kw), recompiles=rec, mismatches=mm,
                    oracle_recompiles=rec0,
                    bytes_dev=s.stats.peak_block_bytes_per_device,
                    bytes_oracle=s0.stats.peak_block_bytes_per_device,
                    ticks=s.stats.ticks, oracle_ticks=s0.stats.ticks,
                    stats_tp=s.stats.tp, stats_devices=s.stats.devices,
                ))
        print(json.dumps(rows))
        """
    )


def _check(rows):
    for r in rows:
        ctx = r["kw"] + f" tp={r['tp']}"
        assert r["mismatches"] == 0, f"token mismatch under mesh: {ctx}"
        assert r["recompiles"] == 0, f"recompiles after warmup: {ctx}"
        assert r["oracle_recompiles"] == 0, ctx
        # tick-for-tick schedule parity: same trace, same tick count
        assert r["ticks"] == r["oracle_ticks"], ctx
        # the paged pool footprint shards exactly 1/tp per device
        assert r["bytes_dev"] * r["tp"] == r["bytes_oracle"], ctx
        assert r["stats_tp"] == r["tp"] and r["stats_devices"] == r["tp"], ctx


def test_tp2_parity_dense_subprocess():
    """Fast tier-1 gate: tp=2, dense attention, greedy decode."""
    _check(_run(_compare_script("[{}]", tps=(2,))))


@pytest.mark.slow
def test_tp_parity_matrix_subprocess():
    """tp in {2, 4} x {dense, pallas shard_map, exact spec decode}."""
    arms = ("[{}, {'attn_impl': 'pallas'}, "
            "{'spec_decode': True, 'draft_k': 2, 'draft_mode': 'exact'}]")
    _check(_run(_compare_script(arms, tps=(2, 4)), timeout=600))


def test_validate_tp_heads():
    validate_tp_heads(8, 4, 2)          # 4 q / 2 kv heads per shard
    validate_tp_heads(4, 4, 4)          # MHA, one head per shard
    with pytest.raises(ValueError):
        validate_tp_heads(8, 4, 0)      # degenerate tp
    with pytest.raises(ValueError):
        validate_tp_heads(8, 4, 3)      # heads not divisible
    with pytest.raises(ValueError):
        validate_tp_heads(8, 2, 4)      # kv heads not divisible
    with pytest.raises(ValueError):
        validate_tp_heads(12, 8, 4)     # per-shard GQA ratio fractional


def test_mesh_ctor_validation():
    """Mesh plumbing rejects unsupported layouts without needing >1 device."""
    import dataclasses

    import jax
    import numpy as np

    from repro.configs import get_config, reduced_config
    from repro.models.transformer import init_params
    from repro.serve.scheduler import ServeSession

    cfg = dataclasses.replace(
        reduced_config(get_config("granite-3-2b")),
        num_layers=1, d_model=32, num_heads=2, num_kv_heads=2, head_dim=16,
        d_ff=64, vocab_size=128, remat=False, q_chunk=32, dtype="float32",
    )
    params = init_params(cfg, jax.random.PRNGKey(0))
    mesh = jax.make_mesh((1,), ("model",))
    with pytest.raises(ValueError, match="paged"):
        ServeSession(cfg, params, num_slots=2, max_len=32, prompt_buckets=(8,),
                     cache_layout="slots", mesh=mesh)
    with pytest.raises(ValueError, match="model"):
        ServeSession(cfg, params, num_slots=2, max_len=32, prompt_buckets=(8,),
                     cache_layout="paged", block_size=8, num_blocks=16,
                     mesh=mesh, tp_axis="tp")
    # tp=1 mesh is a degenerate but valid configuration
    sess = ServeSession(cfg, params, num_slots=2, max_len=32,
                        prompt_buckets=(8,), cache_layout="paged",
                        block_size=8, num_blocks=16, mesh=mesh)
    sess.warmup()
    sess.submit(np.arange(4, dtype=np.int32), max_new=4)
    res = sess.run()
    assert len(res[0].tokens) == 4
    assert sess.stats.tp == 1 and sess.stats.devices == 1
