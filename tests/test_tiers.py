"""Quality-tier serving invariants (the MSR/approx execution-mode ladder).

* **per-request bit-parity**: a mixed-tier session's greedy outputs are
  bit-identical, request by request, to single-mode oracle sessions that
  serve each rung's requests alone — across both host loops (sync/async),
  both KV layouts (slots/paged), and both paged attention impls
  (gather/pallas).  This is the load-bearing contract: per-row activation
  scales (``act_per_row``) make batch rows independent, and the per-rung
  dispatch masking (sentinel tables / OOB ``cur_len``) makes non-rung rows
  write-inert, so batch composition can never leak across rungs.
* **zero recompiles across tier mixes**: after ``warmup()`` every rung's
  decode tick and admit program is compiled; serving any mix of rungs
  afterwards must hit only cached programs.
* **shed/restore hysteresis**: a burst beyond ``shed_queue_depth`` demotes
  new admissions down the ladder (one rung per breach step); after the
  queue drains, ``shed_hold_steps`` consecutive healthy steps restore one
  rung at a time until back at the requested rung.
* constructor/submit validation fails loudly.

Marked ``slow``: CI runs this file in the kernel-differential step under
``REPRO_FORCE_INTERPRET=1`` so the MSR rung exercises the real Pallas
kernel body.
"""
import dataclasses

import jax
import numpy as np
import pytest

from repro.configs import get_config, reduced_config
from repro.serve import (
    ServeSession,
    resolve_execution_mode,
    scheduler_compile_stats,
)

pytestmark = pytest.mark.slow

KEY = jax.random.PRNGKey(0)
TIERS = ("exact", "approx_lowrank", "approx_msr")
TIER_MULTIPLIER = "mul8x8_2"


def _cfg(**over):
    return dataclasses.replace(
        reduced_config(get_config("granite-3-2b")), remat=False, q_chunk=16,
        **over,
    )


_PARAMS = {}


def _params(cfg):
    if cfg.name not in _PARAMS:
        from repro.models.transformer import init_params

        _PARAMS[cfg.name] = init_params(cfg, KEY)
    return _PARAMS[cfg.name]


def _session(cfg, **over):
    kw = dict(num_slots=3, max_len=32, prompt_buckets=(4, 8))
    kw.update(over)
    return ServeSession(cfg, _params(cfg), **kw)


def _tier_trace(rng, n):
    """[(req_id, prompt, max_new, tier)] — rungs round-robin plus a
    tier=None request (defaults to the best rung)."""
    out = []
    for i in range(n):
        p = rng.integers(0, 512, int(rng.integers(2, 9)))
        tier = None if i == n - 1 else TIERS[i % len(TIERS)]
        out.append((i, p, int(rng.integers(2, 6)), tier))
    return out


def _serve(cfg, trace, **over):
    sess = _session(cfg, **over)
    for rid, p, n, tier in trace:
        sess.submit(p, max_new=n, req_id=rid, tier=tier)
    return sess.run()


@pytest.mark.parametrize(
    "loop,layout,attn",
    [
        ("sync", "slots", None),
        ("sync", "paged", "gather"),
        ("async", "slots", None),
        ("async", "paged", "gather"),
        ("async", "paged", "pallas"),
    ],
)
def test_mixed_tiers_bit_identical_to_single_mode_oracles(loop, layout, attn):
    cfg = _cfg()
    rng = np.random.default_rng(3)
    trace = _tier_trace(rng, 7)
    kw = dict(loop=loop, cache_layout=layout)
    if layout == "paged":
        kw.update(block_size=8, attn_impl=attn)

    mixed = _serve(cfg, trace, tiers=TIERS, tier_multiplier=TIER_MULTIPLIER,
                   **kw)
    assert set(mixed) == {rid for rid, *_ in trace}

    for t in TIERS:
        mine = [(rid, p, n, tier) for rid, p, n, tier in trace
                if (tier or TIERS[0]) == t]
        if not mine:
            continue
        # single-mode oracle: same execution config, no tier routing at all
        ocfg = dataclasses.replace(
            cfg, approx=resolve_execution_mode(t, TIER_MULTIPLIER,
                                               act_per_row=True))
        oracle = _serve(ocfg, [(rid, p, n, None) for rid, p, n, _ in mine],
                        **kw)
        for rid, *_ in mine:
            assert mixed[rid].tier == t
            assert np.array_equal(mixed[rid].tokens, oracle[rid].tokens), (
                loop, layout, attn, t, rid)


def test_zero_recompiles_across_tier_mixes():
    """One warmed session serves three different rung mixes back to back —
    all-exact, round-robin, all-MSR — with zero new compiles."""
    cfg = _cfg()
    rng = np.random.default_rng(5)
    sess = _session(cfg, loop="async", cache_layout="paged", block_size=8,
                    tiers=TIERS, tier_multiplier=TIER_MULTIPLIER)
    sess.warmup()
    before = dict(scheduler_compile_stats())
    mixes = (
        [TIERS[0]] * 4,
        [TIERS[i % len(TIERS)] for i in range(5)],
        [TIERS[-1]] * 4,
    )
    rid = 0
    for mix in mixes:
        for t in mix:
            p = rng.integers(0, 512, int(rng.integers(2, 9)))
            sess.submit(p, max_new=int(rng.integers(2, 5)), req_id=rid, tier=t)
            rid += 1
        sess.run()
    assert scheduler_compile_stats() == before
    assert len(sess.results) == rid


def test_shed_demotes_and_hysteresis_restores():
    cfg = _cfg()
    rng = np.random.default_rng(11)
    hold = 4
    sess = _session(cfg, num_slots=2, cache_layout="paged", block_size=8,
                    tiers=TIERS, tier_multiplier=TIER_MULTIPLIER,
                    shed_queue_depth=2, shed_hold_steps=hold)
    for i in range(10):
        p = rng.integers(0, 512, int(rng.integers(2, 9)))
        sess.submit(p, max_new=3, req_id=i, arrival=0)
    sess.run()
    st = sess.stats
    assert st.tier_demotions >= 1
    served = {r.tier for r in sess.results.values()}
    assert served & set(TIERS[1:]), "spike never demoted an admission"
    assert all(r.tier in TIERS for r in sess.results.values())
    # restores are lazy: they need healthy steps to accumulate the hold
    for _ in range(2 * hold * len(TIERS)):
        sess.step()
    assert sess.stats.shed_level == 0
    assert sess.stats.tier_restorations >= 1
    # post-drain admissions serve at the requested rung again
    sess.submit(rng.integers(0, 512, 4), max_new=2, req_id=99)
    res = sess.run()
    assert res[99].tier == TIERS[0]


def test_tier_gauges_track_active_rungs():
    cfg = _cfg()
    sess = _session(cfg, tiers=TIERS)
    sess.submit(np.arange(1, 5), max_new=2, req_id=0, tier="approx_msr")
    res = sess.run()
    assert res[0].tier == "approx_msr"
    # gauge decays back to zero once everything released
    assert all(v == 0 for v in sess.stats.active_per_tier.values())


def test_tiers_validation():
    cfg = _cfg()
    with pytest.raises(ValueError, match="tiers"):
        _session(cfg, tiers=())
    with pytest.raises(ValueError, match="duplicate"):
        _session(cfg, tiers=("exact", "exact"))
    with pytest.raises(ValueError, match="execution mode"):
        _session(cfg, tiers=("exact", "nope"))
    with pytest.raises(ValueError, match="spec_decode"):
        _session(cfg, cache_layout="paged", block_size=8, spec_decode=True,
                 tiers=TIERS)
    with pytest.raises(ValueError, match="shed"):
        _session(cfg, shed_queue_depth=4)           # shedder needs a ladder
    with pytest.raises(ValueError, match="shed"):
        _session(cfg, tiers=("exact",), shed_queue_depth=4)

    sess = _session(cfg)
    with pytest.raises(ValueError, match="tier"):
        sess.submit(np.arange(1, 4), max_new=2, tier="exact")
    tiered = _session(cfg, tiers=TIERS)
    with pytest.raises(ValueError, match="tier"):
        tiered.submit(np.arange(1, 4), max_new=2, tier="nope")
