"""Roofline machinery: HLO collective parsing, HBM traffic model, terms."""
import pytest

from repro.launch.roofline import (
    CollectiveStats,
    estimate_hbm_bytes,
    parse_collectives,
    roofline_terms,
    PEAK_FLOPS,
)

HLO = """
HloModule jit_f
ENTRY %main {
  %p0 = f32[16,1024]{1,0} parameter(0)
  %ag = f32[1024,128]{1,0} all-gather(%p0), channel_id=1, replica_groups=[16,16]<=[16,16]T(1,0), dimensions={0}
  %dot = f32[16,128]{1,0} dot(%p0, %ag), lhs_contracting_dims={1}, rhs_contracting_dims={0}
  %ar = f32[256,64]{1,0} all-reduce(%dot), channel_id=3, replica_groups={{0,1,2,3}}, to_apply=%add
  %rs = f32[64,64]{1,0} reduce-scatter(%ar), channel_id=4, replica_groups=[8,2]<=[16], dimensions={0}
  %cp = f32[8,8]{1,0} collective-permute(%rs), channel_id=5, source_target_pairs={{0,1}}
  ROOT %out = f32[64,64]{1,0} add(%rs, %rs)
}
"""


def test_parse_collectives():
    st = parse_collectives(HLO)
    assert st.counts["all-gather"] == 1
    assert st.counts["all-reduce"] == 1
    assert st.counts["reduce-scatter"] == 1
    ag = 1024 * 128 * 4
    assert st.per_op["all-gather"] == pytest.approx((16 - 1) / 16 * ag)
    ar = 256 * 64 * 4
    assert st.per_op["all-reduce"] == pytest.approx(2 * (4 - 1) / 4 * ar)
    rs = 64 * 64 * 4
    assert st.per_op["reduce-scatter"] == pytest.approx((2 - 1) / 2 * rs)
    # collective-permute has no replica_groups= -> group size 1 -> skipped
    assert st.total_bytes > 0


def test_estimate_hbm_bytes_counts_dots_not_elementwise():
    b = estimate_hbm_bytes(HLO)
    # dot: p0 (64KB) + ag (512KB) + out (8KB); add excluded; collectives incl.
    assert b >= 16 * 1024 * 4 + 1024 * 128 * 4 + 16 * 128 * 4


def test_roofline_terms_dominance():
    t = roofline_terms(
        flops_per_device=1.97e14,      # 1s of compute
        bytes_per_device=8.19e10,      # 0.1s of HBM
        wire_bytes_per_device=5e9,     # 0.1s of ICI
        n_devices=256,
        model_flops_global=1.97e14 * 256 / 2,
    )
    assert t["bound"] == "compute"
    assert t["t_compute_s"] == pytest.approx(1.0)
    assert t["useful_flop_fraction"] == pytest.approx(0.5)
    assert t["roofline_fraction"] == pytest.approx(0.5)


def test_roofline_fraction_definition():
    t = roofline_terms(
        flops_per_device=1e12,
        bytes_per_device=1e12,         # memory bound
        wire_bytes_per_device=0,
        n_devices=4,
        model_flops_global=4e12,
    )
    assert t["bound"] == "memory"
    # model flops per chip-second at the bound vs peak
    expect = (4e12 / (1e12 / 819e9)) / 4 / PEAK_FLOPS
    assert t["roofline_fraction"] == pytest.approx(expect)
