"""Checkpointing: atomic snapshots, keep-k, auto-resume, elastic restore."""
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.train.checkpoint import (
    latest_step,
    list_steps,
    restore_checkpoint,
    save_checkpoint,
)


def _tree(seed=0):
    k = jax.random.PRNGKey(seed)
    return {
        "params": {"w": jax.random.normal(k, (8, 4)), "b": jnp.zeros((4,))},
        "opt": {"m": {"w": jnp.ones((8, 4)), "b": jnp.ones((4,))}, "step": jnp.int32(7)},
    }


def test_save_restore_roundtrip(tmp_path):
    d = str(tmp_path / "ckpt")
    tree = _tree()
    save_checkpoint(d, 3, tree)
    restored, step = restore_checkpoint(d, jax.tree.map(lambda x: x, tree))
    assert step == 3
    for a, b in zip(jax.tree.leaves(tree), jax.tree.leaves(restored)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_keep_k_gc(tmp_path):
    d = str(tmp_path / "ckpt")
    for s in range(6):
        save_checkpoint(d, s, _tree(s), keep=3)
    assert list_steps(d) == [3, 4, 5]
    assert latest_step(d) == 5


def test_latest_pointer_crash_fallback(tmp_path):
    d = str(tmp_path / "ckpt")
    save_checkpoint(d, 1, _tree())
    save_checkpoint(d, 2, _tree())
    # simulate a crash that corrupted LATEST
    with open(os.path.join(d, "LATEST"), "w") as f:
        f.write("garbage")
    assert latest_step(d) == 2


def test_restore_missing_raises(tmp_path):
    with pytest.raises(FileNotFoundError):
        restore_checkpoint(str(tmp_path / "none"), _tree())


def test_elastic_restore_different_sharding(tmp_path):
    """A checkpoint saved under one device layout restores under another:
    leaves are logical arrays; shardings are applied at restore time."""
    d = str(tmp_path / "ckpt")
    tree = _tree()
    save_checkpoint(d, 9, tree)
    mesh = jax.make_mesh((1,), ("data",))
    from jax.sharding import NamedSharding, PartitionSpec as P

    sh = jax.tree.map(lambda _: NamedSharding(mesh, P()), tree)
    restored, step = restore_checkpoint(d, tree, shardings=sh)
    assert step == 9
    for leaf in jax.tree.leaves(restored):
        assert isinstance(leaf.sharding, NamedSharding)


def test_resume_training_state(tmp_path):
    """checkpoint/restart: resume from the latest snapshot and continue."""
    import dataclasses

    from repro.configs import get_config, reduced_config
    from repro.data.synthetic import token_batches
    from repro.train import optim as O
    from repro.train.loop import init_state, make_train_step

    cfg = dataclasses.replace(
        reduced_config(get_config("granite-3-2b")),
        num_layers=1, d_model=32, num_heads=2, num_kv_heads=1, head_dim=16,
        d_ff=64, vocab_size=64, remat=False,
    )
    opt = O.OptConfig(lr=1e-3, total_steps=10)
    state = init_state(cfg, opt, jax.random.PRNGKey(0))
    step_fn = jax.jit(make_train_step(cfg, opt))
    toks, labels = next(token_batches(cfg.vocab_size, 2, 8))
    batch = {"tokens": jnp.asarray(toks), "labels": jnp.asarray(labels)}
    state, _ = step_fn(state, batch)
    d = str(tmp_path / "ck")
    save_checkpoint(d, 1, state)
    restored, step = restore_checkpoint(d, jax.eval_shape(lambda: state))
    assert step == 1
    state2, m = step_fn(restored, batch)
    assert np.isfinite(float(m["loss"]))
