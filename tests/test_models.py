"""Per-architecture smoke tests (reduced configs, CPU) + layer correctness
against naive references."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config, list_archs, reduced_config
from repro.core.approx import ApproxConfig
from repro.models import ssm as S
from repro.models.attention import attention_core
from repro.models.moe import MoEParams, init_moe, moe_ffn
from repro.models.transformer import decode_step, forward, init_cache, init_params

ARCHS = list_archs()
KEY = jax.random.PRNGKey(0)


def _batch(cfg, B, S_):
    if cfg.embed_input:
        return {"tokens": jnp.zeros((B, S_), jnp.int32)}
    return {"embeddings": jax.random.normal(KEY, (B, S_, cfg.d_model), jnp.float32)}


def test_all_ten_archs_registered():
    assert len(ARCHS) == 10
    for a in (
        "musicgen-large", "yi-34b", "granite-3-2b", "deepseek-7b",
        "deepseek-coder-33b", "falcon-mamba-7b", "qwen2-moe-a2.7b",
        "grok-1-314b", "qwen2-vl-2b", "zamba2-2.7b",
    ):
        assert a in ARCHS


@pytest.mark.parametrize("arch", ARCHS)
def test_arch_smoke_forward_and_train_step(arch):
    """Reduced config: one forward + one train grad step, shape + finiteness."""
    cfg = reduced_config(get_config(arch))
    params = init_params(cfg, KEY)
    B, S_ = 2, 16
    batch = _batch(cfg, B, S_)
    logits, aux = jax.jit(lambda p, b: forward(cfg, p, b))(params, batch)
    assert logits.shape == (B, S_, cfg.padded_vocab)
    assert bool(jnp.all(jnp.isfinite(logits)))

    def loss(p):
        lg, a = forward(cfg, p, batch)
        return jnp.mean(lg[..., : cfg.vocab_size] ** 2) + 0.01 * a

    g = jax.jit(jax.grad(loss))(params)
    assert all(bool(jnp.all(jnp.isfinite(x))) for x in jax.tree.leaves(g))


@pytest.mark.parametrize("arch", ARCHS)
def test_arch_smoke_decode(arch):
    cfg = reduced_config(get_config(arch))
    params = init_params(cfg, KEY)
    B = 2
    cache = init_cache(cfg, B, 32, jnp.float32)
    db = _batch(cfg, B, 1)
    logits, cache2 = jax.jit(lambda p, c, b, l: decode_step(cfg, p, c, b, l))(
        params, cache, db, jnp.zeros((B,), jnp.int32)
    )
    assert logits.shape == (B, 1, cfg.padded_vocab)
    assert bool(jnp.all(jnp.isfinite(logits)))
    assert jax.tree.structure(cache) == jax.tree.structure(cache2)


def test_decode_matches_forward_dense():
    """Teacher-forced decode through the KV cache must reproduce the
    train-path logits (float mode, dense arch)."""
    cfg = dataclasses.replace(
        reduced_config(get_config("granite-3-2b")), remat=False, q_chunk=64
    )
    params = init_params(cfg, KEY)
    B, S_ = 2, 12
    toks = jax.random.randint(KEY, (B, S_), 0, cfg.vocab_size)
    ref, _ = forward(cfg, params, {"tokens": toks})
    cache = init_cache(cfg, B, S_, jnp.float32)
    outs = []
    for i in range(S_):
        lg, cache = decode_step(
            cfg, params, cache, {"tokens": toks[:, i : i + 1]},
            jnp.full((B,), i, jnp.int32),
        )
        outs.append(lg[:, 0])
    got = jnp.stack(outs, axis=1)
    np.testing.assert_allclose(np.asarray(got), np.asarray(ref), rtol=2e-2, atol=2e-3)


def test_decode_matches_forward_ssm():
    cfg = dataclasses.replace(reduced_config(get_config("falcon-mamba-7b")), remat=False)
    params = init_params(cfg, KEY)
    B, S_ = 1, 8
    toks = jax.random.randint(KEY, (B, S_), 0, cfg.vocab_size)
    ref, _ = forward(cfg, params, {"tokens": toks})
    cache = init_cache(cfg, B, S_, jnp.float32)
    outs = []
    for i in range(S_):
        lg, cache = decode_step(
            cfg, params, cache, {"tokens": toks[:, i : i + 1]},
            jnp.full((B,), i, jnp.int32),
        )
        outs.append(lg[:, 0])
    got = jnp.stack(outs, axis=1)
    np.testing.assert_allclose(np.asarray(got), np.asarray(ref), rtol=2e-2, atol=2e-3)


def test_attention_core_vs_naive():
    B, S_, H, hd = 2, 32, 4, 16
    q = jax.random.normal(KEY, (B, S_, H, hd), jnp.float32)
    k = jax.random.normal(jax.random.PRNGKey(1), (B, S_, 2, hd), jnp.float32)
    v = jax.random.normal(jax.random.PRNGKey(2), (B, S_, 2, hd), jnp.float32)
    out = attention_core(q, k, v, causal=True, q_chunk=8)
    # naive reference
    kr = jnp.repeat(k, 2, axis=2)
    vr = jnp.repeat(v, 2, axis=2)
    s = jnp.einsum("bqhd,bkhd->bhqk", q, kr) / np.sqrt(hd)
    mask = jnp.tril(jnp.ones((S_, S_), bool))
    s = jnp.where(mask, s, -1e30)
    ref = jnp.einsum("bhqk,bkhd->bqhd", jax.nn.softmax(s, -1), vr)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref), rtol=2e-2, atol=2e-3)


def test_mamba1_scan_vs_naive_recurrence():
    B, S_, di, N = 1, 16, 4, 3
    rng = np.random.default_rng(0)
    dA = jnp.asarray(rng.uniform(0.5, 0.99, (B, S_, di, N)), jnp.float32)
    dBx = jnp.asarray(rng.normal(size=(B, S_, di, N)), jnp.float32)
    h0 = jnp.zeros((B, di, N))
    h_all, h_last = S._selective_scan_chunked(dA, dBx, h0, chunk=4)
    h = np.zeros((B, di, N), np.float32)
    for t in range(S_):
        h = np.asarray(dA[:, t]) * h + np.asarray(dBx[:, t])
        np.testing.assert_allclose(np.asarray(h_all[:, t]), h, rtol=1e-4, atol=1e-5)
    np.testing.assert_allclose(np.asarray(h_last), h, rtol=1e-4, atol=1e-5)


def test_mamba2_ssd_vs_naive_recurrence():
    B, S_, nh, hd, N = 1, 12, 2, 4, 3
    rng = np.random.default_rng(1)
    X = jnp.asarray(rng.normal(size=(B, S_, nh, hd)), jnp.float32)
    a = jnp.asarray(rng.uniform(-0.5, -0.01, (B, S_, nh)), jnp.float32)
    Bm = jnp.asarray(rng.normal(size=(B, S_, N)), jnp.float32)
    Cm = jnp.asarray(rng.normal(size=(B, S_, N)), jnp.float32)
    h0 = jnp.zeros((B, nh, hd, N))
    Y, h_last = S.ssd_chunked(X, a, Bm, Cm, h0, chunk=4)
    # naive: h_t = exp(a_t) h_{t-1} + X_t B_t^T ; y_t = h_t C_t
    h = np.zeros((B, nh, hd, N), np.float32)
    for t in range(S_):
        dec = np.exp(np.asarray(a[:, t]))[:, :, None, None]
        h = dec * h + np.einsum("bhd,bn->bhdn", np.asarray(X[:, t]), np.asarray(Bm[:, t]))
        y = np.einsum("bhdn,bn->bhd", h, np.asarray(Cm[:, t]))
        np.testing.assert_allclose(np.asarray(Y[:, t]), y, rtol=1e-3, atol=1e-4)


def test_moe_capacity_and_combine():
    T, d, E, ff = 32, 8, 4, 16
    p = init_moe(KEY, d, ff, E, shared_d_ff=8)
    x = jax.random.normal(KEY, (T, d), jnp.float32)
    out, aux = moe_ffn(x, p, top_k=2, cfg=ApproxConfig(mode="float"))
    assert out.shape == (T, d)
    assert bool(jnp.all(jnp.isfinite(out)))
    assert float(aux) > 0
    # unrolled experts path must agree exactly
    out2, _ = moe_ffn(x, p, top_k=2, cfg=ApproxConfig(mode="float"), unroll_experts=True)
    np.testing.assert_allclose(np.asarray(out), np.asarray(out2), rtol=1e-5, atol=1e-6)


def test_scan_vs_unrolled_layers():
    cfg = reduced_config(get_config("granite-3-2b"))
    params = init_params(cfg, KEY)
    batch = _batch(cfg, 2, 8)
    l1, _ = forward(cfg, params, batch)
    l2, _ = forward(dataclasses.replace(cfg, scan_layers=False), params, batch)
    np.testing.assert_allclose(np.asarray(l1), np.asarray(l2), rtol=1e-4, atol=1e-5)
