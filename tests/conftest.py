"""Shared test fixtures: src importability, deterministic seeding, and the
session-wide Pallas interpret-mode flag for non-TPU backends."""
from __future__ import annotations

import os
import pathlib
import sys

# `PYTHONPATH`-free importability: pyproject.toml sets pythonpath=["src"] for
# pytest>=7; this fallback covers direct module imports and older runners.
_SRC = str(pathlib.Path(__file__).resolve().parents[1] / "src")
if _SRC not in sys.path:
    sys.path.insert(0, _SRC)

import numpy as np
import pytest


@pytest.fixture(scope="session", autouse=True)
def pallas_interpret_off_tpu():
    """Force Pallas kernels into interpret mode for the whole session when no
    TPU is attached (kernels/approx_matmul/ops.py honors the env flag)."""
    import jax

    if jax.default_backend() != "tpu":
        os.environ["REPRO_FORCE_INTERPRET"] = "1"
    yield


@pytest.fixture(autouse=True)
def _seed_global_numpy():
    """Legacy global-state RNG users get a fixed seed per test."""
    np.random.seed(0)
    yield


@pytest.fixture
def rng():
    """Deterministic numpy Generator."""
    return np.random.default_rng(0)


@pytest.fixture
def key():
    """Deterministic jax PRNG key."""
    import jax

    return jax.random.PRNGKey(0)
