"""Optional-``hypothesis`` shim for the property-based tests.

When ``hypothesis`` is installed (see requirements-dev.txt) the real
``given``/``settings``/``strategies`` are re-exported unchanged.  When it is
absent (minimal CI images, the baked container), a deterministic fallback
runs each property test over a fixed number of pseudo-random examples drawn
with ``random.Random`` seeded from the test name — same assertions, reduced
(but reproducible) coverage, zero collection errors either way.
"""
from __future__ import annotations

try:
    from hypothesis import given, settings, strategies  # noqa: F401

    HAVE_HYPOTHESIS = True
except ModuleNotFoundError:
    import inspect
    import random
    import types

    HAVE_HYPOTHESIS = False

    _FALLBACK_MAX_EXAMPLES = 10

    class _Strategy:
        def __init__(self, draw):
            self._draw = draw

        def example(self, rng: random.Random):
            return self._draw(rng)

    def _integers(min_value: int, max_value: int) -> _Strategy:
        return _Strategy(lambda rng: rng.randint(min_value, max_value))

    def _sampled_from(elements) -> _Strategy:
        seq = list(elements)
        return _Strategy(lambda rng: seq[rng.randrange(len(seq))])

    strategies = types.SimpleNamespace(integers=_integers, sampled_from=_sampled_from)

    def settings(max_examples: int | None = None, **_ignored):
        def deco(fn):
            fn._compat_max_examples = max_examples
            return fn

        return deco

    def given(*strats: _Strategy):
        def deco(fn):
            def wrapper():
                n = min(
                    getattr(wrapper, "_compat_max_examples", None)
                    or _FALLBACK_MAX_EXAMPLES,
                    _FALLBACK_MAX_EXAMPLES,
                )
                rng = random.Random(fn.__qualname__)
                for _ in range(n):
                    fn(*(s.example(rng) for s in strats))

            wrapper.__name__ = fn.__name__
            wrapper.__doc__ = fn.__doc__
            wrapper.__module__ = fn.__module__
            if hasattr(fn, "pytestmark"):
                wrapper.pytestmark = fn.pytestmark
            # empty signature: pytest must not mistake property args for fixtures
            wrapper.__signature__ = inspect.Signature()
            return wrapper

        return deco
