"""Hardware cost model + synthetic data pipeline."""
import numpy as np
import pytest

from repro.core.hwcost import (
    PAPER_TABLE_VI,
    PAPER_TABLE_VII,
    systolic_array_cost,
    unit_gate_estimate,
)
from repro.data.synthetic import image_dataset, token_batches


def test_paper_improvements_match_printed():
    base = PAPER_TABLE_VI["exact3x3"]
    imp1 = PAPER_TABLE_VI["mul3x3_1"].improvement_over(base)
    imp2 = PAPER_TABLE_VI["mul3x3_2"].improvement_over(base)
    assert imp1["area_pct"] == pytest.approx(36.17, abs=0.05)
    assert imp2["area_pct"] == pytest.approx(31.38, abs=0.05)
    assert imp1["power_pct"] == pytest.approx(35.66, abs=0.05)
    assert imp2["power_pct"] == pytest.approx(36.73, abs=0.05)
    assert imp1["delay_pct"] == pytest.approx(42.22, abs=0.05)
    base8 = PAPER_TABLE_VII["exact8x8"]
    for name, area in [("mul8x8_1", 19.93), ("mul8x8_2", 13.12), ("mul8x8_3", 23.27)]:
        assert PAPER_TABLE_VII[name].improvement_over(base8)["area_pct"] == pytest.approx(area, abs=0.05)


def test_unit_gate_trend():
    """The structural estimate reproduces the ordering: approximate designs
    are cheaper than exact; MUL8x8_3 (removed product) cheapest of the three."""
    e1 = unit_gate_estimate("mul8x8_1")["relative_area"]
    e2 = unit_gate_estimate("mul8x8_2")["relative_area"]
    e3 = unit_gate_estimate("mul8x8_3")["relative_area"]
    assert e1 < 1.0 and e2 < 1.0 and e3 < 1.0
    assert e3 < e2


def test_systolic_rollup():
    c = systolic_array_cost("mul8x8_2")
    assert c["macs"] == 128 * 128
    assert 0 < c["area_saving_pct"] < 25
    assert 0 < c["power_saving_pct"] < 30
    ex = systolic_array_cost("exact")
    assert ex["area_saving_pct"] == pytest.approx(0.0)


def test_image_dataset_learnable_and_deterministic():
    d1 = image_dataset("mnist", n_train=64, n_test=32, seed=3)
    d2 = image_dataset("mnist", n_train=64, n_test=32, seed=3)
    assert np.array_equal(d1.x_train, d2.x_train)
    assert d1.x_train.shape == (64, 28, 28, 1)
    assert d1.x_train.min() >= 0 and d1.x_train.max() <= 1
    # classes are separable by template correlation
    c = image_dataset("cifar10", n_train=16, n_test=8, seed=0)
    assert c.x_train.shape == (16, 32, 32, 3)


def test_token_batches_shapes_and_determinism():
    it1 = token_batches(100, 2, 16, seed=5)
    it2 = token_batches(100, 2, 16, seed=5)
    t1, l1 = next(it1)
    t2, l2 = next(it2)
    assert np.array_equal(t1, t2)
    assert t1.shape == (2, 16) and l1.shape == (2, 16)
    # labels are next-token shifted
    assert np.array_equal(t1[:, 1:], l1[:, :-1])
    assert t1.max() < 100
