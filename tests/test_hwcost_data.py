"""Hardware cost model + synthetic data pipeline."""
import numpy as np
import pytest

from repro.core.hwcost import (
    COST_TABLE,
    PAPER_TABLE_VI,
    PAPER_TABLE_VII,
    mac_cost,
    systolic_array_cost,
    unit_gate_estimate,
)
from repro.core.multipliers import MSR_SPECS, MULTIPLIERS
from repro.data.synthetic import image_dataset, token_batches


def test_paper_improvements_match_printed():
    base = PAPER_TABLE_VI["exact3x3"]
    imp1 = PAPER_TABLE_VI["mul3x3_1"].improvement_over(base)
    imp2 = PAPER_TABLE_VI["mul3x3_2"].improvement_over(base)
    assert imp1["area_pct"] == pytest.approx(36.17, abs=0.05)
    assert imp2["area_pct"] == pytest.approx(31.38, abs=0.05)
    assert imp1["power_pct"] == pytest.approx(35.66, abs=0.05)
    assert imp2["power_pct"] == pytest.approx(36.73, abs=0.05)
    assert imp1["delay_pct"] == pytest.approx(42.22, abs=0.05)
    base8 = PAPER_TABLE_VII["exact8x8"]
    for name, area in [("mul8x8_1", 19.93), ("mul8x8_2", 13.12), ("mul8x8_3", 23.27)]:
        assert PAPER_TABLE_VII[name].improvement_over(base8)["area_pct"] == pytest.approx(area, abs=0.05)


def test_unit_gate_trend():
    """The structural estimate reproduces the ordering: approximate designs
    are cheaper than exact; MUL8x8_3 (removed product) cheapest of the three."""
    e1 = unit_gate_estimate("mul8x8_1")["relative_area"]
    e2 = unit_gate_estimate("mul8x8_2")["relative_area"]
    e3 = unit_gate_estimate("mul8x8_3")["relative_area"]
    assert e1 < 1.0 and e2 < 1.0 and e3 < 1.0
    assert e3 < e2


def test_cost_table_covers_every_registered_multiplier():
    """Serve-time quality tiers read modeled throughput from COST_TABLE, so
    EVERY name in the multiplier registry must have a row (and paper-
    synthesized rows must be the Table VII data, not estimates)."""
    assert set(MULTIPLIERS) <= set(COST_TABLE)
    assert COST_TABLE["exact"] == PAPER_TABLE_VII["exact8x8"]
    assert COST_TABLE["pkm"] == PAPER_TABLE_VII["pkm"]
    assert mac_cost("exact8x8") == COST_TABLE["exact"]
    for name, row in COST_TABLE.items():
        assert row.area_um2 > 0 and row.power_mw > 0 and row.delay_ns > 0, name


def test_msr_cost_rows_follow_the_truncation_model():
    """The MSR delay model is monotone in keep_bits (fewer partial-product
    rows -> shallower add tree), every MSR rung beats the exact critical
    path, and msr2 (2 kept bits) is the cheapest design in the table."""
    exact = COST_TABLE["exact"]
    delays = {n: COST_TABLE[n].delay_ns for n in MSR_SPECS}
    assert all(d < exact.delay_ns for d in delays.values())
    ordered = sorted(MSR_SPECS, key=lambda n: MSR_SPECS[n].keep_bits)
    assert [delays[n] for n in ordered] == sorted(delays.values())
    assert min(COST_TABLE, key=lambda n: COST_TABLE[n].delay_ns) == ordered[0]
    for n in MSR_SPECS:
        assert COST_TABLE[n].area_um2 < exact.area_um2
        assert COST_TABLE[n].power_mw < exact.power_mw


def test_systolic_rollup():
    c = systolic_array_cost("mul8x8_2")
    assert c["macs"] == 128 * 128
    assert 0 < c["area_saving_pct"] < 25
    assert 0 < c["power_saving_pct"] < 30
    ex = systolic_array_cost("exact")
    assert ex["area_saving_pct"] == pytest.approx(0.0)
    # estimated rows (MSR family) roll up through the same path
    msr = systolic_array_cost("mul8x8_msr4")
    assert msr["delay_saving_pct"] > 0 and msr["area_saving_pct"] > 0


def test_image_dataset_learnable_and_deterministic():
    d1 = image_dataset("mnist", n_train=64, n_test=32, seed=3)
    d2 = image_dataset("mnist", n_train=64, n_test=32, seed=3)
    assert np.array_equal(d1.x_train, d2.x_train)
    assert d1.x_train.shape == (64, 28, 28, 1)
    assert d1.x_train.min() >= 0 and d1.x_train.max() <= 1
    # classes are separable by template correlation
    c = image_dataset("cifar10", n_train=16, n_test=8, seed=0)
    assert c.x_train.shape == (16, 32, 32, 3)


def test_token_batches_shapes_and_determinism():
    it1 = token_batches(100, 2, 16, seed=5)
    it2 = token_batches(100, 2, 16, seed=5)
    t1, l1 = next(it1)
    t2, l2 = next(it2)
    assert np.array_equal(t1, t2)
    assert t1.shape == (2, 16) and l1.shape == (2, 16)
    # labels are next-token shifted
    assert np.array_equal(t1[:, 1:], l1[:, :-1])
    assert t1.max() < 100
