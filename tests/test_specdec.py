"""Self-speculative decoding invariants (serve stack PR 7).

* exactness: with ``spec_decode=True`` the approximate path only ever
  DRAFTS — every emitted token is re-derived by the exact verify pass, so
  greedy outputs are bit-identical to the non-speculative oracle and to
  standalone ``generate``, under both host loops and both attention
  impls, for any draft execution mode;
* the self-test draft: ``draft_mode="exact"`` drafts with the very model
  that verifies, so every drafted token must be accepted
  (``accept_rate == 1.0`` exactly when max_new is a multiple of
  draft_k + 1 — no end-of-request clipping);
* accept extremes: with ``draft_k=1`` every verify is either accept-0
  (the drafted token was rejected; only the correction token lands) or
  accept-all-K — an accept rate strictly inside (0, 1) proves BOTH tick
  shapes occurred and the output still matched the oracle;
* eos inside the drafted span truncates acceptance exactly where
  sequential decode would have stopped;
* preemption mid-flight discards drafted-but-unharvested tokens and the
  replay is bit-identical (positional key schedule, same as PR 6);
* fixed compiled shapes: zero recompiles after ``warmup()`` across a
  randomized trace — the spec tick and length-carry merge are warmed for
  the session's (draft_k, admit width) set;
* accounting: a spec tick's device capacity is
  ``num_slots * (draft_k + 1)`` token-slots; busy counts emitted tokens,
  the accept-rate counters never exceed their denominators.

PR-7 also carries the preemption-accounting bugfix sweep; the regression
tests for per-request effective-bucket prefill charging and the SJF
replay-length key live here with the spec tests (the exact-fill boundary
test lives in tests/test_scheduler.py, where submit's comment points).
"""
import dataclasses

import jax
import numpy as np
import pytest

from repro.configs import get_config, reduced_config
from repro.serve import (
    SamplingConfig,
    ServeSession,
    generate,
    scheduler_compile_stats,
)

KEY = jax.random.PRNGKey(0)


def _cfg(arch="granite-3-2b", **over):
    return dataclasses.replace(
        reduced_config(get_config(arch)), remat=False, q_chunk=16, **over
    )


_PARAMS = {}


def _params(cfg):
    if cfg.name not in _PARAMS:
        from repro.models.transformer import init_params

        _PARAMS[cfg.name] = init_params(cfg, KEY)
    return _PARAMS[cfg.name]


def _spec_session(cfg, **over):
    kw = dict(num_slots=3, max_len=32, prompt_buckets=(4, 8),
              cache_layout="paged", block_size=4, spec_decode=True,
              draft_k=3, draft_mode="approx_lowrank")
    kw.update(over)
    return ServeSession(cfg, _params(cfg), **kw)


def _mixed_prompts(n=4, seed=3):
    rng = np.random.default_rng(seed)
    return [rng.integers(1, 99, int(rng.integers(2, 9))).astype(np.int32)
            for _ in range(n)]


def _oracle(cfg, prompts, max_new=8, **over):
    """Sync non-speculative paged run of the same trace."""
    kw = dict(num_slots=3, max_len=32, prompt_buckets=(4, 8),
              cache_layout="paged", block_size=4, loop="sync")
    kw.update(over)
    sess = ServeSession(cfg, _params(cfg), **kw)
    ids = [sess.submit(p, max_new=max_new, req_id=i)
           for i, p in enumerate(prompts)]
    res = sess.run(max_steps=10_000)
    assert sess.drained
    return {i: res[i].tokens.tolist() for i in ids}


# ---------------------------------------------------------------------------
# Construction-time contract (fast tier)
# ---------------------------------------------------------------------------


def test_spec_decode_validation():
    cfg = _cfg()
    with pytest.raises(ValueError, match="paged"):
        ServeSession(cfg, _params(cfg), cache_layout="slots",
                     spec_decode=True)
    with pytest.raises(ValueError, match="steps_per_tick"):
        _spec_session(cfg, steps_per_tick=2)
    with pytest.raises(ValueError, match="draft_k"):
        _spec_session(cfg, draft_k=0)
    moe = _cfg("qwen2-moe-a2.7b")
    with pytest.raises(ValueError, match="moe"):
        ServeSession(moe, _params(moe), cache_layout="paged", block_size=4,
                     max_len=32, prompt_buckets=(4, 8), spec_decode=True)


def test_stats_spec_fields_documented():
    """The accept-rate readout is part of the bench JSON contract."""
    from repro.serve import SchedulerStats

    assert {"draft_tokens", "accepted_tokens", "verify_calls",
            "accept_rate"} <= set(SchedulerStats.DOCS)
    st = SchedulerStats()
    assert st.accept_rate == 0.0                  # no drafts yet: defined
    st.draft_tokens, st.accepted_tokens = 8, 6
    assert st.accept_rate == 0.75


# ---------------------------------------------------------------------------
# PR-7 satellite regressions: preemption-accounting sweep (fast tier)
# ---------------------------------------------------------------------------


def test_ready_key_ranks_preemption_replay_length():
    """SJF must charge a preempted request its REPLAY prompt (original +
    accepted tokens), not the original: the replay is what re-admission
    actually prefills.  Regression — the key used ``req.prompt`` and let
    an expensive replay jump ahead of genuinely short fresh jobs."""
    cfg = _cfg()
    sess = ServeSession(cfg, _params(cfg), num_slots=2, max_len=64,
                        prompt_buckets=(4, 8, 16), cache_layout="paged",
                        block_size=4, policy="sjf", preemption=True)
    rid = sess.submit(np.arange(1, 5, dtype=np.int32), max_new=2)   # bucket 4
    req = sess._ready[0][2]
    assert sess._ready_key(req) == 2 + 4
    # preempted after 5 accepted tokens: replay prompt is 9 -> bucket 16
    sess._preempt_resume[rid] = ([11, 12, 13, 14, 15], None)
    assert sess._ready_key(req) == 2 + 16
    # _pick_victim's explicit override ranks a still-resident row the same
    assert sess._ready_key(req, eff_len=9) == 2 + 16
    # ordering: the replay now sorts AFTER a fresh medium job (4 + 8 = 12)
    fresh = sess.submit(np.arange(1, 6, dtype=np.int32), max_new=4)
    fresh_req = next(r for _, _, r in sess._ready if r.req_id == fresh)
    assert sess._ready_key(req) > sess._ready_key(fresh_req) == 12


@pytest.mark.slow
def test_admit_charges_per_request_effective_buckets():
    """One admission batch with mixed prompt lengths: each request is
    charged ITS OWN bucket.  Regression — the batch-max padding bucket
    was charged for every row, overcounting prefill_tokens whenever a
    batch mixed buckets (and the starvation budget metered the same
    wrong number)."""
    cfg = _cfg()
    sess = ServeSession(cfg, _params(cfg), num_slots=2, max_len=32,
                        prompt_buckets=(4, 8), cache_layout="paged",
                        block_size=4, loop="sync")
    sess.submit(np.asarray([1, 2], np.int32), max_new=2)      # bucket 4
    sess.submit(np.arange(1, 7, dtype=np.int32), max_new=2)   # bucket 8
    sess.run(max_steps=1_000)
    assert sess.drained
    assert sess.stats.admit_calls == 1           # one batch: buckets mixed
    assert sess.stats.prefills == {4: 1, 8: 1}
    assert sess.stats.prefill_tokens == 12        # batch-max would say 16


@pytest.mark.slow
@pytest.mark.parametrize("loop", ["sync", "async"])
def test_preemption_replay_charged_at_replay_bucket(loop):
    """A preempted victim re-admits by prefilling prompt + accepted
    tokens: the charge must land in the REPLAY bucket.  Regression — the
    original prompt's bucket was charged, so every preemption undercounted
    prefill_tokens/work_ticks and skewed the starvation gauge."""
    cfg = _cfg()
    rng = np.random.default_rng(11)
    prompts = [rng.integers(1, 99, 6).astype(np.int32) for _ in range(2)]
    sess = ServeSession(cfg, _params(cfg), num_slots=2, max_len=32,
                        prompt_buckets=(8, 32), cache_layout="paged",
                        block_size=4, num_blocks=5, loop=loop,
                        preemption=True)
    for i, p in enumerate(prompts):
        sess.submit(p, max_new=12, req_id=i)
    sess.run(max_steps=10_000)
    assert sess.drained
    st = sess.stats
    assert st.preemptions >= 1
    # every admission (initial + one per replay) left a per-request charge
    assert sum(st.prefills.values()) == st.admitted + st.preemptions
    assert st.prefill_tokens == sum(b * n for b, n in st.prefills.items())
    # the replay prompt (6 + accepted > 8) charges the 32 bucket; the
    # original-prompt bug charged bucket 8 for every admission
    assert st.prefills.get(32, 0) >= 1


# ---------------------------------------------------------------------------
# Exactness: spec output == non-spec oracle == generate (slow tier)
# ---------------------------------------------------------------------------


@pytest.mark.slow
@pytest.mark.parametrize("loop", ["sync", "async"])
@pytest.mark.parametrize("attn_impl", ["gather", "pallas"])
def test_spec_parity_with_nonspec_oracle(loop, attn_impl):
    """Greedy spec outputs are bit-identical to the non-speculative paged
    oracle and to standalone ``generate`` — the approximate path drafts,
    the exact path decides, so the multiplier's error rate can only cost
    speed, never tokens."""
    cfg = _cfg()
    prompts = _mixed_prompts()
    oracle = _oracle(cfg, prompts, attn_impl=attn_impl)
    sess = _spec_session(cfg, loop=loop, attn_impl=attn_impl)
    ids = [sess.submit(p, max_new=8, req_id=i)
           for i, p in enumerate(prompts)]
    res = sess.run(max_steps=10_000)
    assert sess.drained
    outs = {i: res[i].tokens.tolist() for i in ids}
    assert outs == oracle
    p = prompts[0]
    alone = np.asarray(
        generate(cfg, _params(cfg), p[None, :], max_new=8)
    )[0, len(p):]
    assert outs[0] == alone.tolist()
    st = sess.stats
    # accounting: busy counts emitted tokens; a spec tick's capacity is
    # num_slots * (draft_k + 1) token-slots
    assert sum(len(r.tokens) - 1 for r in res.values()) == st.busy_slot_steps
    assert (st.busy_slot_steps + st.idle_slot_steps
            == st.ticks * sess.num_slots * (sess.draft_k + 1))
    assert st.verify_calls > 0
    assert st.draft_tokens == st.verify_calls * sess.draft_k
    assert 0 <= st.accepted_tokens <= st.draft_tokens


@pytest.mark.slow
@pytest.mark.parametrize("loop", ["sync", "async"])
def test_exact_draft_accepts_every_token(loop):
    """``draft_mode="exact"``: the draft IS the verifier, so every drafted
    token must be accepted.  max_new = 8 is a multiple of draft_k + 1 = 4,
    so no tick is clipped by end-of-request truncation and the accept
    rate reads exactly 1.0."""
    cfg = _cfg()
    prompts = _mixed_prompts(seed=5)
    sess = _spec_session(cfg, loop=loop, draft_mode="exact")
    ids = [sess.submit(p, max_new=8, req_id=i)
           for i, p in enumerate(prompts)]
    res = sess.run(max_steps=10_000)
    assert sess.drained
    st = sess.stats
    assert st.accept_rate == 1.0
    assert st.accepted_tokens == st.draft_tokens > 0
    assert {i: res[i].tokens.tolist() for i in ids} == _oracle(
        cfg, prompts, max_new=8
    )


@pytest.mark.slow
def test_accept_extremes_draft_k1():
    """draft_k = 1 makes every verify an extreme: accept-0 (draft
    rejected, only the correction token lands) or accept-all-K.  A
    random-weight approximate draft lands strictly inside (0, 1), so BOTH
    tick shapes occurred — and the output still matches the oracle
    bit-for-bit."""
    cfg = _cfg()
    prompts = _mixed_prompts(n=6, seed=7)
    oracle = _oracle(cfg, prompts, max_new=7)
    sess = _spec_session(cfg, draft_k=1, loop="sync")
    ids = [sess.submit(p, max_new=7, req_id=i)
           for i, p in enumerate(prompts)]
    res = sess.run(max_steps=10_000)
    assert sess.drained
    st = sess.stats
    assert 0 < st.accepted_tokens < st.draft_tokens
    assert {i: res[i].tokens.tolist() for i in ids} == oracle


@pytest.mark.slow
@pytest.mark.parametrize("draft_mode", ["exact", "approx_lowrank"])
def test_eos_inside_drafted_span(draft_mode):
    """EOS at drafted position j truncates acceptance at j even when
    later drafts matched — exactly where sequential decode stops."""
    cfg = _cfg()
    prompt = np.asarray([3, 1, 4, 1], np.int32)
    base = np.asarray(generate(cfg, _params(cfg), prompt[None], max_new=8))[0, 4:]
    eos = int(base[2])                           # third generated token
    sess = _spec_session(cfg, draft_k=4, draft_mode=draft_mode,
                         sampling=SamplingConfig(eos_id=eos))
    rid = sess.submit(prompt, max_new=8)
    other = sess.submit(np.asarray([9, 9], np.int32), max_new=8)
    res = sess.run(max_steps=10_000)
    r = res[rid]
    assert r.finish_reason == "eos"
    hit = int(np.argmax(base == eos))
    assert r.tokens[-1] == eos and len(r.tokens) == hit + 1
    assert np.array_equal(r.tokens, base[: hit + 1])
    assert len(res[other].tokens) == 8           # co-resident row unaffected


@pytest.mark.slow
@pytest.mark.parametrize("loop", ["sync", "async"])
def test_spec_preemption_bit_identical(loop):
    """Preemption mid-spec-flight: drafted-but-unharvested tokens are
    discarded with the victim and the replay regenerates them exactly —
    starved-pool outputs equal the roomy-pool spec run AND the non-spec
    oracle, with prefix sharing live underneath."""
    cfg = _cfg()
    rng = np.random.default_rng(13)
    prefix = rng.integers(1, 50, 12)
    prompts = [np.concatenate([prefix, rng.integers(50, 99, 2)]).astype(np.int32)
               for _ in range(5)]
    oracle = _oracle(cfg, prompts, max_new=12, num_slots=2, max_len=64,
                     prompt_buckets=(8, 32))
    outs = {}
    for blocks in (40, 9):                       # roomy vs starved
        sess = _spec_session(cfg, num_slots=2, max_len=64,
                             prompt_buckets=(8, 32), num_blocks=blocks,
                             loop=loop, prefix_sharing=True,
                             preemption=True)
        ids = [sess.submit(p, max_new=12, req_id=i)
               for i, p in enumerate(prompts)]
        res = sess.run(max_steps=10_000)
        assert sess.drained
        outs[blocks] = {i: res[i].tokens.tolist() for i in ids}
        if blocks == 9:
            assert sess.stats.preemptions >= 1
            assert sess.stats.prefix_hit_blocks > 0
        assert sess._reserved_total == 0
        assert not sess._preempt_resume
    assert outs[40] == outs[9] == oracle


@pytest.mark.slow
@pytest.mark.parametrize("loop", ["sync", "async"])
@pytest.mark.parametrize("attn_impl", ["gather", "pallas"])
def test_spec_zero_recompiles_after_warmup(loop, attn_impl):
    """warmup() compiles the spec tick and the length-carry merge for the
    session's (draft_k, admit width) set: NO arrival pattern, prompt
    length, accept pattern, or max_new mix may recompile afterwards."""
    cfg = _cfg()
    sess = _spec_session(cfg, loop=loop, attn_impl=attn_impl)
    sess.warmup()
    before = scheduler_compile_stats()
    rng = np.random.default_rng(3)
    for i in range(8):
        p = rng.integers(1, 99, int(rng.integers(2, 9))).astype(np.int32)
        sess.submit(p, max_new=int(rng.integers(2, 9)),
                    arrival=int(rng.integers(0, 5)))
    sess.run(max_steps=10_000)
    assert sess.drained
    assert scheduler_compile_stats() == before
    assert sess.stats.completed == 8
    assert sess.stats.verify_calls > 0


@pytest.mark.slow
def test_spec_temperature_sampling_matches_nonspec():
    """The exactness contract is not greedy-only: per-token positional
    fold_in keys mean the verify pass samples with the SAME keys
    sequential decode would have used, so temperature outputs are
    bit-identical too."""
    cfg = _cfg()
    sampling = SamplingConfig(temperature=0.8, top_k=8)
    prompts = _mixed_prompts(n=4, seed=9)
    oracle = _oracle(cfg, prompts, max_new=6, sampling=sampling, seed=42)
    sess = _spec_session(cfg, sampling=sampling, seed=42)
    ids = [sess.submit(p, max_new=6, req_id=i)
           for i, p in enumerate(prompts)]
    res = sess.run(max_steps=10_000)
    assert sess.drained
    assert {i: res[i].tokens.tolist() for i in ids} == oracle


@pytest.mark.slow
def test_serve_specdec_bench_smoke():
    """The accept-rate bench harness: a miniature run must complete with
    the parity/recompile oracles clean (the speed criterion is asserted
    on the real bench config in CI — this pins the machinery)."""
    import benchmarks.serve_specdec as B

    r = B.bench(requests=8, max_new=8)
    assert r["token_mismatches"] == 0
    assert r["recompiles_after_warmup"] == 0
    for arm in r["spec_arms"]:
        assert 0.0 <= arm["accept_rate"] <= 1.0
        assert arm["verify_calls"] > 0
    assert r["exact_draft_accept_rate"] == 1.0
    assert set(r["field_docs"]) >= {"draft_tokens", "accepted_tokens",
                                    "verify_calls", "accept_rate"}


# ---------------------------------------------------------------------------
# PR-8: dynamic draft_k (rolling accept rate vs break-even 1/draft_cost_ratio)
# ---------------------------------------------------------------------------


def test_dynamic_draft_k_validation():
    cfg = _cfg()
    with pytest.raises(ValueError, match="spec_decode"):
        ServeSession(cfg, _params(cfg), cache_layout="paged", block_size=4,
                     max_len=32, prompt_buckets=(4, 8), dynamic_draft_k=True)
    with pytest.raises(ValueError, match="draft_cost_ratio"):
        _spec_session(cfg, dynamic_draft_k=True, draft_cost_ratio=1.0)
    with pytest.raises(ValueError, match="draft_window"):
        _spec_session(cfg, dynamic_draft_k=True, draft_window=0)


def test_dynamic_draft_k_shrink_threshold():
    """Regression pin for the shrink rule: the window shrinks exactly when
    the rolling accept rate is STRICTLY below break-even
    ``1/draft_cost_ratio``, re-grows at/above it, and the rolling window
    clears on every rung change (hysteresis)."""
    cfg = _cfg()
    sess = _spec_session(cfg, draft_k=4, dynamic_draft_k=True,
                         draft_cost_ratio=4.0, draft_window=4)
    assert sess._draft_ks == (4, 2, 1)
    assert sess._draft_k_eff == 4

    def feed(pairs):
        sess._accept_hist.clear()
        sess._accept_hist.extend(pairs)
        sess._update_draft_k()

    # short window: no decision yet
    feed([(4, 0)] * 3)
    assert sess._draft_k_eff == 4 and sess.stats.draft_k_shrinks == 0

    # rate exactly at break-even (4/16 = 1/4): hold at the top rung
    feed([(4, 1)] * 4)
    assert sess._draft_k_eff == 4 and sess.stats.draft_k_shrinks == 0

    # one accepted token fewer (3/16 < 1/4): shrink 4 -> 2, window cleared
    feed([(4, 1)] * 3 + [(4, 0)])
    assert sess._draft_k_eff == 2
    assert sess.stats.draft_k_shrinks == 1
    assert len(sess._accept_hist) == 0
    assert sess.stats.draft_k_current == 2

    # still below break-even: shrink to the floor rung and stay there
    feed([(2, 0)] * 4)
    assert sess._draft_k_eff == 1 and sess.stats.draft_k_shrinks == 2
    feed([(1, 0)] * 4)
    assert sess._draft_k_eff == 1 and sess.stats.draft_k_shrinks == 2

    # at/above break-even: climb back one rung per full window
    feed([(1, 1)] * 4)
    assert sess._draft_k_eff == 2 and sess.stats.draft_k_grows == 1
    feed([(2, 2)] * 4)
    assert sess._draft_k_eff == 4 and sess.stats.draft_k_grows == 2
    assert sess.stats.draft_k_current == 4


@pytest.mark.slow
@pytest.mark.parametrize("loop", ["sync", "async"])
def test_dynamic_draft_k_end_to_end(loop):
    """A lossy draft under a tight window must actually shrink the live
    draft_k, while the emitted tokens stay bit-identical to the
    non-speculative oracle (shrinking only changes chunking, never
    tokens)."""
    cfg = _cfg()
    prompts = _mixed_prompts(n=4, seed=5)
    oracle = _oracle(cfg, prompts, max_new=12)
    # break-even ~0.95: any lossy draft sits below it, forcing shrinks
    sess = _spec_session(cfg, loop=loop, draft_k=4, dynamic_draft_k=True,
                         draft_cost_ratio=1.05, draft_window=2)
    sess.warmup()
    ids = [sess.submit(p, max_new=12, req_id=i)
           for i, p in enumerate(prompts)]
    res = sess.run(max_steps=10_000)
    assert sess.drained
    assert {i: res[i].tokens.tolist() for i in ids} == oracle
    assert sess.stats.draft_k_shrinks >= 1
    assert sess.stats.draft_k_current < 4
