"""Frozen uint8 serving weights (QWeight) + fused-projection perf levers —
both must preserve the multiplier semantics."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config, reduced_config
from repro.core.approx import (
    ApproxConfig,
    QWeight,
    approx_dense,
    concat_weights,
    prequantize_tree,
)
from repro.models.transformer import decode_step, forward, init_cache, init_params

KEY = jax.random.PRNGKey(0)


def _cfg(**over):
    return dataclasses.replace(
        reduced_config(get_config("granite-3-2b")),
        approx=ApproxConfig(multiplier="mul8x8_2", mode="lowrank"),
        remat=False,
        **over,
    )


def test_prequantize_selects_matmul_weights_only():
    cfg = _cfg()
    p = init_params(cfg, KEY)
    pf = prequantize_tree(p, cfg.approx)
    assert isinstance(pf["layers"]["attn"].wq, QWeight)
    assert isinstance(pf["layers"]["ffn"].w_down, QWeight)
    assert isinstance(pf["lm_head"], QWeight)
    assert not isinstance(pf["embed"], QWeight)            # gather stays float
    assert not isinstance(pf["final_norm"], QWeight)
    assert pf["layers"]["attn"].wq.codes.dtype == jnp.uint8


def test_frozen_dense_matches_dynamic():
    cfg = ApproxConfig(multiplier="mul8x8_2", mode="lowrank")
    rng = np.random.default_rng(0)
    w = jnp.asarray(rng.normal(size=(48, 24)), jnp.float32)
    x = jnp.asarray(rng.normal(size=(6, 48)), jnp.float32)
    qw = prequantize_tree({"layers": {"attn_wq_like": {}}, "lm_head": w}, cfg)["lm_head"]
    y_dyn = approx_dense(x, w, cfg)
    y_frz = approx_dense(x, qw, cfg)
    np.testing.assert_allclose(np.asarray(y_dyn), np.asarray(y_frz), rtol=1e-5, atol=1e-5)


def test_concat_weights_frozen():
    cfg = ApproxConfig(multiplier="mul8x8_2", mode="lowrank")
    rng = np.random.default_rng(1)
    w1 = jnp.asarray(rng.normal(size=(16, 8)), jnp.float32)
    w2 = jnp.asarray(rng.normal(size=(16, 4)), jnp.float32)
    t = prequantize_tree({"lm_head": w1, "layers": {"x": {}}}, cfg)
    q1 = t["lm_head"]
    q2 = prequantize_tree({"lm_head": w2, "layers": {}}, cfg)["lm_head"]
    qc = concat_weights([q1, q2], axis=1)
    assert qc.codes.shape == (16, 12)
    x = jnp.asarray(rng.normal(size=(3, 16)), jnp.float32)
    y = approx_dense(x, qc, cfg)
    y1 = approx_dense(x, q1, cfg)
    y2 = approx_dense(x, q2, cfg)
    np.testing.assert_allclose(
        np.asarray(y), np.asarray(jnp.concatenate([y1, y2], -1)), rtol=1e-5, atol=1e-5
    )


def test_fused_projections_bit_identical_lowrank():
    cfg0 = _cfg()
    cfg1 = dataclasses.replace(cfg0, fuse_qkv=True, fuse_gate_up=True)
    p = init_params(cfg0, KEY)
    b = {"tokens": jax.random.randint(KEY, (2, 12), 0, cfg0.vocab_size)}
    l0, _ = forward(cfg0, p, b)
    l1, _ = forward(cfg1, p, b)
    # per-output-channel scales => fused quantization is bit-identical
    assert float(jnp.max(jnp.abs(l0 - l1))) == 0.0


def test_frozen_decode_matches_dynamic():
    cfg = _cfg(q_chunk=16)
    p = init_params(cfg, KEY)
    pf = prequantize_tree(p, cfg.approx)
    cache = init_cache(cfg, 2, 8, jnp.float32)
    args = ({"tokens": jnp.ones((2, 1), jnp.int32)}, jnp.zeros((2,), jnp.int32))
    l_dyn, _ = decode_step(cfg, p, cache, *args)
    l_frz, _ = decode_step(cfg, pf, cache, *args)
    np.testing.assert_allclose(np.asarray(l_dyn), np.asarray(l_frz), rtol=1e-4, atol=1e-4)
