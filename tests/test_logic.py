"""Bitwise (gather-free) multiplier logic == truth-table LUTs, including the
second Pallas kernel (elementwise)."""
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import multipliers as M
from repro.core.logic import approx_mul3x3, approx_mul8x8_bitwise
from repro.kernels.approx_mul_eltwise.ops import approx_mul_eltwise_pallas
from repro.kernels.approx_mul_eltwise.ref import approx_mul_eltwise_ref


def _grid(n):
    a = np.arange(n)[:, None] * np.ones((1, n), np.int32)
    b = np.arange(n)[None, :] * np.ones((n, 1), np.int32)
    return jnp.asarray(a), jnp.asarray(b)


def test_bitwise_3x3_matches_tables():
    a, b = _grid(8)
    assert np.array_equal(np.asarray(approx_mul3x3(a, b, 1)), M.mul3x3_1_table())
    assert np.array_equal(np.asarray(approx_mul3x3(a, b, 2)), M.mul3x3_2_table())


@pytest.mark.parametrize(
    "design,removed,name",
    [(1, False, "mul8x8_1"), (2, False, "mul8x8_2"), (2, True, "mul8x8_3")],
)
def test_bitwise_8x8_matches_luts(design, removed, name):
    a, b = _grid(256)
    got = np.asarray(approx_mul8x8_bitwise(a, b, design, removed))
    assert np.array_equal(got, M.mul8x8_table(name))


@pytest.mark.parametrize("name", ["mul8x8_1", "mul8x8_2", "mul8x8_3"])
def test_eltwise_pallas_kernel(name):
    rng = np.random.default_rng(0)
    a = jnp.asarray(rng.integers(0, 256, (37, 21)), jnp.uint8)
    b = jnp.asarray(rng.integers(0, 256, (37, 21)), jnp.uint8)
    ref = np.asarray(approx_mul_eltwise_ref(a, b, name))
    out = np.asarray(approx_mul_eltwise_pallas(a, b, multiplier=name, block=256))
    assert np.array_equal(ref, out)
