"""Property-based differential tests: ``approx_matmul_pallas`` must be
bit-exact to the ``mul8x8_table`` LUT oracle on EVERY shape, not just the
hand-picked ones in test_kernels.py.

Runs through ``_hypothesis_compat``: real ``hypothesis`` when installed,
otherwise a deterministic seeded fallback with the same assertions.

Coverage axes:
* random M/N/K including odd / prime / non-multiple-of-block sizes;
* leading batch dimensions on the lhs (1 and 2 extra dims);
* every kernel-supported multiplier (the aggregated designs with a low-rank
  factorization: exact + mul8x8_1/2/3 — pkm/etm have no aggregation spec,
  so the kernel rejects them, pinned below);
* pruned operand ranges (the paper's co-optimized (0,31) bands).

Marked ``slow``: each example pads to >= (8, 128) x (128, 128) interpret-mode
kernel work; CI runs these in the second-tier job.
"""
import jax.numpy as jnp
import numpy as np
import pytest
from _hypothesis_compat import given, settings, strategies as st

from repro.core import multipliers as M
from repro.kernels.approx_matmul.ops import approx_matmul_pallas, select_blocks
from repro.kernels.approx_matmul.ref import approx_matmul_ref

pytestmark = pytest.mark.slow

# Multipliers the Pallas/low-rank path supports: those with an aggregation
# spec (lowrank.build_correction). pkm/etm are LUT/ref-only designs.
KERNEL_MULTIPLIERS = tuple(
    name for name in M.MULTIPLIERS if name not in ("pkm", "etm")
)


def _codes(rng: np.random.Generator, shape, high: int):
    return jnp.asarray(rng.integers(0, high + 1, shape), jnp.uint8)


def _seed(*parts) -> int:
    """Deterministic example seed from ints/registry names — NOT Python
    hash(), whose per-process str randomization would make a failing
    counterexample irreproducible."""
    acc = 0
    for p in parts:
        acc = (acc * 1_000_003 + (M.MULTIPLIERS.index(p) if isinstance(p, str) else int(p))) % 2**32
    return acc


def _check(a, b, name: str):
    lut = jnp.asarray(M.mul8x8_table(name))
    ref = np.asarray(approx_matmul_ref(a, b, lut))
    out = np.asarray(approx_matmul_pallas(a, b, multiplier=name))
    assert out.shape == ref.shape
    assert np.array_equal(ref, out), (name, a.shape, b.shape)


def test_kernel_multiplier_registry_is_exhaustive():
    """Every registered multiplier either runs through the kernel or is
    pinned as a known ref-only design — no silent third category."""
    from repro.core import lowrank as lr

    for name in M.MULTIPLIERS:
        if name in KERNEL_MULTIPLIERS:
            lr.build_correction(name, side="rhs")   # must not raise
        else:
            with pytest.raises(KeyError):
                lr.build_correction(name, side="rhs")
    assert set(KERNEL_MULTIPLIERS) == {"exact", "mul8x8_1", "mul8x8_2", "mul8x8_3"}


@settings(max_examples=20, deadline=None)
@given(
    st.integers(1, 40),                      # M
    st.integers(1, 40),                      # N
    st.integers(1, 70),                      # K
    st.sampled_from(KERNEL_MULTIPLIERS),
    st.integers(0, 2**31 - 1),               # data seed
)
def test_pallas_matches_lut_oracle_random_shapes(m, n, k, name, seed):
    rng = np.random.default_rng(seed)
    _check(_codes(rng, (m, k), 255), _codes(rng, (k, n), 255), name)


@settings(max_examples=10, deadline=None)
@given(
    st.integers(1, 3),                       # leading batch dim
    st.integers(1, 3),                       # second batch dim (1 == absent)
    st.integers(1, 12),                      # M
    st.integers(1, 24),                      # N
    st.integers(1, 48),                      # K
    st.sampled_from(KERNEL_MULTIPLIERS),
)
def test_pallas_matches_lut_oracle_leading_batch_dims(b1, b2, m, n, k, name):
    rng = np.random.default_rng(_seed(b1, b2, m, n, k, name))
    shape = (b1, m, k) if b2 == 1 else (b1, b2, m, k)
    _check(_codes(rng, shape, 255), _codes(rng, (k, n), 255), name)


@settings(max_examples=10, deadline=None)
@given(
    st.integers(1, 16),
    st.integers(1, 16),
    st.integers(1, 64),
    st.sampled_from(KERNEL_MULTIPLIERS),
    st.sampled_from([31, 63, 255]),          # pruned operand bands
    st.sampled_from([31, 255]),
)
def test_pallas_matches_lut_oracle_pruned_ranges(m, n, k, name, amax, wmax):
    """Range-pruned calls (lhs_max/rhs_max drop correction features) must
    stay exact on the restricted domain — the co-optimized band profile."""
    rng = np.random.default_rng(_seed(m, n, k, name, amax, wmax))
    a = _codes(rng, (m, k), amax)
    b = _codes(rng, (k, n), wmax)
    lut = jnp.asarray(M.mul8x8_table(name))
    ref = np.asarray(approx_matmul_ref(a, b, lut))
    out = np.asarray(
        approx_matmul_pallas(a, b, multiplier=name, lhs_max=amax, rhs_max=wmax)
    )
    assert np.array_equal(ref, out), (name, m, n, k, amax, wmax)


@settings(max_examples=15, deadline=None)
@given(
    st.integers(1, 300),
    st.integers(1, 300),
    st.integers(1, 600),
    st.integers(0, 2**31 - 1),
)
def test_select_blocks_invariants(m, n, k, seed):
    """Structural invariants of the block-shrink logic for ANY problem:
    blocks divide the padded dims, padding never loses data, sublane/lane
    minima hold, and blocks never exceed the requested maxima."""
    (bm_, bn_, bk_), (mp, np_, kp) = select_blocks(m, n, k)
    assert mp % bm_ == 0 and np_ % bn_ == 0 and kp % bk_ == 0
    assert mp >= m and np_ >= n and kp >= k
    assert bm_ % 8 == 0 and bn_ % 128 == 0 and bk_ % 128 == 0
    assert bm_ <= 128 and bn_ <= 128 and bk_ <= 256
    # padding is tight: strictly less than one block of waste
    assert mp - m < bm_ and np_ - n < bn_ and kp - k < bk_