"""Property-based differential tests for the Pallas kernel families.

* ``approx_matmul_pallas`` must be bit-exact to the ``mul8x8_table`` LUT
  oracle on EVERY shape, not just the hand-picked ones in test_kernels.py;
* ``paged_attention_pallas`` (the paged decode-attention kernel) must match
  its pure-JAX exact-softmax oracle ``paged_attention_ref`` to f32 roundoff
  on random block-table layouts — sentinel-padded rows, sentinel holes,
  off-boundary and past-table ``cur_len``, GQA ``Hkv < n_heads``.

Runs through ``_hypothesis_compat``: real ``hypothesis`` when installed,
otherwise a deterministic seeded fallback with the same assertions.

approx-matmul coverage axes:
* random M/N/K including odd / prime / non-multiple-of-block sizes;
* leading batch dimensions on the lhs (1 and 2 extra dims);
* EVERY registered multiplier family — aggregated (exact + mul8x8_1/2/3,
  low-rank indicator corrections), truncation (pkm/etm, generic "lut"-kind
  corrections), and the MSR fixed-shift family (mul8x8_msr2/4/6) — all
  route through the same fused kernel decomposition;
* pruned operand ranges (the paper's co-optimized (0,31) bands).

Marked ``slow``: each example runs interpret-mode kernel work; CI runs
these in the second-tier job under ``REPRO_FORCE_INTERPRET=1``.
"""
import jax.numpy as jnp
import numpy as np
import pytest
from _hypothesis_compat import given, settings, strategies as st

from repro.core import multipliers as M
from repro.kernels.approx_matmul.ops import approx_matmul_pallas, select_blocks
from repro.kernels.approx_matmul.ref import approx_matmul_ref
from repro.kernels.paged_attention import (
    paged_attention_pallas,
    paged_attention_ref,
)

pytestmark = pytest.mark.slow

# Every registered multiplier runs through the Pallas kernel: aggregated
# designs via the low-rank indicator corrections, pkm/etm/MSR via the
# generic per-bit "lut"-kind corrections (both exact by construction).
KERNEL_MULTIPLIERS = M.MULTIPLIERS


def _codes(rng: np.random.Generator, shape, high: int):
    return jnp.asarray(rng.integers(0, high + 1, shape), jnp.uint8)


def _seed(*parts) -> int:
    """Deterministic example seed from ints/registry names — NOT Python
    hash(), whose per-process str randomization would make a failing
    counterexample irreproducible."""
    acc = 0
    for p in parts:
        acc = (acc * 1_000_003 + (M.MULTIPLIERS.index(p) if isinstance(p, str) else int(p))) % 2**32
    return acc


def _check(a, b, name: str):
    lut = jnp.asarray(M.mul8x8_table(name))
    ref = np.asarray(approx_matmul_ref(a, b, lut))
    out = np.asarray(approx_matmul_pallas(a, b, multiplier=name))
    assert out.shape == ref.shape
    assert np.array_equal(ref, out), (name, a.shape, b.shape)


def test_kernel_multiplier_registry_is_exhaustive():
    """EVERY registered multiplier builds a correction whose reconstructed
    error table equals exact - LUT entrywise — the kernel decomposition's
    exactness precondition, with no ref-only escape hatch left."""
    from repro.core import lowrank as lr

    assert set(KERNEL_MULTIPLIERS) == set(M.MULTIPLIERS)
    exact = M.exact_table(8, 8).astype(np.int64)
    for name in M.MULTIPLIERS:
        for side in ("lhs", "rhs"):
            corr = lr.build_correction(name, side=side)
            err = exact - M.mul8x8_table(name).astype(np.int64)
            assert np.array_equal(corr.error_table(), err), (name, side)


@settings(max_examples=20, deadline=None)
@given(
    st.integers(1, 40),                      # M
    st.integers(1, 40),                      # N
    st.integers(1, 70),                      # K
    st.sampled_from(KERNEL_MULTIPLIERS),
    st.integers(0, 2**31 - 1),               # data seed
)
def test_pallas_matches_lut_oracle_random_shapes(m, n, k, name, seed):
    rng = np.random.default_rng(seed)
    _check(_codes(rng, (m, k), 255), _codes(rng, (k, n), 255), name)


@settings(max_examples=10, deadline=None)
@given(
    st.integers(1, 3),                       # leading batch dim
    st.integers(1, 3),                       # second batch dim (1 == absent)
    st.integers(1, 12),                      # M
    st.integers(1, 24),                      # N
    st.integers(1, 48),                      # K
    st.sampled_from(KERNEL_MULTIPLIERS),
)
def test_pallas_matches_lut_oracle_leading_batch_dims(b1, b2, m, n, k, name):
    rng = np.random.default_rng(_seed(b1, b2, m, n, k, name))
    shape = (b1, m, k) if b2 == 1 else (b1, b2, m, k)
    _check(_codes(rng, shape, 255), _codes(rng, (k, n), 255), name)


@settings(max_examples=10, deadline=None)
@given(
    st.integers(1, 16),
    st.integers(1, 16),
    st.integers(1, 64),
    st.sampled_from(KERNEL_MULTIPLIERS),
    st.sampled_from([31, 63, 255]),          # pruned operand bands
    st.sampled_from([31, 255]),
)
def test_pallas_matches_lut_oracle_pruned_ranges(m, n, k, name, amax, wmax):
    """Range-pruned calls (lhs_max/rhs_max drop correction features) must
    stay exact on the restricted domain — the co-optimized band profile."""
    rng = np.random.default_rng(_seed(m, n, k, name, amax, wmax))
    a = _codes(rng, (m, k), amax)
    b = _codes(rng, (k, n), wmax)
    lut = jnp.asarray(M.mul8x8_table(name))
    ref = np.asarray(approx_matmul_ref(a, b, lut))
    out = np.asarray(
        approx_matmul_pallas(a, b, multiplier=name, lhs_max=amax, rhs_max=wmax)
    )
    assert np.array_equal(ref, out), (name, m, n, k, amax, wmax)


@settings(max_examples=15, deadline=None)
@given(
    st.integers(1, 300),
    st.integers(1, 300),
    st.integers(1, 600),
    st.integers(0, 2**31 - 1),
)
def test_select_blocks_invariants(m, n, k, seed):
    """Structural invariants of the block-shrink logic for ANY problem:
    blocks divide the padded dims, padding never loses data, sublane/lane
    minima hold, and blocks never exceed the requested maxima."""
    (bm_, bn_, bk_), (mp, np_, kp) = select_blocks(m, n, k)
    assert mp % bm_ == 0 and np_ % bn_ == 0 and kp % bk_ == 0
    assert mp >= m and np_ >= n and kp >= k
    assert bm_ % 8 == 0 and bn_ % 128 == 0 and bk_ % 128 == 0
    assert bm_ <= 128 and bn_ <= 128 and bk_ <= 256
    # padding is tight: strictly less than one block of waste
    assert mp - m < bm_ and np_ - n < bn_ and kp - k < bk_


# ---------------------------------------------------------------------------
# Paged decode-attention kernel vs the pure-JAX oracle
# ---------------------------------------------------------------------------


def _paged_case(rng, B, W, bs, n_kv, g, hd, *, holes=False):
    """Random paged decode-attention inputs: each row holds a random number
    of distinct blocks (possibly zero — an inactive all-sentinel row), its
    ``cur_len`` lands anywhere in the last allocated block (including offset
    0, the fresh-boundary case), and with ``holes`` an allocated middle
    block is knocked back to the sentinel — the predicate-skip case the
    clamp-gather path never sees."""
    H = n_kv * g
    num_blocks = B * W + 1                       # at least one spare block
    q = jnp.asarray(rng.normal(size=(B, H, hd)), jnp.float32)
    kn = jnp.asarray(rng.normal(size=(B, n_kv, hd)), jnp.float32)
    vn = jnp.asarray(rng.normal(size=(B, n_kv, hd)), jnp.float32)
    kp = jnp.asarray(rng.normal(size=(num_blocks, bs, n_kv, hd)), jnp.float32)
    vp = jnp.asarray(rng.normal(size=(num_blocks, bs, n_kv, hd)), jnp.float32)
    tbl = np.full((B, W), num_blocks, np.int32)
    cur = np.zeros((B,), np.int32)
    free = list(rng.permutation(num_blocks))
    for b in range(B):
        n_alloc = int(rng.integers(0, W + 1))
        tbl[b, :n_alloc] = [free.pop() for _ in range(n_alloc)]
        if n_alloc:
            cur[b] = int(rng.integers((n_alloc - 1) * bs, n_alloc * bs))
            if holes and n_alloc > 1:
                tbl[b, int(rng.integers(0, n_alloc - 1))] = num_blocks
        else:
            cur[b] = int(rng.integers(0, W * bs))   # inactive row
    return q, kn, vn, kp, vp, jnp.asarray(tbl), jnp.asarray(cur)


def _check_paged(args, bs):
    out = np.asarray(paged_attention_pallas(*args, block_size=bs))
    ref = np.asarray(paged_attention_ref(*args, block_size=bs))
    assert out.shape == ref.shape
    # online vs fused softmax reorders the f32 sums: roundoff, not bitwise
    np.testing.assert_allclose(out, ref, rtol=2e-5, atol=2e-5)


@settings(max_examples=12, deadline=None)
@given(
    st.integers(1, 4),                       # B
    st.integers(1, 4),                       # W (table width)
    st.sampled_from([1, 2, 4, 8]),           # block_size
    st.integers(1, 2),                       # Hkv
    st.integers(1, 3),                       # GQA group (H = Hkv * g)
    st.sampled_from([4, 16]),                # head_dim
    st.integers(0, 2**31 - 1),               # data seed
)
def test_paged_attention_matches_ref_random_tables(B, W, bs, n_kv, g, hd, seed):
    rng = np.random.default_rng(seed)
    _check_paged(_paged_case(rng, B, W, bs, n_kv, g, hd), bs)


@settings(max_examples=8, deadline=None)
@given(
    st.integers(2, 4),                       # B
    st.integers(2, 4),                       # W
    st.sampled_from([2, 4]),                 # block_size
    st.integers(1, 3),                       # GQA group
    st.integers(0, 2**31 - 1),
)
def test_paged_attention_skips_sentinel_holes(B, W, bs, g, seed):
    """Sentinel entries BELOW cur_len (never produced by the scheduler, but
    exactly what the kernel's predicate-skip must handle): both kernel and
    oracle must exclude those positions entirely."""
    rng = np.random.default_rng(seed)
    _check_paged(_paged_case(rng, B, W, bs, 2, g, 8, holes=True), bs)


def test_paged_attention_inactive_rows_are_exact_zero():
    """All-sentinel rows (empty decode slots) flush exactly 0.0 — no NaNs
    from the 0/0 normalizer, no garbage from the clamped DMA."""
    rng = np.random.default_rng(0)
    q, kn, vn, kp, vp, tbl, cur = _paged_case(rng, 3, 2, 4, 2, 2, 8)
    tbl = jnp.full_like(tbl, kp.shape[0])    # every row inactive
    out = np.asarray(paged_attention_pallas(q, kn, vn, kp, vp, tbl, cur, block_size=4))
    assert np.array_equal(out, np.zeros_like(out))


def test_paged_attention_past_table_cur_len():
    """Overshoot rows (cur_len beyond the table, the scheduler's discarded
    garbage regime) still produce finite outputs that agree with the
    oracle: the fused append simply never lands."""
    rng = np.random.default_rng(1)
    q, kn, vn, kp, vp, tbl, cur = _paged_case(rng, 2, 2, 4, 1, 2, 8)
    cur = jnp.asarray([2 * 4 + 3, 2 * 4], jnp.int32)    # both past the table
    args = (q, kn, vn, kp, vp, tbl, cur)
    _check_paged(args, 4)
    assert np.isfinite(np.asarray(paged_attention_pallas(*args, block_size=4))).all()


def test_paged_attention_ops_validation():
    """Shape mistakes fail loudly in the wrapper, not deep in pallas."""
    rng = np.random.default_rng(0)
    q, kn, vn, kp, vp, tbl, cur = _paged_case(rng, 2, 2, 4, 2, 2, 8)
    with pytest.raises(ValueError, match="block_size"):
        paged_attention_pallas(q, kn, vn, kp, vp, tbl, cur, block_size=8)
    with pytest.raises(ValueError, match="new-token"):
        paged_attention_pallas(q, kn[:1], vn, kp, vp, tbl, cur, block_size=4)
    with pytest.raises(ValueError, match="batch"):
        paged_attention_pallas(q, kn, vn, kp, vp, tbl[:1], cur, block_size=4)
    with pytest.raises(ValueError, match="incompatible"):
        paged_attention_pallas(q[:, :3], kn, vn, kp, vp, tbl, cur, block_size=4)