"""Training substrate: optimizer, loop, microbatching, regularized QAT."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config, reduced_config
from repro.core.approx import ApproxConfig
from repro.data.synthetic import token_batches
from repro.models.transformer import init_params
from repro.train import optim as O
from repro.train.loop import cross_entropy, init_state, make_train_step, train_loop

KEY = jax.random.PRNGKey(0)


def _tiny_cfg(**over):
    cfg = reduced_config(get_config("granite-3-2b"))
    return dataclasses.replace(
        cfg, num_layers=2, d_model=64, num_heads=2, num_kv_heads=1, head_dim=32,
        d_ff=128, vocab_size=128, remat=False, **over
    )


def _batches(cfg, B=4, S=16):
    it = token_batches(cfg.vocab_size, B, S, seed=0)
    for toks, labels in it:
        yield {"tokens": jnp.asarray(toks), "labels": jnp.asarray(labels)}


def test_cross_entropy_matches_naive():
    logits = jax.random.normal(KEY, (2, 4, 8))
    labels = jax.random.randint(KEY, (2, 4), 0, 8)
    ce = cross_entropy(logits, labels)
    ref = -np.mean(
        np.take_along_axis(
            np.asarray(jax.nn.log_softmax(logits)), np.asarray(labels)[..., None], -1
        )
    )
    assert float(ce) == pytest.approx(ref, rel=1e-5)


def test_loss_decreases_float():
    cfg = _tiny_cfg()
    opt = O.OptConfig(kind="adamw", lr=3e-3, warmup_steps=5, total_steps=60, clip_norm=1.0)
    _, hist = train_loop(cfg, opt, _batches(cfg), steps=30, key=KEY)
    first = np.mean(hist["loss"][:5])
    last = np.mean(hist["loss"][-5:])
    assert last < first - 0.2, (first, last)


def test_train_step_lowrank_qat_runs():
    cfg = _tiny_cfg(approx=ApproxConfig(multiplier="mul8x8_2", mode="lowrank", band_reg=1e-4))
    opt = O.OptConfig(lr=1e-3, total_steps=10)
    state = init_state(cfg, opt, KEY)
    step = jax.jit(make_train_step(cfg, opt))
    batch = next(_batches(cfg))
    state, m = step(state, batch)
    assert np.isfinite(float(m["loss"]))
    assert float(m["band_reg"]) >= 0
    assert all(bool(jnp.all(jnp.isfinite(x))) for x in jax.tree.leaves(state["params"]))


def test_microbatch_grad_accum_equivalent():
    cfg = _tiny_cfg()
    opt = O.OptConfig(kind="sgd", lr=1e-2, clip_norm=0.0, warmup_steps=0)
    state0 = init_state(cfg, opt, KEY)
    batch = next(_batches(cfg, B=8))
    s1, m1 = jax.jit(make_train_step(cfg, opt, microbatch=1))(
        jax.tree.map(jnp.copy, state0), batch
    )
    s2, m2 = jax.jit(make_train_step(cfg, opt, microbatch=4))(
        jax.tree.map(jnp.copy, state0), batch
    )
    for a, b in zip(jax.tree.leaves(s1["params"]), jax.tree.leaves(s2["params"])):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b), rtol=2e-4, atol=2e-5)


def test_optimizers_step_and_shapes():
    params = {"w": jnp.ones((4, 4)), "b": jnp.zeros((4,))}
    grads = jax.tree.map(jnp.ones_like, params)
    for kind in ("adamw", "sgd"):
        cfg = O.OptConfig(kind=kind, lr=0.1, warmup_steps=0)
        st = O.init_opt_state(cfg, params)
        p2, st2, m = O.apply_updates(cfg, params, grads, st)
        assert int(st2["step"]) == 1
        assert float(m["grad_norm"]) > 0
        assert jax.tree.structure(p2) == jax.tree.structure(params)
        assert float(jnp.sum(jnp.abs(p2["w"] - params["w"]))) > 0


def test_clip_by_global_norm():
    tree = {"a": jnp.full((10,), 10.0)}
    clipped, gn = O.clip_by_global_norm(tree, 1.0)
    assert float(gn) == pytest.approx(np.sqrt(1000), rel=1e-5)
    assert float(O.global_norm(clipped)) == pytest.approx(1.0, rel=1e-4)


def test_band_regularizer_moves_weights_into_band():
    """The paper's co-optimization: retraining with the band regularizer must
    reduce the fraction of weight codes above 31."""
    from repro.quant.affine import calibrate, quantize

    cfg = _tiny_cfg(approx=ApproxConfig(multiplier="mul8x8_3", mode="exact_quant", band_reg=10.0))
    opt = O.OptConfig(lr=5e-3, total_steps=40, warmup_steps=0)

    def frac_out(params):
        out, tot = 0, 0
        for leaf in jax.tree.leaves(params):
            if leaf.ndim >= 2:
                qp = calibrate(leaf, axis=(leaf.ndim - 2,), qmax=255)
                q = np.asarray(quantize(leaf, qp))
                out += (q > 31).sum()
                tot += q.size
        return out / tot

    state = init_state(cfg, opt, KEY)
    before = frac_out(state["params"])
    state, _ = train_loop(cfg, opt, _batches(cfg), steps=25, state=state)
    after = frac_out(state["params"])
    assert after < before, (before, after)
