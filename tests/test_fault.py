"""Fault tolerance: straggler detection, preemption, restart supervision,
gradient compression."""
import os
import signal

import jax.numpy as jnp
import numpy as np
import pytest

from repro.train.compression import compress_decompress
from repro.train.fault import PreemptionGuard, StragglerMonitor, run_with_restarts


def test_straggler_monitor_flags_outliers():
    mon = StragglerMonitor(threshold=2.0, warmup=3)
    seen = []
    mon.on_straggler = lambda step, dt, ewma: seen.append(step)
    for i in range(20):
        dt = 1.0 if i != 12 else 5.0
        mon.record(i, dt)
    assert seen == [12]
    assert mon.ewma == pytest.approx(1.0, rel=0.05)


def test_straggler_monitor_ewma_excludes_outliers():
    mon = StragglerMonitor(threshold=2.0, warmup=2)
    for i in range(10):
        mon.record(i, 1.0)
    mon.record(10, 100.0)
    assert mon.ewma < 2.0  # outlier not folded in


def test_preemption_guard():
    with PreemptionGuard(signals=(signal.SIGUSR1,)) as g:
        assert not g.should_stop
        os.kill(os.getpid(), signal.SIGUSR1)
        assert g.should_stop


def test_run_with_restarts_resumes():
    """Simulated node failure: fn crashes twice, supervisor restarts, work
    resumes from 'checkpoint' (a captured counter)."""
    ckpt = {"step": 0}
    crashes = []

    def job(attempt):
        start = ckpt["step"]
        for s in range(start, 10):
            ckpt["step"] = s + 1
            if s == 4 and attempt == 0:
                raise RuntimeError("node lost")
            if s == 7 and attempt == 1:
                raise RuntimeError("preempted")
        return ckpt["step"]

    out = run_with_restarts(job, max_restarts=3, on_restart=lambda a, e: crashes.append(str(e)))
    assert out == 10
    assert len(crashes) == 2
    assert ckpt["step"] == 10


def test_run_with_restarts_exhausts():
    def job(attempt):
        raise RuntimeError("always")

    with pytest.raises(RuntimeError):
        run_with_restarts(job, max_restarts=2)


def test_gradient_compression_error_feedback():
    rng = np.random.default_rng(0)
    g = {"w": jnp.asarray(rng.normal(size=(64, 64)), jnp.float32)}
    # single step: quantization error bounded by scale
    deq, err = compress_decompress(g, None)
    scale = float(jnp.max(jnp.abs(g["w"]))) / 127.0
    assert float(jnp.max(jnp.abs(deq["w"] - g["w"]))) <= 0.5 * scale + 1e-7
    # error feedback: accumulated average of decompressed grads converges to
    # the true average (bias cancels over steps)
    total_true = np.zeros((8,), np.float32)
    total_deq = np.zeros((8,), np.float32)
    err = None
    for i in range(200):
        gi = {"w": jnp.asarray(rng.normal(size=(8,)) * 0.01, jnp.float32)}
        deq, err = compress_decompress(gi, err)
        total_true += np.asarray(gi["w"])
        total_deq += np.asarray(deq["w"])
    resid = np.abs(total_deq - total_true).max()
    # residual stays bounded by one quantization step, not O(n) drift
    assert resid < 0.01
