"""Paged block-table KV cache invariants (serve stack PR 3).

* block accounting: allocation is proportional to the ACTUAL context
  (``ceil(prompt_len / block_size)`` at admit, one append per boundary
  crossing, worst case ``ceil((prompt_len + max_new - 1) / block_size)``),
  and no block leaks or double-frees across randomized traces — including
  eos exits and an oversubscribed pool;
* decode parity: greedy paged outputs are bit-identical to the slot-layout
  engine AND to standalone ``generate`` across attention-family configs,
  decode-chunk sizes, and admission interleavings;
* fixed compiled shapes: zero recompiles after ``warmup()`` on a mixed
  Poisson trace (block-table contents are traced data);
* host bookkeeping units: ``BlockPool`` heap discipline, bisect buckets,
  and submit-time validation that names the offending request;
* attention impls: ``attn_impl="pallas"`` (the in-place block-pool kernel,
  interpret mode on CPU) produces greedy outputs bit-identical to the
  ``"gather"`` oracle and to standalone ``generate`` under BOTH host
  loops, with zero recompiles after warmup; unknown impls are rejected at
  construction naming the valid choices.
"""
import dataclasses

import jax
import numpy as np
import pytest

from repro.configs import get_config, reduced_config
from repro.serve import (
    ATTN_IMPLS,
    BlockPool,
    PromptBuckets,
    SamplingConfig,
    ServeSession,
    freeze_params,
    generate,
    resolve_execution_mode,
    scheduler_compile_stats,
)

KEY = jax.random.PRNGKey(0)


def _cfg(arch="granite-3-2b", **over):
    return dataclasses.replace(
        reduced_config(get_config(arch)), remat=False, q_chunk=16, **over
    )


_PARAMS = {}


def _params(cfg):
    if cfg.name not in _PARAMS:
        from repro.models.transformer import init_params

        _PARAMS[cfg.name] = init_params(cfg, KEY)
    return _PARAMS[cfg.name]


def _random_trace(rng, n, vocab, *, plen=(2, 9), new=(1, 7), arrival_rate=0.0):
    out, t = [], 0
    for _ in range(n):
        p = rng.integers(0, vocab, int(rng.integers(*plen)))
        if arrival_rate > 0:
            t += int(rng.poisson(arrival_rate))
        out.append((p, int(rng.integers(*new)), t))
    return out


def _paged_session(cfg, **over):
    kw = dict(num_slots=3, max_len=32, prompt_buckets=(4, 8),
              cache_layout="paged", block_size=4)
    kw.update(over)
    return ServeSession(cfg, _params(cfg), **kw)


def _assert_pool_clean(sess):
    """Every block returned, every reservation dropped, tables scrubbed."""
    assert sess.blocks.free_count == sess.num_blocks
    assert sess.blocks.busy_count == 0
    assert sess._reserved_total == 0
    assert (sess._tables == sess.num_blocks).all()
    assert all(not h for h in sess._held)
    assert (sess._future == 0).all()


# ---------------------------------------------------------------------------
# Host-side bookkeeping units (fast tier)
# ---------------------------------------------------------------------------


def test_block_pool_heap_discipline():
    p = BlockPool(4)
    assert p.sentinel == 4 and p.free_count == 4
    got = [p.acquire() for _ in range(3)]
    assert got == [0, 1, 2]                       # lowest-first, deterministic
    p.release(1)
    assert p.acquire() == 1                       # heap returns the freed min
    assert p.busy_count == 3


def test_block_pool_acquire_many_all_or_nothing():
    p = BlockPool(3)
    assert p.acquire_many(2) == [0, 1]
    assert p.acquire_many(2) is None              # only 1 free: untouched
    assert p.free_count == 1
    assert p.acquire_many(1) == [2]


def test_block_pool_double_free_and_range():
    p = BlockPool(2)
    a = p.acquire()
    p.release(a)
    with pytest.raises(ValueError):
        p.release(a)                              # double free
    with pytest.raises(ValueError):
        p.release(5)                              # out of range
    with pytest.raises(ValueError):
        BlockPool(0)


def test_prompt_buckets_bisect_matches_linear_scan():
    sizes = (4, 8, 16, 64, 256)
    b = PromptBuckets(sizes)
    for n in range(1, 257):
        expected = next(s for s in sizes if n <= s)
        assert b.bucket(n) == expected, n
    with pytest.raises(ValueError):
        b.bucket(257)


def test_submit_validation_names_request():
    sess = _paged_session(_cfg())
    with pytest.raises(ValueError, match="request 7"):
        sess.submit(np.arange(9), max_new=2, req_id=7)       # no bucket fits
    with pytest.raises(ValueError, match="request 7"):
        sess.submit(np.arange(4), max_new=40, req_id=7)      # exceeds max_len
    with pytest.raises(ValueError, match=r"request 0.*empty"):
        sess.submit(np.asarray([], np.int32), max_new=2)


def test_paged_session_validation():
    cfg = _cfg()
    with pytest.raises(ValueError, match="multiple of"):
        _paged_session(cfg, max_len=30)                      # 30 % 4 != 0
    with pytest.raises(ValueError, match="zero_on_evict"):
        _paged_session(cfg, zero_on_evict=True)
    with pytest.raises(ValueError, match="nothing to page"):
        ServeSession(_cfg("falcon-mamba-7b"), None, cache_layout="paged")
    with pytest.raises(ValueError, match="cache_layout"):
        ServeSession(cfg, _params(cfg), cache_layout="sharded")
    with pytest.raises(ValueError, match="policy"):
        ServeSession(cfg, _params(cfg), policy="lifo")
    # a request whose worst case can never fit the pool fails at submit
    sess = _paged_session(cfg, num_blocks=2)
    with pytest.raises(ValueError, match="never be admitted"):
        sess.submit(np.arange(1, 5), max_new=10, req_id=3)


def test_attn_impl_validation_names_choices():
    """Unknown attention impls are rejected at construction, the error
    names the valid set, and the Pallas kernel refuses the slot layout
    (there is no block table to walk) — the PR-3/4 validation style."""
    cfg = _cfg()
    assert ATTN_IMPLS == ("gather", "pallas")
    with pytest.raises(ValueError, match=r"attn_impl.*gather.*pallas"):
        _paged_session(cfg, attn_impl="vectorized")
    with pytest.raises(ValueError, match="cache_layout='paged'"):
        ServeSession(cfg, _params(cfg), cache_layout="slots", attn_impl="pallas")
    # the model layer rejects bad impls too (belt for non-session callers)
    from repro.models.attention import paged_decode_attention

    with pytest.raises(ValueError, match="attn_impl"):
        paged_decode_attention(
            None, None, np.zeros((1, 1, 1, 1)), None, None, None,
            block_size=1, n_heads=1, n_kv=1, cfg=cfg.approx,
            attn_impl="bogus",
        )
    # the active impl is surfaced in the stats artifact fields
    assert _paged_session(cfg, attn_impl="pallas").stats.attn_impl == "pallas"


# ---------------------------------------------------------------------------
# Invariants over randomized traces
# ---------------------------------------------------------------------------


@pytest.mark.slow
@pytest.mark.parametrize("steps_per_tick", [1, 3])
def test_paged_parity_with_slots_and_generate(steps_per_tick):
    """The tentpole oracle: greedy paged outputs are bit-identical to the
    slot engine and to standalone ``generate`` on the same randomized
    arrival/length trace — the block gather/scatter path must be exact."""
    cfg = _cfg()
    rng = np.random.default_rng(2)
    trace = _random_trace(rng, 10, cfg.vocab_size, arrival_rate=1.5)
    outs = {}
    for layout in ("slots", "paged"):
        kw = dict(num_slots=3, max_len=32, prompt_buckets=(4, 8),
                  steps_per_tick=steps_per_tick)
        if layout == "paged":
            kw.update(cache_layout="paged", block_size=4)
        sess = ServeSession(cfg, _params(cfg), **kw)
        ids = [sess.submit(p, max_new=n, arrival=t, req_id=i)
               for i, (p, n, t) in enumerate(trace)]
        res = sess.run(max_steps=10_000)
        assert sess.drained
        outs[layout] = {i: res[i].tokens.tolist() for i in ids}
        if layout == "paged":
            _assert_pool_clean(sess)
    assert outs["slots"] == outs["paged"]
    for i, (p, n, _) in enumerate(trace):
        alone = np.asarray(
            generate(cfg, _params(cfg), p[None, :].astype(np.int32), max_new=n)
        )[0, len(p):]
        assert outs["paged"][i] == alone.tolist(), i


@pytest.mark.slow
def test_paged_parity_moe_family():
    """The paged gather must compose with the MoE decode block too."""
    cfg = _cfg("qwen2-moe-a2.7b")
    sess = ServeSession(cfg, _params(cfg), num_slots=2, max_len=16,
                        prompt_buckets=(4, 8), cache_layout="paged",
                        block_size=4)
    prompts = [np.asarray([1, 2, 3], np.int32), np.asarray([4, 5], np.int32),
               np.asarray([6, 7, 8, 9, 1], np.int32)]
    ids = [sess.submit(p, max_new=3) for p in prompts]
    res = sess.run()
    for rid, p in zip(ids, prompts):
        alone = np.asarray(
            generate(cfg, _params(cfg), p[None], max_new=3)
        )[0, len(p):]
        assert np.array_equal(alone, res[rid].tokens), rid
    _assert_pool_clean(sess)


@pytest.mark.slow
def test_paged_allocation_tracks_actual_context():
    """Blocks held grow with the request's REAL context: exactly
    ``ceil(prompt_len / block_size)`` right after admit, one more per block
    boundary crossed during decode, never past the worst case — the memory
    proportionality the layout exists for."""
    cfg = _cfg()
    bs = 4
    for plen, max_new in [(2, 3), (4, 9), (7, 6), (8, 2)]:
        sess = _paged_session(cfg, num_slots=1, block_size=bs)
        rid = sess.submit(np.arange(1, plen + 1, dtype=np.int32), max_new=max_new)
        worst = -(-(plen + max_new - 1) // bs)
        # drive admission by hand so the admit-time allocation is observable
        # before the first decode tick appends a boundary block
        sess._pull_arrivals()
        sess._admit_many(sess._pop_admissible()[0])
        seen = [len(sess._held[0])]
        assert seen[0] == -(-plen // bs), (plen, max_new, seen)   # admit alloc
        while not sess.drained:
            sess.step()
            if sess._active[0] is not None:
                seen.append(len(sess._held[0]))
        assert max(seen) <= worst, (plen, max_new, seen)
        # growth is one block at a time (boundary crossings only)
        assert all(b - a in (0, 1) for a, b in zip(seen, seen[1:]))
        assert len(sess.results[rid].tokens) == max_new
        _assert_pool_clean(sess)
        # a length-finished request touches exactly its worst case: its last
        # cache write lands at position prompt_len + max_new - 2 (``seen``
        # can miss the final boundary block when it finishes that same tick)
        assert sess.stats.peak_blocks_in_use == worst, (plen, max_new)


@pytest.mark.slow
def test_paged_no_leak_under_eos_and_oversubscription():
    """Randomized trace with eos exits against a pool SMALLER than
    num_slots * max_len (the oversubscribed regime): every request still
    completes, nothing leaks, nothing double-frees, and concurrency exceeds
    what slot stripes could reach with the same memory."""
    cfg = _cfg()
    # 12 blocks x 4 = 48 KV rows for 4 slots x 32 max_len (128 rows striped)
    sess = _paged_session(cfg, num_slots=4, num_blocks=12,
                          sampling=SamplingConfig(temperature=0.7, top_k=16,
                                                  eos_id=3),
                          steps_per_tick=2)
    rng = np.random.default_rng(4)
    trace = _random_trace(rng, 14, cfg.vocab_size, new=(2, 8), arrival_rate=1.0)
    ids = [sess.submit(p, max_new=n, arrival=t) for p, n, t in trace]
    res = sess.run(max_steps=10_000)
    assert sess.drained and sorted(res) == sorted(ids)
    assert sess.stats.completed == len(trace)
    assert sess.stats.peak_blocks_in_use <= 12
    # stripes of 32 rows would cap residency at 48 // 32 == 1 request
    assert sess.stats.peak_active > 48 // 32
    for rid, (p, n, _) in zip(ids, trace):
        assert 1 <= len(res[rid].tokens) <= n
    _assert_pool_clean(sess)


@pytest.mark.slow
def test_paged_zero_recompiles_after_warmup():
    """Block tables are traced data: no arrival pattern, context layout, or
    block-boundary crossing may recompile after ``warmup()``."""
    cfg = _cfg()
    sess = _paged_session(cfg, num_slots=3, num_blocks=18, steps_per_tick=2)
    sess.warmup()
    before = scheduler_compile_stats()
    rng = np.random.default_rng(5)
    for p, n, t in _random_trace(rng, 12, cfg.vocab_size, arrival_rate=1.0):
        sess.submit(p, max_new=n, arrival=t)
    sess.run()
    assert scheduler_compile_stats() == before
    assert sess.stats.completed == 12
    _assert_pool_clean(sess)


@pytest.mark.slow
def test_paged_memory_admission_preserves_order():
    """When the head request's worst case doesn't fit the pool, admission
    WAITS (no skip-ahead): policy order survives memory pressure, and the
    head admits as soon as enough blocks free up."""
    cfg = _cfg()
    sess = _paged_session(cfg, num_slots=2, num_blocks=4)   # 16 KV rows
    big = sess.submit(np.arange(1, 8, dtype=np.int32), max_new=9)   # 4 blocks
    small = sess.submit(np.asarray([1, 2], np.int32), max_new=2)    # 1 block
    res = sess.run(max_steps=10_000)
    assert sess.drained
    # big holds the whole pool first; small must not jump the queue
    assert res[big].admitted_tick <= res[small].admitted_tick
    assert len(res[big].tokens) == 9 and len(res[small].tokens) == 2
    _assert_pool_clean(sess)


@pytest.mark.slow
@pytest.mark.parametrize("mode", ["exact_quant", "approx_lowrank"])
def test_paged_quantized_modes_with_frozen_weights(mode):
    """Every execution mode (incl. freeze_params QWeight trees) routes
    through the paged layout unchanged; statistical contract: shapes,
    counts, vocab range."""
    cfg = _cfg(approx=resolve_execution_mode(mode))
    params = freeze_params(cfg, _params(_cfg()))
    sess = ServeSession(cfg, params, num_slots=2, max_len=24,
                        prompt_buckets=(4, 8), cache_layout="paged",
                        block_size=8)
    ids = [sess.submit(np.arange(1, 5, dtype=np.int32) * (i + 1) % 64, max_new=4)
           for i in range(4)]
    res = sess.run()
    for rid in ids:
        toks = res[rid].tokens
        assert toks.shape == (4,)
        assert 0 <= int(toks.min()) and int(toks.max()) < cfg.vocab_size
    _assert_pool_clean(sess)


@pytest.mark.slow
@pytest.mark.parametrize("loop", ["sync", "async"])
def test_pallas_attn_parity_with_gather_and_generate(loop):
    """The kernel oracle: greedy outputs under ``attn_impl="pallas"``
    (interpret mode on CPU — the real kernel body) are bit-identical to the
    ``"gather"`` path AND to standalone ``generate`` on the same randomized
    arrival/length trace, under both host loops.  Chunked decode
    (steps_per_tick=2) exercises the kernel's read of the *pre-scatter*
    pool across scan steps: step s+1 must see step s's persisted token."""
    cfg = _cfg()
    rng = np.random.default_rng(7)
    trace = _random_trace(rng, 10, cfg.vocab_size, arrival_rate=1.5)
    outs = {}
    for impl in ("gather", "pallas"):
        sess = _paged_session(cfg, num_slots=3, steps_per_tick=2,
                              loop=loop, attn_impl=impl)
        ids = [sess.submit(p, max_new=n, arrival=t, req_id=i)
               for i, (p, n, t) in enumerate(trace)]
        res = sess.run(max_steps=10_000)
        assert sess.drained
        outs[impl] = {i: res[i].tokens.tolist() for i in ids}
        _assert_pool_clean(sess)
    assert outs["gather"] == outs["pallas"]
    for i, (p, n, _) in enumerate(trace):
        alone = np.asarray(
            generate(cfg, _params(cfg), p[None, :].astype(np.int32), max_new=n)
        )[0, len(p):]
        assert outs["pallas"][i] == alone.tolist(), i


@pytest.mark.slow
def test_pallas_attn_zero_recompiles_after_warmup():
    """Block tables and lengths reach the kernel as scalar-prefetch traced
    data: no arrival pattern or block layout may recompile the pallas
    decode program after ``warmup()`` — and switching impls compiles a
    SEPARATE program rather than silently reusing the other's."""
    cfg = _cfg()
    sess = _paged_session(cfg, num_slots=3, num_blocks=18, steps_per_tick=2,
                          attn_impl="pallas")
    sess.warmup()
    before = scheduler_compile_stats()
    rng = np.random.default_rng(5)
    for p, n, t in _random_trace(rng, 10, cfg.vocab_size, arrival_rate=1.0):
        sess.submit(p, max_new=n, arrival=t)
    sess.run()
    assert scheduler_compile_stats() == before
    assert sess.stats.completed == 10
    _assert_pool_clean(sess)


@pytest.mark.slow
def test_pallas_attn_reduced_cache_dtype_runs():
    """bf16 pool: the kernel must attend the POOL-ROUNDED fused token (the
    value every later step reads back), and the session must stay sane.
    Token parity vs gather is statistical under reduced cache dtypes — the
    gather path also rounds its softmax probs to the cache dtype — so this
    pins shape/range/accounting contracts, not bitwise tokens."""
    import jax.numpy as jnp

    cfg = _cfg()
    sess = _paged_session(cfg, num_slots=2, cache_dtype=jnp.bfloat16,
                          attn_impl="pallas")
    ids = [sess.submit(np.arange(1, 4 + i, dtype=np.int32), max_new=3)
           for i in range(3)]
    res = sess.run(max_steps=10_000)
    assert sess.drained
    for rid in ids:
        toks = res[rid].tokens
        assert toks.shape == (3,)
        assert 0 <= int(toks.min()) and int(toks.max()) < cfg.vocab_size
    _assert_pool_clean(sess)


@pytest.mark.slow
def test_attn_paged_bench_smoke():
    """The kernel-vs-gather bench harness: a miniature run must complete
    with the exactness oracles clean and the HBM-traffic ratio above its
    W*block_size/context floor (the real bench config runs in CI)."""
    import benchmarks.attn_paged_kernel as B

    r = B.bench(requests=6)
    assert r["token_mismatches"] == 0
    assert r["recompiles_after_warmup"] == 0
    assert r["hbm_bytes_ratio"] >= r["floor_ratio"] > 1.0
    assert r["hbm_gathered_bytes_per_tick"] > r["hbm_inplace_bytes_per_tick"]
    for row in r["micro"]:
        assert row["gathered_kv_bytes"] >= row["inplace_kv_bytes"]
    assert set(r["field_docs"]) >= {"hbm_bytes_ratio", "floor_ratio"}


@pytest.mark.slow
def test_serve_paged_bench_smoke():
    """The equal-memory bench harness: a miniature run must complete with
    zero recompiles, zero cross-engine token mismatches, and sane
    accounting (the >= 1.3x concurrency criterion is asserted on the real
    bench config in CI — this pins the machinery)."""
    import benchmarks.serve_paged as B

    r = B.bench(requests=10, slot_slots=2, paged_slots=4, steps_per_tick=2)
    assert r["token_mismatches"] == 0
    assert r["recompiles_after_warmup"] == 0
    assert r["useful_tokens"] > 0
    assert r["slot_tok_s"] > 0 and r["paged_tok_s"] > 0
    assert r["paged_peak_blocks"] <= r["paged_num_blocks"]
    assert r["kv_budget_rows"] == r["paged_num_blocks"] * r["block_size"]
