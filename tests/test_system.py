"""End-to-end behaviour of the paper's system: approximate multipliers wired
through quantized DNNs, co-optimization recovering accuracy, serving."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config, reduced_config
from repro.core.approx import ApproxConfig
from repro.core import multipliers as M
from repro.core.metrics import dal, multiplier_metrics
from repro.data.synthetic import image_dataset
from repro.models.cnn import cnn_forward, init_cnn
from repro.models.transformer import init_params
from repro.serve.engine import greedy_generate

KEY = jax.random.PRNGKey(0)


def _train_cnn(model, data, cfg, steps=60, lr=0.05, bs=64):
    params = model["layers"]

    def loss_fn(layers, x, y):
        m = dict(model, layers=layers)
        logits = cnn_forward(m, x, cfg)
        return -jnp.mean(
            jnp.sum(jax.nn.log_softmax(logits) * jax.nn.one_hot(y, 10), -1)
        )

    @jax.jit
    def step(layers, x, y):
        l, g = jax.value_and_grad(loss_fn)(layers, x, y)
        return jax.tree.map(lambda p, gr: p - lr * gr, layers, g), l

    n = data.x_train.shape[0]
    for i in range(steps):
        j = (i * bs) % (n - bs)
        params, _ = step(params, jnp.asarray(data.x_train[j : j + bs]), jnp.asarray(data.y_train[j : j + bs]))
    return dict(model, layers=params)


def _acc(model, data, cfg):
    logits = cnn_forward(model, jnp.asarray(data.x_test[:256]), cfg)
    return float(jnp.mean(jnp.argmax(logits, -1) == jnp.asarray(data.y_test[:256])))


def test_lenet_dal_and_cooptimization():
    """The paper's core claim at reduced scale: (1) swapping the exact
    multiplier for MUL8x8_2 costs little accuracy; (2) a poor multiplier
    (PKM) costs much more; (3) the learned task is genuinely learned."""
    data = image_dataset("mnist", n_train=1024, n_test=256, seed=0)
    model = init_cnn("lenet", KEY, in_shape=(28, 28, 1))
    fl = ApproxConfig(mode="float")
    model = _train_cnn(model, data, fl, steps=80)
    acc_float = _acc(model, data, fl)
    assert acc_float > 0.8, acc_float

    acc_m2 = _acc(model, data, ApproxConfig(multiplier="mul8x8_2", mode="lowrank"))
    acc_pkm = _acc(model, data, ApproxConfig(multiplier="pkm", mode="lut"))
    assert dal(acc_float, acc_m2) < 0.08, (acc_float, acc_m2)
    assert acc_m2 >= acc_pkm - 0.02


def test_multiplier_quality_ordering():
    """Arithmetic quality ordering matches the paper: mul8x8_2 < mul8x8_1 <
    mul8x8_3 < pkm < etm in NMED."""
    nmed = {n: multiplier_metrics(M.mul8x8_table(n)).nmed for n in
            ("mul8x8_1", "mul8x8_2", "mul8x8_3", "pkm", "etm")}
    assert nmed["mul8x8_2"] < nmed["mul8x8_1"] < nmed["mul8x8_3"] < nmed["pkm"] < nmed["etm"]


def test_greedy_generate_smoke():
    cfg = dataclasses.replace(
        reduced_config(get_config("granite-3-2b")), remat=False, q_chunk=16
    )
    params = init_params(cfg, KEY)
    prompt = jnp.asarray([[1, 2, 3], [4, 5, 6]], jnp.int32)
    out = greedy_generate(cfg, params, prompt, max_new=4)
    assert out.shape == (2, 7)
    assert bool(jnp.all(out[:, :3] == prompt))
    assert bool(jnp.all((out >= 0) & (out < cfg.vocab_size)))


def test_approx_serve_consistency():
    """Decoding under the approximate multiplier yields valid tokens and
    deterministic results."""
    cfg = dataclasses.replace(
        reduced_config(get_config("granite-3-2b")),
        remat=False, q_chunk=16,
        approx=ApproxConfig(multiplier="mul8x8_2", mode="lowrank"),
    )
    params = init_params(cfg, KEY)
    prompt = jnp.asarray([[7, 8]], jnp.int32)
    o1 = greedy_generate(cfg, params, prompt, max_new=3)
    o2 = greedy_generate(cfg, params, prompt, max_new=3)
    assert bool(jnp.all(o1 == o2))
