"""Chunked prefill (serve stack PR 10): split a prompt's prefill into
block-table chunks dispatched across successive steps, interleaved with
decode under the existing prefill budgets.

* **exactness**: chunked greedy outputs are bit-identical to the unchunked
  paged oracle (sync + async loops, gather + pallas attention impls, and
  through forced-preemption replay), and to standalone ``generate`` for
  prompts longer than the largest bucket — which only the chunked path can
  admit at all;
* **partial-table invariants**: a block table whose tail entries are still
  sentinels serves reads identically to a truncated context — entries past
  the cursor are invisible whatever they hold — across both attention
  impls, random cursors (block-boundary and mid-block), and chunk ==
  block_size;
* **compiled shapes**: chunk dispatches reuse the one-shot
  (admit width x bucket) program family — zero recompiles after
  ``warmup()``;
* **accounting**: ``prefills`` / ``prefill_tokens`` / ``prefill_chunks``
  charge per-chunk buckets, and ``CompletedRequest.ttft`` samples each
  request's first-token latency exactly once (final chunk, surviving
  preemption).
"""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config, reduced_config
from repro.serve import ServeSession, generate, scheduler_compile_stats

KEY = jax.random.PRNGKey(0)


def _cfg(**over):
    return dataclasses.replace(
        reduced_config(get_config("granite-3-2b")), remat=False, q_chunk=16,
        **over
    )


_PARAMS = {}


def _params(cfg):
    if cfg.name not in _PARAMS:
        from repro.models.transformer import init_params

        _PARAMS[cfg.name] = init_params(cfg, KEY)
    return _PARAMS[cfg.name]


def _session(cfg, *, chunked=True, **over):
    kw = dict(num_slots=3, max_len=48, prompt_buckets=(4, 8, 16),
              cache_layout="paged", block_size=4)
    if chunked:
        kw.update(chunked_prefill=True, prefill_chunk=4)
    kw.update(over)
    return ServeSession(cfg, _params(cfg), **kw)


def _trace(rng, n, vocab, *, plen=(2, 15), new=(1, 7), rate=1.0):
    out, t = [], 0
    for _ in range(n):
        t += int(rng.poisson(rate))
        out.append((rng.integers(0, vocab, int(rng.integers(*plen))),
                    int(rng.integers(*new)), t))
    return out


# ---------------------------------------------------------------------------
# Fast tier: validation + accounting + model-layer parity pins
# ---------------------------------------------------------------------------


def test_chunked_prefill_validation():
    """Composition gates fail at construction with the reason, in the
    session's established validation style."""
    cfg = _cfg()
    with pytest.raises(ValueError, match="requires chunked_prefill"):
        _session(cfg, chunked=False, prefill_chunk=4)
    with pytest.raises(ValueError, match='cache_layout="paged"'):
        ServeSession(cfg, _params(cfg), chunked_prefill=True)
    with pytest.raises(ValueError, match="prompt buckets"):
        _session(cfg, prefill_chunk=5)       # not in the bucket set
    with pytest.raises(ValueError, match="spec_decode"):
        _session(cfg, spec_decode=True)
    with pytest.raises(ValueError, match="tiers"):
        _session(cfg, tiers=("exact", "approx_lowrank"))
    with pytest.raises(ValueError, match="prefix sharing"):
        _session(cfg, prefix_sharing=True)
    # default chunk = largest bucket; chunking off leaves the old submit cap
    assert _session(cfg, prefill_chunk=None).prefill_chunk == 16
    with pytest.raises(ValueError, match="largest"):
        _session(cfg, chunked=False).submit(np.arange(1, 20), max_new=2)
    # chunked: beyond-bucket prompts admit, only raw context binds
    sess = _session(cfg)
    sess.submit(np.arange(1, 20), max_new=2, req_id=0)
    with pytest.raises(ValueError, match="max_len"):
        sess.submit(np.arange(1, 20), max_new=40, req_id=1)


def test_sentinel_tail_table_reads_as_truncated_context():
    """Property: entries past the cursor's block are invisible — a
    sentinel-tailed table and the same table with its tail aimed at
    garbage-filled real blocks attend bit-identically, for random cursors
    (mid-block and block-boundary / chunk == block_size), under BOTH
    attention impls."""
    from repro.models.attention import init_attn, paged_decode_attention

    cfg = _cfg()
    rng = np.random.default_rng(3)
    d, hq, hkv, hd, bs, w, nb, b = 32, 2, 1, 16, 4, 6, 16, 2
    p = init_attn(jax.random.PRNGKey(1), d, hq, hkv, hd)
    k_blocks = jnp.asarray(rng.standard_normal((nb + 1, bs, hkv, hd)),
                           jnp.float32)
    v_blocks = jnp.asarray(rng.standard_normal((nb + 1, bs, hkv, hd)),
                           jnp.float32)
    x = jnp.asarray(rng.standard_normal((b, 1, d)), jnp.float32)
    # cursors: mid-block, block boundary (== chunk == block_size), deeper
    for cur in (2, bs, bs + 1, 2 * bs, 3 * bs - 1):
        need = (cur // bs) + 1              # decode writes at position cur
        tail = np.full((b, w), nb, np.int32)
        real = np.full((b, w), nb, np.int32)
        for row in range(b):
            blocks = rng.choice(nb, size=w, replace=False)
            tail[row, :need] = blocks[:need]
            real[row, :] = blocks           # tail aims at garbage blocks
        cur_len = np.full((b,), cur, np.int32)
        outs = {}
        for impl in ("gather", "pallas"):
            for name, table in (("tail", tail), ("real", real)):
                o, (kb, vb) = paged_decode_attention(
                    x, p, k_blocks, v_blocks, jnp.asarray(table),
                    jnp.asarray(cur_len), block_size=bs, n_heads=hq,
                    n_kv=hkv, cfg=cfg.approx, attn_impl=impl,
                )
                outs[impl, name] = np.asarray(o)
                outs[impl, name, "k"] = np.asarray(kb)
            # the property itself is BITWISE per impl: tail contents are
            # invisible, not merely negligible
            assert np.array_equal(outs[impl, "tail"], outs[impl, "real"]), (
                impl, cur)
            assert np.array_equal(outs[impl, "tail", "k"],
                                  outs[impl, "real", "k"]), (impl, cur)
        # across impls the contract is numerical (greedy-token parity is
        # pinned end-to-end by test_chunked_matches_unchunked_oracle)
        assert np.allclose(outs["gather", "tail"], outs["pallas", "tail"],
                           atol=1e-5), cur


def test_chunk_prefill_step_matches_oneshot_and_fused():
    """Model-layer pin: N-chunk ``paged_chunk_prefill_step`` == one-shot
    ``paged_verify_step`` == fused ``forward`` prefill, bitwise — logits
    AND pool contents — for block-boundary and mid-block chunk splits."""
    from repro.models.transformer import (
        forward, init_paged_cache, paged_chunk_prefill_step,
        paged_verify_step,
    )

    cfg = _cfg()
    params = _params(cfg)
    rng = np.random.default_rng(5)
    b, plen, bs, w, nb = 2, 13, 4, 8, 32
    toks = rng.integers(0, cfg.vocab_size, (b, plen)).astype(np.int32)
    logits_f, _ = forward(cfg, params, {"tokens": jnp.asarray(toks)})
    logits_f = np.asarray(logits_f)

    tables = np.full((b, w), nb, np.int32)
    need = -(-plen // bs)
    for row in range(b):
        tables[row, :need] = np.arange(need) + row * need
    cache = init_paged_cache(cfg, nb, bs, jnp.float32)
    lv, cache_one = paged_verify_step(
        cfg, params, cache, {"tokens": jnp.asarray(toks)},
        jnp.zeros((b,), jnp.int32), jnp.asarray(tables), block_size=bs,
    )
    assert np.array_equal(logits_f, np.asarray(lv))

    for cuts in ((4,), (7,), (4, 8), (5, 6, 11)):   # block-edge + mid-block
        cache = init_paged_cache(cfg, nb, bs, jnp.float32)
        parts, pos = [], 0
        for hi in (*cuts, plen):
            l, cache = paged_chunk_prefill_step(
                cfg, params, cache, {"tokens": jnp.asarray(toks[:, pos:hi])},
                jnp.full((b,), pos, jnp.int32), jnp.asarray(tables),
                block_size=bs,
            )
            parts.append(np.asarray(l))
            pos = hi
        lc = np.concatenate(parts, axis=1)
        assert np.array_equal(logits_f, lc), cuts
        assert np.array_equal(np.asarray(cache_one["k"]),
                              np.asarray(cache["k"])), cuts


def test_per_chunk_accounting_and_ttft():
    """prefills / prefill_tokens / prefill_chunks charge each chunk's own
    bucket; CompletedRequest.ttft matches the stats samples exactly once
    per request."""
    cfg = _cfg()
    sess = _session(cfg, loop="sync", num_slots=2)
    rng = np.random.default_rng(0)
    sess.submit(rng.integers(1, cfg.vocab_size, 10), max_new=3, req_id=0)
    sess.submit(rng.integers(1, cfg.vocab_size, 3), max_new=3, req_id=1)
    res = sess.run(max_steps=500)
    st = sess.stats
    # req 0: chunks 4+4+2 (buckets 4,4,4); req 1: one-shot bucket 4
    assert st.prefill_chunks == 3
    assert st.prefills == {4: 4}
    assert st.prefill_tokens == 16
    assert sorted(st.ttft_ticks) == sorted(r.ttft for r in res.values())
    assert all(r.ttft >= 0 for r in res.values())
    assert len(st.ttft_ticks) == 2


# ---------------------------------------------------------------------------
# Slow tier: end-to-end parity + compiled-shape + bench contracts
# ---------------------------------------------------------------------------


@pytest.mark.slow
@pytest.mark.parametrize("loop", ["sync", "async"])
@pytest.mark.parametrize("attn_impl", ["gather", "pallas"])
def test_chunked_matches_unchunked_oracle(loop, attn_impl):
    """The tentpole oracle: chunking is a pure scheduling change — same
    trace, bit-identical greedy tokens vs the unchunked paged session,
    under both loops and both attention impls."""
    cfg = _cfg()
    rng = np.random.default_rng(9)
    trace = _trace(rng, 8, cfg.vocab_size)
    outs = {}
    for chunked in (False, True):
        sess = _session(cfg, chunked=chunked, loop=loop,
                        attn_impl=attn_impl, prefill_decode_ratio=2.0)
        ids = [sess.submit(p, max_new=n, arrival=t, req_id=i)
               for i, (p, n, t) in enumerate(trace)]
        res = sess.run(max_steps=5_000)
        assert sess.drained
        outs[chunked] = {i: res[i].tokens.tolist() for i in ids}
        if chunked:
            assert sess.stats.prefill_chunks > 0
    assert outs[False] == outs[True]


@pytest.mark.slow
def test_beyond_bucket_prompt_matches_generate():
    """Prompts longer than the largest bucket — admissible ONLY with
    chunking — decode bit-identically to standalone ``generate``."""
    cfg = _cfg()
    rng = np.random.default_rng(4)
    prompts = [rng.integers(1, cfg.vocab_size, n).astype(np.int32)
               for n in (18, 23, 33)]       # all > max bucket 16
    for loop in ("sync", "async"):
        sess = _session(cfg, loop=loop, max_len=48, num_blocks=40)
        for i, p in enumerate(prompts):
            sess.submit(p, max_new=6, req_id=i, arrival=i)
        res = sess.run(max_steps=5_000)
        assert sess.drained
        for i, p in enumerate(prompts):
            alone = np.asarray(
                generate(cfg, _params(cfg), p[None, :], max_new=6)
            )[0, len(p):]
            assert res[i].tokens.tolist() == alone.tolist(), (loop, i)


@pytest.mark.slow
@pytest.mark.parametrize("loop", ["sync", "async"])
def test_chunked_replay_after_forced_preemption(loop):
    """A starved pool forces eviction mid-flight; victims replay their
    (long) prompt + accepted recompute through the CHUNKED path and the
    outputs stay bit-identical to a roomy-pool run."""
    cfg = _cfg()
    rng = np.random.default_rng(12)
    prompts = [rng.integers(1, cfg.vocab_size, n).astype(np.int32)
               for n in (14, 13, 11, 6)]
    outs = {}
    for blocks in (40, 9):
        sess = _session(cfg, loop=loop, num_slots=2, num_blocks=blocks,
                        preemption=True)
        for i, p in enumerate(prompts):
            sess.submit(p, max_new=8, req_id=i, arrival=i)
        res = sess.run(max_steps=5_000)
        assert sess.drained
        outs[blocks] = {i: res[i].tokens.tolist() for i in res}
        # ttft sampled exactly once per request even through preemption
        assert len(sess.stats.ttft_ticks) == len(prompts)
    assert outs[40] == outs[9]


@pytest.mark.slow
def test_zero_recompiles_after_warmup():
    """Chunk dispatches stay inside the warmed (admit width x bucket)
    program set — a mixed trace with beyond-bucket prompts and chunked
    replication compiles nothing after ``warmup()``."""
    cfg = _cfg()
    rng = np.random.default_rng(6)
    sess = _session(cfg, loop="async", prefill_decode_ratio=2.0)
    before = sess.warmup()
    assert before["prefill_chunk"] > 0
    for i, (p, n, t) in enumerate(_trace(rng, 8, cfg.vocab_size,
                                         plen=(2, 20))):
        sess.submit(p, max_new=n, arrival=t, req_id=i)
    sess.run(max_steps=5_000)
    assert sess.drained
    assert sess.compile_stats() == before


@pytest.mark.slow
def test_serve_chunked_bench_smoke():
    """The bench harness: a miniature bursty trace must run both arms at
    equal budgets with zero recompiles, zero cross-arm token mismatches, a
    clean generate oracle, and self-describing metric docs (the gap/TTFT
    win criteria are asserted on the real bench config, solo-run — this
    pins the machinery)."""
    import benchmarks.serve_chunked as B

    r = B.bench(short=4, long=3, oracle=2)
    assert r["recompiles_after_warmup"] == 0
    assert r["token_mismatches"] == 0
    assert r["oracle_mismatches"] == 0
    assert r["total_tokens"]["chunked"] == r["total_tokens"]["unchunked"]
    for arm in ("unchunked", "chunked"):
        a = r["arms"][arm]
        assert a["max_decode_gap_ticks"] >= 0
        assert a["short_ttft_p95_ticks"] >= 0
    assert r["arms"]["chunked"]["prefill_chunks"] > 0
    assert set(r["field_docs"])  # embedded metric docs travel with the JSON
