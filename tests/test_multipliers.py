"""Paper Section II/III: truth tables, aggregation, error metrics."""
import numpy as np
import pytest
from _hypothesis_compat import given, settings, strategies as st

from repro.core import multipliers as M
from repro.core.metrics import multiplier_metrics


# ---- Table I: exact 3x3 rows with product > 31 -----------------------------

def test_exact_3x3_large_rows():
    t = M.exact_table(3, 3)
    large = {(a, b): int(t[a, b]) for a in range(8) for b in range(8) if t[a, b] > 31}
    assert large == {
        (5, 7): 35, (6, 6): 36, (6, 7): 42, (7, 5): 35, (7, 6): 42, (7, 7): 49,
    }


# ---- Table II / III: the K-map rewrites ------------------------------------

def test_mul3x3_1_truth_table():
    t = M.mul3x3_1_table()
    exact = M.exact_table(3, 3)
    for (a, b), v in M.MUL3X3_1_OVERRIDES.items():
        assert t[a, b] == v
    # all other rows exact
    mask = np.ones((8, 8), bool)
    for a, b in M.MUL3X3_1_OVERRIDES:
        mask[a, b] = False
    assert np.array_equal(t[mask], exact[mask])
    # O5 == 0 everywhere (5-bit output claim)
    assert t.max() < 32


def test_mul3x3_2_prediction_unit():
    t1, t2 = M.mul3x3_1_table(), M.mul3x3_2_table()
    for a in range(8):
        for b in range(8):
            if (a >> 1) & 1 and (a >> 2) & 1 and (b >> 1) & 1 and (b >> 2) & 1:
                # prediction unit: O5=1, O4=0 on top of MUL3x3_1 encoding
                assert t2[a, b] == t1[a, b] + 32 - (16 if t1[a, b] & 16 else 0)
            else:
                assert t2[a, b] == t1[a, b]


def test_paper_3x3_metrics_exact():
    m1 = multiplier_metrics(M.mul3x3_1_table(), "mul3x3_1")
    m2 = multiplier_metrics(M.mul3x3_2_table(), "mul3x3_2")
    assert m1.er == pytest.approx(9.375)
    assert m2.er == pytest.approx(9.375)
    assert m1.med == pytest.approx(1.125)   # paper: 1.125
    assert m2.med == pytest.approx(0.5)     # paper: 0.5 (prediction unit)


# ---- aggregation -----------------------------------------------------------

def test_aggregation_with_exact_pieces_is_exact():
    spec = M.AggregationSpec("x", "exact")
    assert np.array_equal(M.aggregate_8x8(spec), M.exact_table(8, 8))


def test_aggregated_multipliers_exact_below_error_support():
    """Pieces < 5 never trigger the K-map rewrites: any operand pair whose
    3-bit pieces are all <= 4 multiplies exactly."""
    for name in ("mul8x8_1", "mul8x8_2"):
        t = M.mul8x8_table(name)
        exact = M.exact_table(8, 8)
        ok_vals = [a for a in range(256) if (a & 7) < 5 and ((a >> 3) & 7) < 5]
        sub = np.ix_(ok_vals, ok_vals)
        assert np.array_equal(t[sub], exact[sub])


def test_mul8x8_symmetry():
    # MUL3x3_1/2 are symmetric tables; symmetric aggregation preserves it
    for name in ("mul8x8_1", "mul8x8_2"):
        t = M.mul8x8_table(name)
        assert np.array_equal(t, t.T)
    # MUL8x8_3 removes A_lo x B_hi only -> asymmetric
    t3 = M.mul8x8_table("mul8x8_3")
    assert not np.array_equal(t3, t3.T)


def test_mul8x8_3_removed_product_semantics():
    """MUL8x8_3 == MUL8x8_2 - (A[2:0] * B[7:6]) << 6 (M2 + shifter removed)."""
    t2 = M.mul8x8_table("mul8x8_2").astype(np.int64)
    t3 = M.mul8x8_table("mul8x8_3").astype(np.int64)
    a = np.arange(256)
    b = np.arange(256)
    m2 = (a[:, None] & 7) * (b[None, :] >> 6) << 6
    assert np.array_equal(t3, t2 - m2)


def test_mul8x8_3_error_free_on_cooptimized_weights():
    """Weights retrained into (0,31) => B[7:6]=0 => removing M2 is free."""
    t2 = M.mul8x8_table("mul8x8_2")
    t3 = M.mul8x8_table("mul8x8_3")
    assert np.array_equal(t2[:, :32], t3[:, :32])


# ---- exhaustive metrics (our architecture-faithful Table V) ----------------

EXPECTED = {
    # name: (ER%, MED) — exhaustive-domain values of the faithful aggregation
    "mul8x8_1": (27.20, 91.125),
    "mul8x8_2": (27.20, 39.03),
    "mul8x8_3": (73.71, 357.59),
    "pkm": (46.73, 903.12),
}


@pytest.mark.parametrize("name,exp", sorted(EXPECTED.items()))
def test_8x8_metrics(name, exp):
    m = multiplier_metrics(M.mul8x8_table(name), name)
    assert m.er == pytest.approx(exp[0], abs=0.01)
    assert m.med == pytest.approx(exp[1], abs=0.01)


def test_med_upper_bound_argument():
    """The DESIGN.md fidelity argument: disjoint 3+3+2 aggregation bounds
    MED(MUL8x8_1) by MED3 * sum(2^shift-pairs) = 1.125 * 81 = 91.125 — the
    paper's printed 137.04 is unreachable; our exhaustive value = the bound
    (errors are sign-consistent so |sum| = sum)."""
    m = multiplier_metrics(M.mul8x8_table("mul8x8_1"))
    assert m.med <= 1.125 * 81 + 1e-9
    assert m.med == pytest.approx(1.125 * 81)


def test_pkm_2x2():
    t = M.pkm_2x2_table()
    assert t[3, 3] == 7
    assert np.array_equal(np.delete(t.ravel(), 15), np.delete(M.exact_table(2, 2).ravel(), 15))


@settings(max_examples=30, deadline=None)
@given(st.integers(0, 255), st.integers(0, 255))
def test_error_bound_property(a, b):
    """Hypothesis: per-pair error of MUL8x8_2 is bounded by the sum of worst
    piece errors: 8*(1+8+8)+4*64 = 392... use the exact exhaustive max."""
    t = M.mul8x8_table("mul8x8_2")
    exact = a * b
    assert abs(int(t[a, b]) - exact) <= 8 * (1 + 8 + 8) + 8 * 64


def test_multiplier_registry():
    for name in M.MULTIPLIERS:
        t = M.get_multiplier(name)
        assert t.shape == (256, 256)
        assert t.dtype == np.int32
        # zero rows/cols: LUT[0, b] == LUT[a, 0] == 0 for aggregated designs
        if name in ("exact", "mul8x8_1", "mul8x8_2", "mul8x8_3", "pkm"):
            assert not t[0].any() and not t[:, 0].any()
