"""core/lowrank.py: the exact MXU decomposition of multiplier error."""
import jax.numpy as jnp
import numpy as np
import pytest
from _hypothesis_compat import given, settings, strategies as st

from repro.core import lowrank as LR
from repro.core import multipliers as M
from repro.core.approx import ApproxConfig, quantized_matmul
from repro.kernels.approx_matmul.ref import approx_matmul_ref

MULS = ("mul8x8_1", "mul8x8_2", "mul8x8_3")


@pytest.mark.parametrize("name", MULS)
@pytest.mark.parametrize("side", ("lhs", "rhs"))
def test_factorization_exact_full_domain(name, side):
    c = LR.build_correction(name, side=side)
    err_true = M.exact_table(8, 8).astype(np.int64) - M.mul8x8_table(name)
    assert np.array_equal(c.error_table().astype(np.int64), err_true)


@pytest.mark.parametrize("name", MULS)
def test_feature_counts(name):
    c = LR.build_correction(name, side="rhs")
    assert c.num_features == (7 if name == "mul8x8_3" else 6)
    # co-optimized weight band prunes to 3 and kills the rank-1 removal term
    c31 = LR.build_correction(name, side="rhs", rhs_max=31)
    assert c31.num_features == 3
    assert all(f.kind == "indicator" for f in c31.features)


@pytest.mark.parametrize("name", MULS)
def test_range_pruned_exact_on_domain(name):
    err_true = M.exact_table(8, 8).astype(np.int64) - M.mul8x8_table(name)
    c = LR.build_correction(name, side="rhs", rhs_max=31)
    assert np.array_equal(c.error_table().astype(np.int64)[:, :32], err_true[:, :32])
    c2 = LR.build_correction(name, side="rhs", lhs_max=31, rhs_max=31)
    assert np.array_equal(c2.error_table().astype(np.int64)[:32, :32], err_true[:32, :32])


@pytest.mark.parametrize("name", MULS)
def test_tables_bf16_exact(name):
    """All u/v table values must be bf16-exact (the XLA path does bf16 dots)."""
    for lm, rm in [(255, 255), (255, 31), (31, 31)]:
        c = LR.build_correction(name, side="rhs", lhs_max=lm, rhs_max=rm)
        for f in c.features:
            for tab in (f.u_tab, f.v_tab):
                rt = np.asarray(
                    jnp.asarray(tab, jnp.float32).astype(jnp.bfloat16).astype(jnp.float32)
                )
                assert np.array_equal(rt, tab.astype(np.float32))


def test_jnp_feature_maps_match_tables():
    c = LR.build_correction("mul8x8_3", side="rhs")
    codes = jnp.arange(256, dtype=jnp.uint8)
    for f in c.features:
        u = np.asarray(LR.u_map_jnp(codes, f.kind, f.u_shift, f.u_bits, f.residue))
        v = np.asarray(LR.v_map_jnp(codes, f.v_terms))
        assert np.array_equal(u, f.u_tab.astype(np.float32))
        assert np.array_equal(v, f.v_tab.astype(np.float32))


@settings(max_examples=20, deadline=None)
@given(
    st.sampled_from(MULS),
    st.integers(1, 12),
    st.integers(1, 48),
    st.integers(1, 12),
    st.integers(0, 2**31 - 1),
)
def test_lowrank_matmul_matches_lut_oracle(name, m, k, n, seed):
    rng = np.random.default_rng(seed)
    a = rng.integers(0, 256, (m, k)).astype(np.uint8)
    b = rng.integers(0, 256, (k, n)).astype(np.uint8)
    lut = jnp.asarray(M.mul8x8_table(name))
    ref = np.asarray(approx_matmul_ref(jnp.asarray(a), jnp.asarray(b), lut))
    got = np.asarray(
        quantized_matmul(jnp.asarray(a), jnp.asarray(b), ApproxConfig(multiplier=name, mode="lowrank"))
    )
    assert np.array_equal(ref, got.astype(np.int64))


@settings(max_examples=10, deadline=None)
@given(st.integers(0, 2**31 - 1))
def test_lowrank_range_pruned_matmul(seed):
    """With weights in the co-optimized band the pruned 3-feature correction
    still matches the LUT oracle bit-exactly."""
    rng = np.random.default_rng(seed)
    a = rng.integers(0, 256, (7, 33)).astype(np.uint8)
    b = rng.integers(0, 32, (33, 9)).astype(np.uint8)
    lut = jnp.asarray(M.mul8x8_table("mul8x8_3"))
    ref = np.asarray(approx_matmul_ref(jnp.asarray(a), jnp.asarray(b), lut))
    got = np.asarray(
        quantized_matmul(
            jnp.asarray(a), jnp.asarray(b),
            ApproxConfig(multiplier="mul8x8_3", mode="lowrank", w_qmax=31),
        )
    )
    assert np.array_equal(ref, got.astype(np.int64))
