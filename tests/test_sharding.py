"""Sharding rules + a small-mesh dry-run executed in a subprocess (so the
forced device count never leaks into this test process)."""
import json
import os
import subprocess
import sys
import textwrap

import jax
import pytest
from jax.sharding import PartitionSpec as P

from repro.configs import get_config
from repro.parallel.sharding import param_pspec, prune_pspec


class _FakeMesh:
    """Minimal stand-in so rule logic is testable without real devices."""

    def __init__(self, shape):
        self.shape = shape

    @property
    def axis_names(self):
        return tuple(self.shape)


def test_param_rules_dense():
    cfg = get_config("granite-3-2b")
    mesh = _FakeMesh({"data": 16, "model": 16})
    assert param_pspec("['layers']['attn'].wq", (40, 2048, 2048), cfg, mesh) == P(None, "data", "model")
    assert param_pspec("['layers']['attn'].wo", (40, 2048, 2048), cfg, mesh) == P(None, "model", "data")
    assert param_pspec("['layers']['ffn'].w_down", (40, 8192, 2048), cfg, mesh) == P(None, "model", "data")
    assert param_pspec("['lm_head']", (2048, 49664), cfg, mesh) == P("data", "model")
    assert param_pspec("['embed']", (49155, 2048), cfg, mesh) == P(None, "data")  # 49155 % 16 != 0
    assert param_pspec("['layers']['ln1']", (40, 2048), cfg, mesh) == P()


def test_param_rules_moe_ep_vs_tp():
    mesh = _FakeMesh({"data": 16, "model": 16})
    # qwen: 60 experts (not divisible by 16) -> expert-TP fallback
    cfg = get_config("qwen2-moe-a2.7b")
    spec = param_pspec("['layers']['moe'].w_gate", (24, 60, 2048, 1408), cfg, mesh)
    assert spec == P(None, None, "data", "model")
    # synthetic 64-expert variant -> EP engages
    import dataclasses

    cfg64 = dataclasses.replace(cfg, moe_experts=64)
    spec = param_pspec("['layers']['moe'].w_gate", (24, 64, 2048, 1408), cfg64, mesh)
    assert spec == P(None, "model", "data", None)


def test_prune_pspec_divisibility():
    mesh = jax.make_mesh((1,), ("data",))
    assert prune_pspec(mesh, P("data"), (7,)) == P(None) or prune_pspec(
        mesh, P("data"), (7,)
    ) == P("data")  # axis size 1 always divides


def test_small_mesh_dryrun_subprocess(tmp_path):
    """End-to-end: lower + compile a reduced arch on a forced 8-device mesh
    (2 data x 4 model), proving the sharding rules produce a compilable
    SPMD program — the same code path the production dry-run uses."""
    script = textwrap.dedent(
        """
        import os
        os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
        import dataclasses, json, sys
        import jax
        from jax.sharding import NamedSharding, PartitionSpec as P
        from repro.configs import get_config, reduced_config, SHAPES
        from repro.core.approx import ApproxConfig
        from repro.launch.dryrun import build_lowerable
        from repro.train import optim as O

        mesh = jax.make_mesh((2, 4), ("data", "model"))
        cfg = dataclasses.replace(
            reduced_config(get_config("granite-3-2b")),
            approx=ApproxConfig(mode="lowrank"), q_chunk=32,
        )
        shape = dataclasses.replace(SHAPES["train_4k"], seq_len=64, global_batch=4)
        with mesh:
            jfn, args = build_lowerable(cfg, shape, mesh, O.OptConfig(), microbatch=1)
            compiled = jfn.lower(*args).compile()
        mem = compiled.memory_analysis()
        hlo = compiled.as_text()
        has_coll = any(op in hlo for op in ("all-reduce", "all-gather", "reduce-scatter"))
        print(json.dumps({"ok": True, "collectives": has_coll,
                          "temp": int(mem.temp_size_in_bytes)}))
        """
    )
    env = dict(os.environ, PYTHONPATH="src")
    out = subprocess.run(
        [sys.executable, "-c", script], capture_output=True, text=True, env=env,
        cwd=os.path.dirname(os.path.dirname(os.path.abspath(__file__))), timeout=420,
    )
    assert out.returncode == 0, out.stderr[-2000:]
    res = json.loads(out.stdout.strip().splitlines()[-1])
    assert res["ok"] and res["collectives"]


def test_constrain_and_cache_pspecs_subprocess():
    """``constrain`` / ``cache_pspecs`` semantics on a real forced 8-device
    mesh: divisibility fallback, missing-axis drop, ``"batch"`` resolution to
    the (pod, data) pair, and the paged-pool head-dim sharding."""
    script = textwrap.dedent(
        """
        import os
        os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
        import dataclasses, json
        import jax
        import jax.numpy as jnp
        from jax.sharding import NamedSharding, PartitionSpec as P
        from repro.configs import get_config, reduced_config
        from repro.models.transformer import init_paged_cache
        from repro.parallel.sharding import cache_pspecs, constrain

        def spec_of(x):
            return tuple(x.sharding.spec) if isinstance(
                x.sharding, NamedSharding) else None

        out = {}
        f = jax.jit(lambda x: constrain(x, ("batch", None, "model")))

        # no mesh context: constrain is a no-op, jit still compiles
        out["no_mesh"] = spec_of(f(jnp.zeros((8, 4, 8)))) is None

        # pure-TP mesh: no pod/data axes -> "batch" drops; "model" applies
        with jax.make_mesh((4,), ("model",)):
            y = f(jnp.zeros((8, 4, 8)))
            out["tp_only"] = spec_of(y) == (None, None, "model")
            # divisibility fallback: 6 % 4 != 0 -> trailing axis dropped
            # (fully replicated normalizes to the empty spec)
            z = f(jnp.zeros((8, 4, 6)))
            out["indivisible"] = spec_of(z) in ((), (None, None, None))

        # pod x data x model mesh: "batch" -> ("pod", "data")
        with jax.make_mesh((2, 2, 2), ("pod", "data", "model")):
            y = f(jnp.zeros((8, 4, 8)))
            out["batch_pair"] = spec_of(y) == (("pod", "data"), None, "model")

        # paged cache_pspecs: k/v shard dim 3 (Hkv) over "model"; block
        # tables / metadata and the block dim stay replicated
        cfg = dataclasses.replace(
            reduced_config(get_config("granite-3-2b")),
            num_layers=2, num_heads=4, num_kv_heads=4, head_dim=16,
        )
        cache = init_paged_cache(cfg, num_blocks=16, block_size=8)
        mesh = jax.make_mesh((4,), ("model",))
        sh = cache_pspecs(cfg, mesh, cache, layout="paged")
        out["paged_kv"] = tuple(sh["k"].spec) == (None, None, None, "model", None)
        out["paged_v"] = tuple(sh["v"].spec) == (None, None, None, "model", None)

        # Hkv not divisible by tp -> replicate rather than mis-shard
        cfg3 = dataclasses.replace(cfg, num_heads=3, num_kv_heads=3)
        cache3 = init_paged_cache(cfg3, num_blocks=16, block_size=8)
        sh3 = cache_pspecs(cfg3, mesh, cache3, layout="paged")
        out["paged_fallback"] = all(
            ax is None for ax in sh3["k"].spec) and all(
            ax is None for ax in sh3["v"].spec)
        print(json.dumps(out))
        """
    )
    env = dict(os.environ, PYTHONPATH="src")
    out = subprocess.run(
        [sys.executable, "-c", script], capture_output=True, text=True, env=env,
        cwd=os.path.dirname(os.path.dirname(os.path.abspath(__file__))), timeout=420,
    )
    assert out.returncode == 0, out.stderr[-2000:]
    res = json.loads(out.stdout.strip().splitlines()[-1])
    assert all(res.values()), {k: v for k, v in res.items() if not v}
