"""Docs integrity (tier-1, no jax needed beyond import side effects).

* every markdown link/anchor in README.md and docs/ resolves
  (scripts/check_docs.py — the same check CI's docs job runs);
* the serve-stack guide exists, is linked from the README, and documents
  the knobs the tuning table promises.
"""
import pathlib
import sys

ROOT = pathlib.Path(__file__).resolve().parents[1]
sys.path.insert(0, str(ROOT / "scripts"))

import check_docs  # noqa: E402


def test_all_doc_links_resolve():
    assert check_docs.main(check_docs.DEFAULT_FILES) == 0


def test_serving_guide_linked_from_readme():
    readme = (ROOT / "README.md").read_text()
    assert "docs/serving.md" in readme
    guide = (ROOT / "docs" / "serving.md").read_text()
    # the knobs the issue's tuning table promises are all documented
    for knob in ("block_size", "num_blocks", "steps_per_tick",
                 "prefill_decode_ratio"):
        assert knob in guide, knob


def test_github_slugification():
    assert check_docs.github_slug("Which knobs to turn") == "which-knobs-to-turn"
    assert check_docs.github_slug("Host loops") == "host-loops"
    assert check_docs.github_slug("`ServeSession` (PR 2)") == "servesession-pr-2"
