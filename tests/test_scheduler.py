"""Continuous-batching scheduler invariants.

* no token loss: every submitted request completes with exactly the tokens
  it asked for;
* order independence: a request's output is bit-identical to running its
  prompt alone through ``generate`` (float/greedy — quantized modes couple
  batch rows through the dynamic per-tensor activation scale, so there only
  the statistical contract holds);
* utilization accounting: per-request decode steps sum to the scheduler's
  busy-slot-step counter, and busy + idle == ticks * num_slots;
* fixed compiled shapes: zero recompiles after ``warmup()`` across a
  randomized arrival/length trace (compile-count check).
"""
import dataclasses

import jax
import numpy as np
import pytest

from repro.configs import get_config, reduced_config
from repro.serve import (
    SamplingConfig,
    ServeSession,
    freeze_params,
    generate,
    resolve_execution_mode,
    scheduler_compile_stats,
)
from repro.serve.cache import PromptBuckets, SlotPool

KEY = jax.random.PRNGKey(0)


def _cfg(arch="granite-3-2b", **over):
    return dataclasses.replace(
        reduced_config(get_config(arch)), remat=False, q_chunk=16, **over
    )


_PARAMS = {}


def _params(cfg):
    if cfg.name not in _PARAMS:
        from repro.models.transformer import init_params

        _PARAMS[cfg.name] = init_params(cfg, KEY)
    return _PARAMS[cfg.name]


def _random_trace(rng, n, vocab, *, plen=(2, 9), new=(1, 7), arrival_rate=0.0):
    """[(prompt, max_new, arrival)] with optional Poisson-ish arrivals."""
    out, t = [], 0
    for _ in range(n):
        p = rng.integers(0, vocab, int(rng.integers(*plen)))
        if arrival_rate > 0:
            t += int(rng.poisson(arrival_rate))
        out.append((p, int(rng.integers(*new)), t))
    return out


def _session(cfg, **over):
    kw = dict(num_slots=3, max_len=32, prompt_buckets=(4, 8))
    kw.update(over)
    return ServeSession(cfg, _params(cfg), **kw)


# ---------------------------------------------------------------------------
# Host-side bookkeeping units
# ---------------------------------------------------------------------------


def test_prompt_buckets():
    b = PromptBuckets((16, 4, 8))
    assert b.sizes == (4, 8, 16) and b.max_size == 16
    assert b.bucket(1) == 4 and b.bucket(4) == 4 and b.bucket(5) == 8
    with pytest.raises(ValueError):
        b.bucket(17)
    padded = b.pad(np.asarray([7, 8, 9], np.int32))
    assert padded.shape == (1, 4) and padded.tolist() == [[7, 8, 9, 0]]


def test_cache_slot_ops_roundtrip():
    """insert_slot / slot_view / evict_slot / insert_prefill_kv /
    scatter_rows on a toy cache pytree (batch axis 1 everywhere)."""
    import jax.numpy as jnp

    from repro.serve import cache as C

    tree = {"k": jnp.zeros((2, 3, 5, 1)), "v": jnp.zeros((2, 3, 5, 1))}
    one = {"k": jnp.ones((2, 1, 5, 1)), "v": 2 * jnp.ones((2, 1, 5, 1))}
    ins = C.insert_slot(tree, one, jnp.int32(1))
    assert float(ins["k"][:, 1].sum()) == 10.0 and float(ins["k"][:, 0].sum()) == 0.0
    view = C.slot_view(ins, jnp.int32(1))
    assert np.array_equal(np.asarray(view["v"]), np.asarray(one["v"]))
    ev = C.evict_slot(ins, jnp.int32(1))
    assert float(ev["k"].sum()) == 0.0 and float(ev["v"].sum()) == 0.0

    kvs = (jnp.ones((2, 1, 2, 1)), 3 * jnp.ones((2, 1, 2, 1)))  # S_bucket=2
    seeded = C.insert_prefill_kv(tree, kvs, jnp.int32(2))
    assert float(seeded["k"][:, 2, :2].sum()) == 4.0
    assert float(seeded["k"][:, 2, 2:].sum()) == 0.0            # past bucket

    # scatter_rows: valid row writes, invalid row is an exact no-op
    full = jnp.arange(2 * 3 * 5.0).reshape(2, 3, 5)
    part = jnp.full((2, 2, 5), -1.0)
    out = C.scatter_rows(full, part, jnp.asarray([2, 0]), jnp.asarray([True, False]))
    assert np.array_equal(np.asarray(out[:, 2]), np.asarray(part[:, 0]))
    assert np.array_equal(np.asarray(out[:, 0]), np.asarray(full[:, 0]))
    assert np.array_equal(np.asarray(out[:, 1]), np.asarray(full[:, 1]))


def test_slot_pool():
    p = SlotPool(2)
    a, b = p.acquire(), p.acquire()
    assert {a, b} == {0, 1} and p.acquire() is None and p.busy_count == 2
    p.release(a)
    assert p.free_count == 1 and p.acquire() == a
    with pytest.raises(ValueError):
        p.release(5)
    p.release(b)
    with pytest.raises(ValueError):
        p.release(b)


def test_submit_validation():
    sess = _session(_cfg())
    with pytest.raises(ValueError):
        sess.submit(np.asarray([], np.int32), max_new=2)        # empty prompt
    with pytest.raises(ValueError):
        sess.submit(np.arange(9), max_new=2)                    # no bucket fits
    with pytest.raises(ValueError):
        sess.submit(np.arange(4), max_new=40)                   # exceeds max_len
    with pytest.raises(ValueError):
        sess.submit(np.arange(4), max_new=0)
    rid = sess.submit(np.arange(1, 4), max_new=2)
    with pytest.raises(ValueError):                 # duplicate explicit id
        sess.submit(np.arange(1, 4), max_new=2, req_id=rid)


# ---------------------------------------------------------------------------
# Invariants over randomized traces
# ---------------------------------------------------------------------------


@pytest.mark.slow
@pytest.mark.parametrize("seed", [0, 1])
def test_no_token_loss_and_accounting(seed):
    """Randomized arrival/length trace: every request completes with exactly
    max_new tokens (greedy, no eos); per-request decode steps sum to the
    busy-slot counter; busy + idle covers every executed tick."""
    cfg = _cfg()
    sess = _session(cfg)
    rng = np.random.default_rng(seed)
    trace = _random_trace(rng, 12, cfg.vocab_size, arrival_rate=1.5)
    ids = [sess.submit(p, max_new=n, arrival=t) for p, n, t in trace]
    res = sess.run(max_steps=10_000)
    assert sess.drained
    assert sorted(res) == sorted(ids)                           # no request lost
    for rid, (p, n, _) in zip(ids, trace):
        assert len(res[rid].tokens) == n                        # no token lost
        assert res[rid].finish_reason == "length"
    st = sess.stats
    assert st.admitted == st.completed == len(trace)
    assert st.generated_tokens == sum(n for _, n, _ in trace)
    # slot-utilization accounting sums to total decode steps
    assert sum(len(r.tokens) - 1 for r in res.values()) == st.busy_slot_steps
    assert st.busy_slot_steps + st.idle_slot_steps == st.ticks * sess.num_slots
    assert 0.0 < st.slot_utilization <= 1.0


@pytest.mark.slow
def test_order_independence_oracle():
    """Each request's tokens are bit-identical to running the same prompt
    alone through ``generate`` — admission order, slot placement, and
    co-resident requests must not leak into a request's output (float)."""
    cfg = _cfg()
    sess = _session(cfg)
    rng = np.random.default_rng(7)
    trace = _random_trace(rng, 10, cfg.vocab_size, new=(2, 7), arrival_rate=2.0)
    ids = [sess.submit(p, max_new=n, arrival=t) for p, n, t in trace]
    res = sess.run()
    for rid, (p, n, _) in zip(ids, trace):
        alone = np.asarray(
            generate(cfg, _params(cfg), p[None, :].astype(np.int32), max_new=n)
        )[0, len(p):]
        assert np.array_equal(alone, res[rid].tokens), rid


@pytest.mark.slow
def test_chunked_decode_parity_and_accounting():
    """steps_per_tick > 1 (decode chunks) must not change any request's
    tokens — only the waste accounting: overshoot past a mid-chunk finish
    counts as idle, and busy still equals the sum of accepted decode steps."""
    cfg = _cfg()
    rng = np.random.default_rng(9)
    trace = _random_trace(rng, 8, cfg.vocab_size, new=(2, 8))
    outs = []
    for k in (1, 3):
        sess = _session(cfg, steps_per_tick=k)
        ids = [sess.submit(p, max_new=n, req_id=i)
               for i, (p, n, _) in enumerate(trace)]
        res = sess.run()
        outs.append({i: res[i].tokens.tolist() for i in ids})
        st = sess.stats
        assert sum(len(r.tokens) - 1 for r in res.values()) == st.busy_slot_steps
        assert st.busy_slot_steps + st.idle_slot_steps == st.ticks * sess.num_slots
        assert st.ticks % k == 0
    assert outs[0] == outs[1]


@pytest.mark.slow
def test_chunked_decode_eos_parity():
    """EOS masking inside a decode chunk matches the unchunked engine."""
    cfg = _cfg()
    prompt = np.asarray([3, 1, 4, 1], np.int32)
    base = np.asarray(generate(cfg, _params(cfg), prompt[None], max_new=6))[0, 4:]
    eos = int(base[1])
    outs = []
    for k in (1, 4):
        sess = _session(cfg, sampling=SamplingConfig(eos_id=eos),
                        steps_per_tick=k)
        rid = sess.submit(prompt, max_new=6)
        res = sess.run()
        assert res[rid].finish_reason == "eos"
        outs.append(res[rid].tokens.tolist())
    assert outs[0] == outs[1]


@pytest.mark.slow
def test_zero_recompiles_after_warmup():
    """After warmup, NO arrival pattern / prompt length / max_new mix may
    trigger a recompile — the fixed-compiled-shapes contract."""
    cfg = _cfg()
    sess = _session(cfg)
    sess.warmup()
    before = scheduler_compile_stats()
    rng = np.random.default_rng(3)
    for p, n, t in _random_trace(rng, 14, cfg.vocab_size, arrival_rate=1.0):
        sess.submit(p, max_new=n, arrival=t)
    sess.run()
    assert scheduler_compile_stats() == before
    assert sess.stats.completed == 14


@pytest.mark.slow
def test_eos_evicts_slot_and_matches_generate():
    """A request that samples eos finishes early ("eos"), frees its slot for
    the queue, and its tokens equal the standalone run's pre-padding prefix."""
    cfg = _cfg()
    prompt = np.asarray([3, 1, 4, 1], np.int32)
    base = np.asarray(generate(cfg, _params(cfg), prompt[None], max_new=6))[0, 4:]
    eos = int(base[2])                       # third generated token
    sess = _session(cfg, sampling=SamplingConfig(eos_id=eos))
    rid = sess.submit(prompt, max_new=6)
    other = sess.submit(np.asarray([9, 9], np.int32), max_new=6)
    res = sess.run()
    r = res[rid]
    assert r.finish_reason == "eos"
    hit = int(np.argmax(base == eos))        # first occurrence (may repeat)
    assert r.tokens[-1] == eos and len(r.tokens) == hit + 1
    assert np.array_equal(r.tokens, base[: hit + 1])
    assert len(res[other].tokens) == 6       # co-resident request unaffected
    assert sess.pool.free_count == sess.num_slots


@pytest.mark.slow
def test_sampling_is_slot_and_schedule_independent():
    """Per-request fold_in keys: under temperature sampling the SAME request
    set yields identical tokens whether served 1-wide or 3-wide."""
    cfg = _cfg()
    sampling = SamplingConfig(temperature=0.8, top_k=8)
    rng = np.random.default_rng(11)
    trace = _random_trace(rng, 6, cfg.vocab_size, new=(2, 6))
    outs = []
    for slots in (1, 3):
        sess = _session(cfg, num_slots=slots, sampling=sampling, seed=42)
        ids = [sess.submit(p, max_new=n, req_id=i)
               for i, (p, n, _) in enumerate(trace)]
        res = sess.run()
        outs.append({i: res[i].tokens.tolist() for i in ids})
    assert outs[0] == outs[1]


@pytest.mark.slow
def test_zero_on_evict_is_semantics_preserving():
    """Scrubbing evicted slots must not change any output (stale rows are
    provably invisible; this pins that the scrub itself is correct too)."""
    cfg = _cfg()
    rng = np.random.default_rng(5)
    trace = _random_trace(rng, 8, cfg.vocab_size, new=(2, 6))
    outs = []
    for zero in (False, True):
        sess = _session(cfg, zero_on_evict=zero)
        ids = [sess.submit(p, max_new=n, req_id=i)
               for i, (p, n, _) in enumerate(trace)]
        res = sess.run()
        outs.append({i: res[i].tokens.tolist() for i in ids})
    assert outs[0] == outs[1]


@pytest.mark.slow
def test_sjf_policy_admits_short_jobs_first():
    """policy="sjf": with the slot busy, the shortest job (max_new +
    bucketed prompt len) admits first regardless of submission order."""
    cfg = _cfg()
    sess = _session(cfg, num_slots=1, policy="sjf")
    mid = sess.submit(np.asarray([1, 2], np.int32), max_new=4)             # 4+4
    long_ = sess.submit(np.asarray([3, 4, 5, 6, 7], np.int32), max_new=8)  # 8+8
    short = sess.submit(np.asarray([5, 6], np.int32), max_new=2)           # 4+2
    res = sess.run()
    # all three compete at the first step: shortest key wins, longest waits
    # (the async loop's predictive turnover may admit a successor while its
    # predecessor's final chunk is still in flight, so policy order is
    # asserted on admission ticks, not finish-vs-admit overlap)
    assert res[short].admitted_tick <= res[mid].admitted_tick
    assert res[mid].admitted_tick <= res[long_].admitted_tick
    assert res[short].finished_tick <= res[long_].finished_tick


@pytest.mark.slow
def test_latency_stats_recorded():
    """Every completed request contributes one TTFT and one total-latency
    sample (in ticks since arrival), and the percentiles are ordered."""
    cfg = _cfg()
    sess = _session(cfg)
    rng = np.random.default_rng(13)
    trace = _random_trace(rng, 8, cfg.vocab_size, new=(2, 6), arrival_rate=1.5)
    for p, n, t in trace:
        sess.submit(p, max_new=n, arrival=t)
    sess.run()
    st = sess.stats
    assert len(st.ttft_ticks) == len(st.latency_ticks) == len(trace)
    assert all(t >= 0 for t in st.ttft_ticks)
    # total latency includes generation, so it dominates TTFT pairwise
    assert all(l >= t for t, l in zip(st.ttft_ticks, st.latency_ticks))
    assert 0 <= st.ttft_p50 <= st.ttft_p95
    assert 0 <= st.latency_p50 <= st.latency_p95
    assert st.peak_active >= 1


@pytest.mark.slow
def test_priority_admission_order():
    """With every slot busy, lower priority values admit first when a slot
    frees; FIFO within a class."""
    cfg = _cfg()
    sess = _session(cfg, num_slots=1)
    first = sess.submit(np.asarray([1, 2], np.int32), max_new=4)
    low = sess.submit(np.asarray([3, 4], np.int32), max_new=2, priority=5)
    high = sess.submit(np.asarray([5, 6], np.int32), max_new=2, priority=1)
    res = sess.run()
    assert res[high].admitted_tick <= res[low].admitted_tick
    # `first` holds the only slot, so both queued requests admit after it
    # (possibly overlapping its in-flight final chunk — predictive turnover)
    assert res[first].admitted_tick <= res[high].admitted_tick


@pytest.mark.slow
def test_ssm_family_decode_admit_parity():
    """SSM caches (conv/ssm state) go through the masked teacher-forced
    admit; per-request outputs still match standalone generate."""
    cfg = _cfg("falcon-mamba-7b")
    sess = ServeSession(cfg, _params(cfg), num_slots=2, max_len=16,
                        prompt_buckets=(4,))
    prompts = [np.asarray([1, 2, 3], np.int32), np.asarray([4, 5], np.int32),
               np.asarray([6, 7, 8, 9], np.int32)]
    ids = [sess.submit(p, max_new=3) for p in prompts]
    res = sess.run()
    for rid, p in zip(ids, prompts):
        alone = np.asarray(
            generate(cfg, _params(cfg), p[None], max_new=3)
        )[0, len(p):]
        assert np.array_equal(alone, res[rid].tokens), rid


@pytest.mark.slow
def test_serve_continuous_bench_smoke():
    """The bench harness itself: a miniature trace must complete with zero
    recompiles after warmup and both arms serving the same useful tokens
    (the >= 1.5x speedup criterion is asserted on the real bench config,
    which is too slow for the suite — this pins the machinery)."""
    import benchmarks.serve_continuous as B

    r = B.bench(requests=10, slots=2, steps_per_tick=2)
    assert r["recompiles_after_warmup"] == 0
    assert r["useful_tokens"] > 0
    assert r["continuous_tok_s"] > 0 and r["static_tok_s"] > 0
    assert 0.0 < r["slot_utilization"] <= 1.0


@pytest.mark.slow
@pytest.mark.parametrize("mode", ["exact_quant", "approx_lowrank"])
def test_quantized_modes_serve_with_frozen_weights(mode):
    """Quantized execution modes (incl. freeze_params QWeight trees) run the
    full admit/decode/evict cycle; statistical contract: shapes, counts,
    vocab range."""
    cfg = _cfg(approx=resolve_execution_mode(mode))
    params = freeze_params(cfg, _params(_cfg()))   # same float master weights
    sess = ServeSession(cfg, params, num_slots=2, max_len=24,
                        prompt_buckets=(4, 8))
    ids = [sess.submit(np.arange(1, 5, dtype=np.int32) * (i + 1) % 64, max_new=4)
           for i in range(4)]
    res = sess.run()
    for rid in ids:
        toks = res[rid].tokens
        assert toks.shape == (4,)
        assert 0 <= int(toks.min()) and int(toks.max()) < cfg.vocab_size
    assert sess.stats.completed == 4

# ---------------------------------------------------------------------------
# PR-6 satellite regressions
# ---------------------------------------------------------------------------


def test_slot_pool_release_many_atomic():
    """A bad batch (double-free, out-of-range, duplicate-in-batch) leaves
    the pool COMPLETELY untouched — validation runs before any mutation."""
    p = SlotPool(3)
    a, b = p.acquire(), p.acquire()
    for bad in ([a, 9], [a, a], [a, b, b], [2]):   # range/dup/over/not-held
        with pytest.raises(ValueError):
            p.release_many(bad)
        assert p.free_count == 1 and p.busy_count == 2
    p.release_many([a, b])
    assert p.free_count == 3 and p.busy_count == 0


def test_pending_heap_preserves_admission_order():
    """The arrival queue is a heap now (was an O(n^2) sorted-list pop(0));
    on a large trace with heavy arrival ties the ready order must stay
    EXACTLY the old semantics: arrival time, ties broken by submission
    order."""
    import heapq

    sess = _session(_cfg(), policy="fifo")
    rng = np.random.default_rng(23)
    arrivals = [int(a) for a in rng.integers(0, 40, 500)]   # dense ties
    ids = [sess.submit(np.asarray([1 + i % 7], np.int32), max_new=1,
                       arrival=a, req_id=i)
           for i, (a) in enumerate(arrivals)]
    # reference: stable sort of (arrival, submission index)
    expected = [i for _, i in sorted((a, i) for i, a in enumerate(arrivals))]
    got = []
    for clock in range(max(arrivals) + 1):
        sess.clock = clock
        sess._pull_arrivals()
        while sess._ready:
            got.append(heapq.heappop(sess._ready)[2].req_id)
    assert got == expected == [ids[i] for i in expected]


def test_overlap_fraction_clamped():
    """Clock jitter can make summed host-block time exceed (or undershoot)
    the wall clock; the reported fraction is clamped to [0, 1]."""
    from repro.serve import SchedulerStats

    st = SchedulerStats()
    assert st.overlap_fraction == 0.0              # no wall time yet
    st.wall_s, st.host_block_s = 1.0, 2.5          # jitter: block > wall
    assert st.overlap_fraction == 0.0
    st.host_block_s = -0.5                         # jitter: negative block
    assert st.overlap_fraction == 1.0
    st.host_block_s = 0.25
    assert st.overlap_fraction == 0.75


@pytest.mark.slow
@pytest.mark.parametrize("layout", ["slots", "paged"])
def test_exact_fill_boundary_admits_and_completes(layout):
    """PR-7 audit of submit's strict `>`: the exact-fill boundary
    prompt_len + max_new == max_len IS admissible — the final token is
    sampled, never written, so the last cache write lands at max_len - 2
    and the decode clamp at max_len - 1 is never binding before the row
    finishes.  One token more is rejected."""
    cfg = _cfg()
    kw = dict(num_slots=2, max_len=16, prompt_buckets=(8,))
    if layout == "paged":
        kw.update(cache_layout="paged", block_size=4)
    sess = ServeSession(cfg, _params(cfg), **kw)
    with pytest.raises(ValueError, match="exceeds cache max_len"):
        sess.submit(np.arange(1, 9, dtype=np.int32), max_new=9)
    prompt = np.arange(1, 9, dtype=np.int32)          # 8 + 8 == max_len
    rid = sess.submit(prompt, max_new=8)
    res = sess.run(max_steps=1_000)
    assert sess.drained
    assert res[rid].finish_reason == "length"
    assert len(res[rid].tokens) == 8
    # the boundary run is bit-identical to an unconstrained cache
    roomy = ServeSession(cfg, _params(cfg), **{**kw, "max_len": 32})
    rid2 = roomy.submit(prompt, max_new=8)
    assert res[rid].tokens.tolist() == roomy.run()[rid2].tokens.tolist()


@pytest.mark.slow
@pytest.mark.parametrize("arch", ["granite-3-2b", "falcon-mamba-7b"])
def test_pad_id_is_semantics_preserving(arch):
    """The bucketed prefill pad token is masked out of attention and the
    teacher-forced admit: serving the same trace with pad_id=0 and a
    deliberately in-vocab pad_id=7 must be bit-identical (attention AND
    ssm/hybrid cache families)."""
    cfg = _cfg(arch)
    rng = np.random.default_rng(29)
    trace = _random_trace(rng, 6, cfg.vocab_size, arrival_rate=1.0)
    outs = {}
    for pad in (0, 7):
        sess = ServeSession(cfg, _params(cfg), num_slots=2, max_len=16,
                            prompt_buckets=(4, 8), pad_id=pad)
        ids = [sess.submit(p, max_new=n, arrival=t, req_id=i)
               for i, (p, n, t) in enumerate(trace)]
        res = sess.run(max_steps=10_000)
        assert sess.drained
        outs[pad] = {i: res[i].tokens.tolist() for i in ids}
    assert outs[0] == outs[7]
