"""Affine quantization + QAT substrate."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from _hypothesis_compat import given, settings, strategies as st

from repro.core.approx import ApproxConfig, approx_dense
from repro.quant.affine import calibrate, dequantize, quantize
from repro.quant.qat import band_regularizer, fake_quant


@settings(max_examples=25, deadline=None)
@given(st.integers(0, 2**31 - 1), st.sampled_from([255, 31]))
def test_quant_roundtrip_error_bound(seed, qmax):
    rng = np.random.default_rng(seed)
    x = jnp.asarray(rng.normal(size=(32, 16)) * rng.uniform(0.1, 10), jnp.float32)
    qp = calibrate(x, qmax=qmax)
    err = np.asarray(jnp.abs(dequantize(quantize(x, qp), qp) - x))
    assert err.max() <= 0.5001 * float(np.max(np.asarray(qp.scale)))


def test_quantize_dtype_and_range():
    x = jnp.asarray(np.random.default_rng(0).normal(size=(64,)), jnp.float32)
    qp = calibrate(x, qmax=255)
    q = quantize(x, qp)
    assert q.dtype == jnp.uint8
    assert int(q.max()) <= 255 and int(q.min()) >= 0


def test_per_channel_calibration():
    x = jnp.stack([jnp.linspace(-1, 1, 16), jnp.linspace(-100, 100, 16)], axis=1)
    qp = calibrate(x, axis=(0,), qmax=255)
    assert qp.scale.shape == (1, 2)
    assert float(qp.scale[0, 1]) > float(qp.scale[0, 0])


def test_zero_point_algebra_exact_multiplier():
    """approx_dense with the EXACT multiplier must equal the float matmul of
    the fake-quantized operands (the zero-point algebra identity)."""
    rng = np.random.default_rng(0)
    x = jnp.asarray(rng.normal(size=(8, 24)), jnp.float32)
    w = jnp.asarray(rng.normal(size=(24, 8)), jnp.float32)
    cfg = ApproxConfig(multiplier="exact", mode="exact_quant", w_per_channel=False)
    y = approx_dense(x, w, cfg)
    qx = calibrate(x, qmax=255)
    qw = calibrate(w, qmax=255)
    x_fq = dequantize(quantize(x, qx), qx)
    w_fq = dequantize(quantize(w, qw), qw)
    np.testing.assert_allclose(np.asarray(y), np.asarray(x_fq @ w_fq), rtol=2e-5, atol=2e-5)


def test_fake_quant_ste_gradient_identity():
    x = jnp.linspace(-1.0, 1.0, 11)
    qp = calibrate(x, qmax=255)
    g = jax.grad(lambda t: jnp.sum(fake_quant(t, qp) * 3.0))(x)
    np.testing.assert_allclose(np.asarray(g), 3.0)


def test_band_regularizer():
    qp_scale = jnp.float32(1.0)
    from repro.quant.affine import QuantParams

    qp = QuantParams(scale=qp_scale, zero_point=jnp.int32(0), qmax=255)
    w_in = jnp.asarray([1.0, 10.0, 31.0])
    w_out = jnp.asarray([40.0, 64.0, 200.0])
    assert float(band_regularizer(w_in, qp)) == 0.0
    assert float(band_regularizer(w_out, qp)) > 0.0
    # gradient points back toward the band
    g = jax.grad(lambda w: band_regularizer(w, qp))(w_out)
    assert bool(jnp.all(g > 0))


def test_approx_dense_value_close_to_float():
    rng = np.random.default_rng(1)
    x = jnp.asarray(rng.normal(size=(16, 64)), jnp.float32)
    w = jnp.asarray(rng.normal(size=(64, 32)), jnp.float32)
    y_f = x @ w
    for mode, tol in [("exact_quant", 0.05), ("lowrank", 0.12)]:
        y = approx_dense(x, w, ApproxConfig(multiplier="mul8x8_2", mode=mode))
        rel = float(jnp.linalg.norm(y - y_f) / jnp.linalg.norm(y_f))
        assert rel < tol, (mode, rel)


def test_approx_dense_grads_flow():
    rng = np.random.default_rng(2)
    x = jnp.asarray(rng.normal(size=(4, 16)), jnp.float32)
    w = jnp.asarray(rng.normal(size=(16, 8)), jnp.float32)
    cfg = ApproxConfig(multiplier="mul8x8_2", mode="lowrank")
    gx, gw = jax.grad(lambda x, w: jnp.sum(approx_dense(x, w, cfg) ** 2), argnums=(0, 1))(x, w)
    assert bool(jnp.all(jnp.isfinite(gx))) and bool(jnp.all(jnp.isfinite(gw)))
    assert float(jnp.linalg.norm(gw)) > 0


def test_approx_dense_remat_transparent():
    """No custom_vjp: jax.checkpoint must not raise and grads must match."""
    rng = np.random.default_rng(3)
    x = jnp.asarray(rng.normal(size=(4, 16)), jnp.float32)
    w = jnp.asarray(rng.normal(size=(16, 8)), jnp.float32)
    cfg = ApproxConfig(multiplier="mul8x8_2", mode="lowrank")
    f = lambda x, w: jnp.sum(approx_dense(x, w, cfg) ** 2)
    g1 = jax.grad(f, argnums=1)(x, w)
    g2 = jax.grad(jax.checkpoint(f), argnums=1)(x, w)
    np.testing.assert_allclose(np.asarray(g1), np.asarray(g2), rtol=1e-6)
