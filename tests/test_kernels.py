"""Pallas approx_matmul kernel vs the pure-jnp LUT oracle (interpret mode)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import multipliers as M
from repro.kernels.approx_matmul.ops import approx_matmul_pallas
from repro.kernels.approx_matmul.ref import approx_matmul_ref

MULS = ("mul8x8_1", "mul8x8_2", "mul8x8_3")
SHAPES = [
    (8, 128, 128),
    (16, 256, 64),
    (128, 256, 128),
    (5, 37, 11),       # ragged: exercises padding
    (130, 300, 257),
    (1, 1, 1),
]


@pytest.mark.parametrize("name", MULS)
@pytest.mark.parametrize("shape", SHAPES)
def test_kernel_matches_oracle(name, shape):
    m, k, n = shape
    rng = np.random.default_rng(hash((name, shape)) % 2**32)
    a = rng.integers(0, 256, (m, k)).astype(np.uint8)
    b = rng.integers(0, 256, (k, n)).astype(np.uint8)
    lut = jnp.asarray(M.mul8x8_table(name))
    ref = np.asarray(approx_matmul_ref(jnp.asarray(a), jnp.asarray(b), lut))
    out = np.asarray(approx_matmul_pallas(jnp.asarray(a), jnp.asarray(b), multiplier=name))
    assert np.array_equal(ref, out), (name, shape)


@pytest.mark.parametrize("dtype", [jnp.uint8, jnp.int32])
def test_kernel_dtypes(dtype):
    rng = np.random.default_rng(0)
    a = jnp.asarray(rng.integers(0, 256, (16, 128)), dtype)
    b = jnp.asarray(rng.integers(0, 256, (128, 16)), dtype)
    lut = jnp.asarray(M.mul8x8_table("mul8x8_2"))
    ref = np.asarray(approx_matmul_ref(a, b, lut))
    out = np.asarray(approx_matmul_pallas(a, b, multiplier="mul8x8_2"))
    assert np.array_equal(ref, out)


def test_kernel_batched_lhs():
    rng = np.random.default_rng(1)
    a = jnp.asarray(rng.integers(0, 256, (3, 4, 64)), jnp.uint8)
    b = jnp.asarray(rng.integers(0, 256, (64, 8)), jnp.uint8)
    lut = jnp.asarray(M.mul8x8_table("mul8x8_1"))
    ref = np.asarray(approx_matmul_ref(a, b, lut))
    out = np.asarray(approx_matmul_pallas(a, b, multiplier="mul8x8_1"))
    assert out.shape == (3, 4, 8)
    assert np.array_equal(ref, out)


def test_kernel_range_pruned():
    """rhs_max=31 prunes features; result must stay exact on the domain."""
    rng = np.random.default_rng(2)
    a = jnp.asarray(rng.integers(0, 256, (32, 128)), jnp.uint8)
    b = jnp.asarray(rng.integers(0, 32, (128, 32)), jnp.uint8)
    lut = jnp.asarray(M.mul8x8_table("mul8x8_2"))
    ref = np.asarray(approx_matmul_ref(a, b, lut))
    out = np.asarray(
        approx_matmul_pallas(a, b, multiplier="mul8x8_2", rhs_max=31)
    )
    assert np.array_equal(ref, out)


def test_kernel_k_tiling_exactness():
    """K > bk exercises the int32 scratch accumulation across k-tiles."""
    rng = np.random.default_rng(3)
    a = jnp.asarray(rng.integers(0, 256, (8, 1024)), jnp.uint8)
    b = jnp.asarray(rng.integers(0, 256, (1024, 8)), jnp.uint8)
    lut = jnp.asarray(M.mul8x8_table("mul8x8_2"))
    ref = np.asarray(approx_matmul_ref(a, b, lut))
    out = np.asarray(approx_matmul_pallas(a, b, multiplier="mul8x8_2", bk=256))
    assert np.array_equal(ref, out)


@pytest.mark.parametrize("name", MULS)
def test_kernel_call_direct_matches_oracle(name):
    """approx_matmul_kernel_call (interpret mode, block-multiple shapes)
    against the dense-LUT reference — the raw kernel under the ops wrapper."""
    from repro.kernels.approx_matmul.kernel import approx_matmul_kernel_call

    rng = np.random.default_rng(hash(name) % 2**32)
    a = jnp.asarray(rng.integers(0, 256, (16, 256)), jnp.uint8)
    b = jnp.asarray(rng.integers(0, 256, (256, 128)), jnp.uint8)
    lut = jnp.asarray(M.mul8x8_table(name))
    ref = np.asarray(approx_matmul_ref(a, b, lut))
    out = np.asarray(
        approx_matmul_kernel_call(
            a, b, multiplier=name, bm=16, bn=128, bk=256, interpret=True
        )
    )
    assert np.array_equal(ref, out)


@pytest.mark.parametrize("name", MULS)
def test_ops_padding_path_non_block_multiple(name):
    """A shape that is a multiple of no block dimension must go through the
    ops.py zero-padding path and still match the LUT oracle bit-exactly."""
    rng = np.random.default_rng(hash((name, "pad")) % 2**32)
    a = jnp.asarray(rng.integers(0, 256, (13, 57)), jnp.uint8)
    b = jnp.asarray(rng.integers(0, 256, (57, 29)), jnp.uint8)
    lut = jnp.asarray(M.mul8x8_table(name))
    ref = np.asarray(approx_matmul_ref(a, b, lut))
    out = np.asarray(approx_matmul_pallas(a, b, multiplier=name))
    assert out.shape == (13, 29)
    assert np.array_equal(ref, out)


def test_quantized_matmul_pallas_dispatch():
    """ApproxConfig(mode='pallas') — the serving engine's 'approx' execution
    mode — must dispatch through the kernel and agree with mode='lut'."""
    from repro.core.approx import ApproxConfig, quantized_matmul

    rng = np.random.default_rng(4)
    a = jnp.asarray(rng.integers(0, 256, (6, 40)), jnp.uint8)
    b = jnp.asarray(rng.integers(0, 256, (40, 10)), jnp.uint8)
    got = quantized_matmul(a, b, ApproxConfig(multiplier="mul8x8_2", mode="pallas"))
    ref = quantized_matmul(a, b, ApproxConfig(multiplier="mul8x8_2", mode="lut"))
    assert np.array_equal(np.asarray(got), np.asarray(ref))


def test_elementwise_lut():
    from repro.kernels.approx_matmul.ref import approx_mul_elementwise

    lut = jnp.asarray(M.mul8x8_table("mul8x8_3"))
    a = jnp.arange(256, dtype=jnp.int32)
    out = np.asarray(approx_mul_elementwise(a[:, None], a[None, :], lut))
    assert np.array_equal(out, np.asarray(lut))


# ---------------------------------------------------------------------------
# Block selection (ops.py shrink logic) — pinned padded shapes
# ---------------------------------------------------------------------------


def test_select_blocks_pinned_shapes():
    """Regression pin for the block-shrink rounding: small M shrinks to the
    8-sublane multiple covering it (NOT the next power of two, NOT bm)."""
    from repro.kernels.approx_matmul.ops import select_blocks

    # M=1: single-row decode — 8 rows of padding, not 128
    assert select_blocks(1, 10, 64) == ((8, 128, 128), (8, 128, 128))
    # M=4: a 4-slot decode batch pads to 8 rows
    assert select_blocks(4, 512, 256) == ((8, 128, 256), (8, 512, 256))
    # M=24: 24-slot decode stays exact (old pow2 rounding padded to 32)
    assert select_blocks(24, 300, 256) == ((24, 128, 256), (24, 384, 256))
    # M=65: pads to 72 (old pow2 rounding padded to 128)
    assert select_blocks(65, 128, 512) == ((72, 128, 256), (72, 128, 512))
    # at/above full blocks: unchanged behavior
    assert select_blocks(128, 128, 256) == ((128, 128, 256), (128, 128, 256))
    assert select_blocks(130, 257, 300) == ((128, 128, 256), (256, 384, 512))
    # tiny K/N still hit the 128-lane minimum
    assert select_blocks(8, 1, 1) == ((8, 128, 128), (8, 128, 128))


@pytest.mark.parametrize("m", [1, 4, 24, 65])
def test_shrunk_blocks_stay_bit_exact(m):
    """The shrunk block shapes must not change results: bit-exact vs LUT."""
    rng = np.random.default_rng(m)
    a = jnp.asarray(rng.integers(0, 256, (m, 96)), jnp.uint8)
    b = jnp.asarray(rng.integers(0, 256, (96, 33)), jnp.uint8)
    lut = jnp.asarray(M.mul8x8_table("mul8x8_2"))
    ref = np.asarray(approx_matmul_ref(a, b, lut))
    out = np.asarray(approx_matmul_pallas(a, b, multiplier="mul8x8_2"))
    assert np.array_equal(ref, out)
