"""Copy-on-write prefix sharing + preemption invariants (serve stack PR 6).

* refcount discipline: ``BlockPool.share``/``release`` never drive a
  refcount negative, free only on the last release, and the atomic
  ``release_many`` validates the whole batch against held refcounts
  before mutating anything;
* ``PrefixCache``: structural rolling keys are content-bound (same
  parent chain + same tokens -> same key), publication is unique,
  eviction is LRU over cache-only blocks;
* sharing parity: greedy outputs with ``prefix_sharing=True`` are
  bit-identical to the non-shared paged oracle on traces with shared
  system prompts, with ``prefix_hit_blocks > 0`` and zero recompiles —
  under both host loops and both attention impls (the kernel reads the
  same physical blocks through several rows' tables);
* CoW forks: a shared partial tail block is forked on first write —
  never written in place — and the forked run stays bit-identical;
* preemption: with the worst-case reservation dropped, pool exhaustion
  evicts a victim and replays it later, bit-identical to an unpreempted
  run of the same request (positional key schedule), with no block leak
  or double-free across fork/preempt/finish interleavings.
"""
import dataclasses

import jax
import numpy as np
import pytest

from repro.configs import get_config, reduced_config
from repro.serve import (
    BlockPool,
    PrefixCache,
    ServeSession,
    generate,
    scheduler_compile_stats,
)

KEY = jax.random.PRNGKey(0)


def _cfg(arch="granite-3-2b", **over):
    return dataclasses.replace(
        reduced_config(get_config(arch)), remat=False, q_chunk=16, **over
    )


_PARAMS = {}


def _params(cfg):
    if cfg.name not in _PARAMS:
        from repro.models.transformer import init_params

        _PARAMS[cfg.name] = init_params(cfg, KEY)
    return _PARAMS[cfg.name]


def _paged_session(cfg, **over):
    kw = dict(num_slots=3, max_len=32, prompt_buckets=(4, 8),
              cache_layout="paged", block_size=4)
    kw.update(over)
    return ServeSession(cfg, _params(cfg), **kw)


def _assert_pool_clean(sess):
    """Drained-session invariant under sharing: the prefix cache may pin
    blocks (refcount exactly 1, the cache's own reference), everything
    else is back on the free heap, tables scrubbed, reservations zero."""
    cached = set(sess._prefix.lru_blocks()) if sess._prefix is not None else set()
    for b in cached:
        assert sess.blocks.refcount(b) == 1, b
    assert sess.blocks.free_count == sess.num_blocks - len(cached)
    assert sess.blocks.busy_count == len(cached)
    assert sess._reserved_total == 0
    assert (sess._tables == sess.num_blocks).all()
    assert all(not h for h in sess._held)
    assert (sess._future == 0).all()
    assert not sess._preempt_resume


def _shared_prefix_trace(n=6, shared=12, unique=2, seed=3):
    """n requests sharing a `shared`-token system prompt + unique tails."""
    rng = np.random.default_rng(seed)
    prefix = rng.integers(1, 50, shared)
    return [np.concatenate([prefix, rng.integers(50, 99, unique)]).astype(np.int32)
            for _ in range(n)]


# ---------------------------------------------------------------------------
# Host-side bookkeeping units (fast tier)
# ---------------------------------------------------------------------------


def test_block_pool_refcount_lifecycle():
    p = BlockPool(3)
    a = p.acquire()
    assert p.refcount(a) == 1
    assert p.share(a) == 2 and p.share(a) == 3
    p.release(a)
    p.release(a)
    assert p.refcount(a) == 2 - 1                 # still held: not freed yet
    assert p.free_count == 2                      # physical blocks, not refs
    p.release(a)
    assert p.refcount(a) == 0 and p.free_count == 3
    assert p.acquire() == a                       # back on the heap


def test_block_pool_share_and_release_validation():
    p = BlockPool(2)
    with pytest.raises(ValueError, match="free"):
        p.share(0)                                # sharing a free block
    a = p.acquire()
    p.release(a)
    with pytest.raises(ValueError, match="double-released"):
        p.release(a)
    with pytest.raises(ValueError):
        p.release(7)                              # out of range
    assert p.free_count == 2                      # failures left pool intact


def test_block_pool_release_many_atomic_against_refcounts():
    """The whole batch is validated against held refcounts BEFORE any
    mutation: a bad batch leaves every refcount and the heap untouched."""
    p = BlockPool(4)
    a, b = p.acquire(), p.acquire()
    p.share(a)                                    # a: 2 refs, b: 1 ref
    with pytest.raises(ValueError, match="2 refs"):
        p.release_many([a, a, b, b])              # b released twice, held once
    assert p.refcount(a) == 2 and p.refcount(b) == 1
    assert p.free_count == 2
    p.release_many([a, a, b])                     # valid multiplicities
    assert p.free_count == 4 and p.busy_count == 0
    with pytest.raises(ValueError):
        p.release_many([a])                       # now free: atomic no-op
    assert p.free_count == 4


def test_prefix_cache_keys_are_content_bound():
    c = PrefixCache()
    k0 = c.key(PrefixCache.ROOT, [1, 2, 3, 4])
    assert c.key(PrefixCache.ROOT, [1, 2, 3, 4]) == k0      # interned
    assert c.key(PrefixCache.ROOT, [1, 2, 3, 5]) != k0      # content differs
    k1 = c.key(k0, [5, 6, 7, 8])
    assert c.key(k0, [5, 6, 7, 8]) == k1
    # same tokens under a different parent chain is a different key
    assert c.key(c.key(PrefixCache.ROOT, [9]), [5, 6, 7, 8]) != k1


def test_prefix_cache_publish_lookup_evict():
    c = PrefixCache()
    k0 = c.key(PrefixCache.ROOT, [1, 2])
    k1 = c.key(k0, [3, 4])
    assert c.lookup(k0) is None
    c.insert(k0, 5)
    c.insert(k1, 9)
    assert c.lookup(k0) == 5 and c.lookup(k1) == 9
    assert c.holds_block(9) and not c.holds_block(7)
    with pytest.raises(ValueError):
        c.insert(k0, 7)                           # double publish
    assert len(c) == 2
    # lookup refreshes recency: touching k0 makes k1 the eviction head
    c.lookup(k0)
    assert c.lru_blocks()[0] == 9
    assert c.drop_block(9) and not c.drop_block(9)
    assert c.lookup(k1) is None and len(c) == 1


def test_sharing_requires_paged_layout():
    cfg = _cfg()
    with pytest.raises(ValueError, match="BlockPool"):
        ServeSession(cfg, _params(cfg), cache_layout="slots",
                     prefix_sharing=True)
    with pytest.raises(ValueError, match="BlockPool"):
        ServeSession(cfg, _params(cfg), cache_layout="slots", preemption=True)


def test_submit_validation_under_sharing_and_preemption():
    cfg = _cfg()
    # sharing without preemption pre-funds a CoW fork for partial tails:
    # worst + 1 must fit the pool
    sess = _paged_session(cfg, num_blocks=3, prefix_sharing=True)
    with pytest.raises(ValueError, match="never be admitted"):
        sess.submit(np.arange(1, 7, dtype=np.int32), max_new=5, req_id=2)
    # preemption replays prompt + accepted tokens through prefill: the
    # final replay prompt must still fit a bucket
    sess = _paged_session(cfg, preemption=True)
    with pytest.raises(ValueError, match="request 4"):
        sess.submit(np.arange(1, 7, dtype=np.int32), max_new=4, req_id=4)


# ---------------------------------------------------------------------------
# Session-level parity + accounting (slow tier)
# ---------------------------------------------------------------------------


@pytest.mark.slow
@pytest.mark.parametrize("loop", ["sync", "async"])
@pytest.mark.parametrize("attn_impl", ["gather", "pallas"])
def test_sharing_parity_with_nonshared_oracle(loop, attn_impl):
    """Shared system prompts: leading table entries map to the SAME
    physical blocks, prefill writes for the shared span are skipped, and
    greedy outputs stay bit-identical to the non-shared paged oracle —
    under both host loops and both attention impls."""
    cfg = _cfg()
    prompts = _shared_prefix_trace()
    outs = {}
    for sharing in (False, True):
        sess = _paged_session(cfg, num_slots=3, max_len=32,
                              prompt_buckets=(4, 8, 16, 32), loop=loop,
                              attn_impl=attn_impl, prefix_sharing=sharing)
        sess.warmup()
        before = scheduler_compile_stats()
        ids = [sess.submit(p, max_new=6, req_id=i)
               for i, p in enumerate(prompts)]
        res = sess.run(max_steps=10_000)
        assert sess.drained
        assert scheduler_compile_stats() == before
        outs[sharing] = {i: res[i].tokens.tolist() for i in ids}
        if sharing:
            # requests 2..n hit all three full shared-prefix blocks
            assert sess.stats.prefix_hit_blocks >= 3 * (len(prompts) - 1)
            _assert_pool_clean(sess)
    assert outs[False] == outs[True]
    # and the oracle itself matches standalone generate
    p = prompts[0]
    alone = np.asarray(
        generate(cfg, _params(cfg), p[None, :], max_new=6)
    )[0, len(p):]
    assert outs[True][0] == alone.tolist()


@pytest.mark.slow
@pytest.mark.parametrize("loop", ["sync", "async"])
def test_cow_fork_on_shared_partial_tail(loop):
    """Identical prompts with a partial tail block (14 tokens, block_size
    4): later requests share the tail, and the first decode write into it
    forks a private copy instead of corrupting the sharer — outputs stay
    bit-identical to the non-shared oracle and ``cow_forks`` counts the
    forks."""
    cfg = _cfg()
    p = np.arange(1, 15, dtype=np.int32)          # 14 tokens: 3.5 blocks
    outs = {}
    for sharing in (False, True):
        sess = _paged_session(cfg, num_slots=3, max_len=32,
                              prompt_buckets=(16,), loop=loop,
                              prefix_sharing=sharing)
        ids = [sess.submit(p, max_new=5, req_id=i) for i in range(3)]
        res = sess.run(max_steps=10_000)
        assert sess.drained
        outs[sharing] = {i: res[i].tokens.tolist() for i in ids}
        if sharing:
            assert sess.stats.cow_forks >= 1
            assert sess.stats.prefix_hit_blocks >= 1
            _assert_pool_clean(sess)
    assert outs[False] == outs[True]
    # identical prompts, greedy sampling: identical outputs per request
    assert len({tuple(t) for t in outs[True].values()}) == 1


@pytest.mark.slow
@pytest.mark.parametrize("loop", ["sync", "async"])
def test_forced_preemption_bit_identical(loop):
    """A pool too small for two worst cases: admission oversubscribes,
    exhaustion evicts the lower-priority resident, and the replayed
    request's tokens are bit-identical to an unpreempted run (roomy pool)
    — the positional key schedule makes replay exact."""
    cfg = _cfg()
    rng = np.random.default_rng(11)
    prompts = [rng.integers(1, 99, 6).astype(np.int32) for _ in range(2)]
    outs = {}
    for blocks in (24, 5):                        # roomy oracle vs starved
        sess = _paged_session(cfg, num_slots=2, max_len=32,
                              prompt_buckets=(8, 32), num_blocks=blocks,
                              loop=loop, prefix_sharing=True,
                              preemption=True)
        ids = [sess.submit(p, max_new=12, req_id=i)
               for i, p in enumerate(prompts)]
        res = sess.run(max_steps=10_000)
        assert sess.drained
        outs[blocks] = {i: res[i].tokens.tolist() for i in ids}
        if blocks == 5:
            # worst = ceil((6+12-1)/4) = 5 each: both cannot stay resident
            assert sess.stats.preemptions >= 1
        _assert_pool_clean(sess)
    assert outs[24] == outs[5]


@pytest.mark.slow
def test_preemption_admits_beyond_worst_case_reservation():
    """The capacity win preemption buys: a pool the reservation-based
    admission serializes over runs CONCURRENTLY under preemption —
    same outputs, higher peak concurrency."""
    cfg = _cfg()
    rng = np.random.default_rng(13)
    prompts = [rng.integers(1, 99, 4).astype(np.int32) for _ in range(3)]
    peak = {}
    outs = {}
    for preempt in (False, True):
        # 3 requests x worst 3 blocks = 9 worst-case blocks vs pool of 5
        sess = _paged_session(cfg, num_slots=3, max_len=16,
                              prompt_buckets=(4, 16), num_blocks=5,
                              preemption=preempt)
        ids = [sess.submit(p, max_new=9, req_id=i)
               for i, p in enumerate(prompts)]
        res = sess.run(max_steps=10_000)
        assert sess.drained
        peak[preempt] = sess.stats.peak_active
        outs[preempt] = {i: res[i].tokens.tolist() for i in ids}
        if not preempt:
            _assert_pool_clean(sess)
    assert outs[False] == outs[True]
    assert peak[True] > peak[False]


@pytest.mark.slow
def test_no_leak_across_fork_preempt_finish_interleavings():
    """Randomized shared-prefix trace against a starved pool with eos
    exits: every admitted block is either released or cache-pinned with
    refcount exactly 1 after drain, across arbitrary interleavings of
    prefix hits, CoW forks, preemptions, eos and length exits."""
    cfg = _cfg()
    rng = np.random.default_rng(17)
    prefix = rng.integers(1, 50, 8)
    sess = _paged_session(cfg, num_slots=3, max_len=32,
                          prompt_buckets=(4, 8, 16, 32), num_blocks=8,
                          prefix_sharing=True, preemption=True,
                          steps_per_tick=2)
    ids = []
    for i in range(10):
        tail = rng.integers(50, 99, int(rng.integers(1, 5)))
        p = np.concatenate([prefix[:int(rng.integers(4, 9))], tail])
        ids.append(sess.submit(p.astype(np.int32),
                               max_new=int(rng.integers(2, 8)),
                               arrival=i // 2))
    res = sess.run(max_steps=20_000)
    assert sess.drained and sorted(res) == sorted(ids)
    assert sess.stats.prefix_hit_blocks > 0
    assert sess.stats.peak_blocks_in_use <= 8
    _assert_pool_clean(sess)
    # the pool's refcounts never went negative: every physical block is
    # accounted for as exactly free or cache-pinned
    for b in range(sess.num_blocks):
        assert sess.blocks.refcount(b) in (0, 1), b


@pytest.mark.slow
def test_preempted_request_matches_solo_generate():
    """End-to-end exactness of recompute-based replay: the preempted
    victim's final tokens equal a standalone ``generate`` of the same
    prompt — preemption is invisible in the output stream."""
    cfg = _cfg()
    rng = np.random.default_rng(19)
    prompts = [rng.integers(1, 99, 6).astype(np.int32) for _ in range(2)]
    sess = _paged_session(cfg, num_slots=2, max_len=32,
                          prompt_buckets=(8, 32), num_blocks=5,
                          preemption=True)
    ids = [sess.submit(p, max_new=12, req_id=i)
           for i, p in enumerate(prompts)]
    res = sess.run(max_steps=10_000)
    assert sess.drained
    assert sess.stats.preemptions >= 1
    for rid, p in zip(ids, prompts):
        alone = np.asarray(
            generate(cfg, _params(cfg), p[None, :], max_new=12)
        )[0, len(p):]
        assert res[rid].tokens.tolist() == alone.tolist(), rid
    _assert_pool_clean(sess)


@pytest.mark.slow
def test_serve_prefix_bench_smoke():
    """The equal-pool bench harness: a miniature run must complete with the
    parity/recompile/preemption oracles clean (the >= 1.5x concurrency
    criterion is asserted on the real bench config in CI — this pins the
    machinery)."""
    import benchmarks.serve_prefix as B

    r = B.bench(requests=12)
    assert r["token_mismatches"] == 0
    assert r["recompiles_after_warmup"] == 0
    assert r["forced_preemptions"] >= 1
    assert r["forced_preemption_mismatches"] == 0
    assert r["prefix_hit_blocks"] > 0
    assert r["useful_tokens"] > 0
    assert r["shared_peak_blocks"] <= r["num_blocks"]
    assert set(r["field_docs"]) >= {"prefix_hit_blocks", "cow_forks",
                                    "preemptions"}
