"""Async double-buffered serve loop + prefill/decode interleaving.

* **loop parity**: the async pipeline must be a pure scheduling change —
  same trace, bit-identical greedy tokens vs the PR-3 sync loop (and, by
  the sync loop's own oracle, vs standalone ``generate``), across both
  cache layouts, with zero recompiles after ``warmup()``;
* **starvation**: under a long-prompt burst with resident decodes, the
  ``prefill_decode_ratio`` policy bounds the work-tick gap between a
  resident request's consecutive accepted tokens by
  ``steps_per_tick * (1 + ratio)`` — deterministically (work ticks charge
  prefill by bucketed tokens, so no wall-clock flakiness);
* **close()**: flushes the in-flight chunk; ``submit`` after ``close()``
  raises a ``RuntimeError`` naming the request id, like every other
  submit-time validation error.
"""
import dataclasses

import jax
import numpy as np
import pytest

from repro.configs import get_config, reduced_config
from repro.serve import ServeSession, scheduler_compile_stats
from repro.serve.scheduler import SchedulerStats

KEY = jax.random.PRNGKey(0)


def _cfg(**over):
    return dataclasses.replace(
        reduced_config(get_config("granite-3-2b")), remat=False, q_chunk=16, **over
    )


_PARAMS = {}


def _params(cfg):
    if cfg.name not in _PARAMS:
        from repro.models.transformer import init_params

        _PARAMS[cfg.name] = init_params(cfg, KEY)
    return _PARAMS[cfg.name]


def _session(cfg, **over):
    kw = dict(num_slots=3, max_len=32, prompt_buckets=(4, 8))
    kw.update(over)
    return ServeSession(cfg, _params(cfg), **kw)


def _trace(rng, n, vocab, *, plen=(2, 9), new=(1, 7), rate=1.0):
    out, t = [], 0
    for _ in range(n):
        t += int(rng.poisson(rate))
        out.append((rng.integers(0, vocab, int(rng.integers(*plen))),
                    int(rng.integers(*new)), t))
    return out


def _burst_trace(rng, vocab):
    """Resident decode-heavy requests, then a clump of long prompts — the
    pattern the interleaving policy exists for."""
    tr = [(rng.integers(0, vocab, 3), 24, 0),
          (rng.integers(0, vocab, 4), 24, 0)]
    tr += [(rng.integers(0, vocab, 15), 2, 2) for _ in range(6)]
    return tr


# ---------------------------------------------------------------------------
# Fast tier: validation + close() semantics
# ---------------------------------------------------------------------------


def test_loop_and_policy_validation():
    cfg = _cfg()
    with pytest.raises(ValueError):
        _session(cfg, loop="double-buffered")
    with pytest.raises(ValueError):          # policies are alternatives
        _session(cfg, prefill_decode_ratio=1.0, prefill_token_budget=8)
    with pytest.raises(ValueError):
        _session(cfg, prefill_decode_ratio=0.0)
    with pytest.raises(ValueError):
        _session(cfg, prefill_token_budget=0)


def test_submit_after_close_raises_with_request_id():
    """A sealed session must refuse new work loudly — silent queueing after
    close() would drop the request on the floor."""
    sess = _session(cfg := _cfg())
    rid = sess.submit(np.asarray([1, 2], np.int32), max_new=2)
    sess.run()
    sess.close()
    with pytest.raises(RuntimeError, match=r"request 1:.*close\(\)"):
        sess.submit(np.asarray([3, 4], np.int32), max_new=2)
    with pytest.raises(RuntimeError, match=r"request 7:.*close\(\)"):
        sess.submit(np.asarray([3, 4], np.int32), max_new=2, req_id=7)
    with pytest.raises(RuntimeError):
        sess.step()
    with pytest.raises(RuntimeError):
        sess.run()
    # idempotent, and results survive
    assert set(sess.close()) == {rid}


def test_close_flushes_inflight_chunk():
    """close() harvests the dispatched-but-unfetched chunk: tokens accepted
    so far are not lost, and the pool invariants hold."""
    sess = _session(_cfg(), steps_per_tick=2)
    rid = sess.submit(np.asarray([1, 2, 3], np.int32), max_new=8)
    sess.step()                              # admit + dispatch, no harvest yet
    assert sess._inflight is not None
    sess.close()
    assert sess._inflight is None
    done = sess.results
    # not finished (8 tokens requested), so the request is still incomplete;
    # but the slot accounting was flushed consistently
    st = sess.stats
    assert st.busy_slot_steps + st.idle_slot_steps == st.ticks * sess.num_slots
    assert rid not in done


def test_stats_field_docs_complete():
    """Every SchedulerStats field and public property carries a one-line
    doc — the contract that makes the bench JSON keys self-describing."""
    fields = {f.name for f in dataclasses.fields(SchedulerStats)}
    props = {
        n for n, v in vars(SchedulerStats).items()
        if isinstance(v, property) and not n.startswith("_")
    }
    documented = set(SchedulerStats.DOCS)
    assert fields | props == documented, (
        f"undocumented: {sorted((fields | props) - documented)}; "
        f"stale docs: {sorted(documented - (fields | props))}"
    )
    # PR-7 speculative-decoding readouts are part of the bench contract
    assert {"draft_tokens", "accepted_tokens", "verify_calls",
            "accept_rate"} <= documented
    # PR-8 tensor-parallel + dynamic-draft readouts
    assert {"tp", "devices", "peak_block_bytes_per_device",
            "draft_k_current", "draft_k_shrinks", "draft_k_grows"} <= documented
    # PR-9 quality-tier / load-shedder gauges
    assert {"tier_demotions", "tier_restorations", "shed_level",
            "active_per_tier"} <= documented


# ---------------------------------------------------------------------------
# Slow tier: parity, starvation, accounting
# ---------------------------------------------------------------------------


@pytest.mark.slow
@pytest.mark.parametrize("layout", ["slots", "paged"])
def test_async_sync_parity_bit_identical(layout):
    """Same trace through both loops: every request's greedy tokens must be
    bit-identical (the async pipeline may only change *when* the host learns
    about tokens, never the tokens themselves)."""
    cfg = _cfg()
    rng = np.random.default_rng(17)
    trace = _trace(rng, 12, cfg.vocab_size, rate=1.5)
    outs = {}
    for loop in ("sync", "async"):
        kw = dict(steps_per_tick=2, loop=loop)
        if layout == "paged":
            kw.update(cache_layout="paged", block_size=8)
        sess = _session(cfg, **kw)
        ids = [sess.submit(p, max_new=n, arrival=t, req_id=i)
               for i, (p, n, t) in enumerate(trace)]
        res = sess.run(max_steps=10_000)
        assert sess.drained
        outs[loop] = {i: res[i].tokens.tolist() for i in ids}
        st = sess.stats
        assert st.busy_slot_steps + st.idle_slot_steps == st.ticks * sess.num_slots
        assert sum(len(r.tokens) - 1 for r in res.values()) == st.busy_slot_steps
    assert outs["sync"] == outs["async"]


@pytest.mark.slow
@pytest.mark.parametrize("loop", ["sync", "async"])
def test_zero_recompiles_after_warmup_per_loop(loop):
    """Both loops keep the fixed-compiled-shapes contract: warmup() covers
    every program (including the async admit-carry merge), then no request
    pattern recompiles."""
    cfg = _cfg()
    sess = _session(cfg, loop=loop)
    sess.warmup()
    before = scheduler_compile_stats()
    rng = np.random.default_rng(3)
    for p, n, t in _trace(rng, 10, cfg.vocab_size):
        sess.submit(p, max_new=n, arrival=t)
    sess.run()
    assert scheduler_compile_stats() == before
    assert sess.stats.completed == 10


@pytest.mark.slow
def test_async_handles_immediate_finishes():
    """max_new=1 / first-token-eos completions are discovered one chunk late
    in the async loop; no token may be lost or duplicated."""
    cfg = _cfg()
    sess = _session(cfg, steps_per_tick=3)
    ids = [sess.submit(np.asarray([i + 1, i + 2], np.int32), max_new=1)
           for i in range(5)]
    ids.append(sess.submit(np.asarray([9, 8, 7], np.int32), max_new=6))
    res = sess.run(max_steps=10_000)
    assert sess.drained
    for rid in ids[:-1]:
        assert len(res[rid].tokens) == 1 and res[rid].finish_reason == "length"
    assert len(res[ids[-1]].tokens) == 6
    st = sess.stats
    assert st.generated_tokens == 5 + 6
    assert st.busy_slot_steps + st.idle_slot_steps == st.ticks * sess.num_slots


@pytest.mark.slow
@pytest.mark.parametrize("ratio", [1.0, 1.5])
def test_interleaving_bounds_decode_starvation(ratio):
    """Long-prompt burst against resident decodes: with
    prefill_decode_ratio=R every resident decode's work-tick gap between
    consecutive tokens stays <= steps_per_tick + ceil(R * steps_per_tick)
    (the carry-based work charge makes the bound exact, including for
    fractional R); the unthrottled scheduler violates that bound on the
    same trace (which is exactly why the policy exists).  Outputs must not
    change — the policy only reorders admission in time."""
    import math

    cfg = _cfg()
    steps = 4
    runs = {}
    for label, kw in [("free", {}), ("ratio", dict(prefill_decode_ratio=ratio))]:
        rng = np.random.default_rng(5)
        sess = ServeSession(
            cfg, _params(cfg), num_slots=4, max_len=64,
            prompt_buckets=(4, 8, 16), steps_per_tick=steps, **kw,
        )
        ids = [sess.submit(p, max_new=n, arrival=t, req_id=i)
               for i, (p, n, t) in enumerate(_burst_trace(rng, cfg.vocab_size))]
        res = sess.run(max_steps=10_000)
        assert sess.drained and sorted(res) == sorted(ids)
        runs[label] = (res, sess.stats)
    bound = steps + math.ceil(ratio * steps)
    free_st, ratio_st = runs["free"][1], runs["ratio"][1]
    assert ratio_st.max_decode_gap_ticks <= bound, (
        ratio_st.max_decode_gap_ticks, bound)
    assert free_st.max_decode_gap_ticks > bound          # the policy's raison d'etre
    assert ratio_st.prefill_stall_ticks > 0              # it actually deferred work
    assert free_st.prefill_stall_ticks == 0
    assert {i: r.tokens.tolist() for i, r in runs["free"][0].items()} == \
           {i: r.tokens.tolist() for i, r in runs["ratio"][0].items()}


@pytest.mark.slow
def test_token_budget_variant_bounds_starvation():
    """prefill_token_budget=B is the flat-budget variant: per-step admitted
    prefill work <= ceil(B / num_slots) work ticks, so the gap stays <=
    steps_per_tick + ceil(B / num_slots)."""
    cfg = _cfg()
    steps, B, slots = 4, 16, 4
    rng = np.random.default_rng(5)
    sess = ServeSession(
        cfg, _params(cfg), num_slots=slots, max_len=64,
        prompt_buckets=(4, 8, 16), steps_per_tick=steps,
        prefill_token_budget=B,
    )
    for i, (p, n, t) in enumerate(_burst_trace(rng, cfg.vocab_size)):
        sess.submit(p, max_new=n, arrival=t, req_id=i)
    sess.run(max_steps=10_000)
    assert sess.drained
    assert sess.stats.max_decode_gap_ticks <= steps + -(-B // slots)


@pytest.mark.slow
def test_serve_async_bench_smoke():
    """The bench harness itself: a miniature trace must run all three arms
    (sync / async / interleaved) with zero recompiles, zero cross-loop
    token mismatches, a clean generate oracle, and self-describing metric
    docs (the >= 1.15x speedup criterion is asserted on the real bench
    config, solo-run — this pins the machinery)."""
    import benchmarks.serve_async as B

    r = B.bench(requests=10, repeats=1, oracle=2)
    assert r["recompiles_after_warmup"] == 0
    assert r["token_mismatches"] == 0 and r["policy_token_mismatches"] == 0
    assert r["oracle_mismatches"] == 0
    assert r["sync_tok_s"] > 0 and r["async_tok_s"] > 0
    assert r["ratio_max_decode_gap_ticks"] <= r["ratio_gap_bound"]
    assert set(r["field_docs"])  # embedded metric docs travel with the JSON


@pytest.mark.slow
def test_serve_tiers_bench_smoke():
    """The quality-tier bench harness: a miniature spike must run all three
    arms (exact_only / static_tiers / shed) with zero recompiles, a
    bit-transparent exact rung (match fraction exactly 1.0), in-range
    quality readouts for every rung, and a shedder that actually demotes
    under the burst (the modeled-throughput win criterion is asserted on
    the real bench config, solo-run — this pins the machinery)."""
    import benchmarks.serve_tiers as B

    r = B.bench(requests=9, shed_queue_depth=2)
    assert r["recompiles_after_warmup"] == 0
    q = r["arms"]["static_tiers"]["quality_vs_exact_oracle"]
    assert q["exact"]["token_match_fraction"] == 1.0
    for t, row in q.items():
        assert row["requests"] > 0
        assert 0.0 <= row["token_match_fraction"] <= 1.0
        assert row["modeled_delay_ns"] > 0
    shed = r["arms"]["shed"]
    assert shed["tier_demotions"] >= 1
    assert shed["modeled_mac_tok_per_us"] > \
        r["arms"]["exact_only"]["modeled_mac_tok_per_us"]
    assert set(r["field_docs"])  # embedded metric docs travel with the JSON


@pytest.mark.slow
def test_overlap_accounting_sane():
    """wall_s/host_block_s are populated by both loops and overlap_fraction
    stays a fraction (comparative claims belong to the solo-run bench, not
    a suite that shares the CPU with other tests)."""
    cfg = _cfg()
    rng = np.random.default_rng(11)
    trace = _trace(rng, 8, cfg.vocab_size)
    for loop in ("sync", "async"):
        sess = _session(cfg, loop=loop)
        for i, (p, n, t) in enumerate(trace):
            sess.submit(p, max_new=n, req_id=i)
        sess.run()
        st = sess.stats
        assert st.wall_s > 0 and st.host_block_s >= 0
        assert 0.0 <= st.overlap_fraction <= 1.0
        assert st.work_ticks >= st.ticks             # prefill charged on top
        assert st.prefill_tokens > 0
