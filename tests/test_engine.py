"""Scan-based serving engine: parity with the legacy per-token loop, fused
prefill cache equivalence, sampling/eos semantics, execution modes."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config, reduced_config
from repro.core.approx import ApproxConfig
from repro.models.transformer import decode_step, forward, init_cache, init_params, seed_cache
from repro.serve import engine
from repro.serve.engine import (
    EXECUTION_MODES,
    SamplingConfig,
    freeze_params,
    generate,
    greedy_generate,
    greedy_generate_legacy,
    resolve_execution_mode,
)

KEY = jax.random.PRNGKey(0)
PROMPT = jnp.asarray([[1, 2, 3], [4, 5, 6]], jnp.int32)


def _cfg(arch="granite-3-2b", **over):
    return dataclasses.replace(
        reduced_config(get_config(arch)), remat=False, q_chunk=16, **over
    )


# ---------------------------------------------------------------------------
# Parity: scan decode == legacy Python loop
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("prefill_mode", ("decode", "fused"))
def test_scan_parity_with_legacy_loop(prefill_mode):
    """Token-for-token identity of the single-jit scan engine vs the
    original per-token dispatch loop."""
    cfg = _cfg()
    params = init_params(cfg, KEY)
    old = greedy_generate_legacy(cfg, params, PROMPT, max_new=6)
    new = generate(cfg, params, PROMPT, max_new=6, prefill_mode=prefill_mode)
    assert np.array_equal(np.asarray(old), np.asarray(new))


def test_scan_parity_ssm_family():
    """SSM caches force decode-mode prefill automatically; parity holds."""
    cfg = _cfg("falcon-mamba-7b")
    params = init_params(cfg, KEY)
    old = greedy_generate_legacy(cfg, params, PROMPT, max_new=4)
    new = generate(cfg, params, PROMPT, max_new=4)   # prefill_mode forced
    assert np.array_equal(np.asarray(old), np.asarray(new))


def test_scan_parity_quantized():
    cfg = _cfg(approx=ApproxConfig(multiplier="mul8x8_2", mode="lowrank"))
    params = init_params(cfg, KEY)
    old = greedy_generate_legacy(cfg, params, PROMPT, max_new=4)
    new = generate(cfg, params, PROMPT, max_new=4, prefill_mode="decode")
    assert np.array_equal(np.asarray(old), np.asarray(new))


def test_greedy_generate_wrapper_delegates_to_scan_engine():
    cfg = _cfg()
    params = init_params(cfg, KEY)
    a = greedy_generate(cfg, params, PROMPT, max_new=5)
    b = generate(cfg, params, PROMPT, max_new=5)
    assert np.array_equal(np.asarray(a), np.asarray(b))
    assert a.shape == (2, 3 + 5)


# ---------------------------------------------------------------------------
# Fused prefill == teacher-forced prefill (cache contents)
# ---------------------------------------------------------------------------


def test_fused_prefill_cache_matches_teacher_forced():
    """One fused full-sequence pass must seed the same KV cache that S0
    decode steps would have written (positions [0, S0))."""
    cfg = _cfg(dtype="float32")
    params = init_params(cfg, KEY)
    B, S0 = PROMPT.shape
    max_len = S0 + 4

    logits, _, kvs = forward(cfg, params, {"tokens": PROMPT}, return_kv=True)
    fused = seed_cache(cfg, init_cache(cfg, B, max_len, jnp.float32), kvs)

    tf = init_cache(cfg, B, max_len, jnp.float32)
    cur = jnp.zeros((B,), jnp.int32)
    last = None
    for i in range(S0):
        last, tf = decode_step(cfg, params, tf, {"tokens": PROMPT[:, i : i + 1]}, cur)
        cur = cur + 1

    for name in ("k", "v"):
        np.testing.assert_allclose(
            np.asarray(fused[name][:, :, :S0]),
            np.asarray(tf[name][:, :, :S0]),
            rtol=1e-5, atol=1e-5,
        )
    # positions >= S0 stay zero in both
    assert not np.asarray(fused["k"][:, :, S0:]).any()
    # and the fused last-position logits match the last teacher-forced step
    np.testing.assert_allclose(
        np.asarray(logits[:, -1, :]), np.asarray(last[:, 0, :]), rtol=2e-4, atol=2e-4
    )


# ---------------------------------------------------------------------------
# Sampling / eos semantics
# ---------------------------------------------------------------------------


def test_stop_on_eos_pads_finished_rows():
    cfg = _cfg()
    params = init_params(cfg, KEY)
    base = generate(cfg, params, PROMPT, max_new=6)
    S0 = PROMPT.shape[1]
    eos = int(base[0, S0 + 1])                     # second generated token, row 0
    out = generate(cfg, params, PROMPT, max_new=6,
                   sampling=SamplingConfig(eos_id=eos))
    row = np.asarray(out[0, S0:])
    hit = int(np.argmax(row == eos))
    assert row[hit] == eos
    assert (row[hit:] == eos).all()                # masked, not truncated
    assert out.shape == base.shape                 # shapes stay static


def test_temperature_sampling_deterministic_under_fixed_key():
    cfg = _cfg()
    params = init_params(cfg, KEY)
    s = SamplingConfig(temperature=0.7, top_k=16)
    r = jax.random.PRNGKey(7)
    o1 = generate(cfg, params, PROMPT, max_new=5, sampling=s, rng=r)
    o2 = generate(cfg, params, PROMPT, max_new=5, sampling=s, rng=r)
    assert np.array_equal(np.asarray(o1), np.asarray(o2))
    assert int(o1.min()) >= 0 and int(o1.max()) < cfg.vocab_size


def test_select_token_greedy_vs_topk():
    logits = jnp.asarray([[0.0, 5.0, 1.0, 4.0]])
    tok = engine._select_token(logits, SamplingConfig(), jax.random.PRNGKey(0))
    assert int(tok[0]) == 1
    # top_k=1 at any temperature degenerates to argmax
    tok = engine._select_token(
        logits, SamplingConfig(temperature=2.0, top_k=1), jax.random.PRNGKey(0)
    )
    assert int(tok[0]) == 1


# ---------------------------------------------------------------------------
# Execution modes + frozen weights
# ---------------------------------------------------------------------------


def test_resolve_execution_mode():
    assert resolve_execution_mode("exact").mode == "float"
    assert resolve_execution_mode("exact_quant").mode == "exact_quant"
    a = resolve_execution_mode("approx", "mul8x8_3")
    assert a.mode == "pallas" and a.multiplier == "mul8x8_3"
    assert resolve_execution_mode("approx_lowrank").mode == "lowrank"
    # approx_msr routes to the MSR fixed-shift family: an MSR multiplier
    # name passes through, anything else falls back to mul8x8_msr4
    m = resolve_execution_mode("approx_msr", "mul8x8_msr2")
    assert m.mode == "pallas" and m.multiplier == "mul8x8_msr2"
    m = resolve_execution_mode("approx_msr", "mul8x8_2")
    assert m.mode == "pallas" and m.multiplier == "mul8x8_msr4"
    assert resolve_execution_mode("approx_msr", act_per_row=True).act_per_row
    with pytest.raises(ValueError):
        resolve_execution_mode("nope")
    assert set(EXECUTION_MODES) == {
        "exact", "exact_quant", "approx", "approx_lowrank", "approx_msr"}


def test_generate_with_frozen_weights():
    cfg = _cfg(approx=resolve_execution_mode("approx_lowrank"))
    params = init_params(cfg, KEY)
    out_dyn = generate(cfg, params, PROMPT, max_new=3)
    out_frz = generate(cfg, freeze_params(cfg, params), PROMPT, max_new=3)
    assert out_frz.shape == out_dyn.shape
    assert int(out_frz.min()) >= 0 and int(out_frz.max()) < cfg.vocab_size


def test_generate_approx_pallas_interpret():
    """The 'approx' execution mode drives every projection matmul through the
    Pallas kernel (interpret mode off-TPU) inside the scan — end to end."""
    cfg = dataclasses.replace(
        _cfg(), num_layers=1, d_model=64, num_heads=2, num_kv_heads=2,
        head_dim=32, d_ff=64, vocab_size=128,
        approx=resolve_execution_mode("approx"),
    )
    params = init_params(cfg, KEY)
    out = generate(cfg, params, PROMPT, max_new=2)
    assert out.shape == (2, 5)
    assert int(out.min()) >= 0 and int(out.max()) < cfg.vocab_size


def test_generate_rejects_embedding_input_archs():
    cfg = _cfg("qwen2-vl-2b")
    if cfg.embed_input:
        pytest.skip("arch takes tokens")
    with pytest.raises(ValueError):
        generate(cfg, {}, PROMPT, max_new=2)
