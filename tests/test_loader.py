"""Distributed loader: determinism, disjoint host slices, elastic resize."""
import numpy as np

from repro.data.loader import ShardedTokenLoader


def test_deterministic_and_restartable():
    l1 = ShardedTokenLoader(vocab=100, global_batch=4, seq_len=16, seed=7)
    l2 = ShardedTokenLoader(vocab=100, global_batch=4, seq_len=16, seed=7)
    b1 = l1.batch_at(5)
    b2 = l2.batch_at(5)           # "restart" straight to step 5
    np.testing.assert_array_equal(b1["tokens"], b2["tokens"])
    assert b1["tokens"].shape == (4, 16)
    np.testing.assert_array_equal(b1["tokens"][:, 1:], b1["labels"][:, :-1])


def test_host_slices_partition_global_batch():
    g = ShardedTokenLoader(vocab=50, global_batch=8, seq_len=8, seed=1)
    full = g.batch_at(3)["tokens"]
    parts = [
        ShardedTokenLoader(vocab=50, global_batch=8, seq_len=8, seed=1,
                           num_hosts=4, host_id=h).batch_at(3)["tokens"]
        for h in range(4)
    ]
    np.testing.assert_array_equal(np.concatenate(parts), full)


def test_elastic_resize_preserves_rows():
    """Re-slicing 2 hosts -> 4 hosts mid-run yields the same global stream."""
    two = [
        ShardedTokenLoader(vocab=50, global_batch=8, seq_len=8, seed=2,
                           num_hosts=2, host_id=h).batch_at(9)["tokens"]
        for h in range(2)
    ]
    four = [
        ShardedTokenLoader(vocab=50, global_batch=8, seq_len=8, seed=2,
                           num_hosts=4, host_id=h).batch_at(9)["tokens"]
        for h in range(4)
    ]
    np.testing.assert_array_equal(np.concatenate(two), np.concatenate(four))
