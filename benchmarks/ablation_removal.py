"""Ablation: which partial product is "M2"? (DESIGN.md §3)

The paper's Fig. 1 text is ambiguous about which multiplier index is removed
in MUL8x8_3. This bench enumerates every single partial-product removal from
the MUL8x8_2 aggregation and reports exhaustive ER/MED/NMED/MRED — the
evidence behind our row-major M_{3i+j} reading (M2 = A[2:0]×B[7:6], M6 =
A[7:6]×B[2:0], matching "A[7:6] or B[7:6] is 00 ⇒ remove M2 or M6"), plus
the DNN-facing consequence: with co-optimized weights (B < 32) the M2
removal is error-free, every alternative is not.
"""
from __future__ import annotations

import time
from typing import List, Tuple

import numpy as np

from repro.core import multipliers as M
from repro.core.metrics import multiplier_metrics

_PIECES = ("lo", "mid", "hi")


def run() -> List[Tuple[str, float, str]]:
    rows = []
    base = M.mul8x8_table("mul8x8_2")
    for pa in _PIECES:
        for pb in _PIECES:
            if (pa, pb) == ("hi", "hi"):
                continue  # that's M8, the exact 2x2 — kept by all designs
            t0 = time.perf_counter()
            spec = M.AggregationSpec("ablate", "mul3x3_2", removed=((pa, pb),))
            tab = M.aggregate_8x8(spec)
            m = multiplier_metrics(tab, f"rm_{pa}x{pb}")
            # error-free on the co-optimized domain? (weights/rhs < 32)
            free_w31 = bool(np.array_equal(tab[:, :32], base[:, :32]))
            # error-free when activations/lhs < 32?
            free_a31 = bool(np.array_equal(tab[:32, :], base[:32, :]))
            us = (time.perf_counter() - t0) * 1e6
            name = "M2" if (pa, pb) == ("lo", "hi") else ("M6" if (pa, pb) == ("hi", "lo") else "")
            rows.append(
                (f"ablation/remove_A{pa}xB{pb}{('_'+name) if name else ''}", us,
                 f"ER={m.er:.2f}% MED={m.med:.2f} NMED={m.nmed:.2f}% "
                 f"error-free@w<32={free_w31} @a<32={free_a31}")
            )
    return rows
