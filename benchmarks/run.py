# One function per paper table. Print ``name,us_per_call,derived`` CSV.
import argparse
import sys


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--full", action="store_true", help="include the large CNNs (slow on CPU)")
    ap.add_argument("--skip", default="", help="comma-separated bench groups to skip")
    args = ap.parse_args()
    skip = set(filter(None, args.skip.split(",")))

    from benchmarks import (
        ablation_removal,
        kernel_bench,
        roofline_summary,
        serve_continuous,
        table_v,
        table_vi_vii,
        table_viii,
    )

    groups = [
        ("table_v", lambda: table_v.run()),
        ("table_vi_vii", lambda: table_vi_vii.run()),
        ("ablation", lambda: ablation_removal.run()),
        ("kernel", lambda: kernel_bench.run()),
        ("serve_continuous", lambda: serve_continuous.run()),
        ("table_viii", lambda: table_viii.run(full=args.full)),
        ("roofline", lambda: roofline_summary.run()),
    ]
    print("name,us_per_call,derived")
    failed = False
    for name, fn in groups:
        if name in skip:
            continue
        try:
            for row_name, us, derived in fn():
                print(f"{row_name},{us:.1f},{derived}")
        except Exception as e:  # noqa: BLE001
            failed = True
            print(f"{name},0,ERROR: {e!r}", file=sys.stderr)
            print(f"{name},0,ERROR: {e!r}")
    if failed:
        sys.exit(1)


if __name__ == "__main__":
    main()
