"""Summarize cached dry-run results (results/dryrun/*.json) as bench rows —
the machine-readable companion of EXPERIMENTS.md §Roofline."""
from __future__ import annotations

import glob
import json
import os
from typing import List, Tuple


def run(out_dir: str = "results/dryrun") -> List[Tuple[str, float, str]]:
    rows = []
    for path in sorted(glob.glob(os.path.join(out_dir, "*.json"))):
        with open(path) as f:
            d = json.load(f)
        tag = os.path.basename(path)[:-5]
        if d.get("skipped"):
            rows.append((f"roofline/{tag}", 0.0, f"SKIPPED: {d['skipped']}"))
            continue
        if "t_compute_s" not in d:   # multi-pod compile-only proof cells
            rows.append(
                (f"roofline/{tag}", d.get("compile_s", 0) * 1e6,
                 f"compile-only OK; temp/device {d.get('temp_size_in_bytes', 0)/1e9:.2f} GB")
            )
            continue
        rows.append(
            (f"roofline/{tag}", d.get("compile_s", 0) * 1e6,
             f"compute {d['t_compute_s']:.4f}s memory {d['t_memory_s']:.4f}s "
             f"collective {d['t_collective_s']:.4f}s -> {d['bound']}-bound; "
             f"useful-flops {d.get('useful_flop_fraction', 0):.3f} "
             f"roofline {d.get('roofline_fraction', 0):.4f}")
        )
    if not rows:
        rows.append(("roofline/none", 0.0, "no dry-run results cached; run repro.launch.dryrun"))
    return rows
