"""Prefix sharing + preemption vs the reservation-based paged oracle at an
EQUAL block-pool size.

Both arms serve the same trace — 80% of requests open with a shared
system prompt (the production shape sharing exists for), 20% are unique —
through ``repro.serve.scheduler.ServeSession`` with identical buckets,
decode chunking, pool size, and greedy sampling.  The only difference:

* **baseline** — PR-3 semantics: every prompt block is written privately
  and admission reserves the request's worst-case block count up front,
  so the pool serializes long-budget requests no matter how much of
  their prompts is identical;
* **shared** — ``prefix_sharing=True, preemption=True``: shared prompt
  blocks map to the same physical blocks (prefill writes for the shared
  span are skipped), partial tails fork copy-on-write on first write,
  and admission oversubscribes the pool — on exhaustion the
  least-important resident is evicted and replayed bit-identically.

The JSON artifact (``BENCH_serve_prefix.json``) records per-arm peak
concurrency and tokens/s, the sharing counters (prefix-hit blocks, CoW
forks, preemptions), the concurrency ratio at equal ``num_blocks`` (the
headline: >= 1.5x on the default config), a forced-preemption
sub-scenario (a pool too small for two worst cases; the evicted request's
tokens must equal a roomy-pool run), the cross-arm token-mismatch count
(must be 0 — asserted, not sampled), the recompile count across the
timed passes (must be 0), and ``SchedulerStats.DOCS`` under
``field_docs`` so every metric key is self-describing.

    PYTHONPATH=src python benchmarks/serve_prefix.py
    PYTHONPATH=src python benchmarks/serve_prefix.py --smoke --out /tmp/b.json
"""
from __future__ import annotations

import argparse
import dataclasses
import json
import time

import jax
import numpy as np

BUCKETS = (8, 16, 32, 64)
SHARED_LEN = 24          # system-prompt tokens: 3 full blocks at block 8
NEW_CHOICES = (4, 8, 8, 16, 24)
MAX_LEN = 96
BLOCK_SIZE = 8


def _tiny_cfg(exec_mode: str = "exact"):
    from repro.configs import get_config, reduced_config
    from repro.serve.engine import resolve_execution_mode

    return dataclasses.replace(
        reduced_config(get_config("granite-3-2b")),
        num_layers=4, d_model=256, num_heads=4, num_kv_heads=2, head_dim=64,
        d_ff=512, vocab_size=1024, remat=False, q_chunk=64, dtype="float32",
        approx=resolve_execution_mode(exec_mode),
    )


def build_trace(n: int, vocab: int, seed: int = 0, rate: float = 1.0,
                shared_frac: float = 0.8):
    """[(prompt, max_new, arrival_tick)] — ``shared_frac`` of the requests
    open with the same ``SHARED_LEN``-token system prompt plus a short
    unique suffix; the rest are fully unique."""
    rng = np.random.default_rng(seed)
    system = rng.integers(0, vocab, SHARED_LEN).astype(np.int32)
    trace, t = [], 0
    while len(trace) < n:
        t += int(rng.poisson(rate))
        tail = rng.integers(0, vocab, int(rng.integers(2, 7))).astype(np.int32)
        if rng.random() < shared_frac:
            prompt = np.concatenate([system, tail])
            # a slice of the shared traffic is same-tick duplicate pairs
            # (best-of-n fan-out): identical prompts resident together
            # share the partial tail block, so the first decode write
            # must fork it copy-on-write
            if rng.random() < 0.2:
                trace.append((
                    prompt, int(NEW_CHOICES[rng.integers(len(NEW_CHOICES))]), t,
                ))
        else:
            prompt = rng.integers(0, vocab,
                                  SHARED_LEN + tail.size).astype(np.int32)
        trace.append((prompt, int(NEW_CHOICES[rng.integers(len(NEW_CHOICES))]), t))
    return trace[:n]


def run_arm(cfg, params, trace, *, sharing: bool, num_slots: int,
            num_blocks: int, steps_per_tick: int = 4):
    """Warm pass (compiles every program incl. copy_block), then a timed
    fresh-session pass.  Returns (tok/s, results, stats, recompiles, s)."""
    from repro.serve.scheduler import ServeSession, scheduler_compile_stats

    def serve():
        sess = ServeSession(
            cfg, params, num_slots=num_slots, max_len=MAX_LEN,
            prompt_buckets=BUCKETS, steps_per_tick=steps_per_tick,
            cache_layout="paged", block_size=BLOCK_SIZE,
            num_blocks=num_blocks, prefix_sharing=sharing,
            preemption=sharing,
        )
        for p, n, t in trace:
            sess.submit(p, max_new=n, arrival=t)
        sess.run()
        return sess

    warm = serve()
    warm.warmup()                            # any program the trace missed
    before = scheduler_compile_stats()
    t0 = time.perf_counter()
    sess = serve()
    dt = time.perf_counter() - t0
    recompiles = sum(scheduler_compile_stats().values()) - sum(before.values())
    useful = sum(len(r.tokens) for r in sess.results.values())
    return useful / dt, sess.results, sess.stats, recompiles, dt


def forced_preemption_scenario(cfg, params):
    """A pool too small for two worst cases: admission oversubscribes, one
    resident is evicted mid-decode and replayed.  Returns the preemption
    count and the mismatch count vs a roomy-pool (never-preempting) run."""
    from repro.serve.scheduler import ServeSession

    rng = np.random.default_rng(11)
    prompts = [rng.integers(0, cfg.vocab_size, 6).astype(np.int32)
               for _ in range(2)]
    outs = {}
    stats = {}
    for blocks in (24, 5):                   # roomy oracle vs starved pool
        sess = ServeSession(
            cfg, params, num_slots=2, max_len=64, prompt_buckets=(8, 32),
            cache_layout="paged", block_size=4, num_blocks=blocks,
            prefix_sharing=True, preemption=True,
        )
        ids = [sess.submit(p, max_new=12, req_id=i)
               for i, p in enumerate(prompts)]
        res = sess.run(max_steps=10_000)
        outs[blocks] = {i: res[i].tokens.tolist() for i in ids}
        stats[blocks] = sess.stats
    mism = sum(outs[24][i] != outs[5][i] for i in outs[24])
    return stats[5].preemptions, mism


def bench(exec_mode: str = "exact", requests: int = 48, num_slots: int = 8,
          num_blocks: int = 24, seed: int = 0, steps_per_tick: int = 4,
          shared_frac: float = 0.8):
    from repro.models.transformer import init_params
    from repro.serve.scheduler import SchedulerStats

    cfg = _tiny_cfg(exec_mode)
    params = init_params(cfg, jax.random.PRNGKey(0))
    trace = build_trace(requests, cfg.vocab_size, seed=seed,
                        shared_frac=shared_frac)

    base_tps, base_res, base_st, base_rc, base_dt = run_arm(
        cfg, params, trace, sharing=False, num_slots=num_slots,
        num_blocks=num_blocks, steps_per_tick=steps_per_tick,
    )
    shared_tps, shared_res, shared_st, shared_rc, shared_dt = run_arm(
        cfg, params, trace, sharing=True, num_slots=num_slots,
        num_blocks=num_blocks, steps_per_tick=steps_per_tick,
    )

    # cross-arm parity oracle: same trace, bit-identical greedy tokens
    mismatches = sum(
        not np.array_equal(base_res[rid].tokens, shared_res[rid].tokens)
        for rid in base_res
    )
    preemptions, preempt_mism = forced_preemption_scenario(cfg, params)
    useful = sum(len(r.tokens) for r in base_res.values())
    return {
        "bench": "serve_prefix",
        "exec_mode": exec_mode,
        "requests": requests,
        "seed": seed,
        "steps_per_tick": steps_per_tick,
        "shared_frac": shared_frac,
        "shared_prompt_len": SHARED_LEN,
        "prompt_buckets": list(BUCKETS),
        "max_new_choices": list(NEW_CHOICES),
        "max_len": MAX_LEN,
        "block_size": BLOCK_SIZE,
        "num_slots": num_slots,
        "num_blocks": num_blocks,
        "useful_tokens": useful,
        "baseline_tok_s": round(base_tps, 1),
        "shared_tok_s": round(shared_tps, 1),
        "speedup": round(shared_tps / base_tps, 3),
        "baseline_peak_concurrent": base_st.peak_active,
        "shared_peak_concurrent": shared_st.peak_active,
        "concurrency_ratio": round(
            shared_st.peak_active / base_st.peak_active, 3),
        "baseline_peak_blocks": base_st.peak_blocks_in_use,
        "shared_peak_blocks": shared_st.peak_blocks_in_use,
        "prefix_hit_blocks": shared_st.prefix_hit_blocks,
        "cow_forks": shared_st.cow_forks,
        "preemptions": shared_st.preemptions,
        "forced_preemptions": preemptions,
        "forced_preemption_mismatches": preempt_mism,
        "baseline_latency_p50": base_st.latency_p50,
        "baseline_latency_p95": base_st.latency_p95,
        "shared_latency_p50": shared_st.latency_p50,
        "shared_latency_p95": shared_st.latency_p95,
        "token_mismatches": mismatches,
        "recompiles_after_warmup": base_rc + shared_rc,
        "baseline_s": round(base_dt, 4),
        "shared_s": round(shared_dt, 4),
        "field_docs": dict(SchedulerStats.DOCS),
    }


def run(exec_mode: str = "exact", requests: int = 48):
    """benchmarks/run.py entry: (name, us_per_call, derived) rows."""
    r = bench(exec_mode=exec_mode, requests=requests)
    return [
        (f"serve/prefix_shared_{exec_mode}", 1e6 / r["shared_tok_s"],
         f"{r['shared_tok_s']} tok/s peak={r['shared_peak_concurrent']} req "
         f"hits={r['prefix_hit_blocks']}"),
        (f"serve/prefix_baseline_{exec_mode}", 1e6 / r["baseline_tok_s"],
         f"{r['baseline_tok_s']} tok/s peak={r['baseline_peak_concurrent']} req"),
        (f"serve/prefix_concurrency_{exec_mode}", 0.0,
         f"{r['concurrency_ratio']}x at {r['num_blocks']} blocks, "
         f"mismatches={r['token_mismatches']}, "
         f"preemptions={r['preemptions']}+{r['forced_preemptions']}"),
    ]


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--exec", dest="exec_mode", default="exact",
                    choices=("exact", "exact_quant", "approx", "approx_lowrank"))
    ap.add_argument("--requests", type=int, default=48)
    ap.add_argument("--num-slots", type=int, default=8)
    ap.add_argument("--num-blocks", type=int, default=24,
                    help="block-pool size for BOTH arms (the equal-memory "
                         "knob: baseline reserves worst cases against it, "
                         "sharing packs actual shared context into it)")
    ap.add_argument("--shared-frac", type=float, default=0.8)
    ap.add_argument("--steps", type=int, default=4,
                    help="decode-chunk size (steps per dispatch)")
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--smoke", action="store_true",
                    help="miniature config: exercises every oracle without "
                         "the full trace (CI gate for the harness itself)")
    ap.add_argument("--out", default="BENCH_serve_prefix.json")
    args = ap.parse_args()
    if args.smoke:
        args.requests = min(args.requests, 14)
    r = bench(exec_mode=args.exec_mode, requests=args.requests,
              num_slots=args.num_slots, num_blocks=args.num_blocks,
              seed=args.seed, steps_per_tick=args.steps,
              shared_frac=args.shared_frac)
    with open(args.out, "w") as f:
        json.dump(r, f, indent=2)
        f.write("\n")
    print(json.dumps({k: v for k, v in r.items() if k != "field_docs"},
                     indent=2))
    failures = []
    if r["token_mismatches"]:
        failures.append(f"{r['token_mismatches']} requests differ between arms")
    if r["forced_preemption_mismatches"] or not r["forced_preemptions"]:
        failures.append(
            f"forced-preemption scenario: {r['forced_preemptions']} "
            f"preemptions, {r['forced_preemption_mismatches']} mismatches")
    if r["recompiles_after_warmup"]:
        failures.append(f"{r['recompiles_after_warmup']} recompiles after warmup")
    if not args.smoke and r["concurrency_ratio"] < 1.5:
        failures.append(f"concurrency {r['concurrency_ratio']}x < 1.5x at "
                        f"equal num_blocks")
    for msg in failures:
        print(f"FAIL: {msg}")
    raise SystemExit(1 if failures else 0)


if __name__ == "__main__":
    main()
