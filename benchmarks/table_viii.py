"""Paper Table VIII: DNN accuracy under approximate multipliers, with and
without hardware-driven co-optimization (retraining).

Offline container => deterministic synthetic MNIST/CIFAR-shaped datasets
(data/synthetic.py). The protocol mirrors the paper: train float -> quantize
with each multiplier -> measure DAL -> retrain (QAT fine-tune with the
weight-band regularizer + the deeper LeNet+) -> measure recovery. VGG16/
AlexNet/ResNet-19 run under --full (CPU minutes).
"""
from __future__ import annotations

import time
from typing import List, Tuple

import jax
import jax.numpy as jnp

from repro.core.approx import ApproxConfig
from repro.core.metrics import dal
from repro.data.synthetic import image_dataset
from repro.models.cnn import cnn_forward, init_cnn

KEY = jax.random.PRNGKey(0)
MULTIPLIERS = ("mul8x8_1", "mul8x8_2", "mul8x8_3", "pkm")


def _train(model, data, cfg, steps, lr=0.05, bs=64):
    def loss_fn(layers, x, y):
        logits = cnn_forward(dict(model, layers=layers), x, cfg)
        return -jnp.mean(jnp.sum(jax.nn.log_softmax(logits) * jax.nn.one_hot(y, 10), -1))

    @jax.jit
    def step(layers, x, y):
        l, g = jax.value_and_grad(loss_fn)(layers, x, y)
        return jax.tree.map(lambda p, gr: p - lr * gr, layers, g), l

    layers = model["layers"]
    n = data.x_train.shape[0]
    for i in range(steps):
        j = (i * bs) % (n - bs)
        layers, _ = step(layers, jnp.asarray(data.x_train[j:j+bs]), jnp.asarray(data.y_train[j:j+bs]))
    return dict(model, layers=layers)


def _acc(model, data, cfg, n=256):
    logits = cnn_forward(model, jnp.asarray(data.x_test[:n]), cfg)
    return float(jnp.mean(jnp.argmax(logits, -1) == jnp.asarray(data.y_test[:n])))


def run(full: bool = False) -> List[Tuple[str, float, str]]:
    rows = []
    nets = [("lenet", "mnist"), ("lenet_plus", "mnist"), ("lenet", "cifar10"), ("lenet_plus", "cifar10")]
    if full:
        nets += [("alexnet", "cifar10"), ("vgg16", "cifar10"), ("resnet19", "cifar10")]
    steps = 120 if not full else 300
    for net, ds in nets:
        t0 = time.perf_counter()
        data = image_dataset(ds, n_train=1024, n_test=256, seed=0)
        shape = (28, 28, 1) if ds == "mnist" else (32, 32, 3)
        model = init_cnn(net, KEY, in_shape=shape)
        fl = ApproxConfig(mode="float")
        model = _train(model, data, fl, steps)
        acc0 = _acc(model, data, fl)
        parts = [f"exact={acc0:.3f}"]
        for mname in MULTIPLIERS:
            mode = "lowrank" if mname.startswith("mul8x8") else "lut"
            acfg = ApproxConfig(multiplier=mname, mode=mode)
            a = _acc(model, data, acfg)
            # co-optimization: short QAT fine-tune under the approximate fwd
            retrained = _train(dict(model), data, acfg, steps=30, lr=0.01)
            a_re = _acc(retrained, data, acfg)
            parts.append(f"{mname}={a:.3f}->retrain {a_re:.3f} (DAL {dal(acc0, a_re):+.3f})")
        us = (time.perf_counter() - t0) * 1e6
        rows.append((f"table_viii/{net}-{ds}", us, "; ".join(parts)))
    return rows
