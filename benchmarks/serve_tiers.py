"""Load-adaptive quality-tier serving: the MSR/approx execution-mode ladder
under a load spike, measured against the exact-only baseline.

Every arm serves the SAME burst trace (all arrivals at tick 0 — the spike)
through ``repro.serve.scheduler.ServeSession`` (paged layout, identical
buckets/pool/slots, greedy):

* **exact_only** — no tiers: every request decodes through the exact float
  path; this arm is also the per-request quality oracle.
* **static_tiers** — ``tiers=TIERS`` with requests PINNED round-robin to
  rungs; no shedder.  This arm measures per-tier output quality: for each
  rung, the mean positionwise token-match fraction of its requests against
  the exact_only arm's outputs for the same request ids.  The ``exact``
  rung must read 1.0 exactly (mixed-tier batching is bit-transparent).
* **shed** — ``tiers=TIERS`` with every request submitted at the best rung
  and the load-adaptive shedder armed (``shed_queue_depth``): under the
  spike the scheduler demotes new admissions down the ladder, then restores
  after the hysteresis hold once the queue drains.

Throughput is reported two ways, because the container has no approximate
hardware:

* ``wall_tok_s`` — useful tokens / wall seconds on this host (the MSR rung
  runs the Pallas kernel in interpret mode off-TPU, so wall numbers
  UNDERSTATE the approximate rungs);
* ``modeled_mac_tok_per_us`` — useful tokens / Sum_tokens(delay_ns of the
  serving rung's multiplier) * 1e3, the MAC-critical-path-limited
  throughput on the modeled accelerator (``repro.core.hwcost.COST_TABLE``:
  paper Table VII rows + unit-gate estimates for the MSR family).  Each
  token is costed at the delay of the rung it was actually served at, so
  shedder demotions translate directly into modeled headroom.

The JSON artifact (``BENCH_serve_tiers.json``) records per-arm wall and
modeled throughput, per-tier quality and token counts, the shed arm's
demotion/restoration counts, the recompile count across the timed passes
(must be 0), and ``SchedulerStats.DOCS`` under ``field_docs``.  The gate:
the shed arm must sustain HIGHER modeled throughput than exact_only under
the spike, with zero recompiles and exact-rung quality == 1.0.

    PYTHONPATH=src python benchmarks/serve_tiers.py
    PYTHONPATH=src python benchmarks/serve_tiers.py --smoke --out /tmp/b.json
"""
from __future__ import annotations

import argparse
import dataclasses
import json
import time

import jax
import numpy as np

BUCKETS = (8, 16)
NEW_CHOICES = (4, 6, 8)
MAX_LEN = 32
BLOCK_SIZE = 8
TIERS = ("exact", "approx_lowrank", "approx_msr")
TIER_MULTIPLIER = "mul8x8_2"


def _tiny_cfg():
    from repro.configs import get_config, reduced_config
    from repro.serve.engine import resolve_execution_mode

    cfg = dataclasses.replace(
        reduced_config(get_config("granite-3-2b")),
        num_layers=2, d_model=128, num_heads=4, num_kv_heads=2, head_dim=32,
        d_ff=256, vocab_size=512, remat=False, q_chunk=32, dtype="float32",
    )
    return dataclasses.replace(cfg, approx=resolve_execution_mode("exact"))


def tier_delay_ns(tier: str) -> float:
    """Modeled MAC critical path for a rung: the COST_TABLE delay of the
    multiplier that rung actually routes to (exact rungs cost the exact
    row; '' — a no-tiers session — is the exact path)."""
    from repro.core.hwcost import COST_TABLE
    from repro.serve.engine import resolve_execution_mode

    if not tier:
        return COST_TABLE["exact"].delay_ns
    acfg = resolve_execution_mode(tier, TIER_MULTIPLIER)
    name = "exact" if acfg.mode in ("float", "exact_quant") else acfg.multiplier
    return COST_TABLE[name].delay_ns


def build_trace(n: int, vocab: int, seed: int = 0):
    """[(prompt, max_new)] — mixed prompt lengths under the bucket set; the
    arms submit every request at arrival tick 0 (the spike)."""
    rng = np.random.default_rng(seed)
    trace = []
    for _ in range(n):
        prompt = rng.integers(0, vocab,
                              int(rng.integers(2, BUCKETS[-1] + 1))).astype(np.int32)
        trace.append((prompt, int(NEW_CHOICES[rng.integers(len(NEW_CHOICES))])))
    return trace


def run_arm(cfg, params, trace, *, tiers=None, pin_tiers: bool = False,
            shed_queue_depth=None, shed_hold_steps: int = 6,
            num_slots: int = 4):
    """Warm pass (compiles every rung's decode tick + prefill programs),
    then a timed fresh-session pass.  Returns
    (tok/s, results, stats, recompiles, seconds)."""
    from repro.serve.scheduler import ServeSession, scheduler_compile_stats

    def serve():
        sess = ServeSession(
            cfg, params, num_slots=num_slots, max_len=MAX_LEN,
            prompt_buckets=BUCKETS, cache_layout="paged",
            block_size=BLOCK_SIZE, tiers=tiers,
            tier_multiplier=TIER_MULTIPLIER,
            shed_queue_depth=shed_queue_depth,
            shed_hold_steps=shed_hold_steps,
        )
        for i, (p, n) in enumerate(trace):
            tier = tiers[i % len(tiers)] if pin_tiers else None
            sess.submit(p, max_new=n, arrival=0, req_id=i, tier=tier)
        sess.run()
        return sess

    warm = serve()
    warm.warmup()                            # any program the trace missed
    before = scheduler_compile_stats()
    t0 = time.perf_counter()
    sess = serve()
    dt = time.perf_counter() - t0
    recompiles = sum(scheduler_compile_stats().values()) - sum(before.values())
    useful = sum(len(r.tokens) for r in sess.results.values())
    return useful / dt, sess.results, sess.stats, recompiles, dt


def modeled_tok_per_us(results) -> float:
    """Useful tokens per microsecond of modeled MAC critical-path time:
    every token is costed at the delay of the rung it was served at."""
    ns = sum(len(r.tokens) * tier_delay_ns(r.tier) for r in results.values())
    toks = sum(len(r.tokens) for r in results.values())
    return toks / ns * 1e3 if ns else 0.0


def _match_fraction(got, oracle) -> float:
    got, oracle = list(got), list(oracle)
    n = max(len(got), len(oracle))
    if n == 0:
        return 1.0
    m = min(len(got), len(oracle))
    return sum(int(a == b) for a, b in zip(got[:m], oracle[:m])) / n


def bench(requests: int = 24, num_slots: int = 4, seed: int = 0,
          shed_queue_depth: int = 4):
    from repro.models.transformer import init_params
    from repro.serve.scheduler import SchedulerStats

    cfg = _tiny_cfg()
    params = init_params(cfg, jax.random.PRNGKey(0))
    trace = build_trace(requests, cfg.vocab_size, seed=seed)

    base_tps, base_res, base_st, base_rc, base_dt = run_arm(
        cfg, params, trace, num_slots=num_slots)
    static_tps, static_res, static_st, static_rc, static_dt = run_arm(
        cfg, params, trace, tiers=TIERS, pin_tiers=True, num_slots=num_slots)
    shed_tps, shed_res, shed_st, shed_rc, shed_dt = run_arm(
        cfg, params, trace, tiers=TIERS, shed_queue_depth=shed_queue_depth,
        num_slots=num_slots)

    quality = {}
    for t in TIERS:
        fr = [_match_fraction(r.tokens, base_res[rid].tokens)
              for rid, r in static_res.items() if r.tier == t]
        quality[t] = {
            "requests": len(fr),
            "token_match_fraction": round(float(np.mean(fr)), 4) if fr else None,
            "modeled_delay_ns": tier_delay_ns(t),
        }
    shed_tier_tokens = {t: 0 for t in TIERS}
    for r in shed_res.values():
        shed_tier_tokens[r.tier] += len(r.tokens)

    base_model = modeled_tok_per_us(base_res)
    shed_model = modeled_tok_per_us(shed_res)
    return {
        "bench": "serve_tiers",
        "requests": requests,
        "seed": seed,
        "tiers": list(TIERS),
        "tier_multiplier": TIER_MULTIPLIER,
        "prompt_buckets": list(BUCKETS),
        "max_new_choices": list(NEW_CHOICES),
        "max_len": MAX_LEN,
        "block_size": BLOCK_SIZE,
        "num_slots": num_slots,
        "shed_queue_depth": shed_queue_depth,
        "useful_tokens": sum(len(r.tokens) for r in base_res.values()),
        "arms": {
            "exact_only": {
                "wall_tok_s": round(base_tps, 1),
                "modeled_mac_tok_per_us": round(base_model, 4),
                "ticks": base_st.ticks,
                "seconds": round(base_dt, 4),
            },
            "static_tiers": {
                "wall_tok_s": round(static_tps, 1),
                "modeled_mac_tok_per_us": round(
                    modeled_tok_per_us(static_res), 4),
                "ticks": static_st.ticks,
                "seconds": round(static_dt, 4),
                "quality_vs_exact_oracle": quality,
            },
            "shed": {
                "wall_tok_s": round(shed_tps, 1),
                "modeled_mac_tok_per_us": round(shed_model, 4),
                "modeled_speedup_vs_exact": round(
                    shed_model / base_model, 3) if base_model else None,
                "ticks": shed_st.ticks,
                "seconds": round(shed_dt, 4),
                "tier_demotions": shed_st.tier_demotions,
                "tier_restorations": shed_st.tier_restorations,
                "shed_level_final": shed_st.shed_level,
                "tokens_per_tier": shed_tier_tokens,
            },
        },
        "recompiles_after_warmup": base_rc + static_rc + shed_rc,
        "field_docs": dict(SchedulerStats.DOCS),
    }


def run(requests: int = 24):
    """benchmarks/run.py entry: (name, us_per_call, derived) rows."""
    r = bench(requests=requests)
    rows = []
    for name, arm in r["arms"].items():
        rows.append((
            f"serve/tiers_{name}", 1e6 / arm["wall_tok_s"],
            f"{arm['wall_tok_s']} tok/s wall, "
            f"{arm['modeled_mac_tok_per_us']} tok/us modeled, "
            f"recompiles={r['recompiles_after_warmup']}",
        ))
    return rows


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--requests", type=int, default=24)
    ap.add_argument("--num-slots", type=int, default=4)
    ap.add_argument("--shed-queue-depth", type=int, default=4)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--smoke", action="store_true",
                    help="miniature trace: exercises every arm and gate "
                         "without the full spike (CI gate for the harness)")
    ap.add_argument("--out", default="BENCH_serve_tiers.json")
    args = ap.parse_args()
    if args.smoke:
        args.requests = min(args.requests, 9)
        args.shed_queue_depth = min(args.shed_queue_depth, 2)
    r = bench(requests=args.requests, num_slots=args.num_slots,
              seed=args.seed, shed_queue_depth=args.shed_queue_depth)
    with open(args.out, "w") as f:
        json.dump(r, f, indent=2)
        f.write("\n")
    print(json.dumps({k: v for k, v in r.items() if k != "field_docs"},
                     indent=2))
    failures = []
    arms = r["arms"]
    if r["recompiles_after_warmup"]:
        failures.append(f"{r['recompiles_after_warmup']} recompiles after warmup")
    q = arms["static_tiers"]["quality_vs_exact_oracle"]
    if q["exact"]["token_match_fraction"] != 1.0:
        failures.append(
            f"exact-rung quality {q['exact']['token_match_fraction']} != 1.0 "
            "— mixed-tier batching is not bit-transparent")
    for t, row in q.items():
        f_ = row["token_match_fraction"]
        if f_ is None or not (0.0 <= f_ <= 1.0):
            failures.append(f"tier {t}: degenerate quality readout {f_}")
    if arms["shed"]["tier_demotions"] == 0:
        failures.append("spike never triggered the shedder")
    if arms["shed"]["modeled_mac_tok_per_us"] <= \
            arms["exact_only"]["modeled_mac_tok_per_us"]:
        failures.append(
            "shed arm modeled throughput "
            f"{arms['shed']['modeled_mac_tok_per_us']} <= exact_only "
            f"{arms['exact_only']['modeled_mac_tok_per_us']}")
    for msg in failures:
        print(f"FAIL: {msg}")
    raise SystemExit(1 if failures else 0)


if __name__ == "__main__":
    main()
