"""Self-speculative decoding: the approximate-multiplier path as a draft
model, measured against the exact non-speculative paged baseline.

Every arm serves the SAME trace through ``repro.serve.scheduler
.ServeSession`` (paged layout, identical buckets/pool/slots, greedy):

* **baseline** — exact non-speculative decode, one token per tick;
* **spec arms** — ``spec_decode=True``: each tick runs ``draft_k`` decode
  steps through the approximate path (same weights, only ``cfg.approx``
  swapped — see ``repro.serve.engine.draft_config``), then ONE exact
  verify pass over the ``draft_k + 1`` positions that accepts the longest
  matching prefix plus a correction token.  Outputs are bit-identical to
  the baseline by construction; the multiplier's error rate shows up ONLY
  in the accept rate (and therefore the speed), never in the tokens.

The headline readout is the paper's co-design angle: accept rate as a
function of the draft multiplier (mul8x8_2 vs mul8x8_3 under the
low-rank compensated path) — a lower-error multiplier drafts more
accepted tokens per verify.  An ``exact``-draft self-test arm (the draft
IS the verifier) must read accept_rate == 1.0 exactly.

The JSON artifact (``BENCH_serve_specdec.json``) records per-arm accept
rate, tokens/s, and verify counts, the cross-arm token-mismatch count
(must be 0 — asserted), the recompile count across the timed passes
(must be 0), and ``SchedulerStats.DOCS`` under ``field_docs`` so every
metric key is self-describing.

    PYTHONPATH=src python benchmarks/serve_specdec.py
    PYTHONPATH=src python benchmarks/serve_specdec.py --smoke --out /tmp/b.json
"""
from __future__ import annotations

import argparse
import dataclasses
import json
import time

import jax
import numpy as np

BUCKETS = (8, 16, 32)
NEW_CHOICES = (4, 8, 12, 16)
MAX_LEN = 64
BLOCK_SIZE = 8
DRAFT_ARMS = (("approx_lowrank", "mul8x8_2"), ("approx_lowrank", "mul8x8_3"))


def _tiny_cfg():
    from repro.configs import get_config, reduced_config

    return dataclasses.replace(
        reduced_config(get_config("granite-3-2b")),
        num_layers=4, d_model=256, num_heads=4, num_kv_heads=2, head_dim=64,
        d_ff=512, vocab_size=1024, remat=False, q_chunk=64, dtype="float32",
    )


def build_trace(n: int, vocab: int, seed: int = 0, rate: float = 1.0,
                max_new: int | None = None):
    """[(prompt, max_new, arrival_tick)] — mixed prompt lengths under the
    bucket set, Poisson-ish arrivals."""
    rng = np.random.default_rng(seed)
    choices = [c for c in NEW_CHOICES if max_new is None or c <= max_new]
    trace, t = [], 0
    for _ in range(n):
        t += int(rng.poisson(rate))
        prompt = rng.integers(0, vocab,
                              int(rng.integers(2, BUCKETS[-1] + 1))).astype(np.int32)
        trace.append((prompt, int(choices[rng.integers(len(choices))]), t))
    return trace


def run_arm(cfg, params, trace, *, spec: bool, draft_mode: str = "approx",
            multiplier: str = "mul8x8_2", draft_k: int = 4,
            num_slots: int = 4):
    """Warm pass (compiles the spec tick / decode tick and every prefill
    program), then a timed fresh-session pass.  Returns
    (tok/s, results, stats, recompiles, seconds)."""
    from repro.serve.scheduler import ServeSession, scheduler_compile_stats

    def serve():
        sess = ServeSession(
            cfg, params, num_slots=num_slots, max_len=MAX_LEN,
            prompt_buckets=BUCKETS, cache_layout="paged",
            block_size=BLOCK_SIZE, spec_decode=spec, draft_k=draft_k,
            draft_mode=draft_mode, draft_multiplier=multiplier,
        )
        for p, n, t in trace:
            sess.submit(p, max_new=n, arrival=t)
        sess.run()
        return sess

    warm = serve()
    warm.warmup()                            # any program the trace missed
    before = scheduler_compile_stats()
    t0 = time.perf_counter()
    sess = serve()
    dt = time.perf_counter() - t0
    recompiles = sum(scheduler_compile_stats().values()) - sum(before.values())
    useful = sum(len(r.tokens) for r in sess.results.values())
    return useful / dt, sess.results, sess.stats, recompiles, dt


def exact_draft_selftest(cfg, params, *, draft_k: int = 4):
    """``draft_mode="exact"``: the draft is the verifier, so every drafted
    token must survive.  max_new is a multiple of draft_k + 1, so no tick
    is clipped by end-of-request truncation and the accept rate must read
    exactly 1.0."""
    from repro.serve.scheduler import ServeSession

    rng = np.random.default_rng(7)
    sess = ServeSession(
        cfg, params, num_slots=2, max_len=MAX_LEN, prompt_buckets=BUCKETS,
        cache_layout="paged", block_size=BLOCK_SIZE, spec_decode=True,
        draft_k=draft_k, draft_mode="exact",
    )
    for i in range(4):
        p = rng.integers(0, cfg.vocab_size, int(rng.integers(2, 9)))
        sess.submit(p.astype(np.int32), max_new=2 * (draft_k + 1), req_id=i)
    sess.run(max_steps=10_000)
    return sess.stats.accept_rate


def bench(requests: int = 32, num_slots: int = 4, draft_k: int = 4,
          seed: int = 0, max_new: int | None = None):
    from repro.models.transformer import init_params
    from repro.serve.scheduler import SchedulerStats

    cfg = _tiny_cfg()
    params = init_params(cfg, jax.random.PRNGKey(0))
    trace = build_trace(requests, cfg.vocab_size, seed=seed, max_new=max_new)

    base_tps, base_res, base_st, base_rc, base_dt = run_arm(
        cfg, params, trace, spec=False, num_slots=num_slots,
    )
    mismatches = 0
    recompiles = base_rc
    arms = []
    for draft_mode, multiplier in DRAFT_ARMS:
        tps, res, st, rc, dt = run_arm(
            cfg, params, trace, spec=True, draft_mode=draft_mode,
            multiplier=multiplier, draft_k=draft_k, num_slots=num_slots,
        )
        mismatches += sum(
            not np.array_equal(base_res[rid].tokens, res[rid].tokens)
            for rid in base_res
        )
        recompiles += rc
        arms.append({
            "draft_mode": draft_mode,
            "multiplier": multiplier,
            "tok_s": round(tps, 1),
            "speedup_vs_baseline": round(tps / base_tps, 3),
            "accept_rate": round(st.accept_rate, 4),
            "accepted_tokens": st.accepted_tokens,
            "draft_tokens": st.draft_tokens,
            "verify_calls": st.verify_calls,
            "ticks": st.ticks,
            "seconds": round(dt, 4),
        })
    return {
        "bench": "serve_specdec",
        "requests": requests,
        "seed": seed,
        "draft_k": draft_k,
        "prompt_buckets": list(BUCKETS),
        "max_new_choices": [c for c in NEW_CHOICES
                            if max_new is None or c <= max_new],
        "max_len": MAX_LEN,
        "block_size": BLOCK_SIZE,
        "num_slots": num_slots,
        "useful_tokens": sum(len(r.tokens) for r in base_res.values()),
        "baseline_tok_s": round(base_tps, 1),
        "baseline_ticks": base_st.ticks,
        "baseline_s": round(base_dt, 4),
        "spec_arms": arms,
        "exact_draft_accept_rate": exact_draft_selftest(
            cfg, params, draft_k=draft_k),
        "token_mismatches": mismatches,
        "recompiles_after_warmup": recompiles,
        "field_docs": dict(SchedulerStats.DOCS),
    }


def run(requests: int = 32):
    """benchmarks/run.py entry: (name, us_per_call, derived) rows."""
    r = bench(requests=requests)
    rows = [(f"serve/specdec_baseline", 1e6 / r["baseline_tok_s"],
             f"{r['baseline_tok_s']} tok/s exact non-spec")]
    for arm in r["spec_arms"]:
        rows.append((
            f"serve/specdec_{arm['draft_mode']}_{arm['multiplier']}",
            1e6 / arm["tok_s"],
            f"{arm['tok_s']} tok/s accept={arm['accept_rate']} "
            f"({arm['accepted_tokens']}/{arm['draft_tokens']}), "
            f"mismatches={r['token_mismatches']}",
        ))
    return rows


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--requests", type=int, default=32)
    ap.add_argument("--num-slots", type=int, default=4)
    ap.add_argument("--draft-k", type=int, default=4)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--smoke", action="store_true",
                    help="miniature config: exercises every oracle without "
                         "the full trace (CI gate for the harness itself)")
    ap.add_argument("--out", default="BENCH_serve_specdec.json")
    args = ap.parse_args()
    max_new = None
    if args.smoke:
        args.requests = min(args.requests, 8)
        max_new = 8
    r = bench(requests=args.requests, num_slots=args.num_slots,
              draft_k=args.draft_k, seed=args.seed, max_new=max_new)
    with open(args.out, "w") as f:
        json.dump(r, f, indent=2)
        f.write("\n")
    print(json.dumps({k: v for k, v in r.items() if k != "field_docs"},
                     indent=2))
    failures = []
    if r["token_mismatches"]:
        failures.append(
            f"{r['token_mismatches']} request outputs differ from the exact "
            "baseline — the verify pass failed the exactness contract")
    if r["recompiles_after_warmup"]:
        failures.append(f"{r['recompiles_after_warmup']} recompiles after warmup")
    if r["exact_draft_accept_rate"] != 1.0:
        failures.append(
            f"exact-draft self-test accept rate "
            f"{r['exact_draft_accept_rate']} != 1.0")
    for arm in r["spec_arms"]:
        if not (0.0 <= arm["accept_rate"] <= 1.0) or arm["verify_calls"] <= 0:
            failures.append(f"arm {arm['multiplier']}: degenerate readout")
    for msg in failures:
        print(f"FAIL: {msg}")
    raise SystemExit(1 if failures else 0)


if __name__ == "__main__":
    main()
