"""Paged decode-attention: Pallas block-pool kernel vs the XLA block gather.

Two levels, one JSON artifact (``BENCH_attn_paged.json``):

* **kernel micro** — the attention compute alone (projections excluded from
  both arms), sweeping (B, context, block_size): the gather arm is the
  pure-JAX clamp-gather-mask math (``kernels.paged_attention.ref``, jit'd),
  the kernel arm is ``paged_attention_pallas``.  Off-TPU the kernel runs
  through the Pallas **interpreter**, so its wall clock measures the
  interpreter, not the hardware — the honest cross-platform metric is the
  analytic HBM KV traffic each arm implies, reported per call;
* **serve level** — the same mixed-length Poisson trace served through
  ``ServeSession(cache_layout="paged")`` under both ``attn_impl`` arms,
  with the exactness oracles asserted (bit-identical greedy tokens across
  arms, zero recompiles after warmup) and the per-tick KV traffic
  *instrumented from the live session*: the gather arm materializes the
  full ``(num_slots, W*block_size, Hkv, hd)`` transient per layer per
  decode step regardless of how short the resident contexts are, while the
  kernel reads exactly the blocks holding valid positions.  The headline
  ``hbm_bytes_ratio`` (gathered / in-place, mean over decode ticks) is
  therefore >= ``W * block_size / mean_context`` by construction — the
  table-width-vs-actual-context waste the kernel eliminates.

CPU wall-clock swings ~2x under contention (docs/serving.md §Benchmarks):
run timed benches alone; the byte accounting is deterministic either way.

    PYTHONPATH=src python benchmarks/attn_paged_kernel.py
    PYTHONPATH=src python benchmarks/attn_paged_kernel.py --requests 48
"""
from __future__ import annotations

import argparse
import dataclasses
import functools
import json
import time

import jax
import jax.numpy as jnp
import numpy as np

BUCKETS = (4, 8, 16)
NEW_CHOICES = (2, 4, 4, 8, 16, 48)
MAX_LEN = 64
BLOCK_SIZE = 8

FIELD_DOCS = {
    "micro": "per-(B, context, block_size) attention-only rows; *_us are "
             "post-compile medians (pallas arm interpreted off-TPU — see "
             "interpret_mode), *_kv_bytes are the analytic per-call KV "
             "reads each arm implies",
    "gathered_kv_bytes": "bytes the gather arm moves per call: the full "
                         "B x W x block_size x Hkv x hd K+V transient, "
                         "independent of the actual contexts",
    "inplace_kv_bytes": "bytes the kernel arm reads per call: only blocks "
                        "holding >= 1 valid position (sentinel/empty "
                        "blocks skipped by predicate)",
    "hbm_gathered_bytes_per_tick": "serve level: mean bytes/decode-tick of "
                                   "the per-layer K+V block gather the "
                                   "gather impl materializes (instrumented "
                                   "at the dispatch boundary, so same-step "
                                   "admissions are included)",
    "hbm_inplace_bytes_per_tick": "serve level: mean bytes/decode-tick the "
                                  "kernel reads for the same dispatches — "
                                  "blocks holding valid positions only "
                                  "(sentinel steps re-map to the last held "
                                  "block, so they issue no extra DMA)",
    "hbm_bytes_ratio": "gathered / in-place (the per-tick KV traffic the "
                       "kernel eliminates); >= table_width * block_size / "
                       "mean_context by construction",
    "floor_ratio": "table_width * block_size / mean_context — the lower "
                   "bound hbm_bytes_ratio must clear (equality iff every "
                   "slot were always occupied)",
    "mean_active": "mean resident requests per decode tick",
    "mean_context": "mean block-rounded context per resident request "
                    "(KV positions actually read by the kernel)",
    "token_mismatches": "requests whose greedy tokens differ between "
                        "attn_impl arms (must be 0)",
    "recompiles_after_warmup": "compile-count delta across the timed "
                               "pallas run (must be 0)",
    "interpret_mode": "True when the Pallas arm ran through the "
                      "interpreter (any non-TPU backend) — wall clocks "
                      "then measure the interpreter, trust the byte "
                      "fields",
}


def _tiny_cfg():
    from repro.configs import get_config, reduced_config
    from repro.serve.engine import resolve_execution_mode

    return dataclasses.replace(
        reduced_config(get_config("granite-3-2b")),
        num_layers=4, d_model=256, num_heads=4, num_kv_heads=2, head_dim=64,
        d_ff=512, vocab_size=1024, remat=False, q_chunk=64, dtype="float32",
        approx=resolve_execution_mode("exact"),
    )


def _time_med(fn, *args, reps: int = 5) -> float:
    """Median post-compile microseconds per call."""
    jax.block_until_ready(fn(*args))                     # compile
    ts = []
    for _ in range(reps):
        t0 = time.perf_counter()
        jax.block_until_ready(fn(*args))
        ts.append(time.perf_counter() - t0)
    return float(np.median(ts) * 1e6)


def micro_rows(seed: int = 0):
    """Attention-only sweep: each row builds a pool + tables whose rows sit
    at mixed contexts around ``context``, then times both arms."""
    from repro.kernels.paged_attention import (
        paged_attention_pallas,
        paged_attention_ref,
    )

    H, n_kv, hd = 4, 2, 64
    item = 4                                             # f32 pool
    rng = np.random.default_rng(seed)
    rows = []
    for B, context, bs in [(2, 24, 4), (2, 24, 8), (8, 24, 8),
                           (8, 56, 8), (4, 56, 4), (8, 40, 16)]:
        W = MAX_LEN // bs
        num_blocks = B * W
        q = jnp.asarray(rng.normal(size=(B, H, hd)), jnp.float32)
        kn = jnp.asarray(rng.normal(size=(B, n_kv, hd)), jnp.float32)
        vn = jnp.asarray(rng.normal(size=(B, n_kv, hd)), jnp.float32)
        kp = jnp.asarray(rng.normal(size=(num_blocks, bs, n_kv, hd)), jnp.float32)
        vp = jnp.asarray(rng.normal(size=(num_blocks, bs, n_kv, hd)), jnp.float32)
        cur = rng.integers(context // 2, context, (B,)).astype(np.int32)
        tbl = np.full((B, W), num_blocks, np.int32)
        free = list(range(num_blocks))
        for b in range(B):
            need = int(cur[b]) // bs + 1
            tbl[b, :need] = [free.pop() for _ in range(need)]
        tbl = jnp.asarray(tbl)
        curj = jnp.asarray(cur)

        ref_fn = jax.jit(functools.partial(paged_attention_ref, block_size=bs))
        pal_fn = functools.partial(paged_attention_pallas, block_size=bs)
        args = (q, kn, vn, kp, vp, tbl, curj)
        np.testing.assert_allclose(
            np.asarray(pal_fn(*args)), np.asarray(ref_fn(*args)),
            rtol=2e-5, atol=2e-5,
        )
        kv_row = n_kv * hd * item * 2                    # K + V, one position
        valid_blocks = int(sum(c // bs + 1 for c in cur))
        rows.append({
            "B": B, "context": context, "block_size": bs, "table_width": W,
            "gather_us": round(_time_med(ref_fn, *args), 1),
            "pallas_us": round(_time_med(pal_fn, *args), 1),
            "gathered_kv_bytes": B * W * bs * kv_row,
            "inplace_kv_bytes": valid_blocks * bs * kv_row,
            "bytes_ratio": round(B * W / valid_blocks, 3),
        })
    return rows


def build_trace(n: int, vocab: int, seed: int = 0, rate: float = 1.0):
    rng = np.random.default_rng(seed)
    trace, t = [], 0
    for _ in range(n):
        t += int(rng.poisson(rate))
        plen = int(rng.integers(2, BUCKETS[-1] + 1))
        trace.append((
            rng.integers(0, vocab, plen).astype(np.int32),
            int(NEW_CHOICES[rng.integers(len(NEW_CHOICES))]),
            t,
        ))
    return trace


class _DispatchSpy:
    """Wraps the scheduler's decode-tick entry point to record the exact
    ``active``/``cur_len`` operands of every dispatched chunk — the rows the
    tick actually attends, including requests admitted earlier in the SAME
    ``step()`` (snapshotting around ``step()`` would miss them: the sync
    loop admits before it decodes).  Forwards ``_cache_size`` so the
    recompile accounting sees through the wrapper."""

    def __init__(self, inner):
        self.inner = inner
        self.dispatches = []                     # (active mask, cur_len)

    def __call__(self, **kw):
        self.dispatches.append(
            (np.asarray(kw["active"]).copy(), np.asarray(kw["cur_len"]).copy())
        )
        return self.inner(**kw)

    def _cache_size(self):
        return self.inner._cache_size()


def serve_arm(cfg, params, trace, *, attn_impl: str, num_slots: int = 6):
    """Sync-loop serve pass (steps_per_tick=1 so one dispatch == one tick):
    returns (tok/s, results, recompiles, and per-tick
    [gathered_bytes, inplace_bytes, n_active, context_rows])."""
    from repro.serve import scheduler as S

    def make():
        sess = S.ServeSession(
            cfg, params, num_slots=num_slots, max_len=MAX_LEN,
            prompt_buckets=BUCKETS, cache_layout="paged",
            block_size=BLOCK_SIZE, loop="sync", steps_per_tick=1,
            attn_impl=attn_impl,
        )
        for p, n, t in trace:
            sess.submit(p, max_new=n, arrival=t)
        return sess

    warm = make()
    warm.run()
    warm.warmup()
    before = S.scheduler_compile_stats()

    sess = make()
    spy = _DispatchSpy(S._decode_tick_jit)
    S._decode_tick_jit = spy
    try:
        t0 = time.perf_counter()
        sess.run()
        dt = time.perf_counter() - t0
    finally:
        S._decode_tick_jit = spy.inner
    recompiles = sum(S.scheduler_compile_stats().values()) - sum(before.values())
    useful = sum(len(r.tokens) for r in sess.results.values())

    # bytes one KV position costs across K + V and every layer
    kv_row = cfg.num_kv_heads * cfg.head_dim * \
        jnp.dtype(sess.cache_dtype).itemsize * 2 * cfg.num_layers
    W = sess.table_width
    ticks = []
    for active, cur_len in spy.dispatches:
        # this chunk attended positions [0, cur_len] per active row: the
        # gather impl materializes every table row in full, the kernel
        # reads only blocks holding >= 1 valid position
        rows = sum(
            (int(cur_len[i]) // BLOCK_SIZE + 1) * BLOCK_SIZE
            for i in np.flatnonzero(active)
        )
        ticks.append((
            num_slots * W * BLOCK_SIZE * kv_row,     # gathered bytes
            rows * kv_row,                            # in-place bytes
            int(active.sum()),
            rows,
        ))
    return useful / dt, sess.results, recompiles, ticks


def bench(requests: int = 48, seed: int = 0):
    from repro.kernels.interpret import default_interpret
    from repro.models.transformer import init_params

    cfg = _tiny_cfg()
    params = init_params(cfg, jax.random.PRNGKey(0))
    trace = build_trace(requests, cfg.vocab_size, seed=seed)

    g_tps, g_res, _, ticks = serve_arm(cfg, params, trace, attn_impl="gather")
    p_tps, p_res, recompiles, _ = serve_arm(cfg, params, trace, attn_impl="pallas")

    mismatches = sum(
        not np.array_equal(g_res[rid].tokens, p_res[rid].tokens)
        for rid in g_res
    )
    gathered = float(np.mean([t[0] for t in ticks]))
    inplace = float(np.mean([t[1] for t in ticks]))
    mean_active = float(np.mean([t[2] for t in ticks]))
    mean_rows = float(np.mean([t[3] for t in ticks]))
    # mean resident context per active row (block-rounded KV positions)
    mean_context = mean_rows / mean_active
    W = MAX_LEN // BLOCK_SIZE
    interpret = default_interpret()
    return {
        "bench": "attn_paged_kernel",
        "requests": requests,
        "seed": seed,
        "prompt_buckets": list(BUCKETS),
        "max_new_choices": list(NEW_CHOICES),
        "max_len": MAX_LEN,
        "block_size": BLOCK_SIZE,
        "table_width": W,
        "interpret_mode": interpret,
        "micro": micro_rows(seed),
        "serve_gather_tok_s": round(g_tps, 1),
        "serve_pallas_tok_s": round(p_tps, 1),
        "hbm_gathered_bytes_per_tick": int(gathered),
        "hbm_inplace_bytes_per_tick": int(inplace),
        "hbm_bytes_ratio": round(gathered / inplace, 3),
        "mean_active": round(mean_active, 2),
        "mean_context": round(mean_context, 1),
        "floor_ratio": round(W * BLOCK_SIZE / mean_context, 3),
        "token_mismatches": mismatches,
        "recompiles_after_warmup": recompiles,
        "field_docs": dict(FIELD_DOCS),
    }


def run(requests: int = 32):
    """benchmarks/run.py entry: (name, us_per_call, derived) rows."""
    r = bench(requests=requests)
    return [
        ("serve/attn_paged_gather", 1e6 / r["serve_gather_tok_s"],
         f"{r['serve_gather_tok_s']} tok/s"),
        ("serve/attn_paged_pallas", 1e6 / r["serve_pallas_tok_s"],
         f"{r['serve_pallas_tok_s']} tok/s (interpret={r['interpret_mode']})"),
        ("serve/attn_paged_hbm_ratio", 0.0,
         f"{r['hbm_bytes_ratio']}x KV traffic eliminated, "
         f"mismatches={r['token_mismatches']}"),
    ]


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--requests", type=int, default=48)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--out", default="BENCH_attn_paged.json")
    args = ap.parse_args()
    r = bench(requests=args.requests, seed=args.seed)
    with open(args.out, "w") as f:
        json.dump(r, f, indent=2)
        f.write("\n")
    print(json.dumps({k: v for k, v in r.items() if k != "field_docs"}, indent=2))
    # exactness oracles fail the run (CI gates on this); perf floors warn
    if r["token_mismatches"]:
        raise SystemExit(
            f"FAIL: {r['token_mismatches']} requests differ between impls")
    if r["recompiles_after_warmup"]:
        raise SystemExit(
            f"FAIL: {r['recompiles_after_warmup']} recompiles after warmup")
    if r["hbm_bytes_ratio"] < r["floor_ratio"]:
        print(f"WARNING: hbm_bytes_ratio {r['hbm_bytes_ratio']} below the "
              f"W*block_size/context floor {r['floor_ratio']}")


if __name__ == "__main__":
    main()
