"""Async double-buffered serve loop vs the PR-3 synchronous loop.

Both arms drive the SAME bursty long-prompt Poisson trace through
``repro.serve.scheduler.ServeSession`` on the paged KV cache — identical
model, buckets, decode chunking, slots, and sampling; the only difference
is the host loop:

* **sync** — the PR-3 baseline: dispatch one decode chunk, block on its
  tokens, bookkeep, repeat; every admission additionally blocks on its
  prefill before the next chunk can launch;
* **async** — the double-buffered pipeline: chunk N+1 (and any admits,
  whose first tokens merge into the device-resident carry) is dispatched
  *before* the host blocks on chunk N, so queue management, admission and
  finish bookkeeping overlap device compute.

The trace is the regime the async loop exists for: a steady decode-heavy
background stream (short prompts, long ``max_new``) punctured by clumps of
long prompts (large buckets, short ``max_new``) that make the sync loop
stall on prefill trains.  A third arm re-runs the async loop with
``prefill_decode_ratio`` to report the starvation story: the
``max_decode_gap_ticks`` gauge drops while outputs stay bit-identical.

The JSON artifact (``BENCH_serve_async.json``) records per-arm useful
tokens/s (best of ``--repeats`` fresh runs — CPU timings swing ~2x under
contention, so run timed benches alone), the async/sync speedup, the
cross-loop token-mismatch count (must be 0), a standalone-``generate``
oracle over a subset of requests (must be 0 mismatches), the recompile
count across every timed pass (must be 0), and ``SchedulerStats.DOCS``
under ``field_docs`` so every metric key is self-describing.

    PYTHONPATH=src python benchmarks/serve_async.py
    PYTHONPATH=src python benchmarks/serve_async.py --smoke --out /tmp/b.json
"""
from __future__ import annotations

import argparse
import dataclasses
import json
import math
import time

import jax
import numpy as np

BUCKETS = (8, 16, 32)
MAX_LEN = 96
BLOCK_SIZE = 8
ORACLE_REQUESTS = 6       # standalone-generate checks (one compile per shape)


def _tiny_cfg(exec_mode: str = "exact"):
    from repro.configs import get_config, reduced_config
    from repro.serve.engine import resolve_execution_mode

    # small enough that host scheduling is a visible fraction of a decode
    # chunk — the regime where the loops differ; the loops' relative cost
    # model is the same at serving scale, where the host gap per chunk is
    # hidden the same way
    return dataclasses.replace(
        reduced_config(get_config("granite-3-2b")),
        num_layers=2, d_model=128, num_heads=2, num_kv_heads=1, head_dim=64,
        d_ff=256, vocab_size=1024, remat=False, q_chunk=64, dtype="float32",
        approx=resolve_execution_mode(exec_mode),
    )


def build_trace(n: int, vocab: int, seed: int = 0):
    """[(prompt, max_new, arrival_tick)]: a Poisson decode-heavy background
    stream with every 8th..6th request replaced by a clump of long prompts
    arriving together — the burst that starves decodes under a greedy
    admission policy and stalls the sync loop on prefill trains."""
    rng = np.random.default_rng(seed)
    trace, t = [], 0
    for i in range(n):
        if i % 8 < 5:        # background: short prompt, decode-heavy
            t += int(rng.poisson(2.0))
            plen = int(rng.integers(2, 9))
            max_new = int(rng.integers(24, 49))
        else:                # burst member: long prompt, clumped arrival
            plen = int(rng.integers(20, 33))
            max_new = int(rng.integers(8, 17))
        trace.append((rng.integers(0, vocab, plen).astype(np.int32), max_new, t))
    return trace


def _server(cfg, params, trace, *, loop: str, num_slots: int,
            steps_per_tick: int, ratio=None):
    from repro.serve.scheduler import ServeSession

    def serve():
        sess = ServeSession(
            cfg, params, num_slots=num_slots, max_len=MAX_LEN,
            prompt_buckets=BUCKETS, steps_per_tick=steps_per_tick,
            cache_layout="paged", block_size=BLOCK_SIZE, loop=loop,
            prefill_decode_ratio=ratio,
        )
        for p, n, t in trace:
            sess.submit(p, max_new=n, arrival=t)
        sess.run()
        return sess

    return serve


def run_arms(cfg, params, trace, arms, *, repeats: int = 3):
    """Warm every arm (compiles every program via warmup()), then run
    ``repeats`` timed fresh-session passes per arm INTERLEAVED round-robin —
    a CPU contention episode then taxes every arm instead of whichever one
    happened to be on the clock — and keep each arm's best pass.  Returns
    ({name: (tok/s, results, stats, best_s)}, recompiles across every timed
    pass)."""
    from repro.serve.scheduler import scheduler_compile_stats

    servers = {name: _server(cfg, params, trace, **kw) for name, kw in arms}
    for serve in servers.values():
        serve().warmup()                     # any program the trace missed
    before = scheduler_compile_stats()
    best = {}
    for _ in range(max(1, repeats)):
        for name, serve in servers.items():
            t0 = time.perf_counter()
            sess = serve()
            dt = time.perf_counter() - t0
            if name not in best or dt < best[name][1]:
                best[name] = (sess, dt)
    recompiles = sum(scheduler_compile_stats().values()) - sum(before.values())
    out = {}
    for name, (sess, dt) in best.items():
        useful = sum(len(r.tokens) for r in sess.results.values())
        out[name] = (useful / dt, sess.results, sess.stats, dt)
    return out, recompiles


def bench(exec_mode: str = "exact", requests: int = 48, seed: int = 0,
          num_slots: int = 8, steps_per_tick: int = 1, repeats: int = 3,
          ratio: float = 1.0, oracle: int = ORACLE_REQUESTS):
    from repro.models.transformer import init_params
    from repro.serve.engine import generate
    from repro.serve.scheduler import SchedulerStats

    cfg = _tiny_cfg(exec_mode)
    params = init_params(cfg, jax.random.PRNGKey(0))
    trace = build_trace(requests, cfg.vocab_size, seed=seed)
    shape = dict(num_slots=num_slots, steps_per_tick=steps_per_tick)

    out, recompiles = run_arms(
        cfg, params, trace,
        [("sync", dict(loop="sync", **shape)),
         ("async", dict(loop="async", **shape)),
         ("ratio", dict(loop="async", ratio=ratio, **shape))],
        repeats=repeats,
    )
    sync_tps, sync_res, sync_st, sync_dt = out["sync"]
    async_tps, async_res, async_st, async_dt = out["async"]
    ratio_tps, ratio_res, ratio_st, _ = out["ratio"]

    # cross-loop parity: the pipeline may only move WHEN the host learns
    # about tokens, never the tokens themselves
    mismatches = sum(
        not np.array_equal(sync_res[rid].tokens, async_res[rid].tokens)
        for rid in sync_res
    )
    policy_mismatches = sum(
        not np.array_equal(async_res[rid].tokens, ratio_res[rid].tokens)
        for rid in async_res
    )
    # standalone-generate oracle over a subset (one compile per shape)
    oracle_mismatches = 0
    oracle_ids = sorted(async_res)[:oracle]
    for rid in oracle_ids:
        p, n, _ = trace[rid]
        alone = np.asarray(
            generate(cfg, params, p[None, :], max_new=n)
        )[0, len(p):]
        oracle_mismatches += not np.array_equal(alone, async_res[rid].tokens)

    useful = sum(len(r.tokens) for r in sync_res.values())
    return {
        "bench": "serve_async",
        "exec_mode": exec_mode,
        "requests": requests,
        "seed": seed,
        "num_slots": num_slots,
        "steps_per_tick": steps_per_tick,
        "repeats_best_of": repeats,
        "prompt_buckets": list(BUCKETS),
        "max_len": MAX_LEN,
        "block_size": BLOCK_SIZE,
        "cache_layout": "paged",
        "useful_tokens": useful,
        "sync_tok_s": round(sync_tps, 1),
        "async_tok_s": round(async_tps, 1),
        "speedup": round(async_tps / sync_tps, 3),
        "sync_overlap_fraction": round(sync_st.overlap_fraction, 3),
        "async_overlap_fraction": round(async_st.overlap_fraction, 3),
        "sync_ticks": sync_st.ticks,
        "async_ticks": async_st.ticks,
        "token_mismatches": mismatches,
        "oracle_requests": len(oracle_ids),
        "oracle_mismatches": oracle_mismatches,
        "recompiles_after_warmup": recompiles,
        "sync_s": round(sync_dt, 4),
        "async_s": round(async_dt, 4),
        # interleaving-policy arm: same trace, rate-limited admission
        "prefill_decode_ratio": ratio,
        "ratio_tok_s": round(ratio_tps, 1),
        "free_max_decode_gap_ticks": async_st.max_decode_gap_ticks,
        "ratio_max_decode_gap_ticks": ratio_st.max_decode_gap_ticks,
        "ratio_gap_bound": steps_per_tick + math.ceil(ratio * steps_per_tick),
        "ratio_prefill_stall_ticks": ratio_st.prefill_stall_ticks,
        "policy_token_mismatches": policy_mismatches,
        "field_docs": dict(SchedulerStats.DOCS),
    }


def run(exec_mode: str = "exact", requests: int = 48):
    """benchmarks/run.py entry: (name, us_per_call, derived) rows."""
    r = bench(exec_mode=exec_mode, requests=requests)
    return [
        (f"serve/async_{exec_mode}", 1e6 / r["async_tok_s"],
         f"{r['async_tok_s']} tok/s overlap={r['async_overlap_fraction']}"),
        (f"serve/sync_baseline_{exec_mode}", 1e6 / r["sync_tok_s"],
         f"{r['sync_tok_s']} tok/s overlap={r['sync_overlap_fraction']}"),
        (f"serve/async_speedup_{exec_mode}", 0.0,
         f"{r['speedup']}x, mismatches={r['token_mismatches']}, "
         f"gap {r['free_max_decode_gap_ticks']}->{r['ratio_max_decode_gap_ticks']} ticks"),
    ]


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--exec", dest="exec_mode", default="exact",
                    choices=("exact", "exact_quant", "approx", "approx_lowrank"))
    ap.add_argument("--requests", type=int, default=48)
    ap.add_argument("--num-slots", type=int, default=8)
    ap.add_argument("--steps", type=int, default=1,
                    help="decode-chunk size (steps per dispatch; 1 is where "
                         "per-dispatch host overhead bites hardest — the "
                         "regime the async loop hides)")
    ap.add_argument("--repeats", type=int, default=5,
                    help="timed passes per arm; best-of wins (contention guard)")
    ap.add_argument("--ratio", type=float, default=1.0,
                    help="prefill_decode_ratio for the interleaving arm")
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--smoke", action="store_true",
                    help="CI-sized run: small trace, single repeat — checks "
                         "machinery (parity/recompiles), not the speedup bar")
    ap.add_argument("--out", default="BENCH_serve_async.json")
    args = ap.parse_args()
    kw = dict(exec_mode=args.exec_mode, requests=args.requests,
              seed=args.seed, num_slots=args.num_slots,
              steps_per_tick=args.steps, repeats=args.repeats,
              ratio=args.ratio)
    if args.smoke:
        kw.update(requests=16, repeats=1, oracle=3)
    r = bench(**kw)
    with open(args.out, "w") as f:
        json.dump(r, f, indent=2)
        f.write("\n")
    print(json.dumps({k: v for k, v in r.items() if k != "field_docs"}, indent=2))
    failures = []
    if r["token_mismatches"] or r["policy_token_mismatches"]:
        failures.append(f"{r['token_mismatches']} sync/async + "
                        f"{r['policy_token_mismatches']} policy token mismatches")
    if r["oracle_mismatches"]:
        failures.append(f"{r['oracle_mismatches']} standalone-generate mismatches")
    if r["recompiles_after_warmup"]:
        failures.append(f"{r['recompiles_after_warmup']} recompiles after warmup")
    if r["ratio_max_decode_gap_ticks"] > r["ratio_gap_bound"]:
        failures.append(
            f"starvation gauge {r['ratio_max_decode_gap_ticks']} exceeds the "
            f"policy bound {r['ratio_gap_bound']}")
    for msg in failures:
        print(f"FAIL: {msg}")
    if not args.smoke and r["speedup"] < 1.15:
        print(f"WARNING: async speedup {r['speedup']}x < 1.15x target "
              "(contended machine? run solo)")
    if failures:
        raise SystemExit(1)


if __name__ == "__main__":
    main()
