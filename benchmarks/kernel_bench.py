"""Approx-matmul implementation comparison: paper-faithful LUT gather vs
exact+low-rank-correction (XLA) vs exact-quant vs float, on CPU wall time.

The absolute CPU numbers are not TPU projections; the point is (a) the LUT
mechanical port is catastrophically slower at identical semantics, and
(b) the lowrank path tracks the exact-quant path within the (1+F) factor.
The TPU-projected numbers live in EXPERIMENTS.md §Perf (from the dry-run).
"""
from __future__ import annotations

import time
from typing import List, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.approx import ApproxConfig, quantized_matmul


def _time(fn, *args, iters=5):
    jax.block_until_ready(fn(*args))   # compile + warm
    t0 = time.perf_counter()
    for _ in range(iters):
        jax.block_until_ready(fn(*args))
    return (time.perf_counter() - t0) / iters * 1e6


def run() -> List[Tuple[str, float, str]]:
    rows = []
    rng = np.random.default_rng(0)
    M_, K_, N_ = 256, 512, 256
    a = jnp.asarray(rng.integers(0, 256, (M_, K_)), jnp.uint8)
    b = jnp.asarray(rng.integers(0, 256, (K_, N_)), jnp.uint8)
    outs = {}
    for mode in ("exact_quant", "lut", "lowrank"):
        cfg = ApproxConfig(multiplier="mul8x8_2", mode=mode)
        f = jax.jit(lambda a, b, c=cfg: quantized_matmul(a, b, c))
        us = _time(f, a, b)
        outs[mode] = np.asarray(f(a, b))
        rows.append(
            (f"kernel/{mode}_matmul_{M_}x{K_}x{N_}", us,
             f"{2*M_*K_*N_/us/1e3:.2f} GFLOP/s-equiv")
        )
    # bit-exactness of lowrank vs lut at these sizes
    match = bool(np.array_equal(outs["lut"], outs["lowrank"].astype(outs["lut"].dtype)))
    rows.append(("kernel/lowrank_bitexact_vs_lut", 0.0, f"equal={match}"))

    # range-pruned variant (co-optimized weights < 32): F=6 -> 3
    bw = jnp.asarray(rng.integers(0, 32, (K_, N_)), jnp.uint8)
    cfgp = ApproxConfig(multiplier="mul8x8_2", mode="lowrank", w_qmax=31)
    fp = jax.jit(lambda a, b: quantized_matmul(a, b, cfgp))
    us = _time(fp, a, bw)
    rows.append((f"kernel/lowrank_pruned_matmul_{M_}x{K_}x{N_}", us, "F=3 (weights<32)"))

    # Pallas kernel (interpret mode on CPU: correctness-representative only)
    from repro.kernels.approx_matmul.ops import approx_matmul_pallas

    fpal = jax.jit(
        lambda a, b: approx_matmul_pallas(a, b, multiplier="mul8x8_2", interpret=True)
    )
    us = _time(fpal, a, b, iters=2)
    ok = bool(np.array_equal(np.asarray(fpal(a, b)), outs["lut"]))
    rows.append((f"kernel/pallas_interpret_{M_}x{K_}x{N_}", us, f"bitexact={ok}"))

    rows.extend(_decode_bench())
    return rows


def _decode_bench(batch: int = 8, prompt_len: int = 8, max_new: int = 32):
    """Decode throughput: legacy per-token Python loop vs the single-jit
    scan engine, same tiny model (float mode isolates dispatch overhead)."""
    import dataclasses

    from repro.configs import get_config, reduced_config
    from repro.models.transformer import init_params
    from repro.serve.engine import generate, greedy_generate_legacy

    cfg = dataclasses.replace(
        reduced_config(get_config("granite-3-2b")), remat=False, q_chunk=64
    )
    params = init_params(cfg, jax.random.PRNGKey(0))
    prompt = jax.random.randint(
        jax.random.PRNGKey(1), (batch, prompt_len), 0, cfg.vocab_size
    )

    def legacy():
        return greedy_generate_legacy(cfg, params, prompt, max_new=max_new)

    def scan():
        return generate(cfg, params, prompt, max_new=max_new)

    rows = []
    for name, fn in (("legacy_loop", legacy), ("scan_engine", scan)):
        jax.block_until_ready(fn())              # compile + warm
        t0 = time.perf_counter()
        iters = 3
        for _ in range(iters):
            jax.block_until_ready(fn())
        dt = (time.perf_counter() - t0) / iters
        rows.append(
            (f"serve/decode_{name}_b{batch}_n{max_new}", dt * 1e6,
             f"{batch * max_new / dt:.1f} tok/s")
        )
    return rows
