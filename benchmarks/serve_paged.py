"""Paged block-table KV cache vs slot stripes at an EQUAL KV-memory budget.

Both arms serve the same Poisson mixed-length trace through
``repro.serve.scheduler.ServeSession`` with identical prompt buckets,
decode chunking, and sampling; the only difference is how the same number
of KV rows is organized:

* **slots** — ``num_slots = budget_rows / max_len`` fixed stripes: every
  resident request reserves the worst case, so concurrency is capped at
  ``budget_rows / max_len`` no matter how short the requests are;
* **paged** — the same ``budget_rows`` sliced into ``block_size``-row
  blocks handed out by *actual* context length, with ``num_slots`` (decode
  width) raised past the stripe bound.  Mixed traffic then packs more
  concurrent requests into the same HBM, which is what keeps the
  approximate-multiplier matmuls saturated (PAPER.md §IV).

The JSON artifact (``BENCH_serve_paged.json``) records per-arm useful
tokens/s, peak concurrency, latency percentiles, the concurrency ratio at
equal memory, and the recompile count across the timed paged run (must be
0).  Both arms must produce bit-identical greedy tokens per request — the
cross-engine parity oracle is asserted, not sampled.

    PYTHONPATH=src python benchmarks/serve_paged.py
    PYTHONPATH=src python benchmarks/serve_paged.py --requests 48 --slot-slots 4
"""
from __future__ import annotations

import argparse
import dataclasses
import json
import time

import jax
import numpy as np

BUCKETS = (4, 8, 16)
# heavy-tailed budgets: short requests dominate, so worst-case stripes
# strand most of their reservation — the regime paging is for
NEW_CHOICES = (2, 4, 4, 8, 16, 48)
MAX_LEN = 64
BLOCK_SIZE = 8


def _tiny_cfg(exec_mode: str = "exact"):
    from repro.configs import get_config, reduced_config
    from repro.serve.engine import resolve_execution_mode

    return dataclasses.replace(
        reduced_config(get_config("granite-3-2b")),
        num_layers=4, d_model=256, num_heads=4, num_kv_heads=2, head_dim=64,
        d_ff=512, vocab_size=1024, remat=False, q_chunk=64, dtype="float32",
        approx=resolve_execution_mode(exec_mode),
    )


def build_trace(n: int, vocab: int, seed: int = 0, rate: float = 1.0):
    """[(prompt, max_new, arrival_tick)] — Poisson arrival gaps, mixed
    prompt lengths, heavy-tailed generation budgets."""
    rng = np.random.default_rng(seed)
    trace, t = [], 0
    for _ in range(n):
        t += int(rng.poisson(rate))
        plen = int(rng.integers(2, BUCKETS[-1] + 1))
        trace.append((
            rng.integers(0, vocab, plen).astype(np.int32),
            int(NEW_CHOICES[rng.integers(len(NEW_CHOICES))]),
            t,
        ))
    return trace


def run_arm(cfg, params, trace, *, layout: str, num_slots: int,
            num_blocks=None, steps_per_tick: int = 4, policy: str = "priority"):
    """Warm pass (compiles every program), then a timed fresh-session pass.
    Returns (tokens_per_s, results, stats, recompiles, elapsed_s)."""
    from repro.serve.scheduler import ServeSession, scheduler_compile_stats

    def serve():
        sess = ServeSession(
            cfg, params, num_slots=num_slots, max_len=MAX_LEN,
            prompt_buckets=BUCKETS, steps_per_tick=steps_per_tick,
            cache_layout=layout, block_size=BLOCK_SIZE,
            num_blocks=num_blocks, policy=policy,
        )
        for p, n, t in trace:
            sess.submit(p, max_new=n, arrival=t)
        sess.run()
        return sess

    warm = serve()
    warm.warmup()                            # any program the trace missed
    before = scheduler_compile_stats()
    t0 = time.perf_counter()
    sess = serve()
    dt = time.perf_counter() - t0
    recompiles = sum(scheduler_compile_stats().values()) - sum(before.values())
    useful = sum(len(r.tokens) for r in sess.results.values())
    return useful / dt, sess.results, sess.stats, recompiles, dt


def bench(exec_mode: str = "exact", requests: int = 64, slot_slots: int = 4,
          paged_slots: int = 12, seed: int = 0, steps_per_tick: int = 4,
          policy: str = "priority"):
    from repro.models.transformer import init_params

    cfg = _tiny_cfg(exec_mode)
    params = init_params(cfg, jax.random.PRNGKey(0))
    trace = build_trace(requests, cfg.vocab_size, seed=seed)

    budget_rows = slot_slots * MAX_LEN           # KV rows per layer, per arm
    slot_tps, slot_res, slot_st, _, slot_dt = run_arm(
        cfg, params, trace, layout="slots", num_slots=slot_slots,
        steps_per_tick=steps_per_tick, policy=policy,
    )
    paged_tps, paged_res, paged_st, recompiles, paged_dt = run_arm(
        cfg, params, trace, layout="paged", num_slots=paged_slots,
        num_blocks=budget_rows // BLOCK_SIZE,
        steps_per_tick=steps_per_tick, policy=policy,
    )

    # cross-engine parity oracle: same trace, bit-identical greedy tokens
    mismatches = sum(
        not np.array_equal(slot_res[rid].tokens, paged_res[rid].tokens)
        for rid in slot_res
    )
    useful = sum(len(r.tokens) for r in slot_res.values())
    return {
        "bench": "serve_paged",
        "exec_mode": exec_mode,
        "requests": requests,
        "seed": seed,
        "steps_per_tick": steps_per_tick,
        "policy": policy,
        "prompt_buckets": list(BUCKETS),
        "max_new_choices": list(NEW_CHOICES),
        "max_len": MAX_LEN,
        "block_size": BLOCK_SIZE,
        "kv_budget_rows": budget_rows,
        "slot_num_slots": slot_slots,
        "paged_num_slots": paged_slots,
        "paged_num_blocks": budget_rows // BLOCK_SIZE,
        "useful_tokens": useful,
        "slot_tok_s": round(slot_tps, 1),
        "paged_tok_s": round(paged_tps, 1),
        "speedup": round(paged_tps / slot_tps, 3),
        "slot_peak_concurrent": slot_st.peak_active,
        "paged_peak_concurrent": paged_st.peak_active,
        "concurrency_ratio": round(paged_st.peak_active / slot_st.peak_active, 3),
        "paged_peak_blocks": paged_st.peak_blocks_in_use,
        "slot_latency_p50": slot_st.latency_p50,
        "slot_latency_p95": slot_st.latency_p95,
        "paged_latency_p50": paged_st.latency_p50,
        "paged_latency_p95": paged_st.latency_p95,
        "token_mismatches": mismatches,
        "recompiles_after_warmup": recompiles,
        "slot_s": round(slot_dt, 4),
        "paged_s": round(paged_dt, 4),
    }


def run(exec_mode: str = "exact", requests: int = 64):
    """benchmarks/run.py entry: (name, us_per_call, derived) rows."""
    r = bench(exec_mode=exec_mode, requests=requests)
    return [
        (f"serve/paged_{exec_mode}", 1e6 / r["paged_tok_s"],
         f"{r['paged_tok_s']} tok/s peak={r['paged_peak_concurrent']} req"),
        (f"serve/slot_equal_mem_{exec_mode}", 1e6 / r["slot_tok_s"],
         f"{r['slot_tok_s']} tok/s peak={r['slot_peak_concurrent']} req"),
        (f"serve/paged_concurrency_{exec_mode}", 0.0,
         f"{r['concurrency_ratio']}x at {r['kv_budget_rows']} KV rows, "
         f"mismatches={r['token_mismatches']}"),
    ]


def main():
    from repro.serve.scheduler import ADMISSION_POLICIES

    ap = argparse.ArgumentParser()
    ap.add_argument("--exec", dest="exec_mode", default="exact",
                    choices=("exact", "exact_quant", "approx", "approx_lowrank"))
    ap.add_argument("--requests", type=int, default=64)
    ap.add_argument("--slot-slots", type=int, default=4,
                    help="slot arm width; fixes the KV budget at "
                         "slot_slots * max_len rows")
    ap.add_argument("--paged-slots", type=int, default=12,
                    help="paged arm decode width (memory stays at the "
                         "slot arm's budget)")
    ap.add_argument("--steps", type=int, default=4,
                    help="decode-chunk size (steps per dispatch)")
    ap.add_argument("--policy", default="priority", choices=ADMISSION_POLICIES)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--out", default="BENCH_serve_paged.json")
    args = ap.parse_args()
    r = bench(exec_mode=args.exec_mode, requests=args.requests,
              slot_slots=args.slot_slots, paged_slots=args.paged_slots,
              seed=args.seed, steps_per_tick=args.steps, policy=args.policy)
    with open(args.out, "w") as f:
        json.dump(r, f, indent=2)
        f.write("\n")
    print(json.dumps(r, indent=2))
    if r["token_mismatches"]:
        print(f"WARNING: {r['token_mismatches']} requests differ between arms")
    if r["concurrency_ratio"] < 1.3 and r["speedup"] < 1.0:
        print(f"WARNING: concurrency {r['concurrency_ratio']}x < 1.3x and "
              f"speedup {r['speedup']}x < 1.0x at equal KV memory")
    if r["recompiles_after_warmup"]:
        print(f"WARNING: {r['recompiles_after_warmup']} recompiles after warmup")


if __name__ == "__main__":
    main()
