"""Chunked prefill vs one-shot prefill on a bursty long-prompt trace.

Both arms drive the SAME trace through ``repro.serve.scheduler.ServeSession``
(paged cache, async loop) at the SAME interleaving budget
(``prefill_decode_ratio``); the only difference is ``chunked_prefill``:

* **unchunked** — a long prompt prefills in ONE bucket-wide dispatch.  Under
  the budget its admission stalls until resident decodes drain, and when it
  finally lands the work-tick clock jumps a whole prompt bucket — decodes
  starve by up to a bucket, and every request queued behind the monolith
  (no skip-ahead) inherits the wait;
* **chunked** — the same prompt is split into ``prefill_chunk``-wide chunks
  dispatched across successive steps and interleaved with decode, so each
  step's prefill work is bounded by one CHUNK bucket per resident prefill
  and short requests behind the long head admit steps earlier.

The trace is a decode-heavy short-prompt background stream punctured by
clumps of long prompts — the burst regime the chunk scheduler exists for.
Outputs must stay bit-identical across arms (the chunk path reads the
written prefix through the block table; same logits, same sampling keys),
so the win is purely scheduling, measured as:

* ``short_ttft_p95_ticks`` — p95 first-token latency over the SHORT
  (background) requests, from the per-request ``CompletedRequest.ttft``;
* ``max_decode_gap_ticks`` — the starvation gauge (worst work-tick gap
  between a resident row's consecutive accepted tokens).

The JSON artifact (``BENCH_serve_chunked.json``) records both gauges per
arm, per-arm tokens/s (best of ``--repeats`` interleaved fresh runs),
cross-arm token mismatches (must be 0), a standalone-``generate`` oracle
(must be 0 mismatches), recompiles after warmup (must be 0), the equal
per-arm total-token schedule, and ``SchedulerStats.DOCS`` under
``field_docs`` so every metric key is self-describing.

    PYTHONPATH=src python benchmarks/serve_chunked.py
    PYTHONPATH=src python benchmarks/serve_chunked.py --smoke --out /tmp/b.json
"""
from __future__ import annotations

import argparse
import dataclasses
import json
import time

import jax
import numpy as np

BUCKETS = (8, 16, 32)
MAX_LEN = 96
BLOCK_SIZE = 8
PREFILL_CHUNK = 8
SHORT_PLEN = 10           # requests below this count as "short" for TTFT
ORACLE_REQUESTS = 6       # standalone-generate checks (one compile per shape)


def _tiny_cfg(exec_mode: str = "exact"):
    from repro.configs import get_config, reduced_config
    from repro.serve.engine import resolve_execution_mode

    # small enough that scheduling effects dominate a decode chunk — the
    # gauges under test are deterministic tick counts, not wall time
    return dataclasses.replace(
        reduced_config(get_config("granite-3-2b")),
        num_layers=2, d_model=128, num_heads=2, num_kv_heads=1, head_dim=64,
        d_ff=256, vocab_size=1024, remat=False, q_chunk=64, dtype="float32",
        approx=resolve_execution_mode(exec_mode),
    )


def build_trace(short: int, long: int, vocab: int, seed: int = 0):
    """[(prompt, max_new, arrival)]: ``short`` decode-heavy background
    requests on a Poisson clock with ``long`` bucket-topping prompts clumped
    into bursts every few arrivals — each burst lands a monolith (or a chunk
    train) in front of the background stream."""
    rng = np.random.default_rng(seed)
    trace, t, li = [], 0, 0
    for i in range(short + long):
        if li < long and i % 4 == 3:      # burst member: long prompt
            plen = int(rng.integers(24, 33))
            max_new = int(rng.integers(6, 13))
            li += 1
        else:                             # background: short, decode-heavy
            t += int(rng.poisson(2.0))
            plen = int(rng.integers(2, SHORT_PLEN))
            max_new = int(rng.integers(16, 33))
        trace.append((rng.integers(0, vocab, plen).astype(np.int32),
                      max_new, t))
    return trace


def _server(cfg, params, trace, *, chunked: bool, num_slots: int,
            steps_per_tick: int, ratio: float):
    from repro.serve.scheduler import ServeSession

    def serve():
        kw = dict(chunked_prefill=True, prefill_chunk=PREFILL_CHUNK) \
            if chunked else {}
        sess = ServeSession(
            cfg, params, num_slots=num_slots, max_len=MAX_LEN,
            prompt_buckets=BUCKETS, steps_per_tick=steps_per_tick,
            cache_layout="paged", block_size=BLOCK_SIZE, loop="async",
            prefill_decode_ratio=ratio, **kw,
        )
        for p, n, t in trace:
            sess.submit(p, max_new=n, arrival=t)
        sess.run()
        return sess

    return serve


def _p95(xs):
    return float(np.percentile(np.asarray(xs, np.float64), 95)) if xs else -1.0


def bench(exec_mode: str = "exact", short: int = 30, long: int = 10,
          seed: int = 0, num_slots: int = 8, steps_per_tick: int = 1,
          repeats: int = 3, ratio: float = 8.0, oracle: int = ORACLE_REQUESTS):
    from repro.models.transformer import init_params
    from repro.serve.engine import generate
    from repro.serve.scheduler import SchedulerStats, scheduler_compile_stats

    cfg = _tiny_cfg(exec_mode)
    params = init_params(cfg, jax.random.PRNGKey(0))
    trace = build_trace(short, long, cfg.vocab_size, seed=seed)
    servers = {
        name: _server(cfg, params, trace, chunked=(name == "chunked"),
                      num_slots=num_slots, steps_per_tick=steps_per_tick,
                      ratio=ratio)
        for name in ("unchunked", "chunked")
    }
    for serve in servers.values():
        serve().warmup()                 # any program the trace missed
    before = scheduler_compile_stats()
    best = {}
    # interleaved best-of: a CPU contention episode taxes both arms
    for _ in range(max(1, repeats)):
        for name, serve in servers.items():
            t0 = time.perf_counter()
            sess = serve()
            dt = time.perf_counter() - t0
            if name not in best or dt < best[name][1]:
                best[name] = (sess, dt)
    recompiles = sum(scheduler_compile_stats().values()) - sum(before.values())

    res = {name: sess.results for name, (sess, _) in best.items()}
    mismatches = sum(
        not np.array_equal(res["unchunked"][rid].tokens,
                           res["chunked"][rid].tokens)
        for rid in res["unchunked"]
    )
    oracle_mismatches = 0
    oracle_ids = sorted(res["chunked"])[:oracle]
    for rid in oracle_ids:
        p, n, _ = trace[rid]
        alone = np.asarray(
            generate(cfg, params, p[None, :], max_new=n)
        )[0, len(p):]
        oracle_mismatches += not np.array_equal(alone, res["chunked"][rid].tokens)

    short_ids = [i for i, (p, _, _) in enumerate(trace)
                 if p.size < SHORT_PLEN]
    arms = {}
    for name, (sess, dt) in best.items():
        st = sess.stats
        useful = sum(len(r.tokens) for r in res[name].values())
        arms[name] = {
            "tok_s": round(useful / dt, 1),
            "best_s": round(dt, 4),
            "max_decode_gap_ticks": st.max_decode_gap_ticks,
            "short_ttft_p95_ticks": _p95(
                [res[name][i].ttft for i in short_ids]
            ),
            "ttft_p95_ticks_all": round(st.ttft_p95, 2),
            "prefill_stall_ticks": st.prefill_stall_ticks,
            "prefill_chunks": st.prefill_chunks,
            "prefill_tokens": st.prefill_tokens,
            "ticks": st.ticks,
        }
    return {
        "bench": "serve_chunked",
        "exec_mode": exec_mode,
        "requests": short + long,
        "short_requests": len(short_ids),
        "seed": seed,
        "num_slots": num_slots,
        "steps_per_tick": steps_per_tick,
        "repeats_best_of": repeats,
        "prompt_buckets": list(BUCKETS),
        "prefill_chunk": PREFILL_CHUNK,
        "prefill_decode_ratio": ratio,
        "max_len": MAX_LEN,
        "block_size": BLOCK_SIZE,
        "cache_layout": "paged",
        # unchanged total-token schedule: the win is scheduling, not work
        "total_tokens": {
            name: sum(len(r.tokens) for r in res[name].values())
            for name in res
        },
        "arms": arms,
        "gap_improvement_ticks": (
            arms["unchunked"]["max_decode_gap_ticks"]
            - arms["chunked"]["max_decode_gap_ticks"]
        ),
        "short_ttft_p95_improvement_ticks": round(
            arms["unchunked"]["short_ttft_p95_ticks"]
            - arms["chunked"]["short_ttft_p95_ticks"], 2
        ),
        "token_mismatches": mismatches,
        "oracle_requests": len(oracle_ids),
        "oracle_mismatches": oracle_mismatches,
        "recompiles_after_warmup": recompiles,
        "field_docs": dict(SchedulerStats.DOCS),
    }


def run(exec_mode: str = "exact", requests: int = 40):
    """benchmarks/run.py entry: (name, us_per_call, derived) rows."""
    r = bench(exec_mode=exec_mode, short=(requests * 3) // 4,
              long=requests - (requests * 3) // 4)
    u, c = r["arms"]["unchunked"], r["arms"]["chunked"]
    return [
        (f"serve/chunked_{exec_mode}", 1e6 / c["tok_s"],
         f"{c['tok_s']} tok/s gap={c['max_decode_gap_ticks']} "
         f"short_ttft_p95={c['short_ttft_p95_ticks']}"),
        (f"serve/unchunked_baseline_{exec_mode}", 1e6 / u["tok_s"],
         f"{u['tok_s']} tok/s gap={u['max_decode_gap_ticks']} "
         f"short_ttft_p95={u['short_ttft_p95_ticks']}"),
        (f"serve/chunked_win_{exec_mode}", 0.0,
         f"gap -{r['gap_improvement_ticks']} ticks, short ttft p95 "
         f"-{r['short_ttft_p95_improvement_ticks']} ticks, "
         f"mismatches={r['token_mismatches']}"),
    ]


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--exec", dest="exec_mode", default="exact",
                    choices=("exact", "exact_quant", "approx", "approx_lowrank"))
    ap.add_argument("--short", type=int, default=30,
                    help="background short requests (TTFT population)")
    ap.add_argument("--long", type=int, default=10,
                    help="burst long-prompt requests")
    ap.add_argument("--num-slots", type=int, default=8)
    ap.add_argument("--steps", type=int, default=1)
    ap.add_argument("--repeats", type=int, default=3,
                    help="timed passes per arm; best-of wins (contention guard)")
    ap.add_argument("--ratio", type=float, default=8.0,
                    help="prefill_decode_ratio, identical in BOTH arms")
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--smoke", action="store_true",
                    help="CI-sized run: small trace, single repeat — checks "
                         "machinery (parity/recompiles), not the win bars")
    ap.add_argument("--out", default="BENCH_serve_chunked.json")
    args = ap.parse_args()
    kw = dict(exec_mode=args.exec_mode, short=args.short, long=args.long,
              seed=args.seed, num_slots=args.num_slots,
              steps_per_tick=args.steps, repeats=args.repeats,
              ratio=args.ratio)
    if args.smoke:
        kw.update(short=8, long=4, repeats=1, oracle=3)
    r = bench(**kw)
    with open(args.out, "w") as f:
        json.dump(r, f, indent=2)
        f.write("\n")
    print(json.dumps({k: v for k, v in r.items() if k != "field_docs"}, indent=2))
    failures = []
    if r["token_mismatches"]:
        failures.append(f"{r['token_mismatches']} cross-arm token mismatches")
    if r["oracle_mismatches"]:
        failures.append(f"{r['oracle_mismatches']} standalone-generate mismatches")
    if r["recompiles_after_warmup"]:
        failures.append(f"{r['recompiles_after_warmup']} recompiles after warmup")
    if r["total_tokens"]["chunked"] != r["total_tokens"]["unchunked"]:
        failures.append("total-token schedule changed between arms")
    if not args.smoke:
        if r["gap_improvement_ticks"] <= 0:
            failures.append(
                "chunked arm did not lower max_decode_gap_ticks "
                f"({r['arms']['unchunked']['max_decode_gap_ticks']} -> "
                f"{r['arms']['chunked']['max_decode_gap_ticks']})")
        if r["short_ttft_p95_improvement_ticks"] <= 0:
            failures.append(
                "chunked arm did not lower short-request p95 TTFT "
                f"({r['arms']['unchunked']['short_ttft_p95_ticks']} -> "
                f"{r['arms']['chunked']['short_ttft_p95_ticks']})")
    for msg in failures:
        print(f"FAIL: {msg}")
    if failures:
        raise SystemExit(1)


if __name__ == "__main__":
    main()
