"""Paper Tables VI/VII: area/power/delay. No EDA tools in the container, so
the paper's ASAP7 DC numbers are data; we add the unit-gate structural
estimate (trend check) and the accelerator-level systolic-array roll-up."""
from __future__ import annotations

import time
from typing import List, Tuple

from repro.core.hwcost import (
    PAPER_TABLE_VI,
    PAPER_TABLE_VII,
    systolic_array_cost,
    unit_gate_estimate,
)


def run() -> List[Tuple[str, float, str]]:
    rows = []
    base = PAPER_TABLE_VI["exact3x3"]
    for name in ("mul3x3_1", "mul3x3_2"):
        t0 = time.perf_counter()
        imp = PAPER_TABLE_VI[name].improvement_over(base)
        est = unit_gate_estimate(name)
        us = (time.perf_counter() - t0) * 1e6
        rows.append(
            (f"table_vi/{name}", us,
             f"area -{imp['area_pct']:.2f}% power -{imp['power_pct']:.2f}% "
             f"delay -{imp['delay_pct']:.2f}% | unit-gate rel-area {est['relative_area']:.3f}")
        )
    base8 = PAPER_TABLE_VII["exact8x8"]
    for name in ("mul8x8_1", "mul8x8_2", "mul8x8_3", "siei", "pkm"):
        t0 = time.perf_counter()
        imp = PAPER_TABLE_VII[name].improvement_over(base8)
        derived = (
            f"area -{imp['area_pct']:.2f}% power -{imp['power_pct']:.2f}% "
            f"delay -{imp['delay_pct']:.2f}%"
        )
        if name.startswith("mul8x8"):
            est = unit_gate_estimate(name)
            derived += f" | unit-gate rel-area {est['relative_area']:.3f}"
        us = (time.perf_counter() - t0) * 1e6
        rows.append((f"table_vii/{name}", us, derived))
    # accelerator-level roll-up (128x128 MAC array)
    for name in ("mul8x8_2", "mul8x8_3"):
        t0 = time.perf_counter()
        c = systolic_array_cost(name)
        us = (time.perf_counter() - t0) * 1e6
        rows.append(
            (f"systolic_128x128/{name}", us,
             f"area {c['area_mm2']:.2f}mm2 (-{c['area_saving_pct']:.1f}%) "
             f"power {c['power_w']:.1f}W (-{c['power_saving_pct']:.1f}%) "
             f"cp {c['critical_path_ns']:.2f}ns")
        )
    return rows
