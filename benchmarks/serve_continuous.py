"""Continuous-batching scheduler vs the PR-1 static-batch engine under a
Poisson arrival trace of mixed-length requests.

The static engine (``repro.serve.engine.generate``) serves requests in
fixed batches: a batch pads every prompt to the group's bucket and decodes
``max(max_new)`` steps for everyone, so short requests burn slot-steps
behind the longest co-batched request.  The continuous scheduler
(``repro.serve.scheduler.ServeSession``) refills each slot the moment its
occupant finishes, so aggregate *useful* tokens/s tracks hardware decode
throughput instead of the batch-max envelope.

Both arms run post-compile (a full warm pass first) over the SAME trace,
same slot/batch width, same prompt buckets.  The JSON artifact
(``BENCH_serve_continuous.json``) records throughput, speedup, slot
utilization, and the recompile count across the timed run (must be 0).

    PYTHONPATH=src python benchmarks/serve_continuous.py
    PYTHONPATH=src python benchmarks/serve_continuous.py --requests 48 --slots 8
"""
from __future__ import annotations

import argparse
import dataclasses
import json
import time

import jax
import numpy as np

BUCKETS = (4, 8, 16)
# heavy-tailed output budgets — the serving regime continuous batching is
# for: a static batch decodes to the group max (48 whp), so its useful
# fraction is mean/max ~ 0.33, while refilled slots track the mean
NEW_CHOICES = (2, 4, 8, 16, 48)
MAX_LEN = 64


def _tiny_cfg(exec_mode: str = "exact"):
    from repro.configs import get_config, reduced_config
    from repro.serve.engine import resolve_execution_mode

    return dataclasses.replace(
        reduced_config(get_config("granite-3-2b")),
        num_layers=4, d_model=256, num_heads=4, num_kv_heads=2, head_dim=64,
        d_ff=512, vocab_size=1024, remat=False, q_chunk=64, dtype="float32",
        approx=resolve_execution_mode(exec_mode),
    )


def build_trace(n: int, vocab: int, seed: int = 0, rate: float = 1.0):
    """[(prompt, max_new, arrival_tick)] — Poisson arrival gaps (mean
    ``rate`` ticks, i.e. ~1 request/decode-step: the heavy-traffic regime),
    mixed prompt lengths and generation budgets (the max_new variance is
    what the static engine pays for)."""
    rng = np.random.default_rng(seed)
    trace, t = [], 0
    for _ in range(n):
        t += int(rng.poisson(rate))
        plen = int(rng.integers(2, BUCKETS[-1] + 1))
        trace.append((
            rng.integers(0, vocab, plen).astype(np.int32),
            int(NEW_CHOICES[rng.integers(len(NEW_CHOICES))]),
            t,
        ))
    return trace


def run_continuous(cfg, params, trace, num_slots: int, steps_per_tick: int = 4):
    """Warm pass (compiles every program), then a timed fresh-session pass.
    Returns (tokens_per_s, stats, recompiles_during_timed_run, useful_tokens,
    elapsed_s)."""
    from repro.serve.scheduler import ServeSession, scheduler_compile_stats

    def serve():
        sess = ServeSession(cfg, params, num_slots=num_slots, max_len=MAX_LEN,
                            prompt_buckets=BUCKETS,
                            steps_per_tick=steps_per_tick)
        for p, n, t in trace:
            sess.submit(p, max_new=n, arrival=t)
        sess.run()
        return sess

    warm = serve()
    warm.warmup()                            # any program the trace missed
    before = scheduler_compile_stats()
    t0 = time.perf_counter()
    sess = serve()
    dt = time.perf_counter() - t0
    recompiles = sum(scheduler_compile_stats().values()) - sum(before.values())
    useful = sum(len(r.tokens) for r in sess.results.values())
    return useful / dt, sess.stats, recompiles, useful, dt


def run_static(cfg, params, trace, batch: int):
    """PR-1 baseline: batches of ``batch`` in arrival order; prompts pad to
    the group's bucket, decode runs to the group's max max_new. Useful
    tokens = what each request actually asked for."""
    from repro.serve.cache import PromptBuckets
    from repro.serve.engine import generate

    buckets = PromptBuckets(BUCKETS)
    groups = []
    for i in range(0, len(trace), batch):
        chunk = trace[i:i + batch]
        sb = max(buckets.bucket(len(p)) for p, _, _ in chunk)
        prompts = np.zeros((len(chunk), sb), np.int32)
        for j, (p, _, _) in enumerate(chunk):
            prompts[j, : len(p)] = p
        groups.append((prompts, max(n for _, n, _ in chunk),
                       sum(n for _, n, _ in chunk)))

    def serve():
        total = 0
        for prompts, max_new, useful in groups:
            jax.block_until_ready(
                generate(cfg, params, prompts, max_new=max_new, max_len=MAX_LEN)
            )
            total += useful
        return total

    serve()                                  # warm every group shape
    t0 = time.perf_counter()
    useful = serve()
    dt = time.perf_counter() - t0
    return useful / dt, useful, dt


def bench(exec_mode: str = "exact", requests: int = 96, slots: int = 8,
          seed: int = 0, steps_per_tick: int = 6):
    from repro.models.transformer import init_params

    cfg = _tiny_cfg(exec_mode)
    params = init_params(cfg, jax.random.PRNGKey(0))
    trace = build_trace(requests, cfg.vocab_size, seed=seed)
    cont_tps, stats, recompiles, cont_tokens, cont_dt = run_continuous(
        cfg, params, trace, slots, steps_per_tick=steps_per_tick
    )
    stat_tps, stat_tokens, stat_dt = run_static(cfg, params, trace, slots)
    assert cont_tokens == stat_tokens, (cont_tokens, stat_tokens)
    return {
        "bench": "serve_continuous",
        "exec_mode": exec_mode,
        "requests": requests,
        "slots": slots,
        "seed": seed,
        "steps_per_tick": steps_per_tick,
        "prompt_buckets": list(BUCKETS),
        "max_new_choices": list(NEW_CHOICES),
        "useful_tokens": cont_tokens,
        "continuous_tok_s": round(cont_tps, 1),
        "static_tok_s": round(stat_tps, 1),
        "speedup": round(cont_tps / stat_tps, 3),
        "slot_utilization": round(stats.slot_utilization, 4),
        "decode_ticks": stats.ticks,
        "admit_calls": stats.admit_calls,
        "recompiles_after_warmup": recompiles,
        "continuous_s": round(cont_dt, 4),
        "static_s": round(stat_dt, 4),
    }


def run(exec_mode: str = "exact", requests: int = 96, slots: int = 8):
    """benchmarks/run.py entry: (name, us_per_call, derived) rows."""
    r = bench(exec_mode=exec_mode, requests=requests, slots=slots)
    per_tok_cont = 1e6 / r["continuous_tok_s"]
    per_tok_stat = 1e6 / r["static_tok_s"]
    return [
        (f"serve/continuous_{exec_mode}_s{slots}", per_tok_cont,
         f"{r['continuous_tok_s']} tok/s util={r['slot_utilization']}"),
        (f"serve/static_batch_{exec_mode}_s{slots}", per_tok_stat,
         f"{r['static_tok_s']} tok/s"),
        (f"serve/continuous_speedup_{exec_mode}", 0.0,
         f"{r['speedup']}x recompiles={r['recompiles_after_warmup']}"),
    ]


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--exec", dest="exec_mode", default="exact",
                    choices=("exact", "exact_quant", "approx", "approx_lowrank"))
    ap.add_argument("--requests", type=int, default=96)
    ap.add_argument("--slots", type=int, default=8)
    ap.add_argument("--steps", type=int, default=6,
                    help="decode-chunk size (steps per dispatch)")
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--out", default="BENCH_serve_continuous.json")
    args = ap.parse_args()
    r = bench(exec_mode=args.exec_mode, requests=args.requests,
              slots=args.slots, seed=args.seed, steps_per_tick=args.steps)
    with open(args.out, "w") as f:
        json.dump(r, f, indent=2)
        f.write("\n")
    print(json.dumps(r, indent=2))
    if r["speedup"] < 1.5:
        print(f"WARNING: speedup {r['speedup']}x below the 1.5x target")
    if r["recompiles_after_warmup"]:
        print(f"WARNING: {r['recompiles_after_warmup']} recompiles after warmup")


if __name__ == "__main__":
    main()
