"""Tensor-parallel serving: per-device KV bytes and attention FLOPs vs tp.

Every arm serves the SAME trace through ``repro.serve.scheduler
.ServeSession`` (paged layout, greedy, identical buckets/pool/slots); the
oracle arm runs with no mesh and each tp arm runs under a ``(tp,)``-device
``"model"`` mesh (params Megatron-split by the ``param_pspec`` rules, the
paged pool sharded along the KV-head dim by ``cache_pspecs(layout=
"paged")``).  The claims this bench pins (ISSUE PR-8, all asserted):

* **parity** — greedy tokens bit-identical to the no-mesh oracle, and
  tick-for-tick schedule parity (same tick count for the same trace:
  sharding changes WHERE bytes live, never what the scheduler decides);
* **zero recompiles** after warmup on every arm (jit caches keyed on
  operand shardings — the warmup normalization must cover them all);
* **1/tp scaling** — measured per-device KV-pool bytes
  (``pool_bytes_per_device``: ``Sharding.shard_shape`` over the pool
  leaves) and analytic per-device attention FLOPs per full-window decode
  tick (``4 * slots * (H/tp) * hd * max_len * layers``: QK^T + AV at 2
  FLOPs/MAC, heads split over the mesh) both scale exactly as ``1/tp``.

Runs on CPU by forcing 8 host devices — XLA_FLAGS is set before jax is
imported, so this module must NOT import jax at the top.

    PYTHONPATH=src python benchmarks/serve_tp.py
    PYTHONPATH=src python benchmarks/serve_tp.py --smoke --out /tmp/b.json
"""
from __future__ import annotations

import argparse
import dataclasses
import json
import os
import time

import numpy as np

BUCKETS = (8, 16, 32)
NEW_CHOICES = (4, 8, 12, 16)
MAX_LEN = 64
BLOCK_SIZE = 8
NUM_BLOCKS = 64
TPS = (1, 2, 4)
FORCED_DEVICES = 8


def _ensure_devices():
    """Force a multi-device CPU before jax initializes (no-op if the flag —
    or a real multi-device backend — is already present)."""
    flags = os.environ.get("XLA_FLAGS", "")
    if "xla_force_host_platform_device_count" not in flags:
        os.environ["XLA_FLAGS"] = (
            flags + f" --xla_force_host_platform_device_count={FORCED_DEVICES}"
        ).strip()


def _tiny_cfg():
    from repro.configs import get_config, reduced_config

    # head counts divisible by every tp arm (4 KV heads / tp=4 -> 1 per shard)
    return dataclasses.replace(
        reduced_config(get_config("granite-3-2b")),
        num_layers=2, d_model=64, num_heads=4, num_kv_heads=4, head_dim=16,
        d_ff=128, vocab_size=512, remat=False, q_chunk=64, dtype="float32",
    )


def build_trace(n: int, vocab: int, seed: int = 0, max_new: int | None = None):
    rng = np.random.default_rng(seed)
    choices = [c for c in NEW_CHOICES if max_new is None or c <= max_new]
    trace, t = [], 0
    for _ in range(n):
        t += int(rng.poisson(1.0))
        prompt = rng.integers(0, vocab,
                              int(rng.integers(2, BUCKETS[-1] + 1))).astype(np.int32)
        trace.append((prompt, int(choices[rng.integers(len(choices))]), t))
    return trace


def attn_flops_per_tick_per_device(cfg, num_slots: int, tp: int) -> int:
    """Analytic decode-attention FLOPs per device for one full-window tick:
    QK^T and AV are each ``2 * hd`` FLOPs per (query head, key) pair, each
    shard holds ``H/tp`` query heads, and the attended window is bounded by
    ``max_len`` rows of the block pool."""
    return 4 * num_slots * (cfg.num_heads // tp) * cfg.head_dim * MAX_LEN \
        * cfg.num_layers


def run_arm(cfg, params, trace, *, tp: int | None, num_slots: int = 4):
    """Warm pass (compiles every program under this arm's mesh), then a
    timed fresh-session pass.  Returns (tok/s, results, session, recompiles,
    seconds, live per-device pool bytes)."""
    import jax

    from repro.serve import cache as C
    from repro.serve.scheduler import ServeSession, scheduler_compile_stats

    mesh = None if tp is None else jax.make_mesh((tp,), ("model",))

    def serve():
        sess = ServeSession(
            cfg, params, num_slots=num_slots, max_len=MAX_LEN,
            prompt_buckets=BUCKETS, cache_layout="paged",
            block_size=BLOCK_SIZE, num_blocks=NUM_BLOCKS, mesh=mesh,
        )
        sess.warmup()
        for p, n, t in trace:
            sess.submit(p, max_new=n, arrival=t)
        sess.run()
        return sess

    warm = serve()
    before = scheduler_compile_stats()
    t0 = time.perf_counter()
    sess = serve()
    dt = time.perf_counter() - t0
    recompiles = sum(scheduler_compile_stats().values()) - sum(before.values())
    useful = sum(len(r.tokens) for r in sess.results.values())
    del warm
    return (useful / dt, sess.results, sess, recompiles, dt,
            C.pool_bytes_per_device(sess.cache))


def bench(requests: int = 32, num_slots: int = 4, seed: int = 0,
          max_new: int | None = None):
    _ensure_devices()
    import jax

    from repro.models.transformer import init_params
    from repro.serve.scheduler import SchedulerStats, _resolve_cache_donation

    cfg = _tiny_cfg()
    params = init_params(cfg, jax.random.PRNGKey(0))
    trace = build_trace(requests, cfg.vocab_size, seed=seed, max_new=max_new)

    base_tps, base_res, base_sess, base_rc, base_dt, base_bytes = run_arm(
        cfg, params, trace, tp=None, num_slots=num_slots)
    base_st = base_sess.stats
    base_flops = attn_flops_per_tick_per_device(cfg, num_slots, 1)

    mismatches = 0
    recompiles = base_rc
    schedule_divergence = 0
    arms = []
    tps = [t for t in TPS if t <= jax.device_count()]
    for tp in tps:
        tok_s, res, sess, rc, dt, pool_bytes = run_arm(
            cfg, params, trace, tp=tp, num_slots=num_slots)
        st = sess.stats
        mismatches += sum(
            not np.array_equal(base_res[rid].tokens, res[rid].tokens)
            for rid in base_res)
        recompiles += rc
        schedule_divergence += int(st.ticks != base_st.ticks)
        flops = attn_flops_per_tick_per_device(cfg, num_slots, tp)
        arms.append({
            "tp": tp,
            "devices": st.devices,
            "tok_s": round(tok_s, 1),
            "ticks": st.ticks,
            "seconds": round(dt, 4),
            "kv_pool_bytes_per_device": pool_bytes,
            "kv_bytes_ratio_vs_tp1": round(pool_bytes / base_bytes, 6),
            "peak_block_bytes_per_device": st.peak_block_bytes_per_device,
            "attn_flops_per_tick_per_device": flops,
            "attn_flops_ratio_vs_tp1": round(flops / base_flops, 6),
        })
    return {
        "bench": "serve_tp",
        "requests": requests,
        "seed": seed,
        "prompt_buckets": list(BUCKETS),
        "max_new_choices": [c for c in NEW_CHOICES
                            if max_new is None or c <= max_new],
        "max_len": MAX_LEN,
        "block_size": BLOCK_SIZE,
        "num_blocks": NUM_BLOCKS,
        "num_slots": num_slots,
        "devices_visible": jax.device_count(),
        "cache_donation": list(_resolve_cache_donation()),
        "useful_tokens": sum(len(r.tokens) for r in base_res.values()),
        "oracle_tok_s": round(base_tps, 1),
        "oracle_ticks": base_st.ticks,
        "oracle_kv_pool_bytes_per_device": base_bytes,
        "arms": arms,
        "token_mismatches": mismatches,
        "schedule_divergence": schedule_divergence,
        "recompiles_after_warmup": recompiles,
        "field_docs": dict(SchedulerStats.DOCS),
    }


def run(requests: int = 32):
    """benchmarks/run.py entry: (name, us_per_call, derived) rows."""
    r = bench(requests=requests)
    rows = []
    for arm in r["arms"]:
        rows.append((
            f"serve/tp{arm['tp']}",
            1e6 / arm["tok_s"],
            f"{arm['tok_s']} tok/s, {arm['kv_pool_bytes_per_device']} "
            f"KV B/dev ({arm['kv_bytes_ratio_vs_tp1']}x tp1), "
            f"mismatches={r['token_mismatches']}",
        ))
    return rows


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--requests", type=int, default=32)
    ap.add_argument("--num-slots", type=int, default=4)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--smoke", action="store_true",
                    help="miniature trace: exercises every oracle without "
                         "the full request count (CI gate for the harness)")
    ap.add_argument("--out", default="BENCH_serve_tp.json")
    args = ap.parse_args()
    max_new = None
    if args.smoke:
        args.requests = min(args.requests, 8)
        max_new = 8
    r = bench(requests=args.requests, num_slots=args.num_slots,
              seed=args.seed, max_new=max_new)
    with open(args.out, "w") as f:
        json.dump(r, f, indent=2)
        f.write("\n")
    print(json.dumps({k: v for k, v in r.items() if k != "field_docs"},
                     indent=2))
    failures = []
    if r["token_mismatches"]:
        failures.append(
            f"{r['token_mismatches']} request outputs differ from the "
            "no-mesh oracle — TP broke greedy-token parity")
    if r["schedule_divergence"]:
        failures.append(
            f"{r['schedule_divergence']} arms diverged from the oracle tick "
            "schedule")
    if r["recompiles_after_warmup"]:
        failures.append(
            f"{r['recompiles_after_warmup']} recompiles after warmup")
    for arm in r["arms"]:
        want = 1.0 / arm["tp"]
        if arm["kv_bytes_ratio_vs_tp1"] != want:
            failures.append(
                f"tp={arm['tp']}: KV bytes/device ratio "
                f"{arm['kv_bytes_ratio_vs_tp1']} != {want}")
        if arm["attn_flops_ratio_vs_tp1"] != want:
            failures.append(
                f"tp={arm['tp']}: attention FLOPs/device ratio "
                f"{arm['attn_flops_ratio_vs_tp1']} != {want}")
    if failures:
        raise SystemExit("serve_tp bench FAILED: " + "; ".join(failures))
    print(f"wrote {args.out}")


if __name__ == "__main__":
    main()
