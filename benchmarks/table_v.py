"""Paper Table V: arithmetic accuracy of approximate multipliers.

Reports the exhaustive-domain ER/MED/NMED/MRED of our architecture-faithful
implementations next to the paper's printed values (see DESIGN.md §3 for why
the 8x8 rows differ: the printed numbers are unreachable from the described
aggregation; the 3x3 metrics match exactly)."""
from __future__ import annotations

import time
from typing import List, Tuple

from repro.core import multipliers as M
from repro.core.metrics import multiplier_metrics

PAPER = {
    "mul8x8_1": (22.8, 137.04, 0.21, 1.50),
    "mul8x8_2": (20.49, 114.83, 0.18, 1.42),
    "mul8x8_3": (31.41, 648.20, 1.00, 2.53),
    "pkm": (49.86, 938.32, 1.44, 3.89),
    "etm": (98.88, None, 2.85, 25.21),
}


def run() -> List[Tuple[str, float, str]]:
    rows = []
    # 3x3 designs (paper-exact)
    for name, tab in [("mul3x3_1", M.mul3x3_1_table()), ("mul3x3_2", M.mul3x3_2_table())]:
        t0 = time.perf_counter()
        m = multiplier_metrics(tab, name)
        us = (time.perf_counter() - t0) * 1e6
        rows.append(
            (f"table_v/{name}", us,
             f"ER={m.er:.3f}% MED={m.med:.3f} (paper: 9.375%/" +
             ("1.125)" if name == "mul3x3_1" else "0.5)"))
        )
    for name in ("mul8x8_1", "mul8x8_2", "mul8x8_3", "pkm", "etm"):
        t0 = time.perf_counter()
        m = multiplier_metrics(M.mul8x8_table(name), name)
        us = (time.perf_counter() - t0) * 1e6
        p = PAPER.get(name)
        rows.append(
            (f"table_v/{name}", us,
             f"ER={m.er:.2f}% MED={m.med:.2f} NMED={m.nmed:.2f}% MRED={m.mred:.2f}%"
             f" | paper ER={p[0]} MED={p[1]} NMED={p[2]} MRED={p[3]}")
        )
    return rows
