import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""§Perf hillclimb driver: run the hypothesis->change->measure iterations on
the three selected cells and log every variant to results/perf/.

Each variant re-lowers the cell through the same dry-run machinery, so the
before/after roofline terms are directly comparable. See EXPERIMENTS.md §Perf
for the narrative (hypothesis + napkin math + confirmed/refuted).
"""
import json
import sys
import time
import traceback

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

from repro.launch.dryrun import run_cell  # noqa: E402

OUT = os.path.join(os.path.dirname(__file__), "..", "results", "perf")

# (tag, arch, shape, kwargs)
VARIANTS = [
    # ---- Cell A: granite-3-2b x train_4k (technique-representative) -------
    ("A0_paper_lut", "granite-3-2b", "train_4k", dict(approx_mode="lut")),
    ("A1_lowrank", "granite-3-2b", "train_4k", dict(approx_mode="lowrank")),
    ("A2_fused", "granite-3-2b", "train_4k",
     dict(approx_mode="lowrank", cfg_overrides=dict(fuse_qkv=True, fuse_gate_up=True))),
    ("A3_fused_w31", "granite-3-2b", "train_4k",
     dict(approx_mode="lowrank", w_qmax=31,
          cfg_overrides=dict(fuse_qkv=True, fuse_gate_up=True))),
    ("A4_fused_w31_bf16p", "granite-3-2b", "train_4k",
     dict(approx_mode="lowrank", w_qmax=31,
          cfg_overrides=dict(fuse_qkv=True, fuse_gate_up=True, param_dtype="bfloat16"))),
    ("A5_ref_exact_quant", "granite-3-2b", "train_4k", dict(approx_mode="exact_quant")),
    ("A6_ref_float", "granite-3-2b", "train_4k", dict(approx_mode="float")),
    # ---- Cell B: most collective-bound (set after baseline table) ----------
    ("B0_base", "yi-34b", "train_4k", dict(approx_mode="lowrank")),
    ("B1_bf16_params", "yi-34b", "train_4k",
     dict(approx_mode="lowrank", cfg_overrides=dict(param_dtype="bfloat16"))),
    ("B2_bf16_fused_w31", "yi-34b", "train_4k",
     dict(approx_mode="lowrank", w_qmax=31,
          cfg_overrides=dict(param_dtype="bfloat16", fuse_qkv=True, fuse_gate_up=True))),
    ("B3_bf16_fused_w31_mb_half", "yi-34b", "train_4k",
     dict(approx_mode="lowrank", w_qmax=31, microbatch_override=8,
          cfg_overrides=dict(param_dtype="bfloat16", fuse_qkv=True, fuse_gate_up=True))),
    # ---- Cell C: worst roofline fraction (decode) ---------------------------
    ("C0_base", "granite-3-2b", "decode_32k", dict(approx_mode="lowrank")),
    ("C1_frozen", "granite-3-2b", "decode_32k",
     dict(approx_mode="lowrank", frozen_weights=True)),
    ("C2_frozen_fused", "granite-3-2b", "decode_32k",
     dict(approx_mode="lowrank", frozen_weights=True,
          cfg_overrides=dict(fuse_qkv=True, fuse_gate_up=True))),
    ("C3_frozen_fused_w31", "granite-3-2b", "decode_32k",
     dict(approx_mode="lowrank", frozen_weights=True, w_qmax=31,
          cfg_overrides=dict(fuse_qkv=True, fuse_gate_up=True))),
    # C4: keep the KV cache sequence-sharded during decode when KV heads
    # don't divide the TP axis (attention_core decode branch)
    ("C4_sp_cache_frozen_fused_w31", "granite-3-2b", "decode_32k",
     dict(approx_mode="lowrank", frozen_weights=True, w_qmax=31,
          cfg_overrides=dict(fuse_qkv=True, fuse_gate_up=True))),
]


def main():
    os.makedirs(OUT, exist_ok=True)
    only = sys.argv[1:] or None
    for tag, arch, shape, kw in VARIANTS:
        if only and not any(tag.startswith(o) for o in only):
            continue
        path = os.path.join(OUT, f"{tag}.json")
        if os.path.exists(path):
            print("cached:", tag)
            continue
        print(f"=== {tag}: {arch} x {shape} {kw} ===", flush=True)
        t0 = time.time()
        try:
            res = run_cell(arch, shape, multi_pod=False, print_analysis=True, **kw)
            res["tag"] = tag
            res["variant_kwargs"] = {k: str(v) for k, v in kw.items()}
        except Exception as e:  # noqa: BLE001
            traceback.print_exc()
            res = {"tag": tag, "arch": arch, "shape": shape, "error": repr(e),
                   "wall_s": time.time() - t0,
                   "variant_kwargs": {k: str(v) for k, v in kw.items()}}
        with open(path, "w") as f:
            json.dump(res, f, indent=1)
        print(f"    -> {path} ({time.time()-t0:.0f}s)", flush=True)


if __name__ == "__main__":
    main()

# appended: C4 — decode SP-cache fix (see attention_core decode branch)
