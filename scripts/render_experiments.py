"""Render EXPERIMENTS.md from results/dryrun + results/perf JSONs.

Static narrative + generated tables, so the document always matches the
cached artifacts:  PYTHONPATH=src python scripts/render_experiments.py
"""
import glob
import json
import os
import sys

ROOT = os.path.join(os.path.dirname(os.path.abspath(__file__)), "..")
DRY = os.path.join(ROOT, "results", "dryrun")
PERF = os.path.join(ROOT, "results", "perf")

ARCH_ORDER = [
    "musicgen-large", "yi-34b", "granite-3-2b", "deepseek-7b",
    "deepseek-coder-33b", "falcon-mamba-7b", "qwen2-moe-a2.7b",
    "grok-1-314b", "qwen2-vl-2b", "zamba2-2.7b",
]
SHAPE_ORDER = ["train_4k", "prefill_32k", "decode_32k", "long_500k"]


def load(pattern):
    out = {}
    for p in glob.glob(pattern):
        with open(p) as f:
            d = json.load(f)
        out[os.path.basename(p)[:-5]] = d
    return out


def fmt_bytes(b):
    if b >= 1e12:
        return f"{b/1e12:.2f} TB"
    if b >= 1e9:
        return f"{b/1e9:.2f} GB"
    return f"{b/1e6:.1f} MB"


def dryrun_table(cells, mesh="16x16", mode="lowrank"):
    rows = []
    for a in ARCH_ORDER:
        for s in SHAPE_ORDER:
            d = cells.get(f"{a}__{s}__{mesh}__{mode}")
            if d is None:
                rows.append(f"| {a} | {s} | — | *(not cached)* ||||||")
                continue
            if d.get("skipped"):
                rows.append(f"| {a} | {s} | skip | sub-quadratic archs only |||||| ")
                continue
            rows.append(
                "| {a} | {s} | {bound} | {tc:.3f} | {tm:.3f} | {tx:.3f} | {uf:.3f} | {rf:.4f} | {mem} |".format(
                    a=a, s=s, bound=d["bound"], tc=d["t_compute_s"],
                    tm=d["t_memory_s"], tx=d["t_collective_s"],
                    uf=d.get("useful_flop_fraction", 0),
                    rf=d.get("roofline_fraction", 0),
                    mem=fmt_bytes(d.get("temp_size_in_bytes", 0)),
                )
            )
    head = ("| arch | shape | bound | compute s | memory s | collective s | "
            "useful-flops | roofline | temp/device |\n|---|---|---|---|---|---|---|---|---|")
    return head + "\n" + "\n".join(rows)


def multipod_table(cells):
    rows = []
    for a in ARCH_ORDER:
        for s in SHAPE_ORDER:
            d = cells.get(f"{a}__{s}__2x16x16__lowrank")
            if d is None:
                rows.append(f"| {a} | {s} | *(not cached)* | | |")
                continue
            if d.get("skipped"):
                rows.append(f"| {a} | {s} | skip (sub-quadratic archs only) | | |")
                continue
            rows.append(
                "| {a} | {s} | OK ({t:.0f}s compile) | {arg} | {tmp} |".format(
                    a=a, s=s, t=d.get("compile_s", 0) + d.get("lower_s", 0),
                    arg=fmt_bytes(d.get("argument_size_in_bytes", 0)),
                    tmp=fmt_bytes(d.get("temp_size_in_bytes", 0)),
                )
            )
    head = ("| arch | shape | 2×16×16 lower+compile | args/device | temp/device |\n"
            "|---|---|---|---|---|")
    return head + "\n" + "\n".join(rows)


def perf_rows(perf, prefix):
    rows = []
    for tag in sorted(perf):
        if not tag.startswith(prefix):
            continue
        d = perf[tag]
        if "error" in d:
            rows.append(f"| {d['tag']} | FAILED: `{d['error'][:90]}` ||||||")
            continue
        rows.append(
            "| {t} | {tc:.2f} | {tm:.2f} | {tx:.2f} | {bound} | {uf:.3f} | {rf:.4f} |".format(
                t=d.get("tag", tag), tc=d["t_compute_s"], tm=d["t_memory_s"],
                tx=d["t_collective_s"], bound=d["bound"],
                uf=d.get("useful_flop_fraction", 0), rf=d.get("roofline_fraction", 0),
            )
        )
    head = ("| variant | compute s | memory s | collective s | bound | useful-flops | roofline |\n"
            "|---|---|---|---|---|---|---|")
    return head + "\n" + "\n".join(rows)


def headpad_rows(perf):
    rows = []
    for tag, d in sorted(perf.items()):
        if not tag.startswith("headpad_before"):
            continue
        rows.append(
            "| {a} × {s} (before) | {tc:.2f} | {tm:.2f} | {tx:.2f} | {rf:.4f} |".format(
                a=d["arch"], s=d["shape"], tc=d["t_compute_s"], tm=d["t_memory_s"],
                tx=d["t_collective_s"], rf=d.get("roofline_fraction", 0))
        )
    return "\n".join(rows)


def main():
    dry = load(os.path.join(DRY, "*.json"))
    perf = load(os.path.join(PERF, "*.json"))
    with open(os.path.join(ROOT, "scripts", "experiments_template.md")) as f:
        tpl = f.read()
    out = (tpl
           .replace("{{DRYRUN_TABLE}}", dryrun_table(dry))
           .replace("{{MULTIPOD_TABLE}}", multipod_table(dry))
           .replace("{{PERF_A}}", perf_rows(perf, "A"))
           .replace("{{PERF_B}}", perf_rows(perf, "B"))
           .replace("{{PERF_C}}", perf_rows(perf, "C"))
           .replace("{{HEADPAD_BEFORE}}", headpad_rows(perf)))
    with open(os.path.join(ROOT, "EXPERIMENTS.md"), "w") as f:
        f.write(out)
    print("wrote EXPERIMENTS.md")


if __name__ == "__main__":
    main()
