"""Check that markdown links and anchors in the repo docs resolve.

Stdlib-only so the CI docs job needs no installs:

    python scripts/check_docs.py            # README.md + docs/*.md
    python scripts/check_docs.py docs/serving.md README.md

For every ``[text](target)`` link in the checked files:

* ``http(s)://`` / ``mailto:`` targets are skipped (no network in CI);
* relative file targets must exist on disk (resolved from the linking
  file's directory);
* ``#anchor`` fragments — same-file or ``path#anchor`` — must match a
  heading in the target file under GitHub's slugification (lowercase,
  punctuation stripped, spaces to hyphens).

Exit code 0 when every link resolves, 1 with one line per broken link.
"""
from __future__ import annotations

import pathlib
import re
import sys

ROOT = pathlib.Path(__file__).resolve().parents[1]
DEFAULT_FILES = ["README.md", *sorted(str(p.relative_to(ROOT)) for p in (ROOT / "docs").glob("*.md"))]

# [text](target) — ignores images' leading "!" on purpose (same resolution
# rules) and fenced code blocks (stripped before matching)
_LINK = re.compile(r"\[[^\]]*\]\(([^)\s]+)\)")
_HEADING = re.compile(r"^#{1,6}\s+(.*?)\s*#*\s*$", re.M)
_FENCE = re.compile(r"```.*?```", re.S)


def github_slug(heading: str) -> str:
    """GitHub's markdown heading -> anchor id (ASCII-ish subset: lowercase,
    drop everything but word chars/spaces/hyphens, spaces become hyphens)."""
    s = re.sub(r"`([^`]*)`", r"\1", heading.strip().lower())
    s = re.sub(r"[^\w\- ]", "", s)
    return s.replace(" ", "-")


def anchors_of(path: pathlib.Path) -> set:
    text = _FENCE.sub("", path.read_text())
    seen: dict = {}
    out = set()
    for m in _HEADING.finditer(text):
        slug = github_slug(m.group(1))
        n = seen.get(slug, 0)
        seen[slug] = n + 1
        out.add(slug if n == 0 else f"{slug}-{n}")   # GitHub dedup rule
    return out


def check_file(relpath: str) -> list:
    """Broken-link messages for one markdown file."""
    path = ROOT / relpath
    errors = []
    if not path.is_file():
        return [f"{relpath}: file not found"]
    text = _FENCE.sub("", path.read_text())
    for m in _LINK.finditer(text):
        target = m.group(1)
        if target.startswith(("http://", "https://", "mailto:")):
            continue
        file_part, _, anchor = target.partition("#")
        dest = path if not file_part else (path.parent / file_part).resolve()
        if not dest.exists():
            errors.append(f"{relpath}: broken link target {target!r}")
            continue
        if anchor:
            if dest.suffix != ".md":
                errors.append(
                    f"{relpath}: anchor on non-markdown target {target!r}")
            elif anchor not in anchors_of(dest):
                errors.append(
                    f"{relpath}: anchor #{anchor} not found in "
                    f"{dest.relative_to(ROOT)}")
    return errors


def main(argv=None) -> int:
    files = (argv if argv else sys.argv[1:]) or DEFAULT_FILES
    errors = []
    for f in files:
        errors.extend(check_file(f))
    for e in errors:
        print(f"FAIL: {e}")
    if not errors:
        print(f"docs check OK: {len(files)} file(s), all links/anchors resolve")
    return 1 if errors else 0


if __name__ == "__main__":
    raise SystemExit(main())
